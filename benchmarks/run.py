"""Benchmark harness: one function per paper table/figure + kernel
micro-benchmarks + dry-run roofline summary.

Prints ``name,us_per_call,derived`` CSV rows.  ``us_per_call`` is the wall
time of the underlying model/kernel evaluation on this host (CPU; TPU is the
target, so derived analytic quantities — the actual reproduction targets —
are in ``derived``).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, iters=3):
    fn()  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


# Rows accumulated for the --json artifact (BENCH_<pr>.json in CI).
_RESULTS: list = []


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")
    _RESULTS.append({"name": name, "us_per_call": round(us, 1),
                     "derived": derived})


def bench_table2_csa_vs_bat():
    """Table II: CSA split tree vs binary adder tree (area / power)."""
    from repro.core.adder_tree import csa_tree_sum
    from repro.hwmodel.adder_tree_cost import PAPER_TABLE2, table2_model
    rng = np.random.default_rng(0)
    p = jnp.asarray(rng.integers(-4, 4, size=(64, 64)), jnp.int32)
    f = jax.jit(lambda x: csa_tree_sum(x, axis=-1))
    us = _timeit(lambda: jax.block_until_ready(f(p)))
    m = table2_model()
    _row("table2_csa_vs_bat", us,
         f"area={m['area']:.4f}(paper {PAPER_TABLE2['area']}) "
         f"P_unsigned={m['power_unsigned']:.4f}(paper {PAPER_TABLE2['power_unsigned']}) "
         f"P_signed={m['power_signed']:.4f}(paper {PAPER_TABLE2['power_signed']})")


def bench_table3_comparison():
    """Table III: throughput + energy efficiency vs published accelerators."""
    from repro.core.pe_array import pe_array_matmul
    from repro.hwmodel import energy
    rng = np.random.default_rng(1)
    w = rng.integers(-2, 2, size=(64, 64))
    a = rng.integers(-2, 2, size=(8, 64))
    us = _timeit(lambda: jax.block_until_ready(
        pe_array_matmul(a, w, w_bits=2, a_bits=2)[0]))
    t3 = energy.table3_ours()
    imp = energy.improvement_vs_bitsystolic()
    _row("table3_comparison", us,
         f"peak={t3['peak_tops']:.2f}TOPS(paper 4.09) "
         f"eff8={t3['eff_8bit']:.2f} eff4={t3['eff_4bit']:.2f} "
         f"eff2={t3['eff_2bit']:.2f}TOPS/W "
         f"vsBitSystolic=+{imp['8bit']:.1%}/+{imp['4bit']:.1%}/+{imp['2bit']:.1%}"
         f"(paper +18.7%/+10.5%/+11.2%)")


def bench_fig7_breakdown():
    """Fig 7: PE-array area/power breakdown; Fig-4 path = 0.97 % area."""
    from repro.hwmodel import breakdown
    t0 = time.perf_counter()
    af = breakdown.area_fractions()
    pf = breakdown.power_breakdown()
    us = (time.perf_counter() - t0) * 1e6
    top_a = max(af, key=af.get)
    _row("fig7_breakdown", us,
         f"indep_path_area={breakdown.indep_path_fraction():.4f}(paper 0.0097) "
         f"largest_area={top_a}:{af[top_a]:.2f} "
         f"tree_power={pf['adder_trees']:.2f}")


def bench_fig8_energy_efficiency():
    """Fig 8: PE-array energy efficiency vs input toggle rate, per precision."""
    from repro.hwmodel import energy
    t0 = time.perf_counter()
    rows = []
    for bits in (8, 4, 3, 2):
        c = energy.fig8_curve(bits, bits, toggles=(0.1, 0.3, 0.5, 0.7, 0.9))
        rows.append(f"{bits}b@0.5={c[0.5]:.1f}")
    us = (time.perf_counter() - t0) * 1e6
    _row("fig8_energy_efficiency", us,
         " ".join(rows) + " (paper 14/52.1/139.8/205.8 @ toggle 0.5)")


def bench_mobilenetv2_power():
    """§IV: mixed-precision MobileNetV2 power reduction vs fixed 8-bit."""
    from repro.hwmodel import mobilenet
    t0 = time.perf_counter()
    sweep = {b: mobilenet.power_reduction_vs_8bit(b)
             for b in (3.0, 3.25, 3.5, 3.75, 4.0, 5.0, 6.0)}
    us = (time.perf_counter() - t0) * 1e6
    best_b = min(sweep, key=lambda b: abs(sweep[b] - mobilenet.PAPER_REDUCTION))
    _row("mobilenetv2_power", us,
         f"macs={mobilenet.total_macs()/1e6:.0f}M "
         f"reduction@avg{best_b}b={sweep[best_b]:.1%}(paper 35.2%) "
         f"sweep={{" + " ".join(f"{b}:{r:.0%}" for b, r in sweep.items()) + "}")


def bench_mobilenetv2_throughput():
    """§IV inference performance: fps on the 64x64 array (cycle model)."""
    from repro.hwmodel import mobilenet
    t0 = time.perf_counter()
    layers = mobilenet.mobilenet_v2_layers()
    fixed8 = {l.name: 8 for l in layers}
    mixed = mobilenet.allocate_bits(3.75, layers)
    fps8 = mobilenet.inference_fps(fixed8)
    fpsm = mobilenet.inference_fps(mixed)
    us = (time.perf_counter() - t0) * 1e6
    _row("mobilenetv2_throughput", us,
         f"fixed8={fps8:.0f}fps mixed@3.75b={fpsm:.0f}fps "
         f"speedup={fpsm/fps8:.2f}x @500MHz 64x64 array")


def bench_kernel_bitserial_matmul():
    """Flagship Pallas kernel vs oracle (interpret mode) + pass-count law."""
    from repro.core import decompose
    from repro.kernels.bitserial_matmul import bitserial_matmul
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.integers(-128, 128, size=(128, 256)), jnp.int8)
    rows = []
    us_all = 0.0
    for w_bits in (2, 4, 8):
        lo, hi = decompose.weight_range(w_bits, True)
        w = rng.integers(lo, hi + 1, size=(256, 128))
        planes = decompose.decompose_weights(w, w_bits)
        f = lambda: jax.block_until_ready(bitserial_matmul(
            x, planes, w_bits=w_bits, interpret=True))
        us = _timeit(f, iters=2)
        us_all += us
        rows.append(f"{w_bits}b:{decompose.num_planes(w_bits)}pass")
    _row("kernel_bitserial_matmul", us_all / 3,
         "MXU_passes_per_wbits={" + " ".join(rows) + "} (cost ~ w_bits/2)")


def bench_kernel_packed_vs_unpacked():
    """Packed-plane layout: weight bytes/element vs the unpacked layout."""
    from repro.core import decompose
    from repro.kernels import ops
    from repro.kernels.bitserial_matmul import packed_bitserial_matmul
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(-128, 128, size=(128, 256)), jnp.int8)
    w = rng.integers(-8, 8, size=(256, 128))
    planes = decompose.decompose_weights(w, 4)
    packed = ops.pack_planes(planes, 4)
    us = _timeit(lambda: jax.block_until_ready(packed_bitserial_matmul(
        x, packed, w_bits=4, interpret=True)), iters=2)
    _row("kernel_packed_planes", us,
         f"bytes/weight packed={packed.nbytes/w.size:.2f} "
         f"unpacked={np.asarray(planes).nbytes/w.size:.2f} (4-bit)")


def bench_act_quant():
    from repro.kernels.act_quant import act_quant
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(256, 1024)), jnp.float32)
    us = _timeit(lambda: jax.block_until_ready(
        act_quant(x, interpret=True)[0]), iters=2)
    _row("kernel_act_quant", us, "per-row int8 quant, fused single HBM read")


def bench_pe_array_utilization():
    """Array utilization across 2..8-bit (the paper's central claim)."""
    from repro.core.pe_array import PEArrayConfig, array_utilization, peak_tops
    cfg = PEArrayConfig()
    t0 = time.perf_counter()
    utils = {b: array_utilization(cfg, b) for b in range(2, 9)}
    tops = {b: peak_tops(cfg, b, b) for b in (2, 4, 8)}
    us = (time.perf_counter() - t0) * 1e6
    _row("pe_array_utilization", us,
         "util={" + " ".join(f"{b}:{u:.3f}" for b, u in utils.items()) + "} "
         f"tops 2/4/8={tops[2]:.2f}/{tops[4]:.2f}/{tops[8]:.2f}")


def bench_continuous_batching():
    """Mixed-workload serving: continuous batching vs batch-at-a-time.

    Heterogeneous prompt lengths AND decode budgets; asserts token-identical
    per-request outputs and reports the decode-step saving (the utilization
    win of per-slot admission)."""
    from repro.configs import reduced_config
    from repro.core.policy import uniform_policy
    from repro.models.layers import Runtime
    from repro.models.transformer import LM
    from repro.serve.engine import BatchServeEngine, Request, ServeEngine

    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    rng = np.random.default_rng(7)
    params = model.init(jax.random.PRNGKey(0))
    policy = uniform_policy(4, 8, backend="decomposed")
    rt = Runtime(policy=policy, mode="serve", moe_dropless=True)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=3 + i % 7),
                    max_new_tokens=(2, 20, 3, 4)[i % 4])
            for i in range(10)]

    cont = ServeEngine(model, params, rt, max_batch=4, max_len=64,
                       decode_chunk=4)
    t0 = time.perf_counter()
    got = cont.run(reqs)
    us = (time.perf_counter() - t0) * 1e6

    base = BatchServeEngine(model, cont.params, rt, max_batch=4, max_len=64)
    want = base.run(reqs)
    identical = all(got[r.uid] == want[r.uid] for r in reqs)
    assert identical, "continuous-batching outputs diverged from baseline"
    assert cont.stats.decode_steps < base.stats.decode_steps, (
        cont.stats.decode_steps, base.stats.decode_steps)
    _row("serve_continuous_batching", us,
         f"decode_steps cont={cont.stats.decode_steps} "
         f"batch={base.stats.decode_steps} "
         f"slot_steps cont={cont.stats.decode_slot_steps} "
         f"batch={base.stats.decode_slot_steps} "
         f"token_identical={identical}")


def bench_serve_precision_tiers():
    """Runtime-reconfigurable precision serving: ONE engine, one preloaded
    8-bit superplane store, requests decoding at 8/8, 4/4 and 2/2.

    Asserts zero prepare_params calls after construction and per-tier
    token-identity with natively-prepared fixed-precision engines; reports
    tokens/s and decode steps per tier plus the hwmodel's effective TOPS
    (the plane-prefix pass-count law: work scales with the EFFECTIVE bits,
    not the stored ones)."""
    from repro.configs import reduced_config
    from repro.core.policy import uniform_policy, uniform_schedule
    from repro.hwmodel import energy
    from repro.models.layers import Runtime
    from repro.models.transformer import LM
    from repro.serve import engine as engine_mod
    from repro.serve.engine import Request, ServeEngine

    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    rng = np.random.default_rng(11)
    params = model.init(jax.random.PRNGKey(0))
    tiers = {"8/8": (8, 8), "4/4": (4, 4), "2/2": (2, 2)}
    sched = uniform_schedule(tiers, backend="decomposed")
    rt = Runtime(policy=sched.policy_for(), mode="serve", moe_dropless=True,
                 schedule=sched)
    names = list(tiers)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=3 + i % 5),
                    max_new_tokens=(3, 6, 4)[i % 3], tier=names[i % 3])
            for i in range(9)]

    eng = ServeEngine(model, params, rt, max_batch=3, max_len=64,
                      decode_chunk=4)
    preps_after_construction = engine_mod.PREPARE_CALLS
    t0 = time.perf_counter()
    got = eng.run(reqs)
    dt = time.perf_counter() - t0
    assert engine_mod.PREPARE_CALLS == preps_after_construction, \
        "weights were re-prepared after construction"

    # Per-tier parity vs engines prepared natively at that precision.
    for tier, (w, a) in tiers.items():
        sub = [r for r in reqs if r.tier == tier]
        native = ServeEngine(
            model, params,
            Runtime(policy=uniform_policy(w, a, backend="decomposed"),
                    mode="serve", moe_dropless=True),
            max_batch=3, max_len=64, decode_chunk=4)
        want = native.run([Request(uid=r.uid, prompt=r.prompt,
                                   max_new_tokens=r.max_new_tokens)
                           for r in sub])
        assert all(got[r.uid] == want[r.uid] for r in sub), tier

    toks = sum(len(v) for v in got.values())
    eff = {t: energy.tier_cost(w, a)["effective_tops"]
           for t, (w, a) in tiers.items()}
    steps = eng.stats.decode_steps_by_tier
    _row("serve_precision_tiers", dt * 1e6 / max(len(reqs), 1),
         f"tokens/s={toks/dt:.1f} preps_after_construction=0 "
         f"tier_switches={eng.stats.tier_switches} "
         "decode_steps={" + " ".join(f"{t}:{steps.get(t, 0)}"
                                     for t in tiers) + "} "
         "eff_TOPS={" + " ".join(f"{t}:{v:.2f}" for t, v in eff.items())
         + "} token_identical_vs_native=True")


def bench_serve_mixed_tiers():
    """Mixed-tier decode batches + per-request KV precision: ONE engine,
    one preloaded superplane store, a mixed 8/4/2 request stream decoding
    TOGETHER in each jitted step (per-row-group plane-prefix GEMMs) with
    per-slot KV tiers (bf16 / int8 / int4-packed in one arena).

    Asserts (the PR's acceptance criteria): zero prepare_params calls after
    construction, per-request token identity with fixed-tier
    BatchServeEngine references, and FEWER total decode steps than
    tier-serialized admission on the same stream."""
    from repro.configs import reduced_config
    from repro.core.policy import uniform_schedule
    from repro.models.layers import Runtime
    from repro.models.transformer import LM
    from repro.serve import engine as engine_mod
    from repro.serve.engine import BatchServeEngine, Request, ServeEngine

    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    rng = np.random.default_rng(13)
    params = model.init(jax.random.PRNGKey(0))
    tiers = {"8/8": (8, 8), "4/4": (4, 4), "2/2": (2, 2)}
    sched = uniform_schedule(tiers, backend="decomposed",
                             kv_tiers={"8/8": None, "4/4": 8, "2/2": 4})
    rt = Runtime(policy=sched.policy_for(), mode="serve", moe_dropless=True,
                 schedule=sched)
    names = list(tiers)
    # Per-tier queue depth (2) below max_batch (3): a serialized engine can
    # only fill slots with the ONE tier currently decoding, so every phase
    # runs under-occupied and the phases add up in time, while mixed
    # admission keeps all slots busy with whatever tier waits next — the
    # paper's continuous 2..8-bit scaling under one preloaded weight array.
    budgets = (8, 6, 7, 5, 8, 6)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=3 + i % 5),
                    max_new_tokens=budgets[i], tier=names[i % 3])
            for i in range(6)]

    mixed = ServeEngine(model, params, rt, max_batch=3, max_len=64,
                        decode_chunk=4)
    preps = engine_mod.PREPARE_CALLS
    t0 = time.perf_counter()
    got = mixed.run(reqs)
    dt = time.perf_counter() - t0
    assert engine_mod.PREPARE_CALLS == preps, \
        "weights were re-prepared after construction"

    serial = ServeEngine(model, mixed.params, rt, max_batch=3, max_len=64,
                         decode_chunk=4, mixed_tiers=False)
    got_serial = serial.run([Request(uid=r.uid, prompt=r.prompt,
                                     max_new_tokens=r.max_new_tokens,
                                     tier=r.tier) for r in reqs])

    # Token identity: mixed == serialized == fixed-tier references.
    for tier in tiers:
        sub = [r for r in reqs if r.tier == tier]
        base = BatchServeEngine(model, mixed.params, rt, max_batch=1,
                                max_len=64, tier=tier)
        want = base.run([Request(uid=r.uid, prompt=r.prompt,
                                 max_new_tokens=r.max_new_tokens, tier=tier)
                         for r in sub])
        assert all(got[r.uid] == want[r.uid] for r in sub), tier
        assert all(got_serial[r.uid] == want[r.uid] for r in sub), tier
    assert mixed.stats.decode_steps < serial.stats.decode_steps, (
        mixed.stats.decode_steps, serial.stats.decode_steps)

    toks = sum(len(v) for v in got.values())
    _row("serve_mixed_tiers", dt * 1e6 / max(len(reqs), 1),
         f"tokens/s={toks/dt:.1f} "
         f"decode_steps mixed={mixed.stats.decode_steps} "
         f"serialized={serial.stats.decode_steps} "
         f"mixed_chunks={mixed.stats.mixed_tier_chunks} "
         f"preps_after_construction=0 kv_modes={sched.kv_modes} "
         "token_identical_vs_fixed_tier=True")


def bench_serve_observability():
    """Telemetry-on vs telemetry-off serving on the mixed-tier trace.

    The two contracts of ``repro.telemetry`` priced and asserted: the
    telemetry-off engine drains the stream without a single hook call
    (the module-level HOOK_CALLS spy), and the telemetry-on engine — with
    the device profiler fencing every dispatch — stays token-identical.
    Derived reports both throughputs plus the TTFT/TPOT p50/p99 the
    registry's histograms estimate without storing samples."""
    from repro.configs import reduced_config
    from repro.core.policy import uniform_schedule
    from repro.models.layers import Runtime
    from repro.models.transformer import LM
    from repro.serve.engine import Request, ServeEngine
    import repro.telemetry as telemetry_mod
    from repro.telemetry import Telemetry

    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    rng = np.random.default_rng(13)
    params = model.init(jax.random.PRNGKey(0))
    tiers = {"8/8": (8, 8), "4/4": (4, 4), "2/2": (2, 2)}
    sched = uniform_schedule(tiers, backend="decomposed",
                             kv_tiers={"8/8": None, "4/4": 8, "2/2": 4})
    rt = Runtime(policy=sched.policy_for(), mode="serve", moe_dropless=True,
                 schedule=sched)
    names = list(tiers)
    budgets = (8, 6, 7, 5, 8, 6)
    prompts = [rng.integers(0, cfg.vocab_size, size=3 + i % 5)
               for i in range(6)]

    def requests():
        return [Request(uid=i, prompt=prompts[i], max_new_tokens=budgets[i],
                        tier=names[i % 3]) for i in range(6)]

    off = ServeEngine(model, params, rt, max_batch=3, max_len=64,
                      decode_chunk=4)
    hooks_before = telemetry_mod.HOOK_CALLS
    t0 = time.perf_counter()
    got_off = off.run(requests())
    dt_off = time.perf_counter() - t0
    assert telemetry_mod.HOOK_CALLS == hooks_before, \
        "telemetry-off engine took observability hooks"

    tele = Telemetry(profile=True)
    on = ServeEngine(model, off.params, rt, max_batch=3, max_len=64,
                     decode_chunk=4, telemetry=tele)
    t0 = time.perf_counter()
    got_on = on.run(requests())
    dt_on = time.perf_counter() - t0
    assert got_on == got_off, "telemetry changed tokens"

    reg = tele.registry
    ttft = reg.get("serve_ttft_ticks")
    tpot = reg.get("serve_tpot_ticks")
    toks = sum(len(v) for v in got_off.values())
    _row("serve_observability", dt_on * 1e6 / max(len(got_on), 1),
         f"tokens/s off={toks/dt_off:.1f} on={toks/dt_on:.1f} "
         f"ttft_ticks p50={ttft.quantile(0.5):.1f} "
         f"p99={ttft.quantile(0.99):.1f} "
         f"tpot_ticks p50={tpot.quantile(0.5):.1f} "
         f"p99={tpot.quantile(0.99):.1f} "
         f"cycle_util={reg.value('serve_modeled_cycle_utilization'):.2f} "
         f"hook_calls=0_when_off token_identical=True")


def bench_fused_decode():
    """One-kernel mixed-tier decode vs the per-group loop it replaced.

    Two engines over the SAME superplane store and mixed 8/4/2 request
    stream: ``fused_decode=True`` (default — rmsnorm-fed activations
    quantized ONCE per input with per-row ranges, one group-switching
    grouped GEMM per projection) vs ``fused_decode=False`` (per-group
    quantize + GEMM + dequant chain).  Asserts token identity (the
    bitwise-stability contract) and — on the pallas backend, counted by
    tracing — that the fused decode step's dispatch count is CONSTANT in
    the number of tier groups and strictly below the per-group path's."""
    from repro.configs import reduced_config
    from repro.core.policy import uniform_schedule
    from repro.models.layers import Runtime
    from repro.models.transformer import LM
    from repro.serve.engine import Request, ServeEngine

    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    rng = np.random.default_rng(17)
    params = model.init(jax.random.PRNGKey(0))
    tiers = {"8/8": (8, 8), "4/4": (4, 4), "2/2": (2, 2)}
    kv_tiers = {"8/8": None, "4/4": 8, "2/2": 4}
    sched = uniform_schedule(tiers, backend="decomposed", kv_tiers=kv_tiers)
    rt = Runtime(policy=sched.policy_for(), mode="serve", moe_dropless=True,
                 schedule=sched)
    names = list(tiers)
    budgets = (8, 6, 7, 5, 8, 6)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=3 + i % 5),
                    max_new_tokens=budgets[i], tier=names[i % 3])
            for i in range(6)]

    fused = ServeEngine(model, params, rt, max_batch=3, max_len=64,
                        decode_chunk=4)
    t0 = time.perf_counter()
    got_f = fused.run(reqs)
    dt_f = time.perf_counter() - t0

    pergroup = ServeEngine(model, fused.params, rt, max_batch=3, max_len=64,
                           decode_chunk=4, fused_decode=False)
    t0 = time.perf_counter()
    got_u = pergroup.run([Request(uid=r.uid, prompt=r.prompt,
                                  max_new_tokens=r.max_new_tokens,
                                  tier=r.tier) for r in reqs])
    dt_u = time.perf_counter() - t0
    assert got_f == got_u, "fused decode changed tokens"

    # Dispatches per jitted decode step, pallas backend (trace-only: the
    # jaxpr is counted, nothing executes, so this runs on any host).
    sched_p = uniform_schedule(tiers, backend="pallas", kv_tiers=kv_tiers)
    rt_p = Runtime(policy=sched_p.policy_for(), mode="serve",
                   moe_dropless=True, schedule=sched_p)
    eng_pf = ServeEngine(model, params, rt_p, max_batch=4, max_len=64,
                         decode_chunk=1)
    eng_pu = ServeEngine(model, eng_pf.params, rt_p, max_batch=4, max_len=64,
                         decode_chunk=1, fused_decode=False)
    g2 = (("8/8", 2), ("4/4", 2))
    g3 = (("8/8", 1), ("4/4", 2), ("2/2", 1))
    nf2, nf3 = (eng_pf.decode_dispatch_count(groups=g) for g in (g2, g3))
    nu2, nu3 = (eng_pu.decode_dispatch_count(groups=g) for g in (g2, g3))
    assert nf2 == nf3, "fused dispatch count must not scale with groups"
    assert nf3 < nu3, "fused path must dispatch fewer kernels"

    toks = sum(len(v) for v in got_f.values())
    _row("fused_decode", dt_f * 1e6 / max(len(reqs), 1),
         f"tokens/s fused={toks/dt_f:.1f} per_group={toks/dt_u:.1f} "
         f"dispatches/step 2-tier fused={nf2} per_group={nu2} "
         f"3-tier fused={nf3} per_group={nu3} "
         f"layout_cache={fused.stats.layout_cache_hits}h/"
         f"{fused.stats.layout_cache_misses}m "
         "token_identical=True")


def bench_serve_slo_scheduling():
    """SLO-aware admission vs FIFO on a deadline-skewed mixed-tier trace.

    One engine per policy over the SAME superplane store and arrival
    trace: four long, patient 8/8-4/4 requests arrive first; three short,
    deadline-tight 2/2 requests arrive one clock tick later, behind them
    in the queue.  FIFO admits the patient backlog first, so every urgent
    request waits out a LONG service time; SLOPolicy (deadline slack
    priced by the hwmodel's per-tier cycle cost) admits the urgent ones
    into the first freed slots, delaying each patient request only by a
    SHORT service time.  Asserts (acceptance criteria): token-identity
    between the two policies (admission order never changes a request's
    tokens — the mixed-batch bit-stability contract), strictly better p99
    queue-wait under SLO, zero deadline misses under SLO while FIFO
    misses the urgent ones (the trace is feasible), and zero weight
    re-preparations."""
    from repro.configs import reduced_config
    from repro.core.policy import uniform_schedule
    from repro.models.layers import Runtime
    from repro.models.transformer import LM
    from repro.serve import Request, ServeEngine, SLOPolicy
    from repro.serve import engine as engine_mod

    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    rng = np.random.default_rng(17)
    params = model.init(jax.random.PRNGKey(0))
    tiers = {"8/8": (8, 8), "4/4": (4, 4), "2/2": (2, 2)}
    sched = uniform_schedule(tiers, backend="decomposed")
    rt = Runtime(policy=sched.policy_for(), mode="serve", moe_dropless=True,
                 schedule=sched)

    def req(uid, budget, tier, deadline):
        return Request(uid=uid,
                       prompt=rng.integers(0, cfg.vocab_size, size=4 + uid),
                       max_new_tokens=budget, tier=tier, deadline=deadline)

    # (arrival clock, request): long patient head, short urgent tail.
    arrivals = [(0.0, req(0, 16, "8/8", 500.0)),
                (0.0, req(1, 16, "4/4", 500.0)),
                (0.0, req(2, 16, "8/8", 500.0)),
                (0.0, req(3, 16, "4/4", 500.0)),
                (1.0, req(4, 2, "2/2", 18.0)),
                (1.0, req(5, 2, "2/2", 18.0)),
                (1.0, req(6, 2, "2/2", 20.0))]

    store = {}

    def serve(policy):
        eng = ServeEngine(model, store.get("params", params), rt,
                          max_batch=2, max_len=64, decode_chunk=4,
                          scheduler_policy=policy)
        store["params"] = eng.params          # share the superplane store
        preps = engine_mod.PREPARE_CALLS
        pending = list(arrivals)
        t0 = time.perf_counter()
        while pending or eng.has_work:
            while pending and (pending[0][0] <= eng.clock
                               or not eng.has_work):
                eng.submit(pending.pop(0)[1])
            eng.step()
        dt = time.perf_counter() - t0
        assert engine_mod.PREPARE_CALLS == preps, "re-prepared mid-run"
        got = eng.results
        waits = np.array([h.queue_wait for h in eng.handles.values()])
        misses = sum(
            1 for h in eng.handles.values()
            if h.finished_at > h.submitted_at + h.request.deadline)
        toks = sum(len(v) for v in got.values())
        return got, waits, misses, toks, dt

    got_f, waits_f, miss_f, toks, dt_f = serve(None)            # FIFO
    got_s, waits_s, miss_s, _, dt_s = serve(SLOPolicy(sched))
    assert got_s == got_f, "admission order changed a request's tokens"
    p50_f, p99_f = np.percentile(waits_f, [50, 99])
    p50_s, p99_s = np.percentile(waits_s, [50, 99])
    assert p99_s < p99_f, (p99_s, p99_f)
    assert miss_s == 0, f"SLO policy missed {miss_s} feasible deadlines"
    _row("serve_slo_scheduling", (dt_f + dt_s) * 1e6 / 14,
         f"queue_wait_p50 fifo={p50_f:.0f} slo={p50_s:.0f} "
         f"p99 fifo={p99_f:.0f} slo={p99_s:.0f} (decode-step ticks) "
         f"deadline_misses fifo={miss_f} slo={miss_s} "
         f"tokens/s fifo={toks/dt_f:.1f} slo={toks/dt_s:.1f} "
         "token_identical=True preps_after_construction=0")


def bench_serve_overload():
    """Overload survival at 4x: FIFO vs SLO vs SLO+preemption on a bursty
    two-tenant trace.

    Eight long best-effort "bulk" requests burst in at t=0 — four times
    the slot count — and pin both slots for the whole horizon; three
    short, deadline-tight "gold" 2/2 requests trickle in behind them.  No
    slot frees before the gold deadlines, so admission-order policies
    cannot save them: FIFO serves the backlog in order (gold waits out
    the ENTIRE bulk queue), plain SLO reorders the queue but still has to
    wait for a free slot, and only SLO+preemption displaces a running
    bulk request (snapshotting its KV lane for a later prefill-free
    resume) to run gold immediately.  Asserts (acceptance criteria):
    token-identity across all three policies — in particular every
    preempted-and-resumed bulk request is bit-identical to its
    uninterrupted runs under FIFO/SLO — zero deadline misses for
    deadline-bearing requests under SLO+preemption, and strictly lower
    p99 queue-wait for them than under either FIFO or plain SLO."""
    from repro.configs import reduced_config
    from repro.core.policy import uniform_schedule
    from repro.models.layers import Runtime
    from repro.models.transformer import LM
    from repro.serve import Request, ServeEngine, SLOPolicy

    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    rng = np.random.default_rng(23)
    params = model.init(jax.random.PRNGKey(0))
    tiers = {"8/8": (8, 8), "4/4": (4, 4), "2/2": (2, 2)}
    sched = uniform_schedule(tiers, backend="decomposed")
    rt = Runtime(policy=sched.policy_for(), mode="serve", moe_dropless=True,
                 schedule=sched)

    def req(uid, budget, tier, deadline, tenant):
        return Request(uid=uid,
                       prompt=rng.integers(0, cfg.vocab_size, size=4 + uid),
                       max_new_tokens=budget, tier=tier, deadline=deadline,
                       tenant=tenant)

    # 4x overload: 8 best-effort requests burst onto 2 slots at t=0; the
    # urgent gold tail arrives once both slots are already pinned for a
    # full 24-tick wave, so no slot frees before the gold deadlines and
    # only displacement can serve them in time.
    arrivals = [(0.0, req(i, 24, t, None, "bulk"))
                for i, t in enumerate(["8/8", "4/4"] * 4)]
    arrivals += [(2.0, req(8, 2, "2/2", 14.0, "gold")),
                 (4.0, req(9, 2, "2/2", 14.0, "gold")),
                 (6.0, req(10, 2, "2/2", 16.0, "gold"))]

    store = {}

    def serve(policy):
        eng = ServeEngine(model, store.get("params", params), rt,
                          max_batch=2, max_len=64, decode_chunk=4,
                          scheduler_policy=policy)
        store["params"] = eng.params          # share the superplane store
        pending = list(arrivals)
        t0 = time.perf_counter()
        while pending or eng.has_work:
            while pending and (pending[0][0] <= eng.clock
                               or not eng.has_work):
                eng.submit(pending.pop(0)[1])
            eng.step()
        dt = time.perf_counter() - t0
        urgent = [h for h in eng.handles.values()
                  if h.request.deadline is not None]
        waits = np.array([h.queue_wait for h in urgent])
        misses = sum(1 for h in urgent
                     if h.finished_at > h.submitted_at + h.request.deadline)
        return eng.results, waits, misses, eng.stats, dt

    got_f, waits_f, miss_f, _, dt_f = serve(None)               # FIFO
    got_s, waits_s, miss_s, _, dt_s = serve(SLOPolicy(sched))   # plain SLO
    got_p, waits_p, miss_p, st_p, dt_p = serve(
        SLOPolicy(sched, preempt=True, preempt_slack=8.0))
    assert got_s == got_f and got_p == got_f, \
        "a preempted-and-resumed stream diverged from its uninterrupted run"
    assert st_p.preemptions > 0 and st_p.resumes == st_p.preemptions
    p99_f, p99_s, p99_p = (float(np.percentile(w, 99))
                           for w in (waits_f, waits_s, waits_p))
    assert miss_p == 0, f"preemption still missed {miss_p} gold deadlines"
    assert p99_p < p99_s < p99_f, (p99_p, p99_s, p99_f)
    toks = sum(len(v) for v in got_f.values())
    _row("serve_overload", (dt_f + dt_s + dt_p) * 1e6 / (3 * len(arrivals)),
         f"gold_p99_queue_wait fifo={p99_f:.0f} slo={p99_s:.0f} "
         f"slo+preempt={p99_p:.0f} (decode-step ticks) "
         f"deadline_misses fifo={miss_f} slo={miss_s} slo+preempt={miss_p} "
         f"preemptions={st_p.preemptions} resumes={st_p.resumes} "
         f"tokens/s fifo={toks/dt_f:.1f} preempt={toks/dt_p:.1f} "
         "token_identical=True")


def bench_autoprec_search():
    """Hardware-aware automatic mixed-precision search (repro.autoprec):
    Pareto front of avg bits vs modeled cycles vs measured divergence.

    Profiles every layer of a small config through the REAL quantization
    path (batched one-pass row groups over the superplane store), runs both
    search strategies (greedy marginal-divergence-per-cycle + MixPrec-style
    differentiable relaxation), jointly re-measures three front points, and
    asserts the acceptance invariants: even truncatable widths only, and a
    selected point that Pareto-dominates the uniform-8 baseline on modeled
    cycles at small measured divergence."""
    from repro.autoprec import (CostModel, measure_divergence, pareto_front,
                                profile_sensitivity, random_calibration,
                                schedule_from_results, search)
    from repro.configs import reduced_config
    from repro.core.decompose import RUNTIME_W_BITS
    from repro.core.policy import uniform_schedule
    from repro.models.transformer import LM
    from repro.serve import prepare_params

    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params, _ = prepare_params(
        params, uniform_schedule({"8/8": (8, 8)}).prepare_policy(),
        model, superplane=True)
    calib = random_calibration(cfg, batches=1, batch=2, seq=8, seed=5)
    choices = (2, 4, 6)

    t0 = time.perf_counter()
    profile = profile_sensitivity(model, params, calib=calib,
                                  choices=choices, block=8)
    cost = CostModel.for_config(cfg)
    front = search(profile.table, cost, choices=choices, strategy="both")
    pts = [front[0], front[len(front) // 2], front[-1]]
    meas = measure_divergence(model, params,
                              {f"p{i}": r.assignment
                               for i, r in enumerate(pts)}, calib=calib)
    for i, r in enumerate(pts):
        r.measured_divergence = meas[f"p{i}"]
    us = (time.perf_counter() - t0) * 1e6

    assert front, "empty Pareto front"
    assert all(b in RUNTIME_W_BITS for r in front
               for b in r.assignment.values()), "non-truncatable width"
    uniform8 = cost.uniform_cycles(8)
    best = pts[-1]
    assert best.cycles_per_token < uniform8, (best.cycles_per_token, uniform8)
    assert best.measured_divergence < 0.1, best.measured_divergence
    schedule_from_results([best])       # must emit a valid schedule
    front = pareto_front(front)
    _row("autoprec_search", us,
         f"front={len(front)}pts "
         "avg_bits/cycles/meas_div={"
         + " ".join(f"{r.avg_bits:.2f}b:{r.cycles_per_token:.0f}cyc:"
                    f"{r.measured_divergence:.1e}" for r in pts)
         + "} " + f"uniform8={uniform8:.0f}cyc "
         f"dominates_uniform8=True")


def bench_serve_tp_scaling():
    """Tensor-parallel sharded serving (``ServeEngine(mesh=...)``): one
    mixed 8/4/2 request stream served at 1-, 2- and 4-device meshes.

    Runs in a subprocess with 4 fake CPU devices (XLA_FLAGS).  Asserts
    (acceptance criteria): every mesh width is TOKEN-IDENTICAL to the
    unsharded engine, and the quantized wire moves <= 1/4 of the f32
    baseline's bytes per gathered activation element at the 8-bit tier —
    proportionally less at 4/2-bit, where codes travel bit-packed.
    Reports tokens/s and analytic wire bytes per decode step per mesh."""
    import json as _json
    import subprocess
    import sys
    import textwrap

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    body = textwrap.dedent("""
        import dataclasses, json, time
        import jax, numpy as np
        from repro.configs import reduced_config
        from repro.core.policy import uniform_schedule
        from repro.distributed import tp_serve
        from repro.launch.mesh import make_serve_mesh
        from repro.models.layers import Runtime
        from repro.models.transformer import LM
        from repro.serve import Request, ServeEngine

        # num_kv_heads=4 so KV genuinely shards at n=2 and n=4 (the
        # reduced GQA configs often collapse to MQA).
        cfg = dataclasses.replace(reduced_config("granite-3-8b"),
                                  num_kv_heads=4)
        model = LM(cfg)
        params = model.init(jax.random.PRNGKey(0))
        tiers = {"8/8": (8, 8), "4/4": (4, 4), "2/2": (2, 2)}
        sched = uniform_schedule(tiers, backend="decomposed",
                                 kv_tiers={"8/8": None, "4/4": 8,
                                           "2/2": 4})
        rt = Runtime(policy=sched.policy_for(), mode="serve",
                     moe_dropless=True, schedule=sched)
        names = list(tiers)

        def requests(base):
            rng = np.random.default_rng(23)
            return [Request(uid=base + i,
                            prompt=rng.integers(0, cfg.vocab_size,
                                                size=3 + i % 5),
                            max_new_tokens=(8, 6, 7, 5, 8)[i],
                            tier=names[i % 3]) for i in range(5)]

        def serve(mesh):
            eng = ServeEngine(model, params, rt, max_batch=4, max_len=64,
                              decode_chunk=4, mesh=mesh)
            eng.run(requests(0))        # compile warm-up, same layouts
            t0 = time.perf_counter()
            got = eng.run(requests(100))
            dt = time.perf_counter() - t0
            return got, dt, eng

        ref, dt_ref, _ = serve(None)
        toks = sum(len(v) for v in ref.values())
        # A representative full-occupancy mixed layout for the analytic
        # wire cost: 2 slots at 8/8, one each at 4/4 and 2/2.
        layout = ((2, 8), (1, 4), (1, 2))
        out = {"tokens": toks, "meshes": {}}
        for n in (1, 2, 4):
            got, dt, eng = serve(make_serve_mesh(n))
            assert got == ref, f"mesh {n} diverged from unsharded tokens"
            tp = eng._tp
            assert tp is not None and tp.n == n
            assert n == 1 or tp.kv_shards
            stats = tp_serve.decode_wire_stats(cfg, tp, layout)
            for rows, bits in layout:   # the bit-serial wire law
                bpe = tp_serve.wire_bytes_per_element(bits)
                assert bpe <= 4.0 / 4.0 * (bits / 8.0 if bits < 8
                                           else 1.0), (bits, bpe)
            assert stats["vs_f32"] == 0 or stats["vs_f32"] >= 4.0
            out["meshes"][n] = {
                "tokens_per_s": toks / dt,
                "wire_bytes_per_step": stats["quant_gather_bytes"],
                "out_bytes_per_step": stats["out_gather_bytes"],
                "bytes_per_element": stats["bytes_per_element"],
                "vs_f32": stats["vs_f32"],
                "kv_shards": tp.kv_shards,
            }
        out["tokens_per_s_unsharded"] = toks / dt_ref
        print("TP_SCALING_JSON " + json.dumps(out))
    """)
    t0 = time.perf_counter()
    r = subprocess.run([sys.executable, "-c", body], capture_output=True,
                       text=True, env=env, timeout=1800)
    us = (time.perf_counter() - t0) * 1e6
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    line = next(l for l in r.stdout.splitlines()
                if l.startswith("TP_SCALING_JSON "))
    res = _json.loads(line.split(" ", 1)[1])
    per_mesh = " ".join(
        f"n{n}:{m['tokens_per_s']:.1f}tok/s:"
        f"{m['wire_bytes_per_step']:.0f}B/step"
        for n, m in sorted(res["meshes"].items(), key=lambda kv: int(kv[0])))
    bpe = res["meshes"]["2"]["bytes_per_element"]
    vs = res["meshes"]["2"]["vs_f32"]
    _row("serve_tp_scaling", us,
         f"tokens/s unsharded={res['tokens_per_s_unsharded']:.1f} "
         + per_mesh + f" wire_bytes/elem@n2={bpe:.3f} vs_f32={vs:.1f}x "
         "(8-bit rows 4x, 4-bit 8x, 2-bit 16x) token_identical=True")


def bench_dryrun_roofline_summary():
    """Summarize the multi-pod dry-run roofline table if results exist."""
    res_dir = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "benchmarks", "results", "dryrun")
    if not os.path.isdir(res_dir):
        _row("dryrun_roofline", 0.0, "no results (run repro.launch.dryrun_all)")
        return
    from repro.launch.roofline import load_cells, roofline_terms
    t0 = time.perf_counter()
    cells = load_cells(res_dir)
    live = [c for c in cells if not c.get("skipped")]
    doms = {}
    for c in live:
        t = roofline_terms(c)
        doms[t["dominant"]] = doms.get(t["dominant"], 0) + 1
    us = (time.perf_counter() - t0) * 1e6
    _row("dryrun_roofline", us,
         f"cells={len(cells)} live={len(live)} "
         f"skipped={len(cells)-len(live)} dominant={doms}")


def bench_spec_decode():
    """Self-speculative decoding: draft at the plane prefix, verify at
    8-bit in one batched forward.

    Asserts (the PR's acceptance criteria): greedy speculative streams
    token-identical to the non-speculative engine at the verify tier for
    k in {2, 4}; zero prepare_params calls after construction (the draft
    model is a free plane-prefix read); and FEWER verify-tier decode
    steps per emitted token than the one-step-per-token baseline
    (demonstrated deterministically with draft == verify tier, where
    acceptance is exactly 1.0, and measured at the 4-bit draft tier)."""
    from repro.configs import reduced_config
    from repro.core.policy import uniform_schedule
    from repro.models.layers import Runtime
    from repro.models.transformer import LM
    from repro.serve import engine as engine_mod
    from repro.serve.engine import Request, ServeEngine
    from repro.spec import SpecConfig

    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    rng = np.random.default_rng(23)
    params = model.init(jax.random.PRNGKey(0))
    sched = uniform_schedule({"8/8": (8, 8), "4/4": (4, 4), "2/2": (2, 2)},
                             backend="decomposed",
                             kv_tiers={"8/8": 8, "4/4": 8, "2/2": 8})
    rt = Runtime(policy=sched.policy_for(), mode="serve", moe_dropless=True,
                 schedule=sched)
    prompts = [rng.integers(0, cfg.vocab_size, size=4 + i % 4)
               for i in range(3)]

    def serve(spec):
        eng = ServeEngine(model, params, rt, max_batch=3, max_len=64,
                          decode_chunk=4)
        preps = engine_mod.PREPARE_CALLS
        t0 = time.perf_counter()
        out = eng.run([Request(uid=i, prompt=p, max_new_tokens=9,
                               tier="8/8", spec=spec)
                       for i, p in enumerate(prompts)])
        dt = time.perf_counter() - t0
        assert engine_mod.PREPARE_CALLS == preps, \
            "weights were re-prepared after construction"
        return out, eng.stats, dt

    base, base_st, base_dt = serve(None)
    base_toks = sum(len(v) for v in base.values())
    for k in (2, 4):
        spec, st, dt = serve(SpecConfig(draft_tier="4/4", k=k))
        assert spec == base, f"k={k}: speculative stream diverged"
        acc = st.spec_accepted / max(st.spec_drafted, 1)
        _row(f"spec_decode_k{k}",
             dt * 1e6 / max(base_toks, 1),
             f"tokens/s={base_toks/dt:.1f} draft=4/4 "
             f"decode_steps={st.decode_steps} "
             f"base_decode_steps={base_st.decode_steps} "
             f"verify_steps/token={st.spec_verify_steps/st.spec_emitted:.2f} "
             f"accept_rate={acc:.2f} token_identical=True")
    # Full-acceptance row (draft == verify tier): acceptance is exactly
    # 1.0, so the verify-step saving is guaranteed, not weight-dependent.
    full, st, dt = serve(SpecConfig(draft_tier="8/8", k=4))
    assert full == base
    assert st.spec_verify_steps < st.spec_emitted, \
        "speculation must take fewer verify-tier steps than tokens emitted"
    assert st.decode_steps * 3 \
        == st.decode_slot_steps + st.decode_idle_slot_steps
    _row("spec_decode_full_accept",
         dt * 1e6 / max(base_toks, 1),
         f"tokens/s={base_toks/dt:.1f} draft=8/8 k=4 "
         f"decode_steps={st.decode_steps} "
         f"base_decode_steps={base_st.decode_steps} "
         f"verify_steps/token={st.spec_verify_steps/st.spec_emitted:.2f} "
         f"accept_rate={st.spec_accepted/max(st.spec_drafted,1):.2f} "
         f"token_identical=True")


BENCHES = {
    "table2_csa_vs_bat": bench_table2_csa_vs_bat,
    "table3_comparison": bench_table3_comparison,
    "fig7_breakdown": bench_fig7_breakdown,
    "fig8_energy_efficiency": bench_fig8_energy_efficiency,
    "mobilenetv2_power": bench_mobilenetv2_power,
    "mobilenetv2_throughput": bench_mobilenetv2_throughput,
    "kernel_bitserial_matmul": bench_kernel_bitserial_matmul,
    "kernel_packed_planes": bench_kernel_packed_vs_unpacked,
    "kernel_act_quant": bench_act_quant,
    "pe_array_utilization": bench_pe_array_utilization,
    "serve_continuous_batching": bench_continuous_batching,
    "serve_precision_tiers": bench_serve_precision_tiers,
    "serve_mixed_tiers": bench_serve_mixed_tiers,
    "serve_observability": bench_serve_observability,
    "fused_decode": bench_fused_decode,
    "serve_slo_scheduling": bench_serve_slo_scheduling,
    "serve_overload": bench_serve_overload,
    "serve_tp_scaling": bench_serve_tp_scaling,
    "spec_decode": bench_spec_decode,
    "autoprec_search": bench_autoprec_search,
    "dryrun_roofline": bench_dryrun_roofline_summary,
}


def main(argv=None) -> None:
    """Run all rows, or a subset: ``run.py --only name [name ...]``;
    ``run.py --list`` enumerates the available rows."""
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="+", choices=sorted(BENCHES),
                    help="run only these rows (CI smoke)")
    ap.add_argument("--list", action="store_true",
                    help="enumerate available rows (name: summary) and exit")
    ap.add_argument("--pr", default=os.environ.get("BENCH_PR", "10"),
                    metavar="N",
                    help="PR number stamped into the default --json "
                         "artifact name (BENCH_PR<N>.json; env BENCH_PR "
                         "overrides the built-in default)")
    ap.add_argument("--json", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="also persist the rows as a JSON artifact "
                         "(default path: BENCH_PR<--pr>.json)")
    args = ap.parse_args(argv)
    if args.json == "":
        args.json = f"BENCH_PR{args.pr}.json"
    if args.list:
        for name in sorted(BENCHES):
            doc = (BENCHES[name].__doc__ or "").strip().splitlines()
            print(f"{name}: {doc[0] if doc else ''}")
        return
    names = args.only or list(BENCHES)
    print("name,us_per_call,derived")
    for name in names:
        BENCHES[name]()
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"rows": _RESULTS}, fh, indent=2)
            fh.write("\n")
        print(f"wrote {args.json} ({len(_RESULTS)} rows)")


if __name__ == "__main__":
    main()
