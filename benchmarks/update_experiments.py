"""Regenerate the §Roofline table inside EXPERIMENTS.md from dry-run JSONs.

    PYTHONPATH=src python benchmarks/update_experiments.py
"""
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.launch.roofline import format_table, load_cells  # noqa: E402

MARK = "<!-- ROOFLINE_TABLE -->"


def main():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    cells = load_cells(os.path.join(root, "benchmarks/results/dryrun"))
    single = [c for c in cells if c.get("mesh", "16x16") == "16x16"
              or (c.get("skipped") and "2x16x16" not in c.get("mesh", ""))]
    # skipped entries lack mesh; derive from filename convention? keep all
    # non-multi-pod rows (roofline table is single-pod per the assignment).
    single = [c for c in cells if "2x16x16" not in str(c.get("mesh", ""))]
    multi = [c for c in cells if "2x16x16" in str(c.get("mesh", ""))]
    table = format_table(single)
    n_live = sum(1 for c in multi if not c.get("skipped"))
    n_skip = sum(1 for c in multi if c.get("skipped"))
    summary = (f"\n\nMulti-pod (2x16x16) pass: {n_live} live cells compiled + "
               f"{n_skip} recorded skips (collective schedules include the "
               f"pod axis; roofline terms reported single-pod per the "
               f"assignment).\n")
    path = os.path.join(root, "EXPERIMENTS.md")
    text = open(path).read()
    new_block = MARK + "\n\n" + table + summary
    if MARK in text:
        pre = text.split(MARK)[0]
        post = text.split("## §Perf", 1)
        text = pre + new_block + "\n## §Perf" + post[1]
    open(path, "w").write(text)
    print(f"updated EXPERIMENTS.md: {len(single)} single-pod rows, "
          f"{n_live}+{n_skip} multi-pod cells")


if __name__ == "__main__":
    main()
