"""Long-context decode with an SSM: O(1) state per token vs a growing KV
cache — why the `long_500k` dry-run cell runs for mamba2/jamba only.

Decodes step-by-step with a mamba2-family model: the recurrent state is a
fixed [H, N, P] tensor regardless of context length, while an attention
model's KV cache grows linearly (and its per-token read cost with it).

    PYTHONPATH=src python examples/long_context_ssm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.core.policy import uniform_policy
from repro.models.layers import Runtime
from repro.models.transformer import LM


def main():
    cfg = reduced_config("mamba2-1.3b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rt = Runtime(policy=uniform_policy(4, 8, backend="decomposed"),
                 mode="serve")

    b = 2
    cache = model.init_cache(b, max_len=8)   # max_len unused by SSM caches
    state_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(cache))
    print(f"SSM recurrent state: {state_bytes/1e3:.1f} KB for batch={b} — "
          f"CONSTANT in context length")

    decode = jax.jit(lambda p, c, t: model.decode_step(p, rt, c, tokens=t))
    tok = jnp.zeros((b, 1), jnp.int32)
    # Warm up / compile.
    logits, cache = decode(params, cache, tok)

    n = 256
    t0 = time.time()
    for i in range(n):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    dt = time.time() - t0
    print(f"decoded {n} tokens x batch {b} in {dt:.2f}s "
          f"({n*b/dt:.0f} tok/s, CPU interpret) — flat per-token cost")

    # Contrast: attention KV for the same arch family at 500k context.
    kv_per_tok = 2 * 8 * 128 * 2          # kvh * dh * bf16 * (k+v), per layer
    print(f"(an attention layer at 524288 ctx would hold "
          f"{524288*kv_per_tok/1e9:.1f} GB KV per layer per sequence; "
          f"the mamba2 state above replaces it)")


if __name__ == "__main__":
    main()
