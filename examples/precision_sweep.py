"""The paper's headline trade-off: per-layer precision vs quality vs energy.

Sweeps uniform and mixed policies on a small LM, reporting next-token CE on
the integer serving path and the hwmodel energy per token — the software
equivalent of the paper's MobileNetV2 experiment (§IV).

    PYTHONPATH=src python examples/precision_sweep.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.core.policy import (LayerPrecision, PrecisionPolicy,
                               uniform_policy)
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.hwmodel import energy
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.serve.engine import prepare_params
from repro.train import optimizer as optim
from repro.train.step import make_loss_fn, make_train_step


def main():
    cfg = reduced_config("qwen3-8b")
    model = LM(cfg)

    # Train briefly in 8-bit QAT so quality differences are meaningful.
    rt_train = Runtime(policy=uniform_policy(8, 8, backend="fake_quant"))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=16))
    ocfg = optim.OptConfig(lr=1e-2, warmup_steps=5, total_steps=80,
                           weight_decay=0.0)
    step = jax.jit(make_train_step(model, rt_train, ocfg))
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": optim.init_state(params, ocfg)}
    for i in range(60):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        state, m = step(state, b)
    print(f"trained 60 steps, final ce={float(m['ce']):.3f}")

    held = {k: jnp.asarray(v) for k, v in data.batch(10_000).items()}
    macs_per_tok = cfg.param_count()  # ~1 MAC per weight per token

    policies = {
        "w8a8 uniform": uniform_policy(8, 8, backend="decomposed"),
        "w6a8 uniform": uniform_policy(6, 8, backend="decomposed"),
        "w4a8 uniform": uniform_policy(4, 8, backend="decomposed"),
        "w3a8 uniform": uniform_policy(3, 8, backend="decomposed"),
        "w2a8 uniform": uniform_policy(2, 8, backend="decomposed"),
        "mixed attn6/mlp4": PrecisionPolicy(rules={
            "layers.*.attn.*": LayerPrecision(6, 8, backend="decomposed"),
            "layers.*.mlp.*": LayerPrecision(4, 8, backend="decomposed"),
        }, default=LayerPrecision(8, 8, backend="decomposed")),
    }
    print(f"{'policy':18s} {'CE':>7s} {'pJ/MAC':>8s} {'rel energy':>10s}")
    e8 = energy.energy_per_mac_j(8, 8) * 1e12
    for name, pol in policies.items():
        prepared, _ = prepare_params(state["params"], pol, model)
        rt = Runtime(policy=pol, mode="serve", moe_dropless=True)
        loss_fn = make_loss_fn(model, rt)
        ce = float(loss_fn(prepared, held)[0])
        bits = pol.lookup("layers.pos0.mlp.up_proj").w_bits
        if "mixed" in name:
            pj = 0.45 * energy.energy_per_mac_j(6, 8) * 1e12 \
                + 0.55 * energy.energy_per_mac_j(4, 8) * 1e12
        else:
            pj = energy.energy_per_mac_j(bits, 8) * 1e12
        print(f"{name:18s} {ce:7.3f} {pj:8.3f} {pj/e8:9.1%}")


if __name__ == "__main__":
    main()
