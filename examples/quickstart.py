"""Quickstart: the paper's weight-combination scheme end to end on one page.

1. Decompose 2..8-bit weights into Table-I 2/3-bit planes.
2. Run the bit-exact bit-serial MAC (Eq. 1) and the PE-array simulator.
3. Run the TPU-native plane-decomposed matmul (Pallas kernel, interpret
   mode on CPU) and compare quality across precisions.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (PEArrayConfig, bitserial_mac, decompose_weights,
                        decomposed_matmul, pe_array_matmul, peak_tops,
                        recompose_weights, weight_range)
from repro.core.policy import LayerPrecision
from repro.kernels import ops


def main():
    rng = np.random.default_rng(0)

    print("== 1. Table-I decomposition ==")
    w5 = rng.integers(*map(int, weight_range(5, True)), size=(4,)) \
        if False else rng.integers(-16, 16, size=(4,))
    planes = decompose_weights(w5, 5)           # 5-bit -> 3-2 (two planes)
    print(f"5-bit weights {w5} -> planes (LSB-first):\n{np.asarray(planes)}")
    print("recomposed:", np.asarray(recompose_weights(planes, 5)))

    print("\n== 2. Bit-serial MAC (Eq. 1) == ")
    a = rng.integers(-8, 8, size=(2, 16))       # 4-bit activations
    w = rng.integers(-16, 16, size=(16, 3))     # 5-bit weights
    mac = bitserial_mac(a, w, a_bits=4, w_bits=5)
    print("bit-serial:", np.asarray(mac))
    print("reference :", a @ w)

    print("\n== 3. 64x64 PE array simulator ==")
    a64 = rng.integers(-2, 2, size=(4, 64))
    w64 = rng.integers(-2, 2, size=(64, 64))
    out, stats = pe_array_matmul(a64, w64, w_bits=2, a_bits=2)
    assert np.array_equal(np.asarray(out), a64 @ w64)
    print(f"2/2-bit: util={stats.utilization:.2f} "
          f"macs/cycle={stats.macs_per_cycle:.0f} "
          f"peak={peak_tops(PEArrayConfig(), 2, 2):.2f} TOPS (paper: 4.09)")

    print("\n== 4. TPU plane-decomposed matmul, quality per precision ==")
    x = rng.normal(size=(8, 256)).astype(np.float32)
    wf = rng.normal(size=(256, 64)).astype(np.float32)
    dense = x @ wf
    for bits in (2, 3, 4, 6, 8):
        y = np.asarray(ops.matmul(
            jnp.asarray(x), jnp.asarray(wf),
            LayerPrecision(w_bits=bits, a_bits=8, backend="decomposed")))
        rel = np.abs(y - dense).mean() / np.abs(dense).mean()
        from repro.core.decompose import num_planes
        print(f"  w{bits}a8: {num_planes(bits)} MXU pass(es), "
              f"mean rel err {rel:.4f}")


if __name__ == "__main__":
    main()
