"""Continuous-batching serving with offline-quantized (plane-decomposed)
weights and an optional int8 KV cache — the paper's inference path as a
service.  Requests with heterogeneous prompt lengths and decode budgets
stream through a fixed-slot cache arena: a slot frees the step its budget
is exhausted and the next request is prefilled into it without touching
the other slots.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import time

import jax
import numpy as np

from repro.configs import reduced_config
from repro.core.policy import uniform_policy
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = reduced_config("qwen3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # The engine performs the weight preload itself: float params ->
    # Table-I planes, prepared once at construction.
    policy = uniform_policy(4, 8, backend="decomposed")
    rt = Runtime(policy=policy, mode="serve", moe_dropless=True)
    engine = ServeEngine(model, params, rt, max_batch=4, max_len=64,
                         kv_bits=8, decode_chunk=8)   # int8 KV cache
    print(f"quantized {len(engine.quantized_paths)} projection weights "
          f"to 4-bit planes")

    rng = np.random.default_rng(1)
    requests = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, size=4 + i % 5),
                max_new_tokens=2 + 3 * (i % 4))
        for i in range(8)
    ]
    # The streaming API: submit returns a handle per request immediately;
    # each step() emits TokenEvents as slots produce tokens.  (The blocking
    # form `engine.run(requests)` is a thin wrapper over this same loop.)
    t0 = time.time()
    handles = [engine.submit(r) for r in requests]
    handles[0].on_token(
        lambda ev: print(f"  [stream] req 0 token {ev.index}: {ev.token}"))
    while engine.has_work:
        engine.step()
    results = {h.uid: h.tokens for h in handles}
    dt = time.time() - t0
    toks = sum(len(v) for v in results.values())
    st = engine.stats
    print(f"served {len(requests)} requests / {toks} tokens "
          f"in {dt:.2f}s ({toks/dt:.1f} tok/s on CPU interpret)")
    print(f"decode: {st.decode_steps} jitted steps in {st.decode_chunks} "
          f"chunk dispatches, {st.decode_slot_steps} active slot-steps")
    for uid in sorted(results):
        print(f"  req {uid}: {results[uid]}")


if __name__ == "__main__":
    main()
