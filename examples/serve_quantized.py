"""Batched serving with offline-quantized (plane-decomposed) weights and an
optional int8 KV cache — the paper's inference path as a service.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import time

import jax
import numpy as np

from repro.configs import reduced_config
from repro.core.policy import uniform_policy
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.serve.engine import Request, ServeEngine, prepare_params


def main():
    cfg = reduced_config("qwen3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # Offline quantization: weights -> Table-I planes (the "preload").
    policy = uniform_policy(4, 8, backend="decomposed")
    prepared, qpaths = prepare_params(params, policy, model)
    n_q = len(qpaths)
    print(f"quantized {n_q} projection weights to 4-bit planes")

    rt = Runtime(policy=policy, mode="serve", moe_dropless=True)
    engine = ServeEngine(model, prepared, rt, max_batch=4, max_len=64,
                         kv_bits=8)   # int8 KV cache

    rng = np.random.default_rng(1)
    requests = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, size=6 + i % 3),
                max_new_tokens=8)
        for i in range(6)
    ]
    t0 = time.time()
    results = engine.run(requests)
    dt = time.time() - t0
    toks = sum(len(v) for v in results.values())
    print(f"served {len(requests)} requests / {toks} tokens "
          f"in {dt:.2f}s ({toks/dt:.1f} tok/s on CPU interpret)")
    for uid in sorted(results):
        print(f"  req {uid}: {results[uid]}")


if __name__ == "__main__":
    main()
