"""End-to-end training driver example: mixed-precision QAT with
checkpoint/auto-resume via the production launcher.

Presets:
  ci    tiny model, 60 steps (runs in ~1 min on CPU — default here)
  full  ~100M-parameter model, 300 steps (the assignment-scale run; use on
        a real machine: same code path, bigger numbers)

    PYTHONPATH=src python examples/train_qat.py [--preset full]
"""
import argparse
import sys

from repro.launch import train as train_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=("ci", "full"), default="ci")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_qat")
    args = ap.parse_args()

    if args.preset == "full":
        # ~100M params: d_model 640, 16 layers, 32k vocab.
        argv = ["--arch", "qwen3-8b", "--d-model", "640", "--layers", "16",
                "--vocab", "32768", "--steps", "300", "--seq-len", "256",
                "--batch", "16", "--accum", "4", "--w-bits", "4",
                "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50"]
    else:
        argv = ["--arch", "qwen3-8b", "--reduced", "--steps", "60",
                "--seq-len", "48", "--batch", "16", "--w-bits", "4",
                "--lr", "1e-2",
                "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "30"]
    train_driver.main(argv)


if __name__ == "__main__":
    main()
