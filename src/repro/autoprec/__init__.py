"""repro.autoprec — hardware-aware automatic mixed-precision search.

Closes the loop the paper's accelerator exists for: the hardware serves any
even per-layer weight width from one preloaded superplane store, and this
package decides WHICH widths, automatically:

    model --(sensitivity: real quantization path)--> per-layer divergences
          --(cost: hwmodel cycles/energy per token)--> priced candidates
          --(search: greedy + differentiable relaxation)--> Pareto front
          --(schedule_io: JSON PrecisionSchedule)--> ServeEngine

Entry points: :func:`profile_sensitivity` / :func:`measure_divergence`
(measured through plane-prefix truncation, never a proxy),
:class:`CostModel` (modeled cycles — the paper's axis, not average bits),
:func:`search` / :func:`greedy_search` / :func:`relaxed_search` /
:func:`pareto_front`, and :func:`save_schedule` / :func:`load_schedule` /
:func:`schedule_from_results`.  ``python -m repro.launch.autoprec`` drives
the whole pipeline and writes a schedule ``repro.launch.serve
--schedule-file`` can serve.
"""
from repro.autoprec.cost import Assignment, CostModel
from repro.autoprec.schedule_io import (load_schedule,
                                        load_schedule_with_meta,
                                        result_to_meta, save_schedule,
                                        schedule_from_dict,
                                        schedule_from_results,
                                        schedule_to_dict)
from repro.autoprec.search import (EVEN_CHOICES, SearchResult,
                                   default_lambdas, greedy_search,
                                   greedy_trajectory, pareto_front,
                                   predicted_divergence, relaxed_search,
                                   search)
from repro.autoprec.sensitivity import (SensitivityProfile,
                                        measure_divergence, measure_tiers,
                                        profile_sensitivity,
                                        random_calibration)

__all__ = [
    "Assignment", "CostModel", "EVEN_CHOICES", "SearchResult",
    "SensitivityProfile", "default_lambdas", "greedy_search",
    "greedy_trajectory", "load_schedule", "load_schedule_with_meta",
    "measure_divergence", "measure_tiers", "pareto_front",
    "predicted_divergence", "profile_sensitivity", "random_calibration",
    "relaxed_search", "result_to_meta", "save_schedule",
    "schedule_from_dict", "schedule_from_results", "schedule_to_dict",
    "search",
]
