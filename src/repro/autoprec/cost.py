"""Hardware pricing of per-layer precision assignments.

The paper's search axis is the *accelerator*, not abstract bit counts: a
layer at ``w_bits`` occupies the PE array for ``w_bits/2`` plane passes at
an ``a_bits``-deep bit-serial activation stream, so the cost of a candidate
assignment is its modeled **cycles per decoded token** (and joules, via the
calibrated Table-III energy model) — not the parameter-weighted average
bitwidth HAWQ-style allocators optimize.  :class:`CostModel` binds one
model's per-layer MAC workload (``ArchConfig.quant_layer_macs``) to the
hwmodel's vectorized per-layer pricing (``hwmodel.energy.per_layer_cost``)
so the search strategies in :mod:`repro.autoprec.search` optimize the
hardware axis directly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Tuple

import numpy as np
import numpy.typing as npt

from repro.hwmodel import energy

# A precision assignment: layer name -> effective weight width.
Assignment = Mapping[str, int]


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Prices per-layer weight-width assignments for one model workload.

    ``macs`` maps every quantizable layer name to its MACs per decoded
    token (period multiplicity and routed-expert counts already folded in
    — see ``ArchConfig.quant_layer_macs``); ``a_bits`` is the uniform
    activation width the engine serves at (the weight width is the
    per-layer search variable, matching the runtime plane-prefix path
    where only ``w_bits`` varies per layer)."""

    macs: Dict[str, int]
    a_bits: int = 8

    @classmethod
    def for_config(cls, cfg: Any, a_bits: int = 8) -> "CostModel":
        """Cost model for an ``ArchConfig`` (its quantizable projections)."""
        return cls(macs=dict(cfg.quant_layer_macs()), a_bits=a_bits)

    @property
    def layers(self) -> Tuple[str, ...]:
        return tuple(self.macs)

    @property
    def total_macs(self) -> float:
        return float(sum(self.macs.values()))

    def _bits_vector(self, assignment: Assignment) -> npt.NDArray[np.int64]:
        missing = [n for n in self.macs if n not in assignment]
        if missing:
            raise KeyError(f"assignment misses layers {missing}")
        unknown = [n for n in assignment if n not in self.macs]
        if unknown:
            raise KeyError(f"assignment names unknown layers {unknown}")
        return np.asarray([assignment[n] for n in self.macs], np.int64)

    def layer_cycles(self, name: str, w_bits: int) -> float:
        """Cycles per token one layer costs at one width (the marginal
        quantity the greedy search trades against divergence)."""
        return self.macs[name] * energy.cycles_per_mac(w_bits, self.a_bits)

    def cycles_per_token(self, assignment: Assignment) -> float:
        """Modeled array cycles per decoded token under ``assignment``."""
        bits = self._bits_vector(assignment)
        macs = np.asarray([self.macs[n] for n in self.macs], np.float64)
        return float(energy.per_layer_cost(macs, bits,
                                           self.a_bits)["cycles"].sum())

    def energy_per_token_j(self, assignment: Assignment) -> float:
        """Modeled joules per decoded token under ``assignment``."""
        bits = self._bits_vector(assignment)
        macs = np.asarray([self.macs[n] for n in self.macs], np.float64)
        return float(energy.per_layer_cost(macs, bits,
                                           self.a_bits)["energy_j"].sum())

    def uniform_cycles(self, w_bits: int) -> float:
        """Cycles per token with every layer at ``w_bits`` (the uniform
        baseline the searched Pareto front must dominate)."""
        return self.cycles_per_token({n: w_bits for n in self.macs})

    def average_bits(self, assignment: Assignment) -> float:
        """MAC-weighted mean weight width (reported alongside cycles; NOT
        the optimization objective — two assignments with equal average
        bits can differ in cycles when their widths sit on layers of very
        different MAC weight)."""
        bits = self._bits_vector(assignment)
        macs = np.asarray([self.macs[n] for n in self.macs], np.float64)
        return float((macs * bits).sum() / macs.sum())
