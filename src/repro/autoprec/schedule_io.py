"""Persist precision schedules: searched assignments as servable artifacts.

A searched mixed-precision result is only useful if it survives the search
process — this module round-trips :class:`~repro.core.policy
.PrecisionSchedule` (tiers, per-tier layer-glob rules, kv_tiers,
default_tier) through JSON, and emits search results as schedules:

* each selected :class:`~repro.autoprec.search.SearchResult` becomes one
  named tier whose per-layer widths are exact-name rules over an 8/8
  default (exact layer names are valid globs, so the schedule contract —
  first matching rule wins — is unchanged);
* the stored 8-bit superplane serves every emitted tier by plane-prefix
  truncation, so a loaded schedule drives ``ServeEngine`` with zero weight
  re-preparations and token-identical to the in-memory original (asserted
  in tests/test_autoprec.py).

File format (``repro.precision_schedule.v1``)::

    {"format": "repro.precision_schedule.v1",
     "schedule": {"default_tier": ..., "tiers": {...}, "rules": {...},
                  "kv_tiers": {...} | null},
     "meta": {...}}        # free-form provenance (e.g. the Pareto table)

``repro.launch.serve --schedule-file`` loads these;
``repro.launch.autoprec`` writes them.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

from repro.autoprec.search import SearchResult
from repro.core.policy import LayerPrecision, PrecisionSchedule

FORMAT = "repro.precision_schedule.v1"


# ---------------------------------------------------------------- dict forms
def precision_to_dict(prec: LayerPrecision) -> Dict[str, Any]:
    """JSON-able form of one LayerPrecision (all five fields, explicit)."""
    return {"w_bits": int(prec.w_bits), "a_bits": int(prec.a_bits),
            "w_signed": bool(prec.w_signed), "a_signed": bool(prec.a_signed),
            "backend": str(prec.backend)}


def precision_from_dict(d: Mapping[str, Any]) -> LayerPrecision:
    try:
        return LayerPrecision(w_bits=int(d["w_bits"]),
                              a_bits=int(d["a_bits"]),
                              w_signed=bool(d["w_signed"]),
                              a_signed=bool(d["a_signed"]),
                              backend=str(d["backend"]))
    except KeyError as e:
        raise ValueError(
            f"malformed LayerPrecision entry {dict(d)!r}: missing field "
            f"{e.args[0]!r}") from e


def schedule_to_dict(schedule: PrecisionSchedule) -> Dict[str, Any]:
    """JSON-able form of a PrecisionSchedule (exact round-trip:
    ``schedule_from_dict(schedule_to_dict(s)) == s``)."""
    return {
        "default_tier": schedule.default_tier,
        "tiers": {t: precision_to_dict(p)
                  for t, p in schedule.tiers.items()},
        "rules": {t: {glob: precision_to_dict(p)
                      for glob, p in by_layer.items()}
                  for t, by_layer in schedule.rules.items()},
        "kv_tiers": None if schedule.kv_tiers is None
        else {t: kb for t, kb in schedule.kv_tiers.items()},
    }


def schedule_from_dict(d: Mapping[str, Any]) -> PrecisionSchedule:
    """Rebuild (and fully re-validate: even bits, serving backends, shared
    signedness) a PrecisionSchedule from its dict form."""
    kv = d.get("kv_tiers")
    if "tiers" not in d:
        raise ValueError(f"malformed schedule dict (no 'tiers'): keys "
                         f"{sorted(d)}")
    return PrecisionSchedule(
        tiers={t: precision_from_dict(p) for t, p in d["tiers"].items()},
        rules={t: {glob: precision_from_dict(p)
                   for glob, p in by_layer.items()}
               for t, by_layer in d.get("rules", {}).items()},
        default_tier=d.get("default_tier"),
        kv_tiers=None if kv is None
        else {t: (None if kb is None else int(kb)) for t, kb in kv.items()})


# --------------------------------------------------------------------- files
def save_schedule(path: str, schedule: PrecisionSchedule,
                  meta: Optional[Mapping[str, Any]] = None) -> None:
    """Write a schedule (+ optional provenance meta) as JSON."""
    doc = {"format": FORMAT, "schedule": schedule_to_dict(schedule),
           "meta": dict(meta) if meta else {}}
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)


def load_schedule_with_meta(
        path: str) -> Tuple[PrecisionSchedule, Dict[str, Any]]:
    """Load a schedule file; returns (schedule, meta).  The schedule is
    re-validated by construction — a file naming odd widths or a dense
    backend fails here, not at serve time."""
    with open(path) as f:
        doc = json.load(f)
    if doc.get("format") != FORMAT:
        raise ValueError(f"{path}: not a {FORMAT} file "
                         f"(format={doc.get('format')!r})")
    return schedule_from_dict(doc["schedule"]), dict(doc.get("meta", {}))


def load_schedule(path: str) -> PrecisionSchedule:
    return load_schedule_with_meta(path)[0]


# ------------------------------------------------------------ search results
def result_to_meta(result: SearchResult) -> Dict[str, Any]:
    """JSON-able provenance record of one search result."""
    return {"assignment": {n: int(b)
                           for n, b in sorted(result.assignment.items())},
            "a_bits": int(result.a_bits),
            "avg_bits": float(result.avg_bits),
            "cycles_per_token": float(result.cycles_per_token),
            "energy_per_token_j": float(result.energy_per_token_j),
            "pred_divergence": float(result.pred_divergence),
            "measured_divergence": result.measured_divergence,
            "strategy": result.strategy}


def schedule_from_results(results: Sequence[SearchResult], *,
                          tier_names: Optional[Sequence[str]] = None,
                          default: int = 0,
                          backend: str = "decomposed",
                          w_signed: bool = True,
                          include_base: bool = True,
                          kv_tiers: Optional[Mapping[str, Optional[int]]]
                          = None) -> PrecisionSchedule:
    """Emit searched results as one servable PrecisionSchedule.

    Each result becomes a tier (named ``tier_names[i]``, default
    ``auto-<avg_bits>b``) whose default precision is 8/``a_bits`` refined
    by one exact-name rule per layer the assignment lowers below 8 bits;
    ``results[default]`` becomes the schedule's default tier.
    ``include_base`` adds a plain uniform-8 ``base`` tier for A/B serving.
    Validation (even truncatable widths, serving backend, shared
    signedness) happens in the PrecisionSchedule constructor."""
    if not results:
        raise ValueError("no search results to emit")
    names = list(tier_names) if tier_names is not None else [
        f"auto-{r.avg_bits:.2f}b" for r in results]
    if len(names) != len(results):
        raise ValueError(f"{len(names)} tier names for "
                         f"{len(results)} results")
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate tier names {names}")
    tiers: Dict[str, LayerPrecision] = {}
    rules: Dict[str, Dict[str, LayerPrecision]] = {}
    for name, r in zip(names, results):
        base = LayerPrecision(w_bits=8, a_bits=r.a_bits, backend=backend,
                              w_signed=w_signed)
        tiers[name] = base
        rules[name] = {
            layer: dataclasses.replace(base, w_bits=int(b))
            for layer, b in r.assignment.items() if int(b) < 8}
    if include_base:
        if "base" in tiers:
            raise ValueError("tier name 'base' is reserved for the uniform "
                             "8-bit reference tier")
        tiers["base"] = LayerPrecision(w_bits=8, a_bits=results[0].a_bits,
                                       backend=backend, w_signed=w_signed)
    return PrecisionSchedule(
        tiers=tiers, rules=rules, default_tier=names[default],
        kv_tiers=None if kv_tiers is None else dict(kv_tiers))
