"""Mixed-precision search: measured sensitivity vs modeled hardware cost.

Two strategies behind one interface, both consuming the same inputs — a
per-``(layer, width)`` divergence table (:mod:`repro.autoprec.sensitivity`,
measured through the real plane-prefix quantization path) and a
:class:`~repro.autoprec.cost.CostModel` (modeled cycles/energy per token) —
and both returning a list of :class:`SearchResult` candidate assignments:

* :func:`greedy_search` — the repaired greedy allocator: start every layer
  at the cheapest width and repeatedly grant the promotion with the best
  **marginal divergence reduction per marginal cycle**, recording the full
  trajectory (cheapest -> richest).  With a ``budget`` it reproduces the
  classic average-bit-constrained allocation
  (``core.policy.allocate_bits_by_sensitivity`` is a thin wrapper).
* :func:`relaxed_search` — a plinio-MixPrec-style differentiable
  relaxation: per-layer softmax distributions over the width choices,
  loss = expected divergence + lambda * expected modeled cycles, annealed
  to a discrete assignment by gradient descent with a falling temperature;
  one Pareto point per lambda.

:func:`pareto_front` prunes any candidate set to its non-dominated
(cycles, divergence) subset — the deliverable a
:class:`~repro.core.policy.PrecisionSchedule` is then emitted from
(:mod:`repro.autoprec.schedule_io`).

The divergence objective both strategies optimize is the **additive
surrogate** ``sum_l sens[l][bits_l]`` (each layer's measured one-at-a-time
divergence).  Candidate points worth serving should be re-measured jointly
(``sensitivity.measure_divergence``) — the CLI does, and stores the
measured value back on the result.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.autoprec.cost import Assignment, CostModel

# layer -> width -> measured output divergence of perturbing ONLY that
# layer to that width (the baseline width, 8, is implicitly 0.0).
SensTable = Mapping[str, Mapping[int, float]]

# Widths reachable by runtime plane-prefix truncation (the serving
# contract a PrecisionSchedule validates).
EVEN_CHOICES = (2, 4, 6, 8)
MAX_BITS = 8


@dataclasses.dataclass
class SearchResult:
    """One searched operating point: a full per-layer width assignment plus
    its prices.  ``pred_divergence`` is the additive surrogate from the
    sensitivity table; ``measured_divergence`` is filled in when the point
    is re-measured jointly through the real quantization path."""

    assignment: Dict[str, int]
    a_bits: int
    avg_bits: float                 # MAC-weighted mean weight width
    cycles_per_token: float
    energy_per_token_j: float
    pred_divergence: float
    strategy: str
    measured_divergence: Optional[float] = None

    @property
    def divergence(self) -> float:
        """Measured divergence when available, surrogate otherwise."""
        return self.pred_divergence if self.measured_divergence is None \
            else self.measured_divergence


def sens_at(sens: SensTable, layer: str, bits: int) -> float:
    """Divergence of one layer at one width (0.0 at the 8-bit baseline or
    for layers the profile left unperturbed)."""
    if bits >= MAX_BITS:
        return 0.0
    table = sens.get(layer)
    if table is None:
        return 0.0
    return float(table[bits])


def predicted_divergence(sens: SensTable, assignment: Assignment) -> float:
    """Additive surrogate: sum of each layer's one-at-a-time divergence."""
    return float(sum(sens_at(sens, n, b) for n, b in assignment.items()))


def _validate_choices(choices: Sequence[int]) -> Tuple[int, ...]:
    ch = tuple(sorted(set(int(c) for c in choices)))
    if not ch:
        raise ValueError("need at least one width choice")
    bad = [c for c in ch if not 2 <= c <= MAX_BITS]
    if bad:
        raise ValueError(f"width choices must lie in 2..{MAX_BITS}, got {bad}")
    return ch


def make_result(assignment: Assignment, sens: SensTable, cost: CostModel,
                strategy: str) -> SearchResult:
    """Price one assignment into a :class:`SearchResult`."""
    a = {n: int(b) for n, b in assignment.items()}
    return SearchResult(
        assignment=a, a_bits=cost.a_bits,
        avg_bits=cost.average_bits(a),
        cycles_per_token=cost.cycles_per_token(a),
        energy_per_token_j=cost.energy_per_token_j(a),
        pred_divergence=predicted_divergence(sens, a),
        strategy=strategy)


# ------------------------------------------------------------------- greedy
def greedy_trajectory(layers: Sequence[str], sens: SensTable,
                      layer_cost: Mapping[str, Mapping[int, float]],
                      choices: Sequence[int], *,
                      budget: Optional[float] = None
                      ) -> List[Dict[str, int]]:
    """Greedy promotion core shared by :func:`greedy_search` and the
    classic budgeted allocator.

    Start every layer at ``min(choices)``; repeatedly promote the layer
    with the highest marginal gain rate — divergence removed per unit of
    ``layer_cost`` added — one choice step at a time, recording every
    intermediate assignment.  ``layer_cost[n][b]`` is the cost of serving
    layer ``n`` at width ``b`` (cycles for the hardware search, ``b *
    param_count`` for the average-bit wrapper).  A ``budget`` caps the
    TOTAL cost: a promotion that would exceed it permanently retires that
    layer (other layers keep promoting), reproducing the historical
    budgeted-allocator semantics.  Returns the trajectory
    cheapest -> richest (first entry: all layers at ``min(choices)``)."""
    ch = _validate_choices(choices)
    nxt = {b: ch[i + 1] for i, b in enumerate(ch[:-1])}
    bits: Dict[str, int] = {n: ch[0] for n in layers}
    total = sum(layer_cost[n][ch[0]] for n in layers)
    points = [dict(bits)]

    def rate(n: str, b_from: int, b_to: int) -> float:
        gain = sens_at(sens, n, b_from) - sens_at(sens, n, b_to)
        dc = layer_cost[n][b_to] - layer_cost[n][b_from]
        return gain / max(dc, 1e-30)

    # Heap entries are (negated rate, layer, from, to); an entry whose
    # `from` no longer matches the layer's current width is stale and
    # skipped (lazy invalidation keeps the loop O(L * |choices| * log L)).
    heap: List[Tuple[float, str, int, int]] = [
        (-rate(n, ch[0], nxt[ch[0]]), n, ch[0], nxt[ch[0]])
        for n in layers if ch[0] in nxt]
    heapq.heapify(heap)
    while heap:
        _, n, b_from, b_to = heapq.heappop(heap)
        if bits[n] != b_from:
            continue                      # stale entry
        dc = layer_cost[n][b_to] - layer_cost[n][b_from]
        if budget is not None and total + dc > budget:
            continue                      # retire this layer: over budget
        bits[n] = b_to
        total += dc
        points.append(dict(bits))
        if b_to in nxt:
            heapq.heappush(heap, (-rate(n, b_to, nxt[b_to]), n, b_to,
                                  nxt[b_to]))
    return points


def greedy_search(sens: SensTable, cost: CostModel, *,
                  choices: Sequence[int] = EVEN_CHOICES
                  ) -> List[SearchResult]:
    """Greedy marginal-divergence-per-marginal-cycle allocator.

    Every promotion step of :func:`greedy_trajectory` (cost =
    ``CostModel.layer_cycles``, no budget) becomes a candidate point, so
    the result sweeps the whole cycles axis from all-``min(choices)`` to
    all-``max(choices)``; run :func:`pareto_front` to prune."""
    ch = _validate_choices(choices)
    layer_cost = {n: {b: cost.layer_cycles(n, b) for b in ch}
                  for n in cost.layers}
    traj = greedy_trajectory(cost.layers, sens, layer_cost, ch)
    return [make_result(a, sens, cost, "greedy") for a in traj]


# ------------------------------------------------- differentiable relaxation
def default_lambdas(sens: SensTable, cost: CostModel, *,
                    choices: Sequence[int] = EVEN_CHOICES,
                    n: int = 9) -> List[float]:
    """Log-spaced lambda sweep centered where the two loss terms balance:
    lambda_mid = (total divergence span) / (total cycle span) over the
    per-layer choice ranges."""
    ch = _validate_choices(choices)
    s_span = sum(max(sens_at(sens, l, b) for b in ch)
                 - min(sens_at(sens, l, b) for b in ch)
                 for l in cost.layers)
    c_span = sum(max(cost.layer_cycles(l, b) for b in ch)
                 - min(cost.layer_cycles(l, b) for b in ch)
                 for l in cost.layers)
    mid = (s_span / c_span) if (s_span > 0 and c_span > 0) else 1.0
    return [float(mid * 10.0 ** e) for e in np.linspace(-2.0, 2.0, n)]


def relaxed_search(sens: SensTable, cost: CostModel, *,
                   choices: Sequence[int] = EVEN_CHOICES,
                   lambdas: Optional[Sequence[float]] = None,
                   steps: int = 200, lr: float = 0.25,
                   temp_start: float = 1.0, temp_end: float = 0.05
                   ) -> List[SearchResult]:
    """plinio-MixPrec-style differentiable precision assignment.

    Each layer holds architecture logits ``alpha[l, k]`` over the width
    choices; the relaxed loss under softmax weights ``p = softmax(alpha /
    temp)`` is ``sum(p * sens) + lambda * sum(p * cycles)``.  Gradient
    descent (Adam) with a geometrically falling temperature anneals every
    layer's distribution toward a vertex; the final assignment is the
    per-layer argmax.  Because the surrogate divergence is additive, the
    converged vertex is verifiable: it must match the per-layer argmin of
    ``sens + lambda * cycles`` (asserted in tests) — the machinery's value
    is that the SAME loss keeps working when the divergence term is a
    jointly measured (non-separable) model, which is the documented
    extension path.  One Pareto candidate per lambda (deduplicated)."""
    ch = _validate_choices(choices)
    layers = cost.layers
    sens_mat = jnp.asarray([[sens_at(sens, l, b) for b in ch]
                            for l in layers], jnp.float32)
    cyc_mat = jnp.asarray([[cost.layer_cycles(l, b) for b in ch]
                           for l in layers], jnp.float32)
    if lambdas is None:
        lambdas = default_lambdas(sens, cost, choices=ch)
    temps = jnp.asarray(
        np.geomspace(temp_start, temp_end, max(2, steps)), jnp.float32)

    def loss(alpha: jax.Array, lam: jax.Array, temp: jax.Array) -> jax.Array:
        p = jax.nn.softmax(alpha / temp, axis=-1)
        return jnp.sum(p * (sens_mat + lam * cyc_mat))

    grad = jax.grad(loss)

    @jax.jit
    def anneal(lam: jax.Array) -> jax.Array:
        """Adam descent over the annealing temperature schedule."""
        b1, b2, eps = 0.9, 0.999, 1e-8
        alpha0 = jnp.zeros_like(sens_mat)

        def step(carry: Tuple[jax.Array, jax.Array, jax.Array, jax.Array],
                 temp: jax.Array
                 ) -> Tuple[Tuple[jax.Array, jax.Array, jax.Array,
                                  jax.Array], None]:
            alpha, m, v, t = carry
            g = grad(alpha, lam, temp)
            t = t + 1.0
            m = b1 * m + (1.0 - b1) * g
            v = b2 * v + (1.0 - b2) * g * g
            mh = m / (1.0 - b1 ** t)
            vh = v / (1.0 - b2 ** t)
            alpha = alpha - lr * mh / (jnp.sqrt(vh) + eps)
            return (alpha, m, v, t), None

        init = (alpha0, jnp.zeros_like(alpha0), jnp.zeros_like(alpha0),
                jnp.zeros((), jnp.float32))
        (alpha, _, _, _), _ = jax.lax.scan(step, init, temps)
        return alpha

    results: List[SearchResult] = []
    seen: set[Tuple[Tuple[str, int], ...]] = set()
    for lam in lambdas:
        alpha = anneal(jnp.float32(lam))
        idx = np.asarray(jnp.argmax(alpha, axis=-1))
        assignment = {l: ch[int(k)] for l, k in zip(layers, idx)}
        key = tuple(sorted(assignment.items()))
        if key in seen:
            continue
        seen.add(key)
        results.append(make_result(assignment, sens, cost, "relaxed"))
    return results


# -------------------------------------------------------------------- front
def pareto_front(results: Sequence[SearchResult]) -> List[SearchResult]:
    """Non-dominated subset in the (cycles_per_token, divergence) plane,
    sorted cheapest first.  Uses each result's ``divergence`` property
    (measured when available, surrogate otherwise); exact ties keep the
    first (stable) candidate."""
    ordered = sorted(results, key=lambda r: (r.cycles_per_token,
                                             r.divergence))
    front: List[SearchResult] = []
    best = float("inf")
    for r in ordered:
        if r.divergence < best:
            front.append(r)
            best = r.divergence
    return front


def search(sens: SensTable, cost: CostModel, *,
           choices: Sequence[int] = EVEN_CHOICES,
           strategy: str = "both",
           lambdas: Optional[Sequence[float]] = None
           ) -> List[SearchResult]:
    """Run the requested strategies and return the merged Pareto front."""
    if strategy not in ("greedy", "relaxed", "both"):
        raise ValueError(f"unknown strategy {strategy!r}")
    candidates: List[SearchResult] = []
    if strategy in ("greedy", "both"):
        candidates.extend(greedy_search(sens, cost, choices=choices))
    if strategy in ("relaxed", "both"):
        candidates.extend(relaxed_search(sens, cost, choices=choices,
                                         lambdas=lambdas))
    return pareto_front(candidates)
