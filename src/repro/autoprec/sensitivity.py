"""Per-layer sensitivity profiling through the REAL quantization path.

Sensitivity here is not a gradient proxy: each probe runs the actual
serving computation — the 8-bit MSB-first superplane store with ONE layer's
weights read at a truncated plane prefix (``nested_quantize`` /
plane-prefix truncation, exactly what a tier rule does at decode time) —
and records the output divergence on calibration batches:

* ``kl``  — mean KL(base || perturbed) of the next-token distributions
  (the task-relevant signal for generation);
* ``mse`` — mean squared logit error (scale-free sanity companion).

Perturbing a layer to 8 bits IS the baseline (truncation to the stored
width is the identity), so those entries are exactly 0.0 by construction —
an anchor the tests assert.

Two execution shapes, identical numbers:

* **sequential** — one jitted full forward per perturbation tier (the
  tier name is jit-static, like the serving engine's dispatch);
* **batched one-pass** (default) — all perturbations of a *block* ride in
  ONE jitted forward as a mixed-tier row-group batch
  (``Runtime.for_groups``): the calibration batch is tiled once per
  probe tier plus a baseline group, and every projection runs one
  plane-prefix GEMM per group.  The mixed-batch bit-stability contract
  (PR 3: every row is bit-identical to tier-homogeneous execution) is
  what makes the two shapes agree; profiling L layers at K widths costs
  ``ceil(L*K/block)`` compiles instead of ``L*K``.

The batched shape needs the slot-batch axis to lead every projection,
which the MoE per-expert dispatch breaks outside the decode path — MoE
configs fall back to sequential automatically.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
import numpy.typing as npt

from repro.autoprec.cost import Assignment
from repro.core.policy import LayerPrecision, PrecisionSchedule
from repro.kernels import ops
from repro.models.layers import Runtime
from repro.serve.engine import prepare_params

BASE_TIER = "base"
MAX_BITS = 8
METRICS = ("kl", "mse")


@dataclasses.dataclass
class SensitivityProfile:
    """Measured per-(layer, width) output divergences.

    ``kl[layer][bits]`` / ``mse[layer][bits]`` hold the divergence of
    perturbing ONLY that layer to that width; ``table`` selects the
    profile's primary ``metric`` — the :mod:`repro.autoprec.search` input."""

    a_bits: int
    choices: Tuple[int, ...]
    metric: str
    kl: Dict[str, Dict[int, float]]
    mse: Dict[str, Dict[int, float]]

    @property
    def layers(self) -> Tuple[str, ...]:
        return tuple(self.kl)

    @property
    def table(self) -> Dict[str, Dict[int, float]]:
        return self.kl if self.metric == "kl" else self.mse


def random_calibration(cfg: Any, *, batches: int = 2, batch: int = 2,
                       seq: int = 16, seed: int = 0
                       ) -> npt.NDArray[np.int32]:
    """Uniform-random token calibration set ``[batches, batch, seq]`` (the
    same distribution the serving drivers exercise models with)."""
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, size=(batches, batch, seq))
    return toks.astype(np.int32)


def _params_prepared(params: Any) -> bool:
    return any(isinstance(l, ops.QuantizedWeight) for l in jax.tree.leaves(
        params, is_leaf=lambda x: isinstance(x, ops.QuantizedWeight)))


def _probe_schedule(rules_by_tier: Mapping[str, Mapping[str, int]], *,
                    a_bits: int, backend: str,
                    w_signed: bool = True) -> PrecisionSchedule:
    """One schedule holding the 8-bit baseline tier plus one tier per
    probe, each probe refining its layers by per-layer width rules — the
    same (validated) object a searched result is later emitted as."""
    base = LayerPrecision(w_bits=MAX_BITS, a_bits=a_bits, backend=backend,
                          w_signed=w_signed)
    tiers = {BASE_TIER: base}
    rules: Dict[str, Dict[str, LayerPrecision]] = {}
    for tier, layer_bits in rules_by_tier.items():
        if tier == BASE_TIER:
            raise ValueError(f"probe tier name {BASE_TIER!r} is reserved")
        tiers[tier] = base
        rules[tier] = {
            name: dataclasses.replace(base, w_bits=int(b))
            for name, b in layer_bits.items() if int(b) < MAX_BITS}
    return PrecisionSchedule(tiers=tiers, rules=rules,
                             default_tier=BASE_TIER)


def _kl_mse(base_logits: jax.Array,
            pert_logits: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Mean token-distribution KL(base || pert) and mean squared logit
    error, in f32."""
    bf = base_logits.astype(jnp.float32)
    pf = pert_logits.astype(jnp.float32)
    lb = jax.nn.log_softmax(bf, axis=-1)
    lp = jax.nn.log_softmax(pf, axis=-1)
    kl = jnp.sum(jnp.exp(lb) * (lb - lp), axis=-1).mean()
    mse = jnp.mean((bf - pf) ** 2)
    return kl, mse


def measure_tiers(model: Any, params: Any,
                  rules_by_tier: Mapping[str, Mapping[str, int]], *,
                  calib: npt.NDArray[np.int32], a_bits: int = 8,
                  backend: str = "decomposed", batched: Optional[bool] = None,
                  block: int = 8) -> Dict[str, Tuple[float, float]]:
    """Measure every probe tier's (kl, mse) divergence vs the 8-bit
    baseline, averaged over the calibration batches.

    ``rules_by_tier`` maps a probe name to the per-layer widths it
    perturbs; ``params`` may be raw floats (prepared into the superplane
    store here, once) or an already-prepared superplane pytree (shared
    with a serving engine — zero extra preparations).  ``batched=None``
    auto-selects the one-pass shape except for MoE configs."""
    calib = np.asarray(calib, np.int32)
    if calib.ndim != 3:
        raise ValueError(f"calib must be [batches, batch, seq], "
                         f"got shape {calib.shape}")
    if batched is None:
        batched = not bool(model.cfg.moe)
    schedule = _probe_schedule(rules_by_tier, a_bits=a_bits, backend=backend)
    rt = Runtime(policy=schedule.policy_for(BASE_TIER), mode="serve",
                 moe_dropless=True, schedule=schedule)
    if not _params_prepared(params):
        params, _ = prepare_params(params, schedule.prepare_policy(), model,
                                   superplane=True)
    tiers = [t for t in rules_by_tier]
    n_batches, batch, _ = calib.shape
    acc = {t: np.zeros((2,), np.float64) for t in tiers}

    if batched:
        def block_fn(blk: Tuple[str, ...]) -> Any:
            groups = ((BASE_TIER, batch),) + tuple((t, batch) for t in blk)
            perm = jnp.arange((len(blk) + 1) * batch, dtype=jnp.int32)
            rt_g = rt.for_groups(groups, perm)

            def run(p: Any, toks: jax.Array) -> Tuple[jax.Array, jax.Array]:
                tiled = jnp.tile(toks, (len(blk) + 1, 1))
                logits, _ = model.forward(p, rt_g, tokens=tiled)
                base = logits[:batch]
                kls: List[jax.Array] = []
                mses: List[jax.Array] = []
                for j in range(len(blk)):
                    pert = logits[(j + 1) * batch:(j + 2) * batch]
                    kl, mse = _kl_mse(base, pert)
                    kls.append(kl)
                    mses.append(mse)
                return jnp.stack(kls), jnp.stack(mses)

            return jax.jit(run)

        for start in range(0, len(tiers), max(1, block)):
            blk = tuple(tiers[start:start + max(1, block)])
            run = block_fn(blk)
            for b in range(n_batches):
                kls, mses = run(params, jnp.asarray(calib[b]))
                kls_np = np.asarray(kls, np.float64)
                mses_np = np.asarray(mses, np.float64)
                for j, t in enumerate(blk):
                    acc[t] += [kls_np[j], mses_np[j]]
    else:
        fwd = jax.jit(
            lambda p, toks, tier: model.forward(p, rt.for_tier(tier),
                                                tokens=toks)[0],
            static_argnames=("tier",))
        div = jax.jit(_kl_mse)
        base_logits = [fwd(params, jnp.asarray(calib[b]), tier=BASE_TIER)
                       for b in range(n_batches)]
        for t in tiers:
            for b in range(n_batches):
                pert = fwd(params, jnp.asarray(calib[b]), tier=t)
                kl, mse = div(base_logits[b], pert)
                acc[t] += [float(kl), float(mse)]

    return {t: (float(acc[t][0] / n_batches), float(acc[t][1] / n_batches))
            for t in tiers}


def profile_sensitivity(model: Any, params: Any, *,
                        calib: npt.NDArray[np.int32],
                        choices: Sequence[int] = (2, 4, 6),
                        a_bits: int = 8, metric: str = "kl",
                        backend: str = "decomposed",
                        layers: Optional[Sequence[str]] = None,
                        batched: Optional[bool] = None,
                        block: int = 8) -> SensitivityProfile:
    """Profile every quantizable layer's divergence at every width in
    ``choices`` (see module docstring for the measurement semantics).

    ``layers`` restricts profiling to a subset (names from
    ``ArchConfig.quant_layer_macs``); widths >= 8 are recorded as exactly
    0.0 without running (truncation identity)."""
    if metric not in METRICS:
        raise ValueError(f"metric must be one of {METRICS}, got {metric!r}")
    all_names = list(model.cfg.quant_layer_macs())
    if layers is None:
        names = all_names
    else:
        unknown = [n for n in layers if n not in all_names]
        if unknown:
            raise KeyError(f"unknown layers {unknown}; "
                           f"model has {all_names}")
        names = [n for n in all_names if n in set(layers)]
    ch = tuple(sorted(set(int(c) for c in choices)))
    probe_bits = [b for b in ch if b < MAX_BITS]
    rules_by_tier = {f"{n}@{b}": {n: b} for n in names for b in probe_bits}
    res = measure_tiers(model, params, rules_by_tier, calib=calib,
                        a_bits=a_bits, backend=backend, batched=batched,
                        block=block)
    kl: Dict[str, Dict[int, float]] = {n: {} for n in names}
    mse: Dict[str, Dict[int, float]] = {n: {} for n in names}
    for n in names:
        for b in ch:
            if b >= MAX_BITS:
                kl[n][b], mse[n][b] = 0.0, 0.0
            else:
                kl[n][b], mse[n][b] = res[f"{n}@{b}"]
    return SensitivityProfile(a_bits=a_bits, choices=ch, metric=metric,
                              kl=kl, mse=mse)


def measure_divergence(model: Any, params: Any,
                       assignments: Mapping[str, Assignment], *,
                       calib: npt.NDArray[np.int32], a_bits: int = 8,
                       metric: str = "kl", backend: str = "decomposed",
                       batched: Optional[bool] = None,
                       block: int = 4) -> Dict[str, float]:
    """JOINT divergence of full per-layer assignments (all layers perturbed
    together) vs the 8-bit baseline — what the additive search surrogate is
    validated against before a point is emitted as a servable schedule."""
    if metric not in METRICS:
        raise ValueError(f"metric must be one of {METRICS}, got {metric!r}")
    rules_by_tier = {name: {l: int(b) for l, b in a.items()}
                     for name, a in assignments.items()}
    res = measure_tiers(model, params, rules_by_tier, calib=calib,
                        a_bits=a_bits, backend=backend, batched=batched,
                        block=block)
    idx = METRICS.index(metric)
    return {name: res[name][idx] for name in assignments}
