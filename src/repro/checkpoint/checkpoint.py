"""Fault-tolerant checkpointing: atomic, manifest-versioned, async-capable,
elastic (mesh-shape-agnostic restore).

Layout:   <dir>/step_<N>/manifest.json + leaf_<i>.npy   (one file per leaf)
Atomicity: written to ``step_<N>.tmp`` then os.replace()'d — a crash mid-save
leaves only a .tmp dir that restore ignores (tested by the preemption test).
Elasticity: leaves are saved as *global* (unsharded) arrays; restore places
them onto any target sharding, so the mesh may change between runs.  At real
1000-node scale the same layout shards per-host (each host saves its addressable
slice; the manifest records the offsets) — single-process here, global arrays.

Beyond train-loop checkpoints, the serving engine spills preempted-slot
snapshots through this module (``ServeEngine(spill_dir=...)``): one step dir
per suspended request, written by an ``AsyncCheckpointer(keep=0)`` (GC off —
live spills must never be collected) and deleted via :func:`remove` as each
request resumes.
"""
from __future__ import annotations

import dataclasses  # noqa: F401  (re-exported convenience for callers)
import json
import os
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np
import numpy.typing as npt


def _flatten(tree: Any) -> Tuple[List[Any], List[str], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = [jax.tree_util.keystr(kp) for kp, _ in
             jax.tree_util.tree_flatten_with_path(tree)[0]]
    return leaves, paths, treedef


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:08d}")


def save(directory: str, step: int, tree: Any,
         extra: Optional[Dict[str, Any]] = None) -> str:
    """Synchronous atomic save.  Returns the final checkpoint path."""
    leaves, paths, _ = _flatten(tree)
    final = _step_dir(directory, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest: Dict[str, Any] = {"step": step, "leaves": [],
                                "extra": extra or {}}
    for i, (leaf, path) in enumerate(zip(leaves, paths)):
        arr = np.asarray(jax.device_get(leaf))
        orig_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or orig_dtype == "bfloat16":
            # numpy cannot serialize bfloat16 natively; store as f32 and
            # record the original dtype (restore casts back to the target).
            arr = arr.astype(np.float32)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({
            "path": path, "file": fname,
            "shape": list(arr.shape), "dtype": orig_dtype})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


class AsyncCheckpointer:
    """Snapshot on the caller thread (device_get), write on a worker thread —
    training continues while the previous checkpoint hits disk.

    ``keep=0`` disables retention GC entirely (every step dir stays until
    explicitly :func:`remove`'d) — the mode the serving engine's preemption
    spills rely on."""

    def __init__(self, directory: str, keep: int = 3) -> None:
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any,
             extra: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work() -> None:
            save(self.directory, step, snapshot, extra)
            gc_old(self.directory, keep=self.keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()


def list_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, "manifest.json")):
                steps.append(int(d.split("_")[1]))
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def gc_old(directory: str, keep: int = 3) -> None:
    steps = list_steps(directory)
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(_step_dir(directory, s), ignore_errors=True)


def remove(directory: str, step: int) -> None:
    """Delete one step dir (and any stale .tmp twin).  Idempotent — a
    missing step is not an error, so resume/cancel cleanup paths need no
    existence dance."""
    shutil.rmtree(_step_dir(directory, step), ignore_errors=True)
    shutil.rmtree(_step_dir(directory, step) + ".tmp", ignore_errors=True)


def restore(directory: str, step: int, target: Any,
            sharding_fn: Optional[
                Callable[[str, npt.NDArray[Any]], Any]] = None
            ) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``target`` (values replaced).

    ``target`` only contributes leaf shapes/dtypes — ``jax.eval_shape``
    skeletons work.  sharding_fn(path, array) -> jax.sharding.Sharding |
    None lets the caller re-shard elastically onto the *current* mesh."""
    path = _step_dir(directory, step)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, paths, treedef = _flatten(target)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    new_leaves = []
    for leaf, p in zip(leaves, paths):
        entry = by_path.get(p)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {p}")
        arr = np.load(os.path.join(path, entry["file"]))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {p}: ckpt {arr.shape} "
                             f"vs target {leaf.shape}")
        sh = sharding_fn(p, arr) if sharding_fn else None
        new_leaves.append(jax.device_put(arr.astype(leaf.dtype), sh)
                          if sh is not None else
                          jax.numpy.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), manifest["extra"]
