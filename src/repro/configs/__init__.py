"""Architecture registry: ``--arch <id>`` selectable configs.

Ten assigned architectures + the paper's own workload (MobileNetV2-style
conv net, handled by ``repro.models.convnet``).
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ArchConfig

from repro.configs.qwen3_8b import CONFIG as _qwen3
from repro.configs.stablelm_12b import CONFIG as _stablelm
from repro.configs.granite_3_8b import CONFIG as _granite
from repro.configs.starcoder2_7b import CONFIG as _starcoder2
from repro.configs.jamba_1_5_large import CONFIG as _jamba
from repro.configs.llama4_scout import CONFIG as _llama4
from repro.configs.grok_1 import CONFIG as _grok
from repro.configs.mamba2_1_3b import CONFIG as _mamba2
from repro.configs.pixtral_12b import CONFIG as _pixtral
from repro.configs.musicgen_large import CONFIG as _musicgen

ARCHS: dict[str, ArchConfig] = {
    c.name: c for c in (
        _qwen3, _stablelm, _granite, _starcoder2, _jamba,
        _llama4, _grok, _mamba2, _pixtral, _musicgen,
    )
}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def reduced_config(name: str) -> ArchConfig:
    """Small same-family config for CPU smoke tests: few layers/width, tiny
    vocab, few experts — one forward/train step must run on one CPU."""
    cfg = get_config(name)
    period = len(cfg.period_pattern())
    n_layers = period * (1 if period > 1 else 2)
    updates = dict(
        name=cfg.name + "-smoke",
        num_layers=n_layers,
        d_model=64,
        vocab_size=512,
        rope_theta=1e4,
    )
    if cfg.num_heads:
        updates.update(num_heads=4, num_kv_heads=min(4, max(1, cfg.num_kv_heads // 8)),
                       head_dim=16)
        if cfg.num_kv_heads == cfg.num_heads:   # MHA archs stay MHA
            updates.update(num_kv_heads=4)
    if cfg.d_ff:
        updates.update(d_ff=128)
    if cfg.moe:
        updates.update(num_experts=4,
                       experts_per_token=min(2, cfg.experts_per_token))
    if cfg.ssm:
        updates.update(ssm_state=16, ssm_headdim=16, ssm_chunk=8)
    return dataclasses.replace(cfg, **updates)
