"""grok-1-314b [moe] — hf:xai-org/grok-1. 8 experts top-2."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b", family="moe",
    num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
    head_dim=128, d_ff=32768, vocab_size=131072,
    moe=True, num_experts=8, experts_per_token=2, moe_every=1,
)
