"""jamba-1.5-large-398b [hybrid] — arXiv:2403.19887.
Mamba+attention 1:7 interleave (1 attn per 8-layer period), MoE 16e top-2
on every other layer."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=24576, vocab_size=65536,
    moe=True, num_experts=16, experts_per_token=2, moe_every=2,
    ssm=True, attn_every=8, ssm_state=128, ssm_headdim=64, ssm_expand=2,
    ssm_chunk=128,
)
