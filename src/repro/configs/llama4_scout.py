"""llama4-scout-17b-a16e [moe] — hf:meta-llama/Llama-4-Scout-17B-16E.
MoE 16e top-1 with shared expert, early fusion (frontend stubbed per spec)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    head_dim=128, d_ff=8192, vocab_size=202048,
    moe=True, num_experts=16, experts_per_token=1, moe_every=1,
    shared_expert=True,
)
