"""mamba2-1.3b [ssm] — arXiv:2405.21060. SSD (state-space duality),
attention-free, ssm_state=128."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=0, num_kv_heads=0,
    head_dim=0, d_ff=0, vocab_size=50280,
    ssm=True, ssm_state=128, ssm_headdim=64, ssm_expand=2,
)
