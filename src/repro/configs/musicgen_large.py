"""musicgen-large [audio] — arXiv:2306.05284. Decoder-only over EnCodec
tokens (kv=32 = MHA); the EnCodec frontend is STUBBED: input_specs provide
precomputed frame embeddings [B, S, d_model]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
    head_dim=64, d_ff=8192, vocab_size=2048,
    frontend="audio",
)
