"""pixtral-12b [vlm] — hf:mistralai/Pixtral-12B-2409.
Mistral-Nemo backbone (head_dim 128); pixtral-ViT frontend STUBBED: the
input_specs provide precomputed patch embeddings [B, S, d_model]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=131072,
    frontend="vision",
)
