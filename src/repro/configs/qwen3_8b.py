"""qwen3-8b [dense] — hf:Qwen/Qwen3-8B. qk_norm, GQA kv=8."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-8b", family="dense",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=12288, vocab_size=151936, qk_norm=True,
    rope_theta=1e6,
)
