"""Core: the paper's contribution — flexible 2..8-bit precision scaling via
efficient weight combination (Table-I decomposition, bit-serial MAC, CSA tree,
PE-array functional model, mixed-precision policy)."""
from repro.core.decompose import (  # noqa: F401
    DECOMP_SCHEDULE,
    RUNTIME_W_BITS,
    SUPERPLANE_BITS,
    SUPPORTED_BITS,
    decompose_superplanes,
    decompose_weights,
    decomposed_matmul,
    num_planes,
    num_prefix_planes,
    plane_shifts,
    prefix_shifts,
    recompose_superplane_prefix,
    recompose_weights,
    superplane_prefix,
    weight_range,
)
from repro.core.quant import (  # noqa: F401
    MAX_BITS,
    QuantConfig,
    compute_scale,
    dequantize,
    fake_quant,
    int_matmul_dequant,
    nested_quantize,
    nested_scale,
    quantize,
    truncate_qint,
)
from repro.core.bitserial import activation_bitplanes, bitserial_mac  # noqa: F401
from repro.core.adder_tree import csa_tree_sum, msb_path_activity  # noqa: F401
from repro.core.pe_array import (  # noqa: F401
    PEArrayConfig,
    PEArrayStats,
    array_utilization,
    pe_array_matmul,
    peak_tops,
)
from repro.core.policy import (  # noqa: F401
    BACKENDS,
    LayerPrecision,
    PrecisionPolicy,
    PrecisionSchedule,
    allocate_bits_by_sensitivity,
    uniform_policy,
    uniform_schedule,
)
