"""Split-path CSA adder tree — functional model (paper §III-C, Fig. 6).

The column adder tree must sum 64 3-bit signed products.  A carry-save tree
cannot sign-extend mid-reduction the way a binary adder tree (BAT) can, so the
paper splits the sum into two independent paths:

  * MSB path: the top bit of each 3-bit signed product has weight -2^2 = -4.
    The tree simply counts the set MSBs (a popcount), and the count is negated
    ("the result should be inverse") before the merge.
  * Low path: the bottom 2 bits are unsigned in [0,3]; an unsigned CSA tree
    sums them.  The lowest 2 result bits pass straight through; the upper bits
    merge with the MSB-path result.

When a column holds unsigned weights every MSB input is 0, the MSB path is
quiet, and no invalid carries toggle — that is the power win of Table II.

This module is the *functional contract* (bit-exact); gate/energy costs live
in ``repro.hwmodel``.
"""
from __future__ import annotations

import jax.numpy as jnp


def split_products(products):
    """Split 3-bit signed products (in [-4, 3]) into (msb_bits, low2)."""
    p = jnp.asarray(products).astype(jnp.int32)
    u = p & 0b111                       # 3-bit two's-complement pattern
    msb = (u >> 2) & 1                  # weight -4
    low2 = u & 0b11                     # unsigned [0, 3]
    return msb, low2


def csa_tree_sum(products, axis: int = -1):
    """Sum 3-bit signed products via the split MSB / low-2-bit paths.

    Bit-exact with ``jnp.sum(products, axis)`` for inputs in [-4, 3].
    """
    msb, low2 = split_products(products)
    msb_count = jnp.sum(msb, axis=axis)        # popcount of sign bits
    low_sum = jnp.sum(low2, axis=axis)         # unsigned CSA path
    # Merge: low 2 bits of low_sum pass through; upper bits add to the
    # (negated) MSB count.  Algebraically: low_sum - 4*msb_count.
    low_pass = low_sum & 0b11
    high = (low_sum >> 2) - msb_count          # "inverse" of the popcount
    return (high << 2) + low_pass


def msb_path_activity(products, axis: int = -1):
    """Fraction of nonzero MSB-path inputs — drives the unsigned-power saving
    in the hwmodel (all-zero for unsigned columns)."""
    msb, _ = split_products(products)
    return jnp.mean(msb.astype(jnp.float32), axis=axis)
