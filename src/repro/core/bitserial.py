"""Bit-serial MAC semantics — a literal, bit-exact model of paper Eq. (1).

    MAC = sum_c ( sum_t sum_r  A^r[t] * W_dcp^r[c] * (-1)^{SF} * 2^t ) * 2^{2c}

Activations stream LSB-first, one bit per cycle `t`; `SF` marks the sign-bit
cycle of a signed activation (two's complement: the MSB has weight -2^{N-1},
realized in hardware by inverting the adder-tree output and adding one).
Decomposed weight planes `c` are combined spatially with shifts 2^{2c}
(the 4-column group's shift-add in Fig. 5).

These functions are the semantic ground truth for everything above them:
the PE-array simulator, the pure-jnp kernel oracle, and the Pallas kernel
are all property-tested against plain integer matmul through this module.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import decompose


def activation_bitplanes(a_int, a_bits: int, *, signed: bool = True):
    """Split integer activations into LSB-first bit-planes.

    Returns (bits, weights): ``bits`` is uint8 {0,1} of shape (N, *a.shape);
    ``weights`` is an int32 vector of per-plane arithmetic weights, where the
    sign-bit plane of a signed activation carries -2^{N-1} (Eq. (1)'s
    (-1)^{SF} factor folded in).
    """
    u = jnp.asarray(a_int).astype(jnp.int32) & ((1 << a_bits) - 1)
    bits = jnp.stack([(u >> t) & 1 for t in range(a_bits)]).astype(jnp.int8)
    weights = []
    for t in range(a_bits):
        w = 1 << t
        if signed and t == a_bits - 1:
            w = -w  # SF cycle: adder-tree output is negated before accumulation
        weights.append(w)
    return bits, jnp.asarray(weights, jnp.int32)


def bitserial_mac(a_int, w_int, a_bits: int, w_bits: int, *,
                  a_signed: bool = True, w_signed: bool = True):
    """Eq. (1) evaluated literally: bit-serial over t, plane-spatial over c.

    a_int: [..., R] integer activations (R = rows reduced over).
    w_int: [R, C] integer weights.
    Returns int32 [..., C], exactly equal to ``a_int @ w_int``.
    """
    planes = decompose.decompose_weights(w_int, w_bits, signed=w_signed)
    shifts = decompose.plane_shifts(w_bits, w_signed)
    bits, bit_weights = activation_bitplanes(a_int, a_bits, signed=a_signed)

    acc = jnp.zeros(a_int.shape[:-1] + (w_int.shape[-1],), jnp.int32)
    for c, s in enumerate(shifts):           # spatial: one column per plane
        w_plane = planes[c].astype(jnp.int32)
        col_acc = jnp.zeros_like(acc)
        for t in range(a_bits):              # temporal: one activation bit per cycle
            # Per-cycle column adder tree: sum over rows of (1-bit A) * W_dcp.
            tree = jnp.matmul(bits[t].astype(jnp.int32), w_plane)
            col_acc = col_acc + tree * bit_weights[t]
        acc = acc + (col_acc << s)           # group shift-add combine (Fig. 5)
    return acc


def cycles_per_mac(a_bits: int) -> int:
    """Bit-serial cycle count per MAC tile pass (one bit of A per clk cycle)."""
    return a_bits


def shift_add_clock_divider(a_bits: int) -> int:
    """clk_SA = clk / a_bits (paper §III-B lower-frequency shift-add domain)."""
    return a_bits
