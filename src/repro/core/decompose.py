"""Table-I weight decomposition — the paper's efficient weight-combination scheme.

An M-bit weight (M in 2..8) is decomposed into a fixed MSB->LSB schedule of
2-bit and 3-bit chunks (paper Table I):

    M : 8        7      6      5    4    3  2
      : 2-2-2-2  3-2-2  2-2-2  3-2  2-2  3  2

Only the MSB chunk can be 3 bits wide, and only the MSB chunk carries the sign
(2-bit mode: sign extension via the shared column signal S; 3-bit mode: top
three bits including the original sign bit loaded verbatim).  All non-MSB
chunks are unsigned 2-bit values.  Consequently every plane `c` sits at shift
`2*c` bits (paper Eq. (1) term 2^{2c}; Table I shifter config {2,2,4}).

Planes are returned LSB-first: ``planes[c]`` has arithmetic weight ``4**c``.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# MSB -> LSB chunk widths, straight from paper Table I.
DECOMP_SCHEDULE: dict[int, tuple[int, ...]] = {
    2: (2,),
    3: (3,),
    4: (2, 2),
    5: (3, 2),
    6: (2, 2, 2),
    7: (3, 2, 2),
    8: (2, 2, 2, 2),
}

SUPPORTED_BITS = tuple(sorted(DECOMP_SCHEDULE))


def schedule(w_bits: int, signed: bool = True) -> tuple[int, ...]:
    """Effective MSB->LSB chunk schedule.

    UNSIGNED weights always use 2-bit mode (paper Fig. 6: an unsigned column
    feeds all-zero MSBs into the adder tree, i.e. chunks are unsigned 2-bit
    values in [0,3]); an odd unsigned width therefore promotes to the next
    even schedule (3 -> 2-2, 5 -> 2-2-2, 7 -> 2-2-2-2) so no chunk ever
    exceeds the 3-bit-signed product range of the datapath."""
    if not signed and w_bits % 2 == 1:
        return DECOMP_SCHEDULE[w_bits + 1]
    return DECOMP_SCHEDULE[w_bits]


def num_planes(w_bits: int, signed: bool = True) -> int:
    """Number of decomposed planes (physical columns per logical weight)."""
    return len(schedule(w_bits, signed))


def plane_shifts(w_bits: int, signed: bool = True) -> tuple[int, ...]:
    """Arithmetic left-shift of each plane, LSB-first.  Always (0, 2, 4, 6)[:P]."""
    return tuple(2 * c for c in range(num_planes(w_bits, signed)))


def plane_widths_lsb_first(w_bits: int, signed: bool = True) -> tuple[int, ...]:
    return tuple(reversed(schedule(w_bits, signed)))


def msb_plane_width(w_bits: int, signed: bool = True) -> int:
    """Width of the sign-carrying MSB chunk (2 -> '2-bit mode', 3 -> '3-bit mode')."""
    return schedule(w_bits, signed)[0]


def weight_range(w_bits: int, signed: bool) -> tuple[int, int]:
    """Representable integer range for an M-bit (un)signed weight."""
    if signed:
        return -(1 << (w_bits - 1)), (1 << (w_bits - 1)) - 1
    return 0, (1 << w_bits) - 1


def plane_value_range(w_bits: int, plane: int, signed: bool) -> tuple[int, int]:
    """Value range of decomposed plane `plane` (LSB-first index)."""
    widths = plane_widths_lsb_first(w_bits, signed)
    w = widths[plane]
    is_msb = plane == len(widths) - 1
    if is_msb and signed:
        return -(1 << (w - 1)), (1 << (w - 1)) - 1
    return 0, (1 << w) - 1


def decompose_weights(w, w_bits: int, *, signed: bool = True):
    """Decompose integer weights into Table-I planes.

    Args:
      w: integer array, values within ``weight_range(w_bits, signed)``.
      w_bits: weight precision, 2..8.
      signed: the paper's column signal S (True = signed weights).

    Returns:
      int8 array of shape ``(P, *w.shape)`` with planes LSB-first; plane ``c``
      has arithmetic weight ``4**c``.  The MSB plane is signed iff ``signed``;
      all other planes are unsigned 2-bit values in [0, 3].
    """
    if w_bits not in DECOMP_SCHEDULE:
        raise ValueError(f"w_bits must be in {SUPPORTED_BITS}, got {w_bits}")
    widths = plane_widths_lsb_first(w_bits, signed)
    # Two's-complement bit pattern of the weight, as an unsigned field.
    u = jnp.asarray(w).astype(jnp.int32) & ((1 << w_bits) - 1)
    planes = []
    shift = 0
    for i, width in enumerate(widths):
        chunk = (u >> shift) & ((1 << width) - 1)
        is_msb = i == len(widths) - 1
        if is_msb and signed:
            # Reinterpret the MSB chunk as a `width`-bit signed value.
            chunk = jnp.where(chunk >= (1 << (width - 1)), chunk - (1 << width), chunk)
        planes.append(chunk)
        shift += width
    return jnp.stack(planes).astype(jnp.int8)


def recompose_weights(planes, w_bits: int, *, signed: bool = True):
    """Exact inverse of :func:`decompose_weights` (int32 output)."""
    shifts = plane_shifts(w_bits, signed)
    if planes.shape[0] != len(shifts):
        raise ValueError(
            f"plane count {planes.shape[0]} != schedule {len(shifts)} for {w_bits}-bit"
        )
    acc = jnp.zeros(planes.shape[1:], jnp.int32)
    for c, s in enumerate(shifts):
        acc = acc + (planes[c].astype(jnp.int32) << s)
    return acc


def planes_count(w_planes) -> int:
    return w_planes.shape[0]


# ------------------------------------------------------------- superplanes
# The runtime-reconfigurable store: every weight decomposed ONCE at
# SUPERPLANE_BITS, planes kept MSB-first so that the first P' planes are
# exactly the Table-I decomposition of the LSB-truncated (nested) weight:
#
#     recompose(planes[:P']) == q8 >> (2 * (4 - P'))        (arithmetic shift)
#
# Truncation therefore only ever touches even widths (each plane carries two
# bits); odd widths remain a *prepare-time* choice, not a runtime one.

SUPERPLANE_BITS = 8
SUPERPLANE_PLANES = 4
RUNTIME_W_BITS = (2, 4, 6, 8)   # widths reachable by plane-prefix truncation


def decompose_superplanes(q8, *, signed: bool = True):
    """Decompose an 8-bit integer weight into four MSB-FIRST 2-bit planes.

    ``planes[0]`` is the sign-carrying MSB chunk (signed iff ``signed``);
    planes 1..3 are unsigned values in [0, 3].  int8 [4, *q8.shape]."""
    return decompose_weights(q8, SUPERPLANE_BITS, signed=signed)[::-1]


def num_prefix_planes(eff_bits: int) -> int:
    """Plane-prefix length serving an effective weight width."""
    if eff_bits not in RUNTIME_W_BITS:
        raise ValueError(
            f"runtime-truncatable widths are {RUNTIME_W_BITS}, got {eff_bits}")
    return eff_bits // 2


def prefix_shifts(num_planes: int) -> tuple[int, ...]:
    """Arithmetic left-shift per MSB-first plane: plane i weighs 4^(P'-1-i)."""
    return tuple(2 * (num_planes - 1 - c) for c in range(num_planes))


def superplane_prefix(planes_msb, eff_bits: int):
    """The MSB plane prefix serving ``eff_bits`` (still MSB-first)."""
    return planes_msb[: num_prefix_planes(eff_bits)]


def recompose_superplane_prefix(planes_msb, eff_bits: int, *,
                                signed: bool = True):
    """Integer value of a truncated superplane == ``q8 >> (8 - eff_bits)``."""
    prefix = superplane_prefix(planes_msb, eff_bits)
    return recompose_weights(prefix[::-1], eff_bits, signed=signed)


def decomposed_matmul_grouped(x_int, planes_msb, row_groups):
    """Per-row-group effective-width oracle (mixed-tier decode batches).

    ``x_int``'s leading axis is already sorted into contiguous tier groups;
    ``row_groups`` is a static tuple of ``(rows, eff_bits)`` covering it.
    Each group matmuls against its own MSB plane prefix of the superplane
    store — one plane-prefix GEMM per group — and the results are
    reassembled along the leading axis.

    Args:
      x_int: int array [B, ..., K] (quantized activations).
      planes_msb: int8 [4, K, N] MSB-first superplane store.
      row_groups: static tuple of (rows, eff_bits), summing to B; eff_bits
        in RUNTIME_W_BITS.

    Returns:
      int32 [B, ..., N] exact per-group MAC result.
    """
    total = sum(r for r, _ in row_groups)
    if total != x_int.shape[0]:
        raise ValueError(f"row_groups cover {total} rows, x has "
                         f"{x_int.shape[0]}")
    outs, off = [], 0
    for rows, eff_bits in row_groups:
        prefix = superplane_prefix(planes_msb, eff_bits)[::-1]  # LSB-first
        outs.append(decomposed_matmul(x_int[off:off + rows], prefix,
                                      eff_bits))
        off += rows
    return jnp.concatenate(outs, axis=0)


def prefix_multipliers(plane_groups: tuple[tuple[int, int], ...]) -> np.ndarray:
    """Per-row plane-multiplier table for group-switching GEMMs.

    The multiplier table turns the per-group plane-prefix *loop* into data:
    row ``r`` of a batch whose group serves ``P'`` MSB-first planes weighs
    plane ``c`` by ``4**(P'-1-c)`` (exactly ``prefix_shifts``) and weighs
    planes beyond its prefix by 0.  A single kernel can then walk ALL
    ``Pmax`` planes and scale each plane's integer partial product by
    ``mult[r, c]`` — rows of different effective widths share one grid, the
    software analogue of the paper's spatial partial-sum combination.

    Args:
      plane_groups: static tuple of ``(rows, num_planes)`` per contiguous
        group, MSB-first plane counts (``num_prefix_planes(eff_bits)``).

    Returns:
      np.int32 ``[sum(rows), max(num_planes)]`` — a compile-time constant.
    """
    pmax = max(p for _, p in plane_groups)
    total = sum(r for r, _ in plane_groups)
    mult = np.zeros((total, pmax), np.int32)
    off = 0
    for rows, p in plane_groups:
        for c in range(p):
            mult[off:off + rows, c] = 4 ** (p - 1 - c)
        off += rows
    return mult


def decomposed_matmul_multipliers(x_int, planes_msb, mult):
    """Multiplier-combine grouped GEMM: the plain-HLO twin of the fused
    group-switching Pallas kernel.

    Computes ``sum_c (x_int @ planes_msb[c]) * mult[:, c]`` in int32 — for a
    table from :func:`prefix_multipliers` this equals
    :func:`decomposed_matmul_grouped` bit-exactly (integer multiplication by
    a power of four is an exact shift; integer addition is associative), but
    with NO per-group dispatch: every row group rides the same ``Pmax``
    matmuls.

    Args:
      x_int: int array [M, K] (quantized activations, group-sorted rows).
      planes_msb: int8 [Pmax, K, N] MSB-first plane prefix (``Pmax`` =
        widest group's plane count).
      mult: int32 [M, Pmax] per-row plane multipliers.

    Returns:
      int32 [M, N] exact per-group MAC result.
    """
    x32 = x_int.astype(jnp.int32)
    mult = jnp.asarray(mult, jnp.int32)
    acc = None
    for c in range(planes_msb.shape[0]):
        part = jnp.matmul(x32, planes_msb[c].astype(jnp.int32))
        part = part * mult[:, c:c + 1]
        acc = part if acc is None else acc + part
    return acc


def decomposed_matmul(x_int, w_planes, w_bits: int):
    """``x_int @ recompose(w_planes)`` computed the paper's way: one integer
    matmul per plane, partial sums combined with shifts (the TPU analogue of
    the 4-column group's shift-add combine).

    Args:
      x_int: int array [..., K] (quantized activations, any int bitwidth <= 8).
      w_planes: int8 [P, K, N] decomposed weight planes (LSB-first).
      w_bits: weight precision (determines the shift schedule).

    Returns:
      int32 [..., N] exact MAC result.
    """
    # Shift schedule is always 2c per plane, independent of the schedule
    # variant (only the MSB chunk may be 3 wide), so derive from plane count.
    shifts = tuple(2 * c for c in range(planes_count(w_planes)))
    x32 = x_int.astype(jnp.int32)
    acc = None
    for c, s in enumerate(shifts):
        part = jnp.matmul(x32, w_planes[c].astype(jnp.int32)) << s
        acc = part if acc is None else acc + part
    return acc
