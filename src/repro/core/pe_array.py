"""Functional simulator of the paper's 64x64 weight-stationary PE array.

Bit-exact model of the full microarchitecture (§III, Figs. 2-5):

  * weights preloaded top-to-bottom, decomposed per Table I (``core.decompose``);
  * activations stream LSB-first, 1 bit / cycle (``core.bitserial``);
  * per-cycle row reduction through the split-path CSA tree (``core.adder_tree``);
  * sign-bit cycle negation (Eq. (1)'s (-1)^{SF});
  * 4-column-group shift-add combine at clk_SA = clk / a_bits (Fig. 5);
  * Fig. 4 independent shift-add paths for the 3-plane (6/7-bit) case, which
    lift array utilization from 48/64 to 63/64 columns.

Also reports the cycle/utilization statistics the hwmodel uses to reproduce
the paper's throughput numbers (4.09 TOPS peak at 2/2-bit: 64*64/2 MACs/cycle
* 2 ops * 1 GHz).
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from repro.core import adder_tree, bitserial, decompose


@dataclasses.dataclass(frozen=True)
class PEArrayConfig:
    rows: int = 64
    cols: int = 64
    group: int = 4
    clk_mhz: float = 1000.0
    # Fig. 4: five extra cross-group shift-add paths for the 3-plane case.
    independent_shift_add: bool = True


def logical_columns_per_pass(cfg: PEArrayConfig, w_bits: int,
                             signed: bool = True) -> tuple[int, int]:
    """(logical output columns per array pass, idle physical columns)."""
    p = decompose.num_planes(w_bits, signed)
    if p == 3:
        if cfg.independent_shift_add:
            n = cfg.cols // p                    # 21 logical, 1 idle (Fig. 4)
            return n, cfg.cols - n * p
        per_group = cfg.group // p               # 1 logical, 1 idle per group
        groups = cfg.cols // cfg.group
        return per_group * groups, groups * (cfg.group - per_group * p)
    per_group = cfg.group // p
    groups = cfg.cols // cfg.group
    return per_group * groups, groups * (cfg.group - per_group * p)


def array_utilization(cfg: PEArrayConfig, w_bits: int,
                      signed: bool = True) -> float:
    n, idle = logical_columns_per_pass(cfg, w_bits, signed)
    return 1.0 - idle / cfg.cols


@dataclasses.dataclass
class PEArrayStats:
    w_bits: int
    a_bits: int
    row_tiles: int
    col_passes: int
    cycles: int
    macs: int
    utilization: float

    @property
    def macs_per_cycle(self) -> float:
        return self.macs / max(self.cycles, 1)

    def tops(self, clk_mhz: float) -> float:
        """2 ops (mul+add) per MAC at the given clock."""
        return 2.0 * self.macs_per_cycle * clk_mhz * 1e6 / 1e12


def pe_array_matmul(a_int, w_int, *, w_bits: int, a_bits: int,
                    a_signed: bool = True, w_signed: bool = True,
                    cfg: PEArrayConfig = PEArrayConfig()):
    """Simulate ``a_int @ w_int`` on the array.  Bit-exact, any R/C (tiled).

    a_int: [B, R] integer activations; w_int: [R, C] integer weights.
    Returns (int32 [B, C], PEArrayStats).
    """
    a_int = jnp.asarray(a_int)
    w_int = jnp.asarray(w_int)
    b, r = a_int.shape
    r2, c = w_int.shape
    assert r == r2, (r, r2)
    p = decompose.num_planes(w_bits, w_signed)
    shifts = decompose.plane_shifts(w_bits, w_signed)
    n_logical, _ = logical_columns_per_pass(cfg, w_bits, w_signed)

    planes = decompose.decompose_weights(w_int, w_bits, signed=w_signed)  # [P,R,C]
    bits, bit_w = bitserial.activation_bitplanes(a_int, a_bits, signed=a_signed)

    out = jnp.zeros((b, c), jnp.int32)
    row_tiles = math.ceil(r / cfg.rows)
    for rt in range(row_tiles):
        r0, r1 = rt * cfg.rows, min((rt + 1) * cfg.rows, r)
        for plane_idx in range(p):
            w_plane = planes[plane_idx, r0:r1].astype(jnp.int32)       # [r_t, C]
            col_acc = jnp.zeros((b, c), jnp.int32)
            for t in range(a_bits):
                a_bit = bits[t, :, r0:r1].astype(jnp.int32)            # [B, r_t]
                # 3-bit-signed products, reduced by the split-path CSA tree.
                prods = a_bit[:, :, None] * w_plane[None, :, :]        # [B,r_t,C]
                tree = adder_tree.csa_tree_sum(prods, axis=1)
                col_acc = col_acc + tree * bit_w[t]                    # SF folded in
            out = out + (col_acc << shifts[plane_idx])                 # group combine

    col_passes = math.ceil(c / n_logical)
    # One activation vector consumes a_bits cycles per (row tile x column pass);
    # B vectors pipeline through back-to-back (systolic fill latency ignored).
    cycles = row_tiles * col_passes * a_bits * b
    stats = PEArrayStats(
        w_bits=w_bits, a_bits=a_bits, row_tiles=row_tiles, col_passes=col_passes,
        cycles=cycles, macs=b * r * c,
        utilization=array_utilization(cfg, w_bits, w_signed),
    )
    return out, stats


def peak_tops(cfg: PEArrayConfig, w_bits: int, a_bits: int) -> float:
    """Peak throughput of the array for a precision pair (paper: 4.09 TOPS
    at 2/2-bit with a 64x64 array at 1 GHz)."""
    n_logical, _ = logical_columns_per_pass(cfg, w_bits)
    macs_per_cycle = cfg.rows * n_logical / a_bits
    return 2.0 * macs_per_cycle * cfg.clk_mhz * 1e6 / 1e12
