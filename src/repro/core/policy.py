"""Per-layer mixed-precision policy — the configuration surface of the paper.

The accelerator's value proposition is *fully mixed-precision* inference:
every layer may run at any (w_bits, a_bits) in 2..8.  This module holds the
policy objects the model layers consult, plus a sensitivity-based allocator
(HAWQ-style gradient-squared proxy) that picks per-layer bitwidths under an
average-bit budget.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import re
from typing import Dict, Optional

# Matmul execution backends, lowest to highest fidelity to the accelerator:
#   dense       - bf16 matmul, no quantization (fp baseline)
#   fake_quant  - QAT: quantize-dequantize with STE, dense matmul (training)
#   decomposed  - integer plane-decomposed matmul, pure-JAX HLO (serving/dry-run)
#   pallas      - the Pallas TPU kernel (serving hot path; interpret on CPU)
BACKENDS = ("dense", "fake_quant", "decomposed", "pallas")


@dataclasses.dataclass(frozen=True)
class LayerPrecision:
    """One layer's (w_bits, a_bits, signedness, backend) operating point.

    Frozen and hashable on purpose: LayerPrecision values travel as
    JIT-STATIC data — they key traces (e.g. as members of the per-row-group
    tuples in ``kernels.ops.matmul``) and must never be traced arrays."""

    w_bits: int = 8
    a_bits: int = 8
    w_signed: bool = True
    a_signed: bool = True
    backend: str = "fake_quant"

    def __post_init__(self):
        if not (2 <= self.w_bits <= 8 and 2 <= self.a_bits <= 8):
            raise ValueError(f"bits out of 2..8: w={self.w_bits} a={self.a_bits}")
        if self.backend not in BACKENDS:
            raise ValueError(f"backend {self.backend!r} not in {BACKENDS}")

    def with_backend(self, backend: str) -> "LayerPrecision":
        """This precision with the execution backend swapped."""
        return dataclasses.replace(self, backend=backend)


DEFAULT_PRECISION = LayerPrecision()


@dataclasses.dataclass
class PrecisionPolicy:
    """Maps layer names (glob patterns) to LayerPrecision.

    First matching rule wins; ``default`` applies otherwise.  Layer names are
    hierarchical, e.g. ``layers.3.attn.q_proj`` or ``layers.*.mlp.up_proj``.
    """

    rules: Dict[str, LayerPrecision] = dataclasses.field(default_factory=dict)
    default: LayerPrecision = DEFAULT_PRECISION

    def lookup(self, name: str) -> LayerPrecision:
        """Precision for one layer name (first matching rule, else default).

        Pure host-side string matching — call it OUTSIDE traced code or on
        static names only (layer names are static throughout the model)."""
        for pattern, prec in self.rules.items():
            if fnmatch.fnmatch(name, pattern):
                return prec
        return self.default

    def with_backend(self, backend: str) -> "PrecisionPolicy":
        """Every rule and the default re-targeted to ``backend``."""
        return PrecisionPolicy(
            rules={k: v.with_backend(backend) for k, v in self.rules.items()},
            default=self.default.with_backend(backend),
        )

    def average_bits(self, layer_names, param_counts=None) -> float:
        """Parameter-weighted mean weight bitwidth over ``layer_names``."""
        names = list(layer_names)
        counts = param_counts or [1] * len(names)
        tot = sum(counts)
        return sum(self.lookup(n).w_bits * c for n, c in zip(names, counts)) / tot


def uniform_policy(w_bits: int, a_bits: int, backend: str = "fake_quant",
                   a_signed: bool = True) -> PrecisionPolicy:
    """Single-precision policy: every layer at (w_bits, a_bits)."""
    return PrecisionPolicy(default=LayerPrecision(
        w_bits=w_bits, a_bits=a_bits, backend=backend, a_signed=a_signed))


# --------------------------------------------------------- runtime schedules
# Runtime-reconfigurable serving: ONE superplane weight store (prepared at 8
# bits), many named quality tiers selectable per request at decode time.
# A PrecisionSchedule replaces the per-prepare PrecisionPolicy for tiered
# engines: it maps (layer name x tier name) -> effective LayerPrecision, and
# every tier's w_bits must be reachable by plane-prefix truncation
# (decompose.RUNTIME_W_BITS) so switching tiers never re-prepares a weight.

from repro.core.decompose import RUNTIME_W_BITS  # noqa: E402


# Per-request KV-cache precision tiers (the decode-memory analogue of the
# weight plane prefix): a schedule may map each tier to a KV storage
# precision — None (bf16), 8 (int8) or 4 (int4-packed).  16 is the internal
# tier code for bf16 in the per-slot arena.
KV_TIER_CHOICES = (None, 8, 4)


@dataclasses.dataclass
class PrecisionSchedule:
    """Named runtime tiers over one preloaded superplane weight store.

    ``tiers`` maps tier name -> that tier's default LayerPrecision; ``rules``
    optionally refines single tiers per layer-name glob (first match wins,
    same contract as PrecisionPolicy).  All precisions must share
    ``w_signed`` (signedness is baked into the stored MSB plane) and use an
    integer serving backend with an even, truncatable ``w_bits``.

    ``kv_tiers`` optionally maps tier name -> KV-cache storage precision
    (None = bf16, 8 = int8, 4 = int4-packed; tiers left out default to
    bf16).  When set, a tiered engine allocates ONE mixed per-slot KV arena
    and every admitted request's slot stores K/V at its tier's KV
    precision — a low tier then shrinks both its weight-plane reads and its
    decode-memory footprint.  Tier names and the derived mode set are
    jit-static; the per-slot tier assignment is traced data
    (``KVCache.kv_bits``)."""

    tiers: Dict[str, LayerPrecision]
    rules: Dict[str, Dict[str, LayerPrecision]] = dataclasses.field(
        default_factory=dict)
    default_tier: Optional[str] = None
    kv_tiers: Optional[Dict[str, Optional[int]]] = None

    def __post_init__(self):
        if not self.tiers:
            raise ValueError("a PrecisionSchedule needs at least one tier")
        if self.default_tier is None:
            self.default_tier = next(iter(self.tiers))
        if self.default_tier not in self.tiers:
            raise ValueError(f"default tier {self.default_tier!r} not in "
                             f"{sorted(self.tiers)}")
        for t in self.rules:
            if t not in self.tiers:
                raise ValueError(f"rules for unknown tier {t!r}")
        if self.kv_tiers is not None:
            for t, kb in self.kv_tiers.items():
                if t not in self.tiers:
                    raise ValueError(f"kv_tiers for unknown tier {t!r}")
                if kb not in KV_TIER_CHOICES:
                    raise ValueError(
                        f"kv tier must be one of {KV_TIER_CHOICES} "
                        f"(None = bf16), got {kb!r} for tier {t!r}")
        signs = set()
        for prec in self._all_precisions():
            if prec.backend not in ("decomposed", "pallas"):
                raise ValueError(
                    f"tier backend must be an integer serving backend, got "
                    f"{prec.backend!r}")
            if prec.w_bits not in RUNTIME_W_BITS:
                raise ValueError(
                    f"tier w_bits must be plane-truncatable {RUNTIME_W_BITS},"
                    f" got {prec.w_bits}")
            signs.add(prec.w_signed)
        if len(signs) > 1:
            raise ValueError("all tiers must share w_signed: the sign mode "
                             "is baked into the preloaded MSB plane")

    def _all_precisions(self):
        for prec in self.tiers.values():
            yield prec
        for by_layer in self.rules.values():
            yield from by_layer.values()

    @property
    def tier_names(self):
        return tuple(self.tiers)

    @property
    def w_signed(self) -> bool:
        return next(iter(self.tiers.values())).w_signed

    # ------------------------------------------------------------ kv tiers
    def kv_bits_for(self, tier: Optional[str] = None) -> Optional[int]:
        """KV storage precision of a tier (None = bf16) — what a
        fixed-precision reference engine at that tier uses globally."""
        tier = self.default_tier if tier is None else tier
        if tier not in self.tiers:
            raise KeyError(f"unknown tier {tier!r}; have {sorted(self.tiers)}")
        if self.kv_tiers is None:
            return None
        return self.kv_tiers.get(tier)

    def kv_code_for(self, tier: Optional[str] = None) -> int:
        """Per-slot arena tier code of a tier (16 = bf16, 8, 4)."""
        kb = self.kv_bits_for(tier)
        return 16 if kb is None else kb

    @property
    def kv_modes(self) -> Optional[tuple]:
        """Static mode set the mixed per-slot KV arena must serve
        (descending tier codes), or None when no kv_tiers are declared."""
        if self.kv_tiers is None:
            return None
        codes = {self.kv_code_for(t) for t in self.tiers}
        return tuple(sorted(codes, reverse=True))

    def tier_bits(self, tier: Optional[str] = None) -> tuple:
        """A tier's default ``(w_bits, a_bits)`` operating point — what the
        hwmodel prices admission with (``energy.relative_tier_costs``).
        Per-layer rule refinements are deliberately ignored here: admission
        is priced per request, not per layer."""
        tier = self.default_tier if tier is None else tier
        if tier not in self.tiers:
            raise KeyError(f"unknown tier {tier!r}; have {sorted(self.tiers)}")
        prec = self.tiers[tier]
        return (prec.w_bits, prec.a_bits)

    def lookup(self, name: str, tier: Optional[str] = None) -> LayerPrecision:
        tier = self.default_tier if tier is None else tier
        if tier not in self.tiers:
            raise KeyError(f"unknown tier {tier!r}; have {sorted(self.tiers)}")
        for pattern, prec in self.rules.get(tier, {}).items():
            if fnmatch.fnmatch(name, pattern):
                return prec
        return self.tiers[tier]

    def policy_for(self, tier: Optional[str] = None) -> PrecisionPolicy:
        """Materialize one tier as a plain PrecisionPolicy — what a
        fixed-precision engine prepared natively at that tier uses (the
        bit-exact reference for the runtime-truncated path)."""
        tier = self.default_tier if tier is None else tier
        if tier not in self.tiers:
            raise KeyError(f"unknown tier {tier!r}; have {sorted(self.tiers)}")
        return PrecisionPolicy(rules=dict(self.rules.get(tier, {})),
                               default=self.tiers[tier])

    def prepare_policy(self) -> PrecisionPolicy:
        """The max-precision policy the superplane store is prepared under
        (8-bit; per-layer signedness from the schedule)."""
        default = next(iter(self.tiers.values()))
        return PrecisionPolicy(default=dataclasses.replace(
            default, w_bits=8, a_bits=8))

    # -------------------------------------------------------- persistence
    def to_json_dict(self) -> Dict:
        """JSON-able dict form (exact round-trip via :meth:`from_json_dict`;
        the format lives in :mod:`repro.autoprec.schedule_io`, which also
        reads/writes whole files)."""
        from repro.autoprec import schedule_io
        return schedule_io.schedule_to_dict(self)

    @classmethod
    def from_json_dict(cls, d: Dict) -> "PrecisionSchedule":
        """Rebuild (and re-validate) a schedule from its dict form."""
        from repro.autoprec import schedule_io
        return schedule_io.schedule_from_dict(d)


def uniform_schedule(tiers: Dict[str, tuple],
                     backend: str = "decomposed",
                     a_signed: bool = True,
                     kv_tiers: Optional[Dict[str, Optional[int]]] = None
                     ) -> PrecisionSchedule:
    """Schedule from ``{name: (w_bits, a_bits)}`` pairs, uniform per tier.

    ``kv_tiers`` optionally maps tier names to KV-cache storage precisions
    (None = bf16, 8, 4) — see :class:`PrecisionSchedule`."""
    return PrecisionSchedule(tiers={
        name: LayerPrecision(w_bits=w, a_bits=a, backend=backend,
                             a_signed=a_signed)
        for name, (w, a) in tiers.items()}, kv_tiers=kv_tiers)


def allocate_bits_by_sensitivity(sensitivities: Dict[str, float],
                                 param_counts: Dict[str, int],
                                 avg_bits: float,
                                 choices=(2, 4, 6, 8),
                                 a_bits: int = 8,
                                 backend: str = "fake_quant") -> PrecisionPolicy:
    """Greedy sensitivity-based bit allocation (HAWQ-flavoured).

    Start everything at min(choices); repeatedly grant one step of extra
    precision to the layer with the best marginal sensitivity reduction per
    budget unit until the parameter-weighted average bitwidth budget is
    exhausted.  A scalar sensitivity models a symmetric quantizer whose
    error halves per extra bit (``sens * 2^-bits``).

    Thin wrapper over :func:`repro.autoprec.search.greedy_trajectory` (the
    measured-sensitivity search core) so the two allocators cannot drift.
    ``choices`` defaults to the EVEN widths the runtime superplane path can
    actually serve (``PrecisionSchedule`` validates against
    ``decompose.RUNTIME_W_BITS``); odd widths may still be requested
    explicitly for the QAT/fake-quant policy path, which has no
    plane-prefix constraint.
    """
    from repro.autoprec.search import greedy_trajectory

    names = sorted(sensitivities)
    missing = [n for n in names if n not in param_counts]
    if missing:
        raise ValueError(f"param_counts misses layers {missing}")
    # Synthetic (layer, width) divergence table from the scalar prior; the
    # budget is the classic parameter-weighted total-bits cap.
    sens = {n: {b: sensitivities[n] * 2.0 ** (-b) for b in choices}
            for n in names}
    layer_cost = {n: {b: float(b * param_counts[n]) for b in choices}
                  for n in names}
    budget = avg_bits * sum(param_counts[n] for n in names)
    traj = greedy_trajectory(names, sens, layer_cost, choices, budget=budget)
    bits = traj[-1]
    rules = {n: LayerPrecision(w_bits=bits[n], a_bits=a_bits, backend=backend)
             for n in names}
    return PrecisionPolicy(rules=rules,
                           default=LayerPrecision(a_bits=a_bits, backend=backend))
