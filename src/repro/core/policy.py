"""Per-layer mixed-precision policy — the configuration surface of the paper.

The accelerator's value proposition is *fully mixed-precision* inference:
every layer may run at any (w_bits, a_bits) in 2..8.  This module holds the
policy objects the model layers consult, plus a sensitivity-based allocator
(HAWQ-style gradient-squared proxy) that picks per-layer bitwidths under an
average-bit budget.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import re
from typing import Dict, Optional

# Matmul execution backends, lowest to highest fidelity to the accelerator:
#   dense       - bf16 matmul, no quantization (fp baseline)
#   fake_quant  - QAT: quantize-dequantize with STE, dense matmul (training)
#   decomposed  - integer plane-decomposed matmul, pure-JAX HLO (serving/dry-run)
#   pallas      - the Pallas TPU kernel (serving hot path; interpret on CPU)
BACKENDS = ("dense", "fake_quant", "decomposed", "pallas")


@dataclasses.dataclass(frozen=True)
class LayerPrecision:
    w_bits: int = 8
    a_bits: int = 8
    w_signed: bool = True
    a_signed: bool = True
    backend: str = "fake_quant"

    def __post_init__(self):
        if not (2 <= self.w_bits <= 8 and 2 <= self.a_bits <= 8):
            raise ValueError(f"bits out of 2..8: w={self.w_bits} a={self.a_bits}")
        if self.backend not in BACKENDS:
            raise ValueError(f"backend {self.backend!r} not in {BACKENDS}")

    def with_backend(self, backend: str) -> "LayerPrecision":
        return dataclasses.replace(self, backend=backend)


DEFAULT_PRECISION = LayerPrecision()


@dataclasses.dataclass
class PrecisionPolicy:
    """Maps layer names (glob patterns) to LayerPrecision.

    First matching rule wins; ``default`` applies otherwise.  Layer names are
    hierarchical, e.g. ``layers.3.attn.q_proj`` or ``layers.*.mlp.up_proj``.
    """

    rules: Dict[str, LayerPrecision] = dataclasses.field(default_factory=dict)
    default: LayerPrecision = DEFAULT_PRECISION

    def lookup(self, name: str) -> LayerPrecision:
        for pattern, prec in self.rules.items():
            if fnmatch.fnmatch(name, pattern):
                return prec
        return self.default

    def with_backend(self, backend: str) -> "PrecisionPolicy":
        return PrecisionPolicy(
            rules={k: v.with_backend(backend) for k, v in self.rules.items()},
            default=self.default.with_backend(backend),
        )

    def average_bits(self, layer_names, param_counts=None) -> float:
        names = list(layer_names)
        counts = param_counts or [1] * len(names)
        tot = sum(counts)
        return sum(self.lookup(n).w_bits * c for n, c in zip(names, counts)) / tot


def uniform_policy(w_bits: int, a_bits: int, backend: str = "fake_quant",
                   a_signed: bool = True) -> PrecisionPolicy:
    return PrecisionPolicy(default=LayerPrecision(
        w_bits=w_bits, a_bits=a_bits, backend=backend, a_signed=a_signed))


def allocate_bits_by_sensitivity(sensitivities: Dict[str, float],
                                 param_counts: Dict[str, int],
                                 avg_bits: float,
                                 choices=(2, 3, 4, 5, 6, 7, 8),
                                 a_bits: int = 8,
                                 backend: str = "fake_quant") -> PrecisionPolicy:
    """Greedy sensitivity-based bit allocation (HAWQ-flavoured).

    Start everything at min(choices); repeatedly grant one step of extra
    precision to the layer with the highest marginal sensitivity-per-parameter
    until the parameter-weighted average bitwidth budget is exhausted.
    """
    names = sorted(sensitivities)
    lo, hi = min(choices), max(choices)
    bits = {n: lo for n in names}
    total_params = sum(param_counts[n] for n in names)
    budget = avg_bits * total_params

    def used():
        return sum(bits[n] * param_counts[n] for n in names)

    # Marginal value of +1 bit ~ sensitivity * 2^{-bits} (quantization error
    # of a symmetric quantizer halves per extra bit).
    import heapq
    heap = [(-sensitivities[n] * 2.0 ** (-bits[n]), n) for n in names]
    heapq.heapify(heap)
    while heap:
        neg_gain, n = heapq.heappop(heap)
        if bits[n] >= hi:
            continue
        step = next(c for c in choices if c > bits[n]) - bits[n]
        if used() + step * param_counts[n] > budget:
            continue
        bits[n] += step
        heapq.heappush(heap, (-sensitivities[n] * 2.0 ** (-bits[n]), n))

    rules = {n: LayerPrecision(w_bits=bits[n], a_bits=a_bits, backend=backend)
             for n in names}
    return PrecisionPolicy(rules=rules,
                           default=LayerPrecision(a_bits=a_bits, backend=backend))
