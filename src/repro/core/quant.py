"""2..8-bit quantization for mixed-precision inference/training.

Symmetric integer quantization (per-tensor or per-channel) matching the
paper's operand format: two's-complement signed or unsigned integers of
2..8 bits (the column signal S selects signed/unsigned).

Fake-quant (QAT) uses the straight-through estimator so the dense bf16
training path learns weights that survive the decomposed integer serving
path bit-exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Quantization spec for one operand of one layer."""

    bits: int = 8
    signed: bool = True          # the paper's per-column signal S
    per_channel: bool = True     # per output-channel scales for weights
    channel_axis: int = -1       # axis holding output channels
    eps: float = 1e-8

    def __post_init__(self):
        if not (2 <= self.bits <= 8):
            raise ValueError(f"bits must be in 2..8, got {self.bits}")

    @property
    def qmin(self) -> int:
        return -(1 << (self.bits - 1)) if self.signed else 0

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1 if self.signed else (1 << self.bits) - 1


def _reduce_axes(x, channel_axis: int):
    axis = channel_axis % x.ndim
    return tuple(a for a in range(x.ndim) if a != axis)


def compute_scale(x, cfg: QuantConfig):
    """Symmetric scale: max|x| mapped to qmax.  Shape broadcasts against x."""
    if cfg.per_channel and x.ndim > 1:
        axes = _reduce_axes(x, cfg.channel_axis)
        amax = jnp.max(jnp.abs(x), axis=axes, keepdims=True)
    else:
        amax = jnp.max(jnp.abs(x))
    return jnp.maximum(amax, cfg.eps) / cfg.qmax


def quantize(x, cfg: QuantConfig, scale=None):
    """float -> int. Returns (q int8/uint8, scale f32), clipped to the q-range.

    Unsigned configs return uint8 — an unsigned 8-bit code point (<=255)
    does not fit int8 (found by the hypothesis roundtrip property test)."""
    scale = compute_scale(x, cfg) if scale is None else scale
    q = jnp.clip(jnp.round(x / scale), cfg.qmin, cfg.qmax)
    dtype = jnp.int8 if cfg.signed else jnp.uint8
    return q.astype(dtype), scale.astype(jnp.float32)


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


@jax.custom_vjp
def _ste_round(x):
    return jnp.round(x)


def _ste_round_fwd(x):
    return jnp.round(x), None


def _ste_round_bwd(_, g):
    return (g,)


_ste_round.defvjp(_ste_round_fwd, _ste_round_bwd)


def fake_quant(x, cfg: QuantConfig, scale=None):
    """Quantize-dequantize with a straight-through gradient (QAT building block).

    Out-of-range values clip in the forward pass; the gradient passes through
    only inside the clip range (standard STE-with-clipping)."""
    scale = compute_scale(x, cfg) if scale is None else scale
    x_scaled = x / scale
    # Clip gradient mask: zero grad outside representable range.
    clipped = jnp.clip(x_scaled, cfg.qmin, cfg.qmax)
    q = _ste_round(clipped)
    return q * scale


MAX_BITS = 8   # the superplane store always quantizes weights at this width


def nested_scale(scale, from_bits: int, to_bits: int):
    """Effective scale after truncating ``from_bits - to_bits`` LSBs.

    Exact in f32: the multiplier is a power of two."""
    return scale * float(1 << (from_bits - to_bits))


def truncate_qint(q, from_bits: int, to_bits: int):
    """Drop the LSBs of an integer code: ``q >> (from_bits - to_bits)``.

    This is the *nested* (progressive) refinement relation: the ``to_bits``
    code is an exact bit-prefix of the ``from_bits`` code, so it is what a
    preloaded superplane array computes when only the MSB planes are read.
    The shift is arithmetic for signed codes (int dtypes) and logical for
    unsigned (the uint8 storage is widened first), i.e. floor rounding —
    the truncated code is biased low by up to one effective LSB, unlike a
    fresh round-to-nearest quantization (documented tradeoff of
    runtime-reconfigurable precision)."""
    shift = from_bits - to_bits
    if shift < 0:
        raise ValueError(f"cannot truncate {from_bits}b up to {to_bits}b")
    return jnp.asarray(q).astype(jnp.int32) >> shift


def nested_quantize(x, cfg: QuantConfig, scale=None):
    """float -> int at ``cfg.bits`` via the nested scheme: round-to-nearest
    once at MAX_BITS, then truncate LSBs.  Returns (q, effective scale).

    Guarantees ``nested_quantize(x, bits=b)`` == LSB-truncation of
    ``nested_quantize(x, bits=MAX_BITS)`` for every b <= MAX_BITS — the
    invariant the runtime plane-prefix serving path relies on."""
    base = dataclasses.replace(cfg, bits=MAX_BITS)
    q8, s8 = quantize(x, base, scale=scale)
    q = truncate_qint(q8, MAX_BITS, cfg.bits)
    dtype = jnp.int8 if cfg.signed else jnp.uint8
    return q.astype(dtype), nested_scale(s8, MAX_BITS, cfg.bits)


def quantize_unsigned_activations(x, bits: int):
    """Post-ReLU activations: unsigned quantization (S=0 column signal)."""
    cfg = QuantConfig(bits=bits, signed=False, per_channel=False)
    return quantize(x, cfg)


def int_matmul_dequant(x_q, w_q, x_scale, w_scale):
    """(x_q @ w_q) * x_scale * w_scale — the integer-domain matmul the
    accelerator performs, mapped back to float."""
    acc = jnp.matmul(x_q.astype(jnp.int32), w_q.astype(jnp.int32))
    return acc.astype(jnp.float32) * x_scale * w_scale
