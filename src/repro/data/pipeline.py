"""Deterministic synthetic data pipeline.

Counter-based (Philox) generation keyed on (seed, step) — any batch is
reproducible from the manifest alone, so checkpoint/restore and elastic
re-sharding never lose pipeline position, and two hosts generating the same
(step, shard) agree bit-for-bit.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # "uniform": iid tokens (throughput testing).  "arith": learnable
    # next-token structure (loss visibly decreases — used by examples/tests).
    task: str = "arith"
    embed_dim: int = 0        # >0: emit precomputed embeddings (vlm/audio stubs)


class SyntheticLM:
    """Stateless batch generator; `state` is just the step counter."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int, *, shard: int = 0, num_shards: int = 1):
        cfg = self.cfg
        assert cfg.global_batch % num_shards == 0
        b = cfg.global_batch // num_shards
        key = np.array([(np.uint64(cfg.seed) << np.uint64(32))
                        | np.uint64(step & 0xFFFFFFFF),
                        (np.uint64(shard) << np.uint64(32))
                        | np.uint64(0xDA7A)], np.uint64)
        rng = np.random.Generator(np.random.Philox(key=key))
        if cfg.task == "uniform":
            toks = rng.integers(0, cfg.vocab_size, size=(b, cfg.seq_len + 1),
                                dtype=np.int32)
        else:
            # Arithmetic sequences mod vocab with per-row stride + 10% noise:
            # learnable by a tiny LM in a few hundred steps.
            start = rng.integers(0, cfg.vocab_size, size=(b, 1))
            stride = rng.integers(1, min(17, cfg.vocab_size), size=(b, 1))
            pos = np.arange(cfg.seq_len + 1)[None, :]
            toks = ((start + stride * pos) % cfg.vocab_size).astype(np.int32)
            noise = rng.random((b, cfg.seq_len + 1)) < 0.1
            toks = np.where(noise, rng.integers(
                0, cfg.vocab_size, size=toks.shape), toks).astype(np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.embed_dim:
            emb = rng.standard_normal(
                (b, cfg.seq_len, cfg.embed_dim), dtype=np.float32)
            out["embeds"] = emb
        return out
