"""Quantized gradient all-reduce with error feedback — the paper's operand
decomposition reused as a wire format for data-parallel training.

Each device quantizes its local gradient to int8 against a globally-agreed
scale (one scalar all-reduce), sums the *integer* codes with psum (sums of
2^k int8 values fit int32 for any realistic replica count), and dequantizes.
Quantization error is carried in a per-device error-feedback buffer, which
preserves convergence (Karimireddy et al.-style EF-SGD argument).

Wire bytes per gradient element: 1 (int8) vs 4 (f32) — a 4x cut of the
collective term for DP-dominated meshes; an optional 2-bit plane mode reuses
``core.decompose`` for 16x (2 bits + shared scale).
"""
from __future__ import annotations

from typing import Any, List, Tuple

import jax
import jax.numpy as jnp

from repro.core import decompose
from repro.kernels import ref


def compressed_psum(g: jax.Array, err: jax.Array, *, axis_name: str,
                    bits: int = 8) -> Tuple[jax.Array, jax.Array]:
    """Quantized psum of one tensor with error feedback.

    g, err: local f32 tensors (same shape).  Returns (mean_grad, new_err).
    Must be called inside shard_map/pmap over ``axis_name``."""
    assert bits in (2, 8)
    n_dev = jax.lax.psum(1, axis_name)
    corrected = g + err
    amax_local = jnp.max(jnp.abs(corrected))
    amax = jax.lax.pmax(amax_local, axis_name)         # scalar collective
    qmax = 127 if bits == 8 else 1
    # Shared reciprocal-multiply scale rule (kernels/ref.quant_scale): the
    # bare `/ qmax` here drifted 1 ulp between eager and jit (XLA
    # strength-reduction), which desynchronizes the globally-agreed scale.
    scale = ref.quant_scale(amax, qmax, eps=1e-12)
    q = jnp.clip(jnp.round(corrected / scale), -qmax - 1, qmax)
    new_err = corrected - q * scale                    # error feedback
    total: jax.Array
    if bits == 8:
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    else:
        # 2-bit plane mode: values in [-2, 1] = one Table-I MSB plane.
        planes = decompose.decompose_weights(q.astype(jnp.int32), 2,
                                             signed=True)
        total = jax.lax.psum(planes[0].astype(jnp.int32), axis_name)
    mean: jax.Array = total.astype(jnp.float32) * scale / n_dev
    return mean, new_err


def compressed_psum_tree(grads: Any, err_tree: Any, *, axis_name: str,
                         bits: int = 8) -> Tuple[Any, Any]:
    """Tree version; returns (mean_grads, new_err_tree)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_tree)
    out_g: List[jax.Array] = []
    out_e: List[jax.Array] = []
    for g, e in zip(flat_g, flat_e):
        mg, ne = compressed_psum(g.astype(jnp.float32), e,
                                 axis_name=axis_name, bits=bits)
        out_g.append(mg)
        out_e.append(ne)
    return (jax.tree.unflatten(treedef, out_g),
            jax.tree.unflatten(treedef, out_e))


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
