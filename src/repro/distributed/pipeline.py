"""Pipeline parallelism: GPipe-style microbatched execution over a "stage"
mesh axis using shard_map + collective_permute.

Orthogonal to the DP x TP production mesh (the dry-run uses 2D/3D meshes);
provided as the PP building block for depth-dominated models and validated
against sequential execution in tests (on fake CPU devices).
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import shard_map

StageFn = Callable[[Any, jax.Array], jax.Array]


def gpipe(stage_fn: StageFn, *, axis_name: str = "stage") -> StageFn:
    """Build a pipelined forward for ``y = stage_{S-1}(... stage_0(x))``.

    stage_fn(stage_params, x) -> y must be shape-preserving ([mb, ...] -> same),
    and is executed with this device's stage parameters.

    Returns pipe(stage_params_local, x_micro [n_micro, mb, ...]) to be called
    INSIDE shard_map(..., in_specs=(P('stage'), P(None))): every device sees
    all microbatches, computes only its stage, and activations flow stage ->
    stage+1 through collective_permute.  Output: [n_micro, mb, ...] valid on
    the last stage (replicated back by the caller if needed).
    """

    def pipe(stage_params: Any, x_micro: jax.Array) -> jax.Array:
        n_stages = jax.lax.psum(1, axis_name)
        stage = jax.lax.axis_index(axis_name)
        n_micro = x_micro.shape[0]
        total = n_micro + n_stages - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        buf = jnp.zeros_like(x_micro)                   # collected outputs
        carry = jnp.zeros_like(x_micro[0])              # inbound activation

        def tick(t: Any, state: Tuple[jax.Array, jax.Array]
                 ) -> Tuple[jax.Array, jax.Array]:
            carry, buf = state
            # Stage 0 injects microbatch t (when still available).
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            inject = jnp.where(t < n_micro, 1, 0)
            x_in = jnp.where((stage == 0) & (inject == 1),
                             x_micro[mb_idx], carry)
            y = stage_fn(stage_params, x_in)
            # Last stage banks microbatch (t - (n_stages-1)) when valid.
            out_idx = t - (n_stages - 1)
            valid_out = (stage == n_stages - 1) & (out_idx >= 0)
            safe_idx = jnp.clip(out_idx, 0, n_micro - 1)
            buf = jnp.where(valid_out,
                            buf.at[safe_idx].set(y), buf)
            # Ship activations to the next stage.
            carry = jax.lax.ppermute(y, axis_name, perm)
            return carry, buf

        state = jax.lax.fori_loop(0, total, tick, (carry, buf))
        out: jax.Array = state[1]
        return out

    return pipe


def run_pipeline(mesh: Mesh, stage_fn: StageFn, stage_params: Any,
                 x_micro: jax.Array,
                 axis_name: str = "stage") -> jax.Array:
    """Convenience wrapper: shard_map the gpipe over ``axis_name``.

    stage_params: pytree with leading stage dim; x_micro: [n_micro, mb, ...].
    Returns the last stage's outputs, gathered to all devices."""
    pipe = gpipe(stage_fn, axis_name=axis_name)

    def shmapped(sp: Any, xm: jax.Array) -> jax.Array:
        out = pipe(jax.tree.map(lambda a: a[0], sp), xm)
        # Broadcast the final stage's buffer to every stage.
        n_stages = jax.lax.psum(1, axis_name)
        stage = jax.lax.axis_index(axis_name)
        mask = (stage == n_stages - 1).astype(out.dtype)
        summed: jax.Array = jax.lax.psum(out * mask, axis_name)
        return summed

    f = shard_map(shmapped, mesh=mesh,
                  in_specs=(P(axis_name), P()), out_specs=P(),
                  check_vma=False)
    y: jax.Array = f(stage_params, x_micro)
    return y
