"""Sharding rules: logical-axis annotations -> PartitionSpecs on the
production mesh (pod, data, model).

Conventions (MaxText-style 2D weight sharding = FSDP x TP):
  * batch        -> ("pod", "data")       (DP across pods and the data axis)
  * d_model rows -> "data"                (FSDP: ZeRO-3-like weight sharding)
  * heads / d_ff / vocab cols -> "model"  (TP)
  * experts      -> "model" when divisible (EP), else 2D TP fallback
  * long-context KV -> "data" when batch < data axis (SP)

Model code annotates activations with :func:`shard` using *logical* names;
unknown/absent mesh axes degrade to no-op so the same model runs unsharded
on CPU tests.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# shard_map moved from jax.experimental to the jax top level across JAX
# releases, and its replication-check kwarg was renamed check_rep ->
# check_vma in the move.  Resolve both once here so every shard_map user
# (tp_matmul, pipeline, tests) works on both sides of the move; callers
# use the new-style ``check_vma`` spelling.
_shard_map: Callable[..., Any]
try:
    from jax.experimental.shard_map import shard_map as _shard_map
except ImportError:  # newer jax removed the experimental alias
    _shard_map = jax.shard_map


def shard_map(f: Callable[..., Any], *args: Any,
              check_vma: Optional[bool] = None,
              **kwargs: Any) -> Callable[..., Any]:
    import inspect
    if check_vma is not None:
        params = inspect.signature(_shard_map).parameters
        kwargs["check_vma" if "check_vma" in params else "check_rep"] = \
            check_vma
    wrapped: Callable[..., Any] = _shard_map(f, *args, **kwargs)
    return wrapped

Axis = Union[str, Sequence[str], None]
# A logical axis resolved against a concrete mesh.
Resolved = Union[str, tuple[str, ...], None]

# Logical name -> preferred mesh axes (first match present in mesh wins; for
# "batch" every present axis is used jointly).
LOGICAL_AXES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "fsdp": ("data",),
    "model": ("model",),
    "expert": ("model",),
    "seq": ("data",),      # sequence parallelism for long-context
    "none": (),
}


def current_mesh() -> Optional[Mesh]:
    """Mesh from the legacy `with mesh:` context (usable under jit tracing)."""
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        m: Mesh = getattr(jax.interpreters.pxla,
                          "thread_resources").env.physical_mesh
    return None if m.empty else m


def resolve_axis(mesh: Mesh, logical: Axis) -> Resolved:
    """Logical axis name -> mesh axis (or tuple) present in this mesh."""
    if logical is None:
        return None
    if isinstance(logical, (tuple, list)):
        found = tuple(a for a in logical if a in mesh.axis_names)
        return found if found else None
    prefs = LOGICAL_AXES.get(logical, (logical,))
    if logical == "batch":
        found = tuple(a for a in prefs if a in mesh.axis_names)
        return found if found else None
    for a in prefs:
        if a in mesh.axis_names:
            return a
    return None


def make_spec(mesh: Mesh, *logical_axes: Axis) -> P:
    return P(*[resolve_axis(mesh, a) for a in logical_axes])


def named_sharding(mesh: Mesh, *logical_axes: Axis) -> NamedSharding:
    return NamedSharding(mesh, make_spec(mesh, *logical_axes))


def shard(x: jax.Array, *logical_axes: Axis,
          divisible_only: bool = True) -> jax.Array:
    """with_sharding_constraint by logical axis names; no-op without a mesh.

    If a dimension does not divide the resolved mesh axes the annotation is
    dropped for that dim (keeps tiny smoke-test models runnable)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    resolved: list[Resolved] = []
    for dim, logical in zip(x.shape, logical_axes):
        axis = resolve_axis(mesh, logical)
        if axis is not None and divisible_only:
            n = 1
            for a in (axis if isinstance(axis, tuple) else (axis,)):
                n *= int(mesh.shape[a])
            if dim % n != 0:
                axis = None
        resolved.append(axis)
    out: jax.Array = jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))
    return out


def mesh_divides(mesh: Optional[Mesh], dim: int, logical: Axis) -> bool:
    if mesh is None:
        return False
    axis = resolve_axis(mesh, logical)
    if axis is None:
        return False
    n = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        n *= int(mesh.shape[a])
    return dim % n == 0
