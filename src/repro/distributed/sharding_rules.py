"""Parameter/batch/cache sharding rules for the production mesh.

2D weight sharding (FSDP over "data" x TP over "model"), EP for expert
weights when the expert count divides the model axis, replication for
vectors.  Rules match on parameter path suffixes produced by
``jax.tree_util.keystr`` (e.g. ``['periods']['pos0']['attn']['q_proj']['w']``).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shlib

# Trailing-dim logical spec: logical axis name (or None) per dim.
_Logical = Tuple[Optional[str], ...]

# (suffix substring, logical spec for the trailing dims).  First match wins.
# Stacked leading period dims are padded with None automatically.
_RULES: Tuple[Tuple[str, _Logical], ...] = (
    # MoE expert banks [E, d, f] / [E, f, d]: EP on E (checked divisible),
    # FSDP on the middle dim.
    ("['moe']['gate_proj']['w']", ("expert", "fsdp", None)),
    ("['moe']['up_proj']['w']", ("expert", "fsdp", None)),
    ("['moe']['down_proj']['w']", ("expert", "fsdp", None)),
    ("['moe']['router']['w']", (None, None)),
    # Attention / MLP projections [in, out].
    ("['q_proj']['w']", ("fsdp", "model")),
    ("['k_proj']['w']", ("fsdp", "model")),
    ("['v_proj']['w']", ("fsdp", "model")),
    ("['o_proj']['w']", ("model", "fsdp")),
    ("['gate_proj']['w']", ("fsdp", "model")),
    ("['up_proj']['w']", ("fsdp", "model")),
    ("['down_proj']['w']", ("model", "fsdp")),
    # SSM projections.
    ("['in_proj']['w']", ("fsdp", "model")),
    ("['out_proj']['w']", ("model", "fsdp")),
    # Embedding / head.
    ("['embed']['emb']", ("model", "fsdp")),
    ("['lm_head']['w']", ("fsdp", "model")),
)

_MOE_TP_FALLBACK: Dict[str, _Logical] = {
    "['moe']['gate_proj']['w']": (None, "fsdp", "model"),
    "['moe']['up_proj']['w']": (None, "fsdp", "model"),
    "['moe']['down_proj']['w']": (None, "model", "fsdp"),
}


def param_spec(mesh: Mesh, path: str, leaf: Any) -> P:
    ndim = int(np.ndim(leaf)) if not hasattr(leaf, "ndim") else int(leaf.ndim)
    is_planes = path.endswith(".planes")   # QuantizedWeight planes [..,P,K,N]
    for suffix, logical in _RULES:
        if suffix in path:
            # EP fallback: experts must divide the model axis.
            if suffix in _MOE_TP_FALLBACK:
                e = leaf.shape[-4] if is_planes else leaf.shape[-3]
                model_size = mesh.shape.get("model", 1)
                if e % model_size != 0:
                    logical = _MOE_TP_FALLBACK[suffix]
            if is_planes and len(logical) == 3:
                # Keep E on the expert dim; plane dim P replicated.
                logical = (logical[0], None) + tuple(logical[1:])
            lead = ndim - len(logical)
            axes: Tuple[shlib.Resolved, ...] = (None,) * lead + tuple(
                shlib.resolve_axis(mesh, a) for a in logical)
            # Drop annotations that do not divide.
            axes = tuple(
                a if a is not None and leaf.shape[i] % _axis_size(mesh, a) == 0
                else None
                for i, a in enumerate(axes))
            return P(*axes)
    return P()  # vectors / norms / biases: replicated


def _axis_size(mesh: Mesh, axis: shlib.Resolved) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= int(mesh.shape[a])
        return n
    return int(mesh.shape[axis])


def tree_shardings(mesh: Mesh, tree: Any) -> Any:
    """NamedSharding pytree for params / optimizer state / caches."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        out.append(NamedSharding(mesh, param_spec(mesh, path, leaf)))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_spec(mesh: Mesh, shape: Sequence[int]) -> P:
    """Batch sharded over (pod, data) when divisible; else replicated
    (e.g. long-context global_batch=1)."""
    ndim = len(shape)
    batch_axes = shlib.resolve_axis(mesh, "batch")
    if batch_axes is None or shape[0] % _axis_size(mesh, batch_axes) != 0:
        return P(*([None] * ndim))
    return P(batch_axes, *([None] * (ndim - 1)))


def batch_shardings(mesh: Mesh, batch: Any) -> Any:
    return jax.tree.map(
        lambda x: NamedSharding(mesh, batch_spec(mesh, np.shape(x))), batch)


def cache_spec(mesh: Mesh, path: str, leaf: Any) -> P:
    """KV/SSM caches: batch axis sharded (dim 1 after the stacked period
    dim 0); KV / SSM heads sharded over model when divisible; long-context
    KV falls back to sequence sharding (SP) when the batch does not divide."""
    ndim = int(leaf.ndim)
    if ndim < 4:
        return P()
    batch_axes = shlib.resolve_axis(mesh, "batch")
    model = shlib.resolve_axis(mesh, "model")
    axes: List[shlib.Resolved] = [None] * ndim
    if batch_axes is not None and leaf.shape[1] % _axis_size(mesh, batch_axes) == 0:
        axes[1] = batch_axes

    def try_axis(dim: int, ax: shlib.Resolved) -> None:
        if ax is not None and leaf.shape[dim] % _axis_size(mesh, ax) == 0:
            axes[dim] = ax

    leafname = path.rsplit(".", 1)[-1] if "." in path else path
    if leafname in ("k", "v"):
        # [periods, B, S, KVH, Dh]: TP over KV heads when they divide the
        # model axis, else over head_dim (both are update-index-free dims, so
        # decode's dynamic_update_slice stays local); SP over S if batch
        # could not shard (long-context, batch=1).
        try_axis(3, model)
        if axes[3] is None:
            try_axis(4, model)
        if axes[1] is None:
            try_axis(2, shlib.resolve_axis(mesh, "seq"))
    elif leafname in ("k_scale", "v_scale"):
        # [periods, B, S, KVH, 1]: follow the KV head sharding.
        try_axis(3, model)
        if axes[1] is None:
            try_axis(2, shlib.resolve_axis(mesh, "seq"))
    elif leafname == "state":
        try_axis(2, model)        # [periods, B, H, N, P]: TP over SSM heads
    elif leafname == "conv":
        try_axis(3, model)        # [periods, B, W, C]: TP over channels
    return P(*axes)


def cache_shardings(mesh: Mesh, cache_tree: Any) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    out = []
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        out.append(NamedSharding(mesh, cache_spec(mesh, path, leaf)))
    return jax.tree_util.tree_unflatten(treedef, out)


# --------------------------------------------------------------- serve TP
# ServeEngine(mesh=) layout — distinct from the training _RULES above: for
# bitwise token identity EVERY sharded projection is N-sharded on its LAST
# weight axis (an N-shard never splits a K-reduction; o/down get their full
# K via the quantized code gather — see distributed/tp_serve.py), and
# everything else (embed, norms, lm_head, MoE, SSM) is replicated.
_SERVE_TP_SHARDED = (
    "['attn']['q_proj']", "['attn']['o_proj']",
    "['mlp']['gate_proj']", "['mlp']['up_proj']", "['mlp']['down_proj']",
)
_SERVE_TP_KV = ("['attn']['k_proj']", "['attn']['v_proj']")


def serve_tp_param_spec(path: str, leaf: Any, *, n: int, kv_shards: bool,
                        axis: str = "model") -> P:
    """Spec for one prepared (QuantizedWeight) param leaf under serve TP.

    Shards the last axis of ``planes``/``packed``/``scale`` leaves of the
    TP projections (k/v only when ``kv_shards``); raises if a sharded axis
    does not divide — serve TP is exact-or-error, never silently partial
    (unlike the training rules above, which drop non-dividing axes)."""
    if not path.endswith((".planes", ".packed", ".scale")):
        return P()
    names = _SERVE_TP_SHARDED + (_SERVE_TP_KV if kv_shards else ())
    if not any(s in path for s in names):
        return P()
    if leaf.shape[-1] % n != 0:
        raise ValueError(
            f"serve TP: {path} last axis {leaf.shape[-1]} does not divide "
            f"across {n} devices")
    return P(*([None] * (leaf.ndim - 1)), axis)


def serve_tp_cache_spec(path: str, leaf: Any, *, n: int, kv_shards: bool,
                        axis: str = "model") -> P:
    """Spec for one stacked arena cache leaf ([periods, B, S, KVH, ...]):
    k/v stores and their scales shard over KV heads when ``kv_shards``;
    lengths, tier codes and SSM state stay replicated."""
    leafname = path.rsplit(".", 1)[-1] if "." in path else path
    if (kv_shards and leaf.ndim >= 5
            and leafname in ("k", "v", "k_scale", "v_scale")):
        if leaf.shape[3] % n != 0:
            raise ValueError(
                f"serve TP: {path} KV-head axis {leaf.shape[3]} does not "
                f"divide across {n} devices")
        axes: List[Optional[str]] = [None] * int(leaf.ndim)
        axes[3] = axis
        return P(*axes)
    return P()


def _serve_tp_specs(tree: Any, spec_fn: Callable[..., P], *, n: int,
                    kv_shards: bool, axis: str = "model") -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = [spec_fn(jax.tree_util.keystr(kp), leaf, n=n,
                   kv_shards=kv_shards, axis=axis) for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def serve_tp_param_specs(tree: Any, *, n: int, kv_shards: bool,
                         axis: str = "model") -> Any:
    """PartitionSpec pytree (same structure as ``tree``) for the prepared
    superplane store under serve TP."""
    return _serve_tp_specs(tree, serve_tp_param_spec, n=n,
                           kv_shards=kv_shards, axis=axis)


def serve_tp_cache_specs(tree: Any, *, n: int, kv_shards: bool,
                         axis: str = "model") -> Any:
    """PartitionSpec pytree for the stacked slot-arena caches."""
    return _serve_tp_specs(tree, serve_tp_cache_spec, n=n,
                           kv_shards=kv_shards, axis=axis)
