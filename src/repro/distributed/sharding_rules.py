"""Parameter/batch/cache sharding rules for the production mesh.

2D weight sharding (FSDP over "data" x TP over "model"), EP for expert
weights when the expert count divides the model axis, replication for
vectors.  Rules match on parameter path suffixes produced by
``jax.tree_util.keystr`` (e.g. ``['periods']['pos0']['attn']['q_proj']['w']``).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shlib

# (suffix substring, logical spec for the trailing dims).  First match wins.
# Stacked leading period dims are padded with None automatically.
_RULES: tuple[tuple[str, tuple], ...] = (
    # MoE expert banks [E, d, f] / [E, f, d]: EP on E (checked divisible),
    # FSDP on the middle dim.
    ("['moe']['gate_proj']['w']", ("expert", "fsdp", None)),
    ("['moe']['up_proj']['w']", ("expert", "fsdp", None)),
    ("['moe']['down_proj']['w']", ("expert", "fsdp", None)),
    ("['moe']['router']['w']", (None, None)),
    # Attention / MLP projections [in, out].
    ("['q_proj']['w']", ("fsdp", "model")),
    ("['k_proj']['w']", ("fsdp", "model")),
    ("['v_proj']['w']", ("fsdp", "model")),
    ("['o_proj']['w']", ("model", "fsdp")),
    ("['gate_proj']['w']", ("fsdp", "model")),
    ("['up_proj']['w']", ("fsdp", "model")),
    ("['down_proj']['w']", ("model", "fsdp")),
    # SSM projections.
    ("['in_proj']['w']", ("fsdp", "model")),
    ("['out_proj']['w']", ("model", "fsdp")),
    # Embedding / head.
    ("['embed']['emb']", ("model", "fsdp")),
    ("['lm_head']['w']", ("fsdp", "model")),
)

_MOE_TP_FALLBACK = {
    "['moe']['gate_proj']['w']": (None, "fsdp", "model"),
    "['moe']['up_proj']['w']": (None, "fsdp", "model"),
    "['moe']['down_proj']['w']": (None, "model", "fsdp"),
}


def param_spec(mesh: Mesh, path: str, leaf) -> P:
    ndim = np.ndim(leaf) if not hasattr(leaf, "ndim") else leaf.ndim
    is_planes = path.endswith(".planes")   # QuantizedWeight planes [..,P,K,N]
    for suffix, logical in _RULES:
        if suffix in path:
            # EP fallback: experts must divide the model axis.
            if suffix in _MOE_TP_FALLBACK:
                e = leaf.shape[-4] if is_planes else leaf.shape[-3]
                model_size = mesh.shape.get("model", 1)
                if e % model_size != 0:
                    logical = _MOE_TP_FALLBACK[suffix]
            if is_planes and len(logical) == 3:
                # Keep E on the expert dim; plane dim P replicated.
                logical = (logical[0], None) + tuple(logical[1:])
            lead = ndim - len(logical)
            axes = (None,) * lead + tuple(
                shlib.resolve_axis(mesh, a) for a in logical)
            # Drop annotations that do not divide.
            axes = tuple(
                a if a is not None and leaf.shape[i] % _axis_size(mesh, a) == 0
                else None
                for i, a in enumerate(axes))
            return P(*axes)
    return P()  # vectors / norms / biases: replicated


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def tree_shardings(mesh: Mesh, tree: Any):
    """NamedSharding pytree for params / optimizer state / caches."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        out.append(NamedSharding(mesh, param_spec(mesh, path, leaf)))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_spec(mesh: Mesh, shape) -> P:
    """Batch sharded over (pod, data) when divisible; else replicated
    (e.g. long-context global_batch=1)."""
    ndim = len(shape)
    batch_axes = shlib.resolve_axis(mesh, "batch")
    if batch_axes is None or shape[0] % _axis_size(mesh, batch_axes) != 0:
        return P(*([None] * ndim))
    return P(batch_axes, *([None] * (ndim - 1)))


def batch_shardings(mesh: Mesh, batch: Any):
    return jax.tree.map(
        lambda x: NamedSharding(mesh, batch_spec(mesh, np.shape(x))), batch)


def cache_spec(mesh: Mesh, path: str, leaf) -> P:
    """KV/SSM caches: batch axis sharded (dim 1 after the stacked period
    dim 0); KV / SSM heads sharded over model when divisible; long-context
    KV falls back to sequence sharding (SP) when the batch does not divide."""
    ndim = leaf.ndim
    if ndim < 4:
        return P()
    batch_axes = shlib.resolve_axis(mesh, "batch")
    model = shlib.resolve_axis(mesh, "model")
    axes = [None] * ndim
    if batch_axes is not None and leaf.shape[1] % _axis_size(mesh, batch_axes) == 0:
        axes[1] = batch_axes

    def try_axis(dim, ax):
        if ax is not None and leaf.shape[dim] % _axis_size(mesh, ax) == 0:
            axes[dim] = ax

    leafname = path.rsplit(".", 1)[-1] if "." in path else path
    if leafname in ("k", "v"):
        # [periods, B, S, KVH, Dh]: TP over KV heads when they divide the
        # model axis, else over head_dim (both are update-index-free dims, so
        # decode's dynamic_update_slice stays local); SP over S if batch
        # could not shard (long-context, batch=1).
        try_axis(3, model)
        if axes[3] is None:
            try_axis(4, model)
        if axes[1] is None:
            try_axis(2, shlib.resolve_axis(mesh, "seq"))
    elif leafname in ("k_scale", "v_scale"):
        # [periods, B, S, KVH, 1]: follow the KV head sharding.
        try_axis(3, model)
        if axes[1] is None:
            try_axis(2, shlib.resolve_axis(mesh, "seq"))
    elif leafname == "state":
        try_axis(2, model)        # [periods, B, H, N, P]: TP over SSM heads
    elif leafname == "conv":
        try_axis(3, model)        # [periods, B, W, C]: TP over channels
    return P(*axes)


def cache_shardings(mesh: Mesh, cache_tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    out = []
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        out.append(NamedSharding(mesh, cache_spec(mesh, path, leaf)))
    return jax.tree_util.tree_unflatten(treedef, out)
