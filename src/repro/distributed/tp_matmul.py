"""Manual tensor-parallel matmuls with QUANTIZED collectives (shard_map).

GSPMD places resharding collectives at the consuming op — on XLA:CPU that is
the f32-promoted dot operand, so the gathers move f32 and an int8 tensor
upstream does not help (§Perf iterations J3/L1, refuted under pjit).  This
module takes explicit control with the classic Megatron column/row-parallel
pair, using the paper's activation quantization as the *wire format*:

  column-parallel (W N-sharded):   y_n = gather_int8(x_sp) @ W[:, n]
  row-parallel (W K-sharded):      y_sp = psum_scatter_bf16(x_n @ W[k_n, :])

The all-gather moves int8 codes + per-row bf16 scales — 4x fewer bytes than
the f32 gather GSPMD emits on CPU (2x fewer than native-bf16 TPU); the
reduce moves bf16 scattered partials — 8x fewer than an f32 all-reduce.

Numerically validated against the unsharded reference on fake devices
(tests/test_tp_matmul.py).  Complements `compression.py` (DP gradients): the
same decomposition idea pointed at the TP axis.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import shard_map
from repro.kernels import ops


def _quantize_rows(x: jax.Array,
                   bits: int = 8) -> Tuple[jax.Array, jax.Array]:
    """Wire quantizer == compute quantizer.

    Routes through the shared kernels/act_quant implementation
    (``ops.quantize_activations`` — Pallas kernel on TPU, bit-identical
    jnp oracle elsewhere) so the wire format and the compute format cannot
    drift, and inherits the reciprocal-multiply scale (``ref.quant_scale``)
    whose bits are stable across eager/jit."""
    q, scale = ops.quantize_activations(x.astype(jnp.float32), a_bits=bits,
                                        signed=True)
    return q, scale.astype(jnp.bfloat16)


def column_parallel_quantized(x_sp: jax.Array, w_ncol: jax.Array, *,
                              axis_name: str) -> jax.Array:
    """INSIDE shard_map: y_n = full(x) @ W_ncol with an int8 gather.

    x_sp:   [..., K/n]  sequence/hidden-sharded activations (SP form).
    w_ncol: [K, N/n]    column-sharded weight.
    Returns [..., N/n].
    """
    q, scale = _quantize_rows(x_sp)
    # Gather int8 shards; tiled=True concatenates along the axis -> [..., K].
    q_all: jax.Array = jax.lax.all_gather(q, axis_name, axis=q.ndim - 1,
                                          tiled=True)
    s_all: jax.Array = jax.lax.all_gather(scale, axis_name,
                                          axis=scale.ndim - 1,
                                          tiled=True)       # [..., n]
    k_shard = x_sp.shape[-1]
    # Per-source-shard dequantization: expand scales across their K/n block.
    s_full = jnp.repeat(s_all, k_shard, axis=-1)            # [..., K]
    x_full = q_all.astype(jnp.bfloat16) * s_full
    return jnp.matmul(x_full, w_ncol.astype(jnp.bfloat16))


def row_parallel_scatter(x_n: jax.Array, w_krow: jax.Array, *,
                         axis_name: str) -> jax.Array:
    """INSIDE shard_map: y_sp = psum_scatter(x_n @ W_krow) in bf16.

    x_n:    [..., N/n]  column-sharded activations (this device's slice).
    w_krow: [N/n, K]    row-sharded weight (matching slice).
    Returns [..., K/n]  (SP-sharded output).
    """
    partial = jnp.matmul(x_n.astype(jnp.bfloat16),
                         w_krow.astype(jnp.bfloat16))       # [..., K]
    out: jax.Array = jax.lax.psum_scatter(partial, axis_name,
                                          scatter_dimension=partial.ndim - 1,
                                          tiled=True)
    return out


def tp_mlp_block(mesh: Mesh, x: jax.Array, w_up: jax.Array,
                 w_down: jax.Array, *, axis_name: str = "model",
                 activation: Callable[[jax.Array], jax.Array]
                 = jax.nn.gelu) -> jax.Array:
    """y = act(x @ w_up) @ w_down with quantized manual-TP collectives.

    x: [..., D] replicated on `axis_name`; w_up: [D, F]; w_down: [F, D].
    Returns [..., D] replicated (for comparison against the reference)."""
    n = int(mesh.shape[axis_name])
    d, f = w_up.shape
    assert d % n == 0 and f % n == 0

    def body(x_sp: jax.Array, w_up_loc: jax.Array,
             w_down_loc: jax.Array) -> jax.Array:
        h = column_parallel_quantized(x_sp, w_up_loc, axis_name=axis_name)
        h = activation(h.astype(jnp.float32)).astype(jnp.bfloat16)
        y_sp = row_parallel_scatter(h, w_down_loc, axis_name=axis_name)
        y: jax.Array = jax.lax.all_gather(y_sp, axis_name,
                                          axis=y_sp.ndim - 1, tiled=True)
        return y

    lead = tuple([None] * (x.ndim - 1))
    fm = shard_map(
        body, mesh=mesh,
        in_specs=(P(*lead, axis_name),       # x: SP on last dim
                  P(None, axis_name),        # w_up: N-sharded
                  P(axis_name, None)),       # w_down: K-sharded
        out_specs=P(),
        check_vma=False)
    out: jax.Array = fm(x, w_up, w_down)
    return out


def collective_bytes_per_token(d: int, f: int,
                               n_shards: int) -> Dict[str, float]:
    """Napkin math for §Perf: wire bytes per token for one MLP block."""
    gather_int8 = d * 1 + (d // (d // n_shards)) * 2        # codes + scales
    gather_f32 = d * 4                                      # GSPMD on CPU
    gather_bf16 = d * 2                                     # native-TPU GSPMD
    scatter_bf16 = d * 2                                    # psum_scatter
    allreduce_f32 = d * 4 * 2                               # AR moves ~2x
    return {
        "gather_int8": gather_int8,
        "vs_f32": gather_f32 / gather_int8,
        "vs_bf16": gather_bf16 / gather_int8,
        "reduce_scatter_bf16": scatter_bf16,
        "vs_allreduce_f32": allreduce_f32 / scatter_bf16,
    }
