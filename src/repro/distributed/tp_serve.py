"""Tensor-parallel serving collectives: the quantized wire INSIDE the
jitted decode scan, bit-identical to the unsharded engine.

``tp_matmul`` proved the wire format on a standalone MLP block; this module
plugs the same idea into ``ServeEngine``'s hot path so ONE engine spans a
mesh.  Layout (deliberately not classic Megatron column/row pairs):

* EVERY sharded projection is N-sharded on its LAST weight axis — q/k/v
  over heads, gate/up over d_ff, AND o_proj/down_proj over d_model.  An
  N-shard never splits a K-reduction, so each device's integer GEMM is an
  exact column slice of the unsharded accumulator; classic row-parallel
  o/down would psum CONTINUOUS partials, whose float summation order is
  device-count-dependent and breaks token identity.
* q/k/v/gate/up read the REPLICATED residual: activation quantization sees
  the full row on every device, so codes and scales are bitwise equal to
  the unsharded engine's with no collective at all.
* o_proj/down_proj read FEATURE-SHARDED inputs (local attention heads /
  local d_ff).  The exactness chain: local ``amax`` -> ``lax.pmax`` (max is
  exact) -> the mesh-shared scale equals the unsharded per-row scale ->
  local codes are an exact K-slice of the unsharded codes -> all-gather the
  CODES (int8, or bit-packed at 4/2-bit tiers — THE quantized wire) ->
  full-K integer GEMM against the local N-shard -> elementwise dequant ->
  all-gather bf16 outputs back to the replicated residual.  Every step is
  either exact integer math or the very same f32 ops the unsharded graph
  runs, so tokens match bit for bit.
* Scales never ride the wire: the pmax already left the per-row f32 scale
  replicated (an improvement over ``tp_matmul``'s bf16-scale gather).

Plane-prefix truncation commutes with this sharding because superplane
codes are per-COLUMN: truncating then slicing columns equals slicing then
truncating, so all tier machinery (mixed row groups, ``fused_decode``,
mid-stream migration) works unchanged on shards — see
``tests/test_sharded_serving.py`` and docs/distributed.md.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

# Projections that read feature-sharded inputs and therefore need the
# quantized gather.  Matched on the layer-name suffix ``models/layers``
# passes to ``linear`` (``layers.pos{i}.attn.o_proj`` etc.); ``.moe.`` and
# ``.mamba.`` projections stay replicated and never match.
_GATHERED_SUFFIXES = (".attn.o_proj", ".mlp.down_proj")


@dataclasses.dataclass(frozen=True)
class TPConfig:
    """Static tensor-parallel context threaded through ``Runtime.tp``.

    Hashable (it rides jit-static Runtime fields): ``n`` devices on mesh
    axis ``axis``; ``kv_shards`` says whether k/v projections and the KV
    arena shard over KV heads (requires ``num_kv_heads % n == 0``) or stay
    replicated (the MQA ``num_kv_heads == 1`` fallback, where every local
    query head reads the one shared KV head)."""

    n: int
    axis: str = "model"
    kv_shards: bool = True

    def gathers(self, name: str) -> bool:
        """True for projections whose input is feature-sharded (o/down)."""
        return name.endswith(_GATHERED_SUFFIXES)


# ------------------------------------------------------- mesh-shared ranges
def _act_quant_pmax(x: jax.Array, bits: int,
                    axis_name: str) -> Tuple[jax.Array, jax.Array]:
    """``ref.act_quant_ref`` (signed) with the row max shared by ``pmax``.

    ``x`` holds each row's K-shard; the max over the full row is the max of
    the shard maxima (exact), so scale and codes are bitwise equal to the
    unsharded oracle's — each device ends up with the K-slice of the exact
    unsharded codes plus the replicated f32 scale."""
    qmax = (1 << (bits - 1)) - 1
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    amax = jax.lax.pmax(amax, axis_name)
    scale = ref.quant_scale(amax, qmax)
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _act_quant_rows_pmax(x: jax.Array, row_groups: Any,
                         perm: Optional[jax.Array],
                         axis_name: str) -> Tuple[jax.Array, jax.Array]:
    """``ops._quantize_activations_rows``'s oracle branch with pmax ranges.

    Mirrors the unsharded helper exactly — un-permuted full-batch pass with
    a per-row f32 qmax, results gathered by ``perm`` — so mixed-tier rows
    keep the bitwise-stability contract across the mesh."""
    lead, k = x.shape[:-1], x.shape[-1]
    qmax_sorted = jnp.asarray(np.concatenate([
        np.full((rows,), float((1 << (g.a_bits - 1)) - 1), np.float32)
        for rows, g in row_groups]))
    if perm is not None:
        qmax_rows = jnp.take(qmax_sorted, jnp.argsort(perm), axis=0)
    else:
        qmax_rows = qmax_sorted
    shape = (x.shape[0],) + (1,) * (x.ndim - 1)
    qmax_full = jnp.broadcast_to(qmax_rows.reshape(shape),
                                 (*lead, 1)).reshape(-1, 1)
    x2 = x.astype(jnp.float32).reshape(-1, k)
    amax = jnp.max(jnp.abs(x2), axis=-1, keepdims=True)
    amax = jax.lax.pmax(amax, axis_name)
    scale = ref.quant_scale(amax, qmax_full)
    q = jnp.clip(jnp.round(x2 / scale), -qmax_full - 1.0,
                 qmax_full).astype(jnp.int8)
    s = scale.astype(jnp.float32)
    qr, sr = q.reshape(*lead, k), s.reshape(*lead, 1)
    if perm is not None:
        qr = jnp.take(qr, perm, axis=0)
        sr = jnp.take(sr, perm, axis=0)
    return qr, sr


# -------------------------------------------------- bit-serial wire format
def wire_pack(q: jax.Array, bits: int) -> jax.Array:
    """Pack signed ``bits``-wide int8 codes, ``8 // bits`` per byte.

    [..., K] -> uint8 [..., K * bits / 8]; code ``j`` of a block lands at
    bit offset ``bits * j`` (two's complement at width ``bits``).  Packing
    is per-K-block and in-order, so it commutes with a tiled all-gather
    along K: unpack(gather(pack(q))) == gather(q)."""
    f = 8 // bits
    mask = jnp.uint8((1 << bits) - 1)
    u = q.astype(jnp.uint8) & mask
    blk = u.reshape(*u.shape[:-1], u.shape[-1] // f, f)
    packed: jax.Array = functools.reduce(
        jnp.bitwise_or,
        [blk[..., j] << jnp.uint8(bits * j) for j in range(f)])
    return packed


def wire_unpack(p: jax.Array, bits: int) -> jax.Array:
    """Inverse of :func:`wire_pack`: uint8 [..., K*bits/8] -> int8 [..., K]
    with sign extension from width ``bits``."""
    f = 8 // bits
    mask = jnp.uint8((1 << bits) - 1)
    fields = jnp.stack([(p >> jnp.uint8(bits * j)) & mask for j in range(f)],
                       axis=-1)
    u = fields.reshape(*p.shape[:-1], p.shape[-1] * f).astype(jnp.int8)
    half = jnp.int8(1 << (bits - 1))
    return jnp.where(u >= half, u - jnp.int8(1 << bits), u)


def wire_bytes_per_element(a_bits: int, signed: bool = True) -> float:
    """Wire bytes per gathered activation element under the bit-serial
    format: 8/6-bit tiers ride raw int8 (1 byte), 4/2-bit tiers pack 2/4
    codes per byte.  The f32 baseline is 4 bytes."""
    return a_bits / 8.0 if signed and a_bits in (2, 4) else 1.0


def gather_codes(q: jax.Array, bits: int, axis_name: str, *,
                 signed: bool = True) -> jax.Array:
    """All-gather activation codes tiled along K — the quantized wire.

    4/2-bit tiers travel bit-packed (uint8, ``8 // bits`` codes per byte)
    when the local K divides the pack factor; 8/6-bit tiers and unsigned
    codes travel as raw int8.  Returns the full-K int8 codes, identical on
    every device to the unsharded quantizer's output."""
    f = 8 // bits if bits in (2, 4) else 1
    if signed and f > 1 and q.shape[-1] % f == 0:
        p = wire_pack(q, bits)
        p_all: jax.Array = jax.lax.all_gather(p, axis_name, axis=p.ndim - 1,
                                              tiled=True)
        return wire_unpack(p_all, bits)
    q_all: jax.Array = jax.lax.all_gather(q, axis_name, axis=q.ndim - 1,
                                          tiled=True)
    return q_all


# ----------------------------------------------------- gathered projections
def gathered_matmul(x: jax.Array, qw: Any, prec: Any, *, tp: TPConfig,
                    out_dtype: Any = None) -> jax.Array:
    """One o/down projection under TP, single precision (inside shard_map).

    x: [..., K/n] feature-sharded input; qw: the local weight N-shard with
    FULL K rows.  Quantize with the pmax-shared range, gather codes over
    the wire, run the local plane-prefix GEMM + dequant (the same
    ``ops.dequant_matmul`` graph as unsharded), and gather the bf16 output
    columns back to the replicated [..., N_full]."""
    if not prec.a_signed:
        raise ValueError("TP gathered projections need signed activations "
                         "(the pmax-shared range is symmetric)")
    out_dtype = x.dtype if out_dtype is None else out_dtype
    q, s = _act_quant_pmax(x.astype(jnp.float32), prec.a_bits, tp.axis)
    q_all = gather_codes(q, prec.a_bits, tp.axis, signed=prec.a_signed)
    y_loc = ops.dequant_matmul(q_all, s, qw, prec, out_dtype)
    y: jax.Array = jax.lax.all_gather(y_loc, tp.axis, axis=y_loc.ndim - 1,
                                      tiled=True)
    return y


def gathered_grouped_matmul(x: jax.Array, qw: Any, row_groups: Any,
                            perm: Optional[jax.Array], *,
                            tp: TPConfig) -> jax.Array:
    """Mixed-tier o/down projection under TP (inside shard_map).

    The sharded twin of ``ops.fused_decode_linear``: ONE pmax-ranged
    activation quantization over the full un-permuted batch, per-GROUP
    quantized gathers (each group's rows travel at ITS ``a_bits`` — the
    bit-serial wire), then the unchanged group-switching GEMM + dequant
    epilogue via ``pre_quant``, and the bf16 output gather.  Returns
    PERMUTED (group-sorted) rows like the unsharded path."""
    if not all(g.a_signed for _, g in row_groups):
        raise ValueError("TP mixed-tier decode needs signed activations")
    configs = tuple(dict.fromkeys(g.a_bits for _, g in row_groups))
    if len(configs) == 1:
        q, s = _act_quant_pmax(x.astype(jnp.float32), configs[0], tp.axis)
        if perm is not None:
            q = jnp.take(q, perm, axis=0)
            s = jnp.take(s, perm, axis=0)
    else:
        q, s = _act_quant_rows_pmax(x, row_groups, perm, tp.axis)
    gathered = []
    off = 0
    for rows, g in row_groups:
        gathered.append(gather_codes(q[off:off + rows], g.a_bits, tp.axis))
        off += rows
    q_all = jnp.concatenate(gathered, axis=0)
    y_loc = ops.fused_decode_linear(x, qw, row_groups, perm,
                                    pre_quant=(q_all, s),
                                    out_dtype=x.dtype)
    y: jax.Array = jax.lax.all_gather(y_loc, tp.axis, axis=y_loc.ndim - 1,
                                      tiled=True)
    return y


# --------------------------------------------------------------- accounting
def decode_wire_stats(cfg: Any, tp: TPConfig,
                      groups: Any) -> Dict[str, float]:
    """Analytic wire bytes for ONE decode step of the whole stack.

    ``groups``: the static ``(rows, a_bits)`` pairs of the decode batch (a
    free-slot row rides its group like the real layout).  Per period the
    quantized wire carries the o_proj gather (H*Dh elements per row) and
    the down_proj gather (d_ff elements per row) at each row's wire width;
    each of the ``n`` devices transmits its 1/n shard to the other n-1
    peers (ring all-gather).  The bf16 output gathers and the 4-byte pmax
    scalars are reported separately; the f32 baseline prices the SAME
    gathered elements at 4 bytes."""
    n = tp.n
    pattern = cfg.period_pattern() * cfg.n_periods
    attn_layers = sum(1 for mixer, _ in pattern if mixer == "attn")
    mlp_layers = sum(1 for _, ff in pattern if ff == "mlp")
    per_row = attn_layers * cfg.num_heads * (cfg.head_dim or 0) \
        + mlp_layers * cfg.d_ff
    gathers = attn_layers + mlp_layers
    quant = 0.0
    base_f32 = 0.0
    elems = 0.0                       # elements actually transmitted
    for rows, a_bits in groups:
        bpe = wire_bytes_per_element(a_bits)
        quant += rows * per_row * bpe * (n - 1) / n
        base_f32 += rows * per_row * 4.0 * (n - 1) / n
        elems += rows * per_row * (n - 1) / n
    rows_total = sum(r for r, _ in groups)
    out_bf16 = rows_total * cfg.d_model * 2.0 * gathers * (n - 1) / n
    pmax = rows_total * 4.0 * gathers * (n - 1) / n
    return {
        "quant_gather_bytes": quant,
        "f32_gather_bytes": base_f32,
        "out_gather_bytes": out_bf16,
        "pmax_bytes": pmax,
        "gathered_elements": elems,
        "bytes_per_element": quant / elems if elems else 0.0,
        "vs_f32": base_f32 / quant if quant else float("inf"),
    }
