"""Analytic hardware model of the paper's accelerator (28 nm, 64x64 array):
structural adder-tree costs (Table II), PE/accelerator energy (Table III,
Fig 8), area/power breakdown (Fig 7), MobileNetV2 workload (§IV)."""
from repro.hwmodel import adder_tree_cost, breakdown, energy, mobilenet  # noqa: F401
