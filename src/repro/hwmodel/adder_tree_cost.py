"""Structural cost model of the paper's split-path CSA tree vs a binary
adder tree (BAT) — reproduces Table II.

Counts full/half adders from an explicit Wallace (3:2) reduction of the bit
matrix for the CSA paths and a CPA cascade for the BAT, then maps counts to
area/power with unit gate costs plus per-path activity factors.  The
*structure* (CSA needs fewer adders; the MSB path idles on unsigned inputs)
is derived; the two activity constants are calibrated to the paper's
measured power ratios (§IV, Table II) and documented as such.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

# Unit costs in gate equivalents (typical std-cell figures).
GE_FA = 6.0
GE_HA = 3.0
GE_REG_BIT = 4.0

# Shared fixed overhead (pipeline/output registers + wiring), identical for
# both trees.  Calibrated once so the *area* ratio matches Table II; the
# same constant then enters both power models.
SHARED_OVERHEAD_GE = 426.0

# BAT sign-extension invalid-carry toggle penalty (paper §III-C motivation).
ACT_SIGN_EXT_PENALTY = 1.12


def wallace_reduce(col_heights: List[int]) -> Tuple[int, int, List[int]]:
    """Reduce a bit-matrix to height <= 2 with 3:2 / 2:2 counters.

    Returns (full_adders, half_adders, final column heights)."""
    fas = has = 0
    heights = list(col_heights)
    while heights and max(heights) > 2:
        new = [0] * (len(heights) + 1)
        for i, h in enumerate(heights):
            fa = h // 3
            rem = h % 3
            ha = 1 if rem == 2 else 0
            fas += fa
            has += ha
            new[i] += fa + ha + (1 if rem == 1 else 0)
            new[i + 1] += fa + ha
        while new and new[-1] == 0:
            new.pop()
        heights = new
    return fas, has, heights


def cpa_fa_count(width: int) -> int:
    """Ripple/other CPA of `width` bits ~ width full adders."""
    return width


@dataclasses.dataclass
class TreeCost:
    fa: int
    ha: int
    cpa_fa: int

    @property
    def area_ge(self) -> float:
        return GE_FA * (self.fa + self.cpa_fa) + GE_HA * self.ha


def bat_cost(n_inputs: int = 64, in_bits: int = 3) -> TreeCost:
    """Binary adder tree: log2(n) levels of CPAs of growing width, summing
    `in_bits`-bit signed numbers (width grows 1 bit per level)."""
    fa = 0
    n = n_inputs
    w = in_bits
    while n > 1:
        fa += (n // 2) * cpa_fa_count(w)
        n //= 2
        w += 1
    return TreeCost(fa=fa, ha=0, cpa_fa=0)


def csa_split_cost(n_inputs: int = 64) -> TreeCost:
    """Paper's split tree: Wallace over the low 2 bits (unsigned) + popcount
    Wallace over the MSBs + merge CPA."""
    fa_lo, ha_lo, cols_lo = wallace_reduce([n_inputs, n_inputs])
    fa_msb, ha_msb, cols_msb = wallace_reduce([n_inputs])
    # Final CPAs: low path (to 9 bits) + merge of high 7 bits with popcount.
    cpa = cpa_fa_count(9) + cpa_fa_count(7)
    return TreeCost(fa=fa_lo + fa_msb, ha=ha_lo + ha_msb, cpa_fa=cpa)


def low_msb_split(n_inputs: int = 64
                  ) -> Tuple[Tuple[int, int], Tuple[int, int]]:
    fa_lo, ha_lo, _ = wallace_reduce([n_inputs, n_inputs])
    fa_msb, ha_msb, _ = wallace_reduce([n_inputs])
    return (fa_lo, ha_lo), (fa_msb, ha_msb)


PAPER_TABLE2 = {"area": 0.8486, "power_unsigned": 0.6897,
                "power_signed": 0.7772}


def _activity_factors(n_inputs: int = 64
                      ) -> Tuple[float, float, float, float, float]:
    """Solve the two path-activity factors so the power model reproduces the
    measured Table II ratios exactly (documented calibration; the structural
    counts above are derived, only these two scalars are fit).

      unsigned: (a_low*LO + REG) / P_bat = 0.6897  (MSB path all-zero)
      signed:   (a_low*LO + a_msb*MSB + REG) / P_bat = 0.7772
    """
    bat = bat_cost(n_inputs)
    (fa_lo, ha_lo), (fa_msb, ha_msb) = low_msb_split(n_inputs)
    lo_ge = fa_lo * GE_FA + ha_lo * GE_HA + 9 * GE_FA
    msb_ge = fa_msb * GE_FA + ha_msb * GE_HA + 7 * GE_FA
    p_bat = bat.fa * GE_FA * ACT_SIGN_EXT_PENALTY + SHARED_OVERHEAD_GE
    a_low = (PAPER_TABLE2["power_unsigned"] * p_bat - SHARED_OVERHEAD_GE) / lo_ge
    a_msb = ((PAPER_TABLE2["power_signed"] - PAPER_TABLE2["power_unsigned"])
             * p_bat) / msb_ge
    return a_low, a_msb, lo_ge, msb_ge, p_bat


def table2_model(n_inputs: int = 64) -> Dict[str, float]:
    """Returns normalized (area, power_unsigned, power_signed) of the CSA
    split tree relative to the BAT — compare with Table II:
    0.8486 / 0.6897 / 0.7772."""
    bat = bat_cost(n_inputs)
    csa = csa_split_cost(n_inputs)
    area_ratio = (csa.area_ge + SHARED_OVERHEAD_GE) \
        / (bat.area_ge + SHARED_OVERHEAD_GE)

    a_low, a_msb, lo_ge, msb_ge, p_bat = _activity_factors(n_inputs)
    lo_power = a_low * lo_ge + SHARED_OVERHEAD_GE
    power_unsigned = lo_power / p_bat          # MSB path all-zero: no toggles
    power_signed = (lo_power + a_msb * msb_ge) / p_bat
    return {
        "area": area_ratio,
        "power_unsigned": power_unsigned,
        "power_signed": power_signed,
        "bat_fa": bat.fa,
        "csa_fa": csa.fa + csa.cpa_fa,
        "csa_ha": csa.ha,
        "activity_low": a_low,
        "activity_msb": a_msb,
    }
