"""PE-array area/power breakdown (Fig 7) from structural gate-equivalent
counts.  Validates the paper's headline: the Fig-4 independent shift-add
paths for 6/7-bit operation cost only ~0.97 % of PE-array area.
"""
from __future__ import annotations

from typing import Dict

from repro.hwmodel.adder_tree_cost import (GE_FA, GE_HA, GE_REG_BIT,
                                           SHARED_OVERHEAD_GE,
                                           csa_split_cost)

ROWS, COLS, GROUPS = 64, 64, 16

# Per-unit gate-equivalent estimates (28 nm std-cell ballpark, documented).
GE_MULT_3B = 8.0        # 3-bit x 1-bit multiplier (gated ANDs + sign ext)
GE_W_REG = 3 * GE_REG_BIT     # 3-bit weight register per PE
GE_ACT_PIPE = 1 * GE_REG_BIT  # systolic activation pipeline bit per PE
ACC_BITS = 24
GE_SHIFTER = 18.0       # two-case configurable shifter (Table I)
SA_BITS = 12
GE_SA_PATH = 2 * GE_SHIFTER + SA_BITS * GE_FA + SA_BITS * GE_REG_BIT
GE_INDEP_PATH = GE_SA_PATH + 276.0   # Fig-4 path + cross-group routing/muxes


def area_breakdown() -> Dict[str, float]:
    tree = csa_split_cost(ROWS)
    areas = {
        "multipliers": ROWS * COLS * GE_MULT_3B,
        "weight_regs": ROWS * COLS * GE_W_REG,
        "act_pipeline": ROWS * COLS * GE_ACT_PIPE,
        "adder_trees": COLS * (tree.area_ge + SHARED_OVERHEAD_GE),
        "accumulators": COLS * ACC_BITS * (GE_FA + GE_REG_BIT),
        "shift_add": GROUPS * 3 * GE_SA_PATH / 2,   # shifters #0/#1/#2 (Fig 5)
        "indep_shift_add": 5 * GE_INDEP_PATH,       # Fig 4 (6/7-bit mode)
    }
    return areas


def area_fractions() -> Dict[str, float]:
    a = area_breakdown()
    tot = sum(a.values())
    return {k: v / tot for k, v in a.items()}


def indep_path_fraction() -> float:
    """Paper: 0.97 % of PE-array area."""
    return area_fractions()["indep_shift_add"]


PAPER_INDEP_FRACTION = 0.0097


def power_breakdown(w_bits: int = 8, a_bits: int = 8) -> Dict[str, float]:
    """Relative dynamic power by component at 8/8-bit (Fig 7b shape):
    activity-weighted areas (registers toggle every cycle, multipliers at
    the input toggle rate, shift-add at clk/a)."""
    a = area_breakdown()
    act = {
        "multipliers": 0.5, "weight_regs": 0.05, "act_pipeline": 1.0,
        "adder_trees": 0.6, "accumulators": 1.0,
        "shift_add": 1.0 / a_bits, "indep_shift_add": 0.0,
    }
    p = {k: a[k] * act[k] for k in a}
    tot = sum(p.values())
    return {k: v / tot for k, v in p.items()}
