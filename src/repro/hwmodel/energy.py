"""PE-array / accelerator energy model — reproduces §IV (Table III, Fig 8).

Throughput is *derived* from the architecture (``core.pe_array.peak_tops``:
plane count, column grouping, bit-serial cycles).  Power is a 4-coefficient
linear model over structural features (accumulator width, multi-plane
combine activity, shift-add clock ratio) solved exactly against the paper's
four measured PE-array efficiency points @0.72 V / 500 MHz:

    8/8: 14   4/4: 52.1   3/3: 139.8   2/2: 205.8   TOPS/W

A striking structural fact falls out: the implied array power is ~9.1-9.9 mW
across ALL precision modes — the efficiency scaling is almost purely the
ops/cycle scaling of the weight-combination scheme, which is the paper's
central claim.

Accelerator-level numbers apply one overhead factor (buffers + control):
the paper's own three points give 14/4.69 = 52.1/17.45 = 205.8/68.94 = 2.985
(constant across precisions — a strong internal-consistency validation).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

import numpy as np
import numpy.typing as npt

from repro.core import pe_array

CAL_VOLTAGE = 0.72
CAL_FREQ_MHZ = 500.0
CAL_TOGGLE = 0.5              # 50 % weight sparsity in §IV
PEAK_VOLTAGE = 1.05
PEAK_FREQ_MHZ = 1000.0

PAPER_PE_EFF = {(8, 8): 14.0, (4, 4): 52.1, (3, 3): 139.8, (2, 2): 205.8}
PAPER_ACCEL_EFF = {(8, 8): 4.69, (4, 4): 17.45, (2, 2): 68.94}
PAPER_PEAK_TOPS = 4.09
ACCEL_OVERHEAD = 2.985        # buffers/NoC/control power factor (see above)
STATIC_FRACTION = 0.12        # leakage share at the calibration point

_CFG = pe_array.PEArrayConfig(clk_mhz=CAL_FREQ_MHZ)


def tops(w_bits: int, a_bits: int, *, freq_mhz: float = CAL_FREQ_MHZ) -> float:
    cfg = dataclasses.replace(_CFG, clk_mhz=freq_mhz)
    return float(pe_array.peak_tops(cfg, w_bits, a_bits))


def _features(w_bits: int, a_bits: int) -> npt.NDArray[np.float64]:
    from repro.core import decompose
    acc_width = (w_bits + a_bits + 6) / 16.0       # +log2(64 rows)
    multi_plane = 1.0 if decompose.num_planes(w_bits) > 1 else 0.0
    return np.array([1.0, acc_width, multi_plane, 1.0 / a_bits])


def _solve_power_coeffs() -> npt.NDArray[np.float64]:
    pts = sorted(PAPER_PE_EFF)
    feats = np.stack([_features(w, a) for w, a in pts])
    targets = np.array([tops(w, a) / PAPER_PE_EFF[(w, a)] for w, a in pts])
    return np.linalg.solve(feats, targets)


_COEFFS = _solve_power_coeffs()


def pe_power_w(w_bits: int, a_bits: int, *, toggle: float = CAL_TOGGLE,
               voltage: float = CAL_VOLTAGE,
               freq_mhz: float = CAL_FREQ_MHZ) -> float:
    """Array power in watts; V^2*f dynamic scaling + toggle-rate scaling."""
    p_cal = float(_features(w_bits, a_bits) @ _COEFFS)
    p_static = STATIC_FRACTION * p_cal
    p_dyn = (p_cal - p_static) * (toggle / CAL_TOGGLE)
    vf = (voltage / CAL_VOLTAGE) ** 2 * (freq_mhz / CAL_FREQ_MHZ)
    return p_dyn * vf + p_static * (voltage / CAL_VOLTAGE)


def pe_efficiency(w_bits: int, a_bits: int, *, toggle: float = CAL_TOGGLE,
                  voltage: float = CAL_VOLTAGE,
                  freq_mhz: float = CAL_FREQ_MHZ) -> float:
    """TOPS/W of the PE array."""
    return tops(w_bits, a_bits, freq_mhz=freq_mhz) / pe_power_w(
        w_bits, a_bits, toggle=toggle, voltage=voltage, freq_mhz=freq_mhz)


def accelerator_efficiency(w_bits: int, a_bits: int, **kw: float) -> float:
    return pe_efficiency(w_bits, a_bits, **kw) / ACCEL_OVERHEAD


def peak_throughput_tops() -> float:
    """Peak accelerator throughput: 2/2-bit @ 1 GHz (paper: 4.09)."""
    return tops(2, 2, freq_mhz=PEAK_FREQ_MHZ)


def energy_per_mac_j(w_bits: int, a_bits: int, *, accelerator: bool = True,
                     **kw: float) -> float:
    eff = accelerator_efficiency(w_bits, a_bits, **kw) if accelerator \
        else pe_efficiency(w_bits, a_bits, **kw)
    return 2.0 / (eff * 1e12)          # 2 ops per MAC


def fig8_curve(w_bits: int, a_bits: int,
               toggles: Sequence[float] = (0.1, 0.2, 0.3, 0.4, 0.5,
                                           0.6, 0.7, 0.8, 0.9)
               ) -> Dict[float, float]:
    """Energy efficiency vs input toggle rate (Fig 8 family of curves)."""
    return {t: pe_efficiency(w_bits, a_bits, toggle=t) for t in toggles}


def table3_ours() -> Dict[str, float]:
    return {
        "tech_nm": 28.0,
        "area_mm2": 0.75,
        "freq_mhz": PEAK_FREQ_MHZ,
        "peak_tops": peak_throughput_tops(),
        "eff_8bit": accelerator_efficiency(8, 8),
        "eff_4bit": accelerator_efficiency(4, 4),
        "eff_2bit": accelerator_efficiency(2, 2),
    }


# ------------------------------------------------------- runtime tier costs
def tier_cost(w_bits: int, a_bits: int, *, freq_mhz: float = CAL_FREQ_MHZ,
              toggle: float = CAL_TOGGLE) -> Dict[str, float]:
    """Cycle/energy cost of serving at an EFFECTIVE precision.

    Runtime plane-prefix truncation means a request's tier — not the stored
    8-bit superplane — sets the work: the array runs ``w_bits/2`` plane
    passes (MXU passes on the TPU analogue) at an activation bit-serial
    depth of ``a_bits`` cycles.  These are the per-tier numbers the
    ``serve_precision_tiers`` benchmark reports."""
    from repro.core import decompose
    return {
        "plane_passes": float(decompose.num_planes(w_bits)),
        "bitserial_depth": float(a_bits),
        "cycles_per_mac": cycles_per_mac(w_bits, a_bits, freq_mhz=freq_mhz),
        "effective_tops": tops(w_bits, a_bits, freq_mhz=freq_mhz),
        "tops_per_w": pe_efficiency(w_bits, a_bits, toggle=toggle,
                                    freq_mhz=freq_mhz),
        "energy_per_mac_j": energy_per_mac_j(w_bits, a_bits, toggle=toggle,
                                             freq_mhz=freq_mhz),
    }


def precision_tier_table(tiers: Dict[str, Tuple[int, int]],
                         **kw: float) -> Dict[str, Dict[str, float]]:
    """Per-tier cost table for ``{tier_name: (w_bits, a_bits)}``."""
    return {name: tier_cost(w, a, **kw) for name, (w, a) in tiers.items()}


@functools.lru_cache(maxsize=None)
def cycles_per_mac(w_bits: int, a_bits: int, *,
                   freq_mhz: float = CAL_FREQ_MHZ) -> float:
    """Array cycles one MAC occupies at an effective (w_bits, a_bits).

    The scalar hot path of :func:`tier_cost` (cached: the search loops in
    ``repro.autoprec`` price thousands of candidate assignments against a
    handful of distinct operating points)."""
    cfg = dataclasses.replace(_CFG, clk_mhz=freq_mhz)
    n_logical, _ = pe_array.logical_columns_per_pass(cfg, w_bits)
    return float(a_bits) / (float(cfg.rows) * float(n_logical))


@functools.lru_cache(maxsize=None)
def _energy_per_mac_cached(w_bits: int, a_bits: int) -> float:
    return energy_per_mac_j(w_bits, a_bits)


def per_layer_cost(mac_counts: Sequence[float],
                   w_bits: Sequence[int],
                   a_bits: int) -> Dict[str, npt.NDArray[np.float64]]:
    """Vectorized per-layer pricing of one precision assignment.

    ``mac_counts[i]`` MACs served at ``w_bits[i]`` effective weight width
    (activations uniform at ``a_bits``) cost ``cycles[i]`` array cycles and
    ``energy_j[i]`` joules under the paper's accelerator model.  Distinct
    operating points are priced once (cached scalars) and broadcast, so
    pricing a whole model is O(layers) table lookups — the inner loop of
    ``repro.autoprec.search``."""
    macs = np.asarray(mac_counts, np.float64)
    wb = np.asarray(w_bits, np.int64)
    if macs.shape != wb.shape:
        raise ValueError(f"mac_counts {macs.shape} and w_bits {wb.shape} "
                         "must align")
    cyc = np.empty_like(macs)
    enj = np.empty_like(macs)
    for b in np.unique(wb):
        m = wb == b
        cyc[m] = cycles_per_mac(int(b), a_bits)
        enj[m] = _energy_per_mac_cached(int(b), a_bits)
    return {"cycles": macs * cyc, "energy_j": macs * enj}


def relative_tier_costs(schedule: Any,
                        mac_counts: Optional[Mapping[str, float]] = None
                        ) -> Dict[str, float]:
    """Relative per-token service cost of each tier of a
    ``PrecisionSchedule``, normalized so the cheapest tier costs 1.0.

    Without ``mac_counts``, a tier is priced by its DEFAULT operating
    point's cycles/MAC (``tier_bits`` — per-layer rule refinements are
    invisible, so tiers that differ only in rules price identically).
    With ``mac_counts`` (layer name -> MACs per token, e.g.
    ``ArchConfig.quant_layer_macs()``) each tier is priced by its
    MAC-weighted per-layer cycles through ``schedule.lookup`` — for
    uniform tiers this reduces to exactly the default pricing, and for
    searched schedules (tiers = per-layer rule sets over a common
    default, the ``repro.autoprec`` output) it is what makes the tiers
    distinguishable at all.

    This is the admission-pricing hook used by
    ``repro.serve.scheduler.SLOPolicy``: a tier that runs more plane passes
    / deeper bit-serial activations occupies the modeled array longer per
    token, so a deadline-aware scheduler must budget more service time for
    its requests."""
    raw = tier_cycles_per_token(schedule, mac_counts)
    floor = min(raw.values())
    return {name: c / floor for name, c in raw.items()}


def tier_cycles_per_token(schedule: Any,
                          mac_counts: Optional[Mapping[str, float]] = None
                          ) -> Dict[str, float]:
    """Absolute modeled array cycles ONE token costs at each tier of a
    ``PrecisionSchedule`` — the unnormalized sibling of
    :func:`relative_tier_costs` (same pricing rules: per-layer
    ``schedule.lookup`` when ``mac_counts`` is given, the tier's default
    operating point otherwise, in which case the figure is cycles/MAC
    rather than cycles/token).

    This is the telemetry layer's price list: ``repro.telemetry`` weighs
    busy and idle decode lanes by these cycles to report *modeled-cycle
    utilization* — the fraction of array cycles the dispatched decode
    chunks occupied that served an actual token, the paper's utilization
    metric made observable.  Absolute (not normalized) pricing is what
    lets an 8/8 lane and a 2/2 lane add into one meaningful ratio."""
    raw: Dict[str, float] = {}
    for t in schedule.tier_names:
        if mac_counts:
            raw[t] = sum(
                float(m) * cycles_per_mac(int(prec.w_bits),
                                          int(prec.a_bits))
                for name, m in mac_counts.items()
                for prec in (schedule.lookup(name, t),))
        else:
            w, a = schedule.tier_bits(t)
            raw[t] = cycles_per_mac(int(w), int(a))
    return raw


def fastest_tier(schedule: Any,
                 mac_counts: Optional[Mapping[str, float]] = None) -> str:
    """Name of the schedule's cheapest (fastest per-token) tier under
    :func:`relative_tier_costs` — ties break lexicographically so the
    answer is deterministic across runs.

    This is the overload-control floor: a deadline request that does not
    fit capacity even at this tier cannot be saved by downtiering, so
    ``SLOPolicy(shed=True)`` sheds it outright."""
    costs = relative_tier_costs(schedule, mac_counts)
    return min(sorted(costs), key=lambda t: costs[t])


def speculative_cycles_per_token(accept_rate: float, k: int,
                                 draft_cost: float,
                                 verify_cost: float) -> float:
    """Modeled cycles per EMITTED token of one self-speculative round
    (draft k tokens at the plane-prefix draft tier, verify the window in
    one verify-tier forward), vs. ``verify_cost`` for plain decoding.

    Under the standard i.i.d. per-position acceptance model with rate
    ``a``, a round emits ``E + 1`` tokens where ``E = sum_{i=1..k} a^i``
    is the expected accepted-prefix length (the ``+1`` is the bonus token:
    the correction on rejection, the extra verify-tier sample on full
    acceptance), so::

        cycles/token = (k * draft_cost + W_v) / (E + 1)

    ``W_v`` is the verify window's cost: the window is ONE (k+1)-position
    batched forward through the same grouped GEMMs as decode, so on the
    paper's weight-stationary array its weight-plane passes amortize over
    the window — we charge one ``verify_cost`` for the pass plus the
    marginal activation work of the k extra positions at the bit-serial
    activation fraction (``act_marginal``).  Costs are in the same units
    as :func:`relative_tier_costs` (relative cycles/token), so speculation
    pays off whenever the result drops below ``verify_cost``.

    The engine's measured counterpart is
    ``EngineStats.spec_verify_steps / spec_emitted`` (verify-tier steps
    per emitted token) with measured ``accept_rate =
    spec_accepted / spec_drafted``."""
    if not 0.0 <= accept_rate <= 1.0:
        raise ValueError(f"accept_rate must be in [0, 1], got {accept_rate}")
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    if draft_cost <= 0.0 or verify_cost <= 0.0:
        raise ValueError("tier costs must be positive")
    expected_accepted = sum(accept_rate ** i for i in range(1, k + 1))
    act_marginal = 0.5          # bit-serial activation share of a position
    verify_window = verify_cost * (1.0 + act_marginal * k)
    round_cycles = k * draft_cost + verify_window
    return round_cycles / (expected_accepted + 1.0)


# Published comparison rows (Table III), scaled-to-28nm values as printed.
TABLE3_OTHERS = {
    "TVLSI22_bitparallel": {"peak_tops": 4.12, "eff_8bit": 3.62,
                            "eff_4bit": 12.13, "eff_2bit": 22.89},
    "UNPU_JSSC18": {"peak_tops": 7.372, "eff_16bit": 7.15, "eff_4bit": 26.93},
    "BitSystolic_TCASI20": {"peak_tops": 0.403, "eff_8bit": 3.95,
                            "eff_4bit": 15.79, "eff_2bit": 61.98},
}


def improvement_vs_bitsystolic() -> Dict[str, float]:
    """Paper claims +18.7 % / +10.5 % / +11.2 % at 8/4/2-bit."""
    ours = table3_ours()
    bs = TABLE3_OTHERS["BitSystolic_TCASI20"]
    return {
        "8bit": ours["eff_8bit"] / bs["eff_8bit"] - 1.0,
        "4bit": ours["eff_4bit"] / bs["eff_4bit"] - 1.0,
        "2bit": ours["eff_2bit"] / bs["eff_2bit"] - 1.0,
    }
