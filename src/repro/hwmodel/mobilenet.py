"""MobileNetV2 workload model — the paper's own benchmark (§IV): mixed
precision cuts inference power 35.2 % vs a fixed 8-bit model.

The paper does not publish its per-layer bit map, so we reproduce the
*mechanism*: per-layer MAC counts from the standard MobileNetV2 config, the
framework's sensitivity-based allocator choosing per-layer bits under an
average-bit budget, and the hwmodel energy-per-MAC.  The benchmark sweeps
the budget and reports the budget at which the 35.2 % reduction is matched.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.hwmodel import energy

# (expansion t, out channels c, repeats n, stride s) — Sandler et al. 2018.
_INVERTED_RESIDUALS = [
    (1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
    (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1),
]


@dataclasses.dataclass
class ConvLayer:
    name: str
    kind: str          # first | dw | pw | head | fc
    macs: int
    params: int


def mobilenet_v2_layers(input_res: int = 224) -> List[ConvLayer]:
    layers: List[ConvLayer] = []
    res = input_res // 2
    cin = 32
    layers.append(ConvLayer("conv_first", "first",
                            3 * 3 * 3 * 32 * res * res, 3 * 3 * 3 * 32))
    idx = 0
    for t, c, n, s in _INVERTED_RESIDUALS:
        for i in range(n):
            stride = s if i == 0 else 1
            hidden = cin * t
            if t != 1:
                layers.append(ConvLayer(f"b{idx}_expand", "pw",
                                        cin * hidden * res * res, cin * hidden))
            res_out = res // stride
            layers.append(ConvLayer(f"b{idx}_dw", "dw",
                                    3 * 3 * hidden * res_out * res_out,
                                    3 * 3 * hidden))
            layers.append(ConvLayer(f"b{idx}_project", "pw",
                                    hidden * c * res_out * res_out, hidden * c))
            cin, res = c, res_out
            idx += 1
    layers.append(ConvLayer("conv_head", "head",
                            cin * 1280 * res * res, cin * 1280))
    layers.append(ConvLayer("fc", "fc", 1280 * 1000, 1280 * 1000))
    return layers


def total_macs(layers: Optional[List[ConvLayer]] = None) -> int:
    return sum(l.macs for l in (layers or mobilenet_v2_layers()))


def allocate_bits(avg_bits: float,
                  layers: Optional[List[ConvLayer]] = None) -> Dict[str, int]:
    """Sensitivity-based per-layer bits via core.policy: first/last layers and
    depthwise convs are precision-critical (HAWQ-style folklore encoded as
    the sensitivity prior: sensitivity ~ 1/params, boosted for first/dw/fc)."""
    from repro.core.policy import allocate_bits_by_sensitivity
    layers = layers or mobilenet_v2_layers()
    sens: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    for l in layers:
        # The allocator's greedy core prices promotions PER BUDGET UNIT
        # (params x bits), so the 1/params weighting is built in; the
        # prior only carries the kind boost.
        boost = 8.0 if l.kind in ("first", "fc", "dw") else 1.0
        sens[l.name] = boost * 1e6
        counts[l.name] = l.params
    policy = allocate_bits_by_sensitivity(sens, counts, avg_bits,
                                          choices=(2, 3, 4, 5, 6, 8))
    return {l.name: int(policy.lookup(l.name).w_bits) for l in layers}


def inference_energy_j(bits: Dict[str, int],
                       layers: Optional[List[ConvLayer]] = None) -> float:
    layers = layers or mobilenet_v2_layers()
    return sum(l.macs * energy.energy_per_mac_j(bits[l.name], bits[l.name])
               for l in layers)


def power_reduction_vs_8bit(avg_bits: float) -> float:
    """Fractional energy-per-inference reduction vs fixed 8/8-bit
    (iso-frame-rate, so energy ratio == power ratio)."""
    layers = mobilenet_v2_layers()
    bits = allocate_bits(avg_bits, layers)
    e_mixed = inference_energy_j(bits, layers)
    e_8bit = sum(l.macs * energy.energy_per_mac_j(8, 8) for l in layers)
    return 1.0 - e_mixed / e_8bit


PAPER_REDUCTION = 0.352


def inference_cycles(bits: Dict[str, int],
                     layers: Optional[List[ConvLayer]] = None,
                     rows: int = 64, cols: int = 64) -> int:
    """Array cycles per inference from the PE-array occupancy model:
    each layer's MACs map onto rows x logical-columns at a_bits cycles/pass
    (weight-stationary; systolic fill ignored as in §IV)."""
    from repro.core.pe_array import PEArrayConfig, logical_columns_per_pass
    cfg = PEArrayConfig(rows=rows, cols=cols)
    total = 0
    for l in (layers or mobilenet_v2_layers()):
        b = bits[l.name]
        n_logical, _ = logical_columns_per_pass(cfg, b)
        macs_per_cycle = rows * n_logical / b      # a_bits == w_bits (§IV)
        total += int(l.macs / macs_per_cycle)
    return total


def inference_fps(bits: Dict[str, int], clk_mhz: float = 500.0) -> float:
    return clk_mhz * 1e6 / inference_cycles(bits)


def throughput_speedup_vs_8bit(avg_bits: float) -> float:
    layers = mobilenet_v2_layers()
    mixed = allocate_bits(avg_bits, layers)
    fixed8 = {l.name: 8 for l in layers}
    return inference_fps(mixed) / inference_fps(fixed8)
