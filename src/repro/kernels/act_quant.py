"""Pallas TPU kernel: fused per-row activation quantization.

The accelerator receives activations already quantized (serial bit feed);
on TPU the quantize step is a VPU pass we fuse into one kernel so the f32
activation tensor is read from HBM exactly once, emitting int8 + per-row
scale.  Rows are the flattened (batch x seq) axis.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref: Any, q_ref: Any, s_ref: Any, *, qmin: int,
            qmax: int) -> None:
    x = x_ref[...]
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    # Explicit f32 reciprocal-multiply: `amax / qmax` with a CONSTANT qmax
    # is strength-reduced by XLA to `amax * (1/qmax)` under jit but stays a
    # true division eagerly (and when qmax is a traced per-row array, as in
    # _rows_kernel) — a 1-ulp scale drift that flips quant codes.  Writing
    # the reciprocal out pins every variant to the same bits.
    scale = jnp.maximum(amax, 1e-8) * (jnp.float32(1.0) / jnp.float32(qmax))
    q = jnp.clip(jnp.round(x / scale), qmin, qmax)
    q_ref[...] = q.astype(q_ref.dtype)
    s_ref[...] = scale.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("bits", "signed", "bm", "interpret"))
def act_quant(x: jax.Array, *, bits: int = 8, signed: bool = True,
              bm: int = 128,
              interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Per-row symmetric quantization. x: f32 [M, K] -> (int8 [M, K], f32 [M, 1]).

    M must tile by bm (ops.py pads); K is kept whole in VMEM (row reduction)."""
    m, k = x.shape
    assert m % bm == 0, (m, bm)
    qmax = (1 << (bits - 1)) - 1 if signed else (1 << bits) - 1
    qmin = -(1 << (bits - 1)) if signed else 0
    qdtype = jnp.int8 if signed else jnp.uint8

    q, s = pl.pallas_call(
        functools.partial(_kernel, qmin=qmin, qmax=qmax),
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), qdtype),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q, s


def _rows_kernel(x_ref: Any, qmax_ref: Any, q_ref: Any, s_ref: Any) -> None:
    x = x_ref[...]
    qmax = qmax_ref[...]                      # f32 [bm, 1], per-row
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    # Same reciprocal-multiply form as _kernel (see comment there); 1/qmax
    # is an exact-IEEE f32 division, matching the constant XLA folds.
    scale = jnp.maximum(amax, 1e-8) * (jnp.float32(1.0) / qmax)
    q = jnp.clip(jnp.round(x / scale), -qmax - 1.0, qmax)
    q_ref[...] = q.astype(q_ref.dtype)
    s_ref[...] = scale.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def act_quant_rows(x: jax.Array, qmax: jax.Array, *, bm: int = 128,
                   interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Per-row symmetric quantization with a PER-ROW signed range.

    The mixed-tier fused decode path quantizes rows of different ``a_bits``
    in ONE kernel: ``qmax`` f32 [M, 1] carries each row's ``2^(b-1) - 1``
    (exact in f32), ``qmin`` is ``-qmax - 1``.  Row-wise this is the exact
    computation of :func:`act_quant` at that row's width — amax is an exact
    max reduction and the divisor is the same f32 value — so results are
    bit-identical to per-width calls.  x: f32 [M, K] ->
    (int8 [M, K], f32 [M, 1]).  Padding rows should carry qmax=1."""
    m, k = x.shape
    assert m % bm == 0, (m, bm)
    assert qmax.shape == (m, 1), (qmax.shape, m)

    q, s = pl.pallas_call(
        _rows_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.int8),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, qmax)
    return q, s
