"""Pallas TPU kernel: fused per-row activation quantization.

The accelerator receives activations already quantized (serial bit feed);
on TPU the quantize step is a VPU pass we fuse into one kernel so the f32
activation tensor is read from HBM exactly once, emitting int8 + per-row
scale.  Rows are the flattened (batch x seq) axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, q_ref, s_ref, *, qmin, qmax):
    x = x_ref[...]
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(x / scale), qmin, qmax)
    q_ref[...] = q.astype(q_ref.dtype)
    s_ref[...] = scale.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("bits", "signed", "bm", "interpret"))
def act_quant(x, *, bits: int = 8, signed: bool = True, bm: int = 128,
              interpret: bool = False):
    """Per-row symmetric quantization. x: f32 [M, K] -> (int8 [M, K], f32 [M, 1]).

    M must tile by bm (ops.py pads); K is kept whole in VMEM (row reduction)."""
    m, k = x.shape
    assert m % bm == 0, (m, bm)
    qmax = (1 << (bits - 1)) - 1 if signed else (1 << bits) - 1
    qmin = -(1 << (bits - 1)) if signed else 0
    qdtype = jnp.int8 if signed else jnp.uint8

    return pl.pallas_call(
        functools.partial(_kernel, qmin=qmin, qmax=qmax),
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), qdtype),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
