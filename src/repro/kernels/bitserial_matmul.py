"""Pallas TPU kernel: plane-decomposed integer GEMM (the paper's MAC array).

TPU-native adaptation of the paper's bit-serial / weight-combination MAC:

  * weight planes (Table-I 2/3-bit chunks, stored as int8) are the
    *stationary* operand — a (P, bk, bn) block resident in VMEM per grid
    step, mirroring "weights preloaded in parallel";
  * the activation tile streams across the K grid axis, mirroring the
    systolic activation flow;
  * per-plane partial sums are combined in the int32 VMEM accumulator with
    static shifts {0,2,4,6} — the 4-column group's shift-add (Fig. 5), fused
    so it costs nothing (the paper needed a slow clock domain for it);
  * each plane product is an int8 x int8 -> int32 MXU pass, so **cost scales
    with weight precision**: 2-bit weights = 1 pass, 8-bit = 4 passes — the
    paper's utilization property on a fixed-width MXU.

Block shapes default to MXU-aligned 128 multiples; the VMEM working set is
  bm*bk (x) + P*bk*bn (w) + bm*bn*4 (acc) bytes.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import decompose


def _kernel(x_ref: Any, w_ref: Any, o_ref: Any, acc_ref: Any, *,
            shifts: Tuple[int, ...], nk: int) -> None:
    """One (i, j, k) grid step: acc += sum_c (x_blk @ w_blk[c]) << shifts[c]."""

    @pl.when(pl.program_id(2) == 0)
    def _init() -> None:
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    acc = acc_ref[...]
    for c, s in enumerate(shifts):  # static plane loop (P in 1..4)
        part = jax.lax.dot_general(
            x, w_ref[c],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        acc = acc + (part << s)
    acc_ref[...] = acc

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush() -> None:
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("w_bits", "msb_first", "bm", "bn", "bk",
                              "interpret"))
def bitserial_matmul(x: jax.Array, w_planes: jax.Array, *, w_bits: int,
                     msb_first: bool = False,
                     bm: int = 128, bn: int = 128, bk: int = 128,
                     interpret: bool = False) -> jax.Array:
    """int32 [M, N] = sum_c (x int8 [M, K] @ w_planes[c] int8 [K, N]) << s_c.

    ``msb_first=False`` (prepared fixed-precision planes): s_c = 2c.
    ``msb_first=True`` (a superplane prefix, runtime-truncated): the caller
    passes the first P' planes of the MSB-first store and the shift table
    flips to s_c = 2(P'-1-c) — the same MXU passes serve any effective
    width with no repacking.  Shapes must tile evenly by (bm, bk, bn); the
    ops.py wrapper pads.
    """
    m, k = x.shape
    p, k2, n = w_planes.shape
    assert k == k2, (k, k2)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    if msb_first:
        shifts = decompose.prefix_shifts(p)
    else:
        shifts = tuple(2 * c for c in range(p))   # LSB-first: 2c per plane
    nk = k // bk

    grid = (m // bm, n // bn, nk)
    out: jax.Array = pl.pallas_call(
        functools.partial(_kernel, shifts=shifts, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((p, bk, bn), lambda i, j, kk: (0, kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x, w_planes)
    return out


def _packed_kernel(x_ref: Any, w_ref: Any, o_ref: Any, acc_ref: Any, *,
                   shifts: Tuple[int, ...], base: int, nk: int,
                   signed: bool) -> None:
    """Packed variant: weight planes packed 4-per-byte (2-bit fields) in one
    uint8 word per 4 planes; unpacked to int8 in VMEM before the MXU pass.

    Beyond-paper optimization: HBM weight traffic scales with w_bits/8 instead
    of P bytes — the decomposition happens at load, exactly where the paper
    does it (weight preload into the array).

    ``base`` > 0 is the runtime-truncation offset: only the fields at bit
    positions >= base (the MSB planes) are read, so one preloaded byte
    serves every even effective width — fewer MXU passes, zero repacking."""

    @pl.when(pl.program_id(2) == 0)
    def _init() -> None:
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    packed = w_ref[...]  # uint8 [bk, bn], 2-bit fields, plane c at bits 2c
    acc = acc_ref[...]
    nplanes = len(shifts)
    for c, s in enumerate(shifts):
        field = (packed >> (base + 2 * c)) & 0x3  # uint8 in [0, 3]
        if signed and c == nplanes - 1:
            # MSB plane: reinterpret 2-bit field as signed [-2, 1].
            plane = jnp.where(field >= 2, field.astype(jnp.int8) - 4,
                              field.astype(jnp.int8))
        else:
            plane = field.astype(jnp.int8)
        part = jax.lax.dot_general(
            x, plane,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        acc = acc + (part << s)
    acc_ref[...] = acc

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush() -> None:
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("w_bits", "eff_bits", "signed", "bm", "bn",
                              "bk", "interpret"))
def packed_bitserial_matmul(x: jax.Array, w_packed: jax.Array, *, w_bits: int,
                            eff_bits: int | None = None, signed: bool = True,
                            bm: int = 128, bn: int = 128, bk: int = 128,
                            interpret: bool = False) -> jax.Array:
    """Packed-plane GEMM: w_packed uint8 [K, N] holds all 2-bit planes of a
    2/4/6/8-bit weight in one byte (plane c at bit position 2c).

    ``eff_bits`` (default: w_bits) runtime-truncates a wider packed store —
    only the top ``eff_bits/2`` fields are extracted and the shift table is
    rebased, so a single preloaded byte per weight serves any even effective
    width <= w_bits.  Only even w_bits (pure 2-bit-mode schedules) pack this
    way; 3/5/7-bit use the unpacked kernel.  Returns int32 [M, N]."""
    assert w_bits in (2, 4, 6, 8), "packed layout covers 2-bit-mode schedules"
    eff_bits = w_bits if eff_bits is None else eff_bits
    assert eff_bits in (2, 4, 6, 8) and eff_bits <= w_bits, (eff_bits, w_bits)
    m, k = x.shape
    k2, n = w_packed.shape
    assert k == k2
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    shifts = decompose.plane_shifts(eff_bits, signed)
    base = w_bits - eff_bits           # LSB fields below this are dropped
    nk = k // bk

    grid = (m // bm, n // bn, nk)
    out: jax.Array = pl.pallas_call(
        functools.partial(_packed_kernel, shifts=shifts, base=base, nk=nk,
                          signed=signed),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x, w_packed)
    return out
