"""Pallas TPU kernel: plane-decomposed GEMM with FUSED dequant epilogue.

``bitserial_matmul`` returns int32 and the wrapper scales by
(x_scale_row * w_scale_col) in separate HLO ops — an extra read+write of the
[M, N] int32 accumulator plus the f32 product.  This kernel applies both
scales inside the flush step, emitting bf16 directly: the accumulator never
leaves VMEM unscaled (§Perf decode lever "fused dequant").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_ref, *, shifts, nk):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    acc = acc_ref[...]
    for c, s in enumerate(shifts):
        part = jax.lax.dot_general(
            x, w_ref[c],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        acc = acc + (part << s)
    acc_ref[...] = acc

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        # Fused dequant epilogue: int32 acc -> bf16 with per-row activation
        # scale x per-column weight scale, entirely in VMEM.
        scaled = acc_ref[...].astype(jnp.float32) \
            * xs_ref[...] * ws_ref[...]
        o_ref[...] = scaled.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("w_bits", "out_dtype", "bm", "bn", "bk", "interpret"))
def fused_dequant_matmul(x, w_planes, x_scale, w_scale, *, w_bits: int,
                         out_dtype=jnp.bfloat16,
                         bm: int = 128, bn: int = 128, bk: int = 128,
                         interpret: bool = False):
    """bf16 [M, N] = ((sum_c (x @ planes[c]) << 2c) * xs * ws).

    x: int8 [M, K]; w_planes: int8 [P, K, N]; x_scale: f32 [M, 1];
    w_scale: f32 [1, N].  Shapes must tile by (bm, bk, bn)."""
    m, k = x.shape
    p, k2, n = w_planes.shape
    assert k == k2 and x_scale.shape == (m, 1) and w_scale.shape == (1, n)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    shifts = tuple(2 * c for c in range(p))
    nk = k // bk

    return pl.pallas_call(
        functools.partial(_kernel, shifts=shifts, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((p, bk, bn), lambda i, j, kk: (0, kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x, w_planes, x_scale, w_scale)
