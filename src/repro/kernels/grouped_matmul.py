"""Pallas TPU kernel: ONE group-switching plane-prefix GEMM for mixed-tier
decode batches.

A mixed-tier batch arrives group-sorted: contiguous row runs at effective
widths 8/6/4/2.  The per-group path launches one ``pallas_call`` per run;
this kernel serves ALL runs from one grid — the software analogue of the
paper's bit-serial systolic array, where a single fixed PE array serves
every precision by switching how many weight bit-planes participate and
combining partial sums spatially (Eq. 1 / Fig. 5).

The switch is data, not control flow: a compile-time int32 multiplier table
``mult[r, c] = 4**(P'_r - 1 - c)`` for plane ``c < P'_r`` (else 0), built by
``decompose.prefix_multipliers`` from the static ``(tier, rows)`` layout.
Every grid step walks the widest prefix (``Pmax`` MSB-first planes; one
int8xint8->int32 MXU pass each) and scales plane ``c``'s partial product by
``mult[:, c]`` — an exact integer shift per row, zero for planes beyond the
row's prefix.  Rows of different widths therefore share every MXU pass and
the result is bit-identical to the per-group kernel (integer multiplication
by a power of four is a shift; integer addition is associative).

Both weight layouts ride the same grid:

  * unpacked — int8 [Pmax, K, N] MSB-first plane prefix, plane ``c`` read
    directly;
  * packed — uint8 [K, N] with all four store planes in one byte; MSB-first
    plane ``c`` is byte field ``store_planes - 1 - c`` (group-INDEPENDENT —
    that is what makes one grid serve every width), sign-reinterpreted only
    for the store's top field.

``grouped_matmul`` emits the raw int32 accumulator; ``grouped_dequant_matmul``
additionally applies the per-row activation scale and per-row weight scale in
the flush step (the fused-dequant epilogue), so the accumulator never
leaves VMEM unscaled.

shard_map compatibility (distributed/tp_serve): every operand is either
replicated (the per-row multiplier table, activation codes after the
quantized all-gather) or sharded on a non-contracting dim (weight planes /
packed bytes / scales on N), so the kernel body needs no collectives and a
device's local call computes an exact N-shard of the unsharded result —
the grid never splits a K-reduction across devices.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import decompose

STORE_PLANES: int = 4   # decompose.SUPERPLANE_PLANES — byte fields per weight


def _plane(w_ref: Any, c: int, *, packed: bool, store_planes: int,
           signed: bool) -> jax.Array:
    """Materialize MSB-first plane ``c`` of the weight block (int8 [bk, bn])."""
    if not packed:
        return w_ref[c]
    field_idx = store_planes - 1 - c        # MSB-first plane c <-> byte field
    field = (w_ref[...] >> (2 * field_idx)) & 0x3
    if signed and field_idx == store_planes - 1:
        # The store's top field is the sign-carrying MSB chunk.
        return jnp.where(field >= 2, field.astype(jnp.int8) - 4,
                         field.astype(jnp.int8))
    return field.astype(jnp.int8)


def _accumulate(x_ref: Any, w_ref: Any, mult_ref: Any, acc_ref: Any, *,
                nplanes: int, packed: bool, store_planes: int,
                signed: bool) -> None:
    """acc += sum_c (x_blk @ plane_c) * mult[:, c]  (static plane loop)."""
    x = x_ref[...]
    mult = mult_ref[...]
    acc = acc_ref[...]
    for c in range(nplanes):
        plane = _plane(w_ref, c, packed=packed, store_planes=store_planes,
                       signed=signed)
        part = jax.lax.dot_general(
            x, plane,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        acc = acc + part * mult[:, c:c + 1]
    acc_ref[...] = acc


def _kernel(x_ref: Any, w_ref: Any, mult_ref: Any, o_ref: Any, acc_ref: Any,
            *, nplanes: int, nk: int, packed: bool, store_planes: int,
            signed: bool) -> None:
    @pl.when(pl.program_id(2) == 0)
    def _init() -> None:
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _accumulate(x_ref, w_ref, mult_ref, acc_ref, nplanes=nplanes,
                packed=packed, store_planes=store_planes, signed=signed)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush() -> None:
        o_ref[...] = acc_ref[...]


def _dequant_kernel(x_ref: Any, w_ref: Any, mult_ref: Any, xs_ref: Any,
                    ws_ref: Any, o_ref: Any, acc_ref: Any, *, nplanes: int,
                    nk: int, packed: bool, store_planes: int,
                    signed: bool) -> None:
    @pl.when(pl.program_id(2) == 0)
    def _init() -> None:
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _accumulate(x_ref, w_ref, mult_ref, acc_ref, nplanes=nplanes,
                packed=packed, store_planes=store_planes, signed=signed)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush() -> None:
        # Fused dequant epilogue: int32 acc -> out dtype with per-row
        # activation scale x per-row weight scale, entirely in VMEM.
        scaled = acc_ref[...].astype(jnp.float32) * xs_ref[...] * ws_ref[...]
        o_ref[...] = scaled.astype(o_ref.dtype)


def _w_spec(nplanes: int, packed: bool, bn: int, bk: int) -> pl.BlockSpec:
    if packed:
        return pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))
    return pl.BlockSpec((nplanes, bk, bn), lambda i, j, kk: (0, kk, j))


def _check_shapes(x: jax.Array, w: jax.Array, mult: jax.Array, nplanes: int,
                  packed: bool, bm: int, bn: int, bk: int) -> tuple[int, int]:
    m, k = x.shape
    if packed:
        k2, n = w.shape
    else:
        p, k2, n = w.shape
        assert p == nplanes, (p, nplanes)
    assert k == k2, (k, k2)
    assert mult.shape == (m, nplanes), (mult.shape, m, nplanes)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    return m, n


@functools.partial(
    jax.jit, static_argnames=("nplanes", "packed", "store_planes", "signed",
                              "bm", "bn", "bk", "interpret"))
def grouped_matmul(x: jax.Array, w: jax.Array, mult: jax.Array, *,
                   nplanes: int, packed: bool = False,
                   store_planes: int = STORE_PLANES, signed: bool = True,
                   bm: int = 128, bn: int = 128, bk: int = 128,
                   interpret: bool = False) -> jax.Array:
    """int32 [M, N] = sum_c (x @ plane_c) * mult[:, c]  — one kernel for a
    whole mixed-width batch.

    x: int8 [M, K] group-sorted activations; w: int8 [nplanes, K, N]
    MSB-first plane prefix (unpacked) or uint8 [K, N] (packed store);
    mult: int32 [M, nplanes] from ``decompose.prefix_multipliers`` (rows
    beyond a group's prefix weigh 0).  Shapes must tile by (bm, bk, bn);
    the ops.py wrapper pads (zero multiplier rows keep padding inert).
    """
    m, n = _check_shapes(x, w, mult, nplanes, packed, bm, bn, bk)
    k = x.shape[1]
    nk = k // bk
    return pl.pallas_call(
        functools.partial(_kernel, nplanes=nplanes, nk=nk, packed=packed,
                          store_planes=store_planes, signed=signed),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            _w_spec(nplanes, packed, bn, bk),
            pl.BlockSpec((bm, nplanes), lambda i, j, kk: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x, w, mult)


@functools.partial(
    jax.jit, static_argnames=("nplanes", "packed", "store_planes", "signed",
                              "out_dtype", "bm", "bn", "bk", "interpret"))
def grouped_dequant_matmul(x: jax.Array, w: jax.Array, mult: jax.Array,
                           x_scale: jax.Array, w_scale: jax.Array, *,
                           nplanes: int, packed: bool = False,
                           store_planes: int = STORE_PLANES,
                           signed: bool = True, out_dtype: Any = jnp.bfloat16,
                           bm: int = 128, bn: int = 128, bk: int = 128,
                           interpret: bool = False) -> jax.Array:
    """``grouped_matmul`` with the dequant epilogue fused into the flush:
    out [M, N] = (acc.astype(f32) * x_scale * w_scale).astype(out_dtype).

    x_scale: f32 [M, 1] per-row activation scale; w_scale: f32 [M, N]
    per-ROW weight scale rows (each row is its group's effective scale —
    ``qw.eff_scale`` broadcast by the static layout), so rows of different
    tiers dequantize correctly inside one grid.
    """
    m, n = _check_shapes(x, w, mult, nplanes, packed, bm, bn, bk)
    assert x_scale.shape == (m, 1), (x_scale.shape, m)
    assert w_scale.shape == (m, n), (w_scale.shape, m, n)
    k = x.shape[1]
    nk = k // bk
    return pl.pallas_call(
        functools.partial(_dequant_kernel, nplanes=nplanes, nk=nk,
                          packed=packed, store_planes=store_planes,
                          signed=signed),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            _w_spec(nplanes, packed, bn, bk),
            pl.BlockSpec((bm, nplanes), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x, w, mult, x_scale, w_scale)
