"""Public jit'd wrappers around the Pallas kernels + backend dispatch.

This is the surface the model layers call.  A single ``matmul`` entry point
routes through one of four backends (see core.policy.BACKENDS):

  dense       bf16/f32 matmul (fp baseline)
  fake_quant  QAT fake-quantized operands, dense matmul (training path)
  decomposed  integer plane-decomposed matmul in plain HLO (serving, dry-run)
  pallas      the Pallas TPU kernels (interpret=True off-TPU)

Weights for the integer paths are prepared once into a ``QuantizedWeight``
(planes + per-channel scale) — the analogue of preloading decomposed weights
into the array.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import decompose, quant
from repro.core.policy import LayerPrecision
from repro.kernels import act_quant as act_quant_kernel
from repro.kernels import bitserial_matmul as bsm
from repro.kernels import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@dataclasses.dataclass
class QuantizedWeight:
    """Decomposed, scaled integer weight — the preloaded array contents.

    Either unpacked planes (int8 [P, K, N]; paper-faithful "one column per
    plane") or the packed layout (uint8 [K, N], all 2-bit planes of one
    weight in one byte — w_bits/8 bytes at rest, the Fig-3 preload done at
    load time; even w_bits only).

    ``msb_first=True`` marks a *superplane* store: the weight was quantized
    once at ``w_bits`` (= quant.MAX_BITS) and the planes are ordered MSB
    first, so any even effective width ``b <= w_bits`` is served at runtime
    by the first ``b/2`` planes with ``eff_scale(b)`` — no re-quantization,
    no repacking (``prepare_superplane``)."""

    planes: Optional[jax.Array]        # int8 [P, K, N] (or None if packed)
    scale: jax.Array                   # f32 [1, N] (per-channel) or scalar
    w_bits: int
    signed: bool = True
    packed: Optional[jax.Array] = None  # uint8 [K, N]
    msb_first: bool = False             # superplane store (see above)

    @property
    def kn(self):
        if self.planes is not None:
            return self.planes.shape[1], self.planes.shape[2]
        return self.packed.shape[0], self.packed.shape[1]

    def get_planes(self):
        """Planes in this artifact's declared order (MSB-first iff
        ``msb_first``); unpacks the byte layout on demand."""
        if self.planes is not None:
            return self.planes
        planes = unpack_planes(self.packed, self.w_bits, self.signed)
        return planes[::-1] if self.msb_first else planes

    def eff_scale(self, eff_bits: int):
        """Per-channel scale of the ``eff_bits``-truncated weight."""
        return quant.nested_scale(self.scale, self.w_bits, eff_bits)


jax.tree_util.register_dataclass(
    QuantizedWeight, data_fields=["planes", "scale", "packed"],
    meta_fields=["w_bits", "signed", "msb_first"])


def prepare_weight(w, prec: LayerPrecision,
                   packed: bool = False) -> QuantizedWeight:
    """Quantize (per-channel symmetric) + Table-I decompose a float weight
    at a fixed precision.

    Even widths quantize *nested*: the integer code is the LSB-truncation
    of the 8-bit code (``quant.nested_quantize``), so a weight prepared
    natively at any even width is bit-identical to the runtime plane-prefix
    truncation of the superplane store — the property that makes
    fixed-precision engines exact references for runtime tiers.  Odd widths
    (3/5/7) are never plane-prefix-truncatable, so they keep
    round-to-nearest and don't pay the nested scheme's floor bias."""
    cfg = quant.QuantConfig(bits=prec.w_bits, signed=prec.w_signed,
                            per_channel=True, channel_axis=-1)
    if prec.w_bits % 2 == 0:
        q, scale = quant.nested_quantize(w, cfg)
    else:
        q, scale = quant.quantize(w, cfg)
    planes = decompose.decompose_weights(q, prec.w_bits, signed=prec.w_signed)
    if packed and prec.w_bits in (2, 4, 6, 8):
        return QuantizedWeight(planes=None, scale=scale, w_bits=prec.w_bits,
                               signed=prec.w_signed,
                               packed=pack_planes(planes, prec.w_bits))
    return QuantizedWeight(planes=planes, scale=scale, w_bits=prec.w_bits,
                           signed=prec.w_signed)


def prepare_superplane(w, *, signed: bool = True,
                       packed: bool = False) -> QuantizedWeight:
    """Quantize + decompose ONCE at 8 bits into the MSB-first superplane
    store — the single preloaded artifact that serves every even runtime
    width (the paper's preload-once / serve-any-precision dataflow)."""
    cfg = quant.QuantConfig(bits=quant.MAX_BITS, signed=signed,
                            per_channel=True, channel_axis=-1)
    q8, scale = quant.quantize(w, cfg)
    planes_msb = decompose.decompose_superplanes(q8, signed=signed)
    if packed:
        # The byte layout is plane-position-indexed (field c at bits 2c), so
        # it is order-agnostic: pack from the LSB-first view.
        return QuantizedWeight(
            planes=None, scale=scale, w_bits=quant.MAX_BITS, signed=signed,
            packed=pack_planes(planes_msb[::-1], quant.MAX_BITS),
            msb_first=True)
    return QuantizedWeight(planes=planes_msb, scale=scale,
                           w_bits=quant.MAX_BITS, signed=signed,
                           msb_first=True)


def truncate_weight(qw: QuantizedWeight, eff_bits: int) -> QuantizedWeight:
    """Materialize a fixed-precision artifact from a superplane store.

    Equivalent to ``prepare_weight`` at ``eff_bits`` (bit-exact, asserted in
    tests/test_precision_tiers.py) but touches only the stored planes —
    useful for exporting one tier without the float weights."""
    if not qw.msb_first:
        raise ValueError("truncate_weight needs a superplane (msb_first) store")
    n = decompose.num_prefix_planes(eff_bits)
    scale = qw.eff_scale(eff_bits)
    if qw.packed is not None:
        planes_msb = unpack_planes(qw.packed, qw.w_bits, qw.signed)[::-1][:n]
    else:
        planes_msb = qw.planes[:n]
    planes = planes_msb[::-1]
    if qw.packed is not None:
        return QuantizedWeight(planes=None, scale=scale, w_bits=eff_bits,
                               signed=qw.signed,
                               packed=pack_planes(planes, eff_bits))
    return QuantizedWeight(planes=planes, scale=scale, w_bits=eff_bits,
                           signed=qw.signed)


def pack_planes(planes, w_bits: int):
    """Pack all 2-bit planes into one uint8 per weight (even w_bits only).

    Plane c occupies bits [2c, 2c+1].  HBM weight bytes become K*N instead of
    P*K*N — and for 2/4-bit, sub-byte-dense relative to int8 storage."""
    assert w_bits in (2, 4, 6, 8)
    p = planes.shape[0]
    acc = jnp.zeros(planes.shape[1:], jnp.uint8)
    for c in range(p):
        field = (planes[c].astype(jnp.int32) & 0x3).astype(jnp.uint8)
        acc = acc | (field << (2 * c))
    return acc


def unpack_planes(packed, w_bits: int, signed: bool = True):
    """Inverse of pack_planes (oracle for the packed kernel)."""
    p = decompose.num_planes(w_bits)
    planes = []
    for c in range(p):
        field = ((packed >> (2 * c)) & 0x3).astype(jnp.int32)
        if signed and c == p - 1:
            field = jnp.where(field >= 2, field - 4, field)
        planes.append(field.astype(jnp.int8))
    return jnp.stack(planes)


def _pad_to(x, m, axis):
    r = x.shape[axis] % m
    if r == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, m - r)
    return jnp.pad(x, pad)


def quantize_activations(x, a_bits: int, *, signed: bool = True,
                         use_pallas: Optional[bool] = None):
    """Per-row activation quantization.  x: f32 [..., K] -> (int8, scale).

    ``use_pallas=None`` routes to the fused Pallas kernel on TPU and to the
    plain-jnp oracle elsewhere (bit-identical numerics; off-TPU the kernel
    only runs interpreted, which is far slower to trace in model code).
    ``True``/``False`` force the respective path — parity is asserted in
    tests/test_kernel_parity.py."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return act_quant_pallas(x, a_bits=a_bits, signed=signed)
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    q, s = ref.act_quant_ref(x2, bits=a_bits, signed=signed)
    return q.reshape(*lead, k), s.reshape(*lead, 1)


def act_quant_pallas(x, *, a_bits: int = 8, signed: bool = True,
                     interpret: Optional[bool] = None):
    """Direct Pallas activation-quant call (padded), for the serving hot path."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    lead, k = x.shape[:-1], x.shape[-1]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    bm = min(128, m) if m % 128 != 0 else 128
    x2p = _pad_to(x2, bm, 0)
    q, s = act_quant_kernel.act_quant(x2p, bits=a_bits, signed=signed, bm=bm,
                                      interpret=interpret)
    return q[:m].reshape(*lead, k), s[:m].reshape(*lead, 1)


def bitserial_matmul_pallas(x_int8, qw: QuantizedWeight, *,
                            eff_bits: Optional[int] = None,
                            row_groups: Optional[tuple] = None,
                            interpret: Optional[bool] = None,
                            bm: int = 128, bn: int = 128, bk: int = 128):
    """Padded Pallas plane-GEMM: int8 [..., K] x planes -> int32 [..., N].

    ``eff_bits`` < qw.w_bits runtime-truncates a superplane store: the
    packed kernel reads only the MSB byte fields in place, the unpacked
    kernel receives the plane prefix — MXU passes scale with the EFFECTIVE
    width, not the stored one.

    ``row_groups`` (static tuple of ``(rows, eff_bits)``, covering x's
    leading axis) is the mixed-tier decode path: the batch is already
    sorted into contiguous tier groups, one plane-prefix GEMM runs per
    group (both the packed and unpacked kernels), and the per-group int32
    results are reassembled along the leading axis."""
    if row_groups is not None:
        if sum(r for r, _ in row_groups) != x_int8.shape[0]:
            raise ValueError(f"row_groups {row_groups} do not cover leading "
                             f"axis {x_int8.shape[0]}")
        outs, off = [], 0
        for rows, eff in row_groups:
            outs.append(bitserial_matmul_pallas(
                x_int8[off:off + rows], qw, eff_bits=eff,
                interpret=interpret, bm=bm, bn=bn, bk=bk))
            off += rows
        return jnp.concatenate(outs, axis=0)
    interpret = (not _on_tpu()) if interpret is None else interpret
    eff = qw.w_bits if eff_bits is None else eff_bits
    if eff != qw.w_bits and not qw.msb_first:
        raise ValueError(
            f"effective {eff}b from a fixed {qw.w_bits}b weight needs a "
            "superplane (msb_first) store")
    lead = x_int8.shape[:-1]
    k, n = qw.kn
    x2 = x_int8.reshape(-1, k)
    m = x2.shape[0]
    bm_eff = min(bm, max(8, m))
    x2 = _pad_to(_pad_to(x2, bm_eff, 0), bk, 1)
    if qw.packed is not None:
        packed = _pad_to(_pad_to(qw.packed, bk, 0), bn, 1)
        out = bsm.packed_bitserial_matmul(
            x2, packed, w_bits=qw.w_bits, eff_bits=eff, signed=qw.signed,
            bm=bm_eff, bn=bn, bk=bk, interpret=interpret)
    else:
        planes = qw.planes
        if qw.msb_first:
            planes = planes[: decompose.num_prefix_planes(eff)]
        planes = _pad_to(_pad_to(planes, bk, 1), bn, 2)
        out = bsm.bitserial_matmul(x2, planes, w_bits=eff,
                                   msb_first=qw.msb_first,
                                   bm=bm_eff, bn=bn, bk=bk,
                                   interpret=interpret)
    return out[:m, :n].reshape(*lead, n)


def matmul(x, w, prec: LayerPrecision, *, qw: Optional[QuantizedWeight] = None,
           a_signed: Optional[bool] = None,
           row_groups: Optional[tuple] = None, perm=None):
    """The framework's matmul: y = x @ w under a mixed-precision policy.

    x: f32/bf16 [..., K].  w: float [K, N] (dense / fake_quant) — for the
    integer backends pass ``qw`` (prepared planes); if absent it is derived
    from ``w`` on the fly (fine under jit: constant-folded for frozen weights).

    ``row_groups`` (static tuple of ``(rows, LayerPrecision)``) is the
    mixed-tier decode-batch path: the batch's rows, viewed through the
    (traced) permutation ``perm`` (identity if None), form contiguous tier
    groups; every group runs one plane-prefix GEMM at ITS w_bits with
    activations quantized at ITS a_bits against the shared superplane store
    ``qw``, and the per-group results are reassembled IN PERMUTED ORDER
    (the caller inverts the permutation).  Activation quantization runs on
    the full un-permuted batch — one pass per distinct (a_bits, a_signed) —
    and only the integer codes and already-materialized scales are
    gathered, so every row's codes AND scales are bitwise identical to a
    tier-homogeneous dispatch (see :func:`_integer_matmul` for why that
    matters).  ``row_groups`` must be static (it keys the jit trace);
    ``prec`` is ignored when it is given.
    """
    if row_groups is not None:
        if qw is None:
            raise ValueError("row_groups needs a prepared weight (qw)")
        total = sum(r for r, _ in row_groups)
        if total != x.shape[0]:
            raise ValueError(f"row_groups cover {total} rows, x leading "
                             f"axis is {x.shape[0]}")
        if len(row_groups) == 1:
            y = matmul(x, None, row_groups[0][1], qw=qw)
            # Keep the contract: grouped results come back in PERMUTED
            # order (gathering finished rows is exact).
            return y if perm is None else jnp.take(y, perm, axis=0)
        # One full-batch activation quantization per distinct a-config, on
        # the UN-permuted x (bitwise identical to the homogeneous path).
        quants = {}
        for _, gprec in row_groups:
            key = (gprec.a_bits, gprec.a_signed)
            if key not in quants:
                q, s = quantize_activations(x.astype(jnp.float32),
                                            gprec.a_bits,
                                            signed=gprec.a_signed)
                if perm is not None:
                    q = jnp.take(q, perm, axis=0)
                    s = jnp.take(s, perm, axis=0)
                quants[key] = (q, s)
        outs, off = [], 0
        for rows, gprec in row_groups:
            x_q, x_s = quants[(gprec.a_bits, gprec.a_signed)]
            sl = slice(off, off + rows)
            outs.append(_dequant_gemm(x_q[sl], x_s[sl], qw, gprec, x.dtype))
            off += rows
        return jnp.concatenate(outs, axis=0)
    a_signed = prec.a_signed if a_signed is None else a_signed
    backend = prec.backend

    if backend == "dense":
        return jnp.matmul(x, w.astype(x.dtype))

    if backend == "fake_quant":
        wcfg = quant.QuantConfig(bits=prec.w_bits, signed=prec.w_signed,
                                 per_channel=True, channel_axis=-1)
        acfg = quant.QuantConfig(bits=prec.a_bits, signed=a_signed,
                                 per_channel=False)
        # Quant math in f32, but cast operands back to the compute dtype
        # BEFORE the matmul: otherwise XLA all-gathers the fake-quantized
        # weights/activations in f32 (2x collective + HBM traffic) and runs
        # f32 matmuls (§Perf iteration 1 — confirmed 1.9x memory-term win).
        wq = quant.fake_quant(w.astype(jnp.float32), wcfg).astype(x.dtype)
        xq = quant.fake_quant(x.astype(jnp.float32), acfg).astype(x.dtype)
        return jnp.matmul(xq, wq)

    if qw is None:
        qw = prepare_weight(w.astype(jnp.float32), prec)
    return _integer_matmul(x, qw, prec, a_signed)


def _integer_matmul(x, qw: QuantizedWeight, prec: LayerPrecision, a_signed):
    """Shared integer path: act-quant + plane-prefix GEMM + dequant.

    Bitwise-stability note (the mixed-tier token-identity contract): the
    grouped path in :func:`matmul` must produce EXACTLY these bits per row.
    Integer codes and GEMMs are exact, but the activation scales are
    continuous — if a group quantized a sliced or gathered sub-batch, XLA
    would re-fuse the upstream normalization into that group's kernel and
    its f32 reductions could round one ulp differently.  The grouped path
    therefore quantizes the full un-permuted batch with this same graph and
    only gathers the RESULTS."""
    x_q, x_s = quantize_activations(x.astype(jnp.float32), prec.a_bits,
                                    signed=a_signed)
    return _dequant_gemm(x_q, x_s, qw, prec, x.dtype)


def _dequant_gemm(x_q, x_s, qw: QuantizedWeight, prec: LayerPrecision,
                  out_dtype):
    """Plane-prefix GEMM on quantized activations + scale-out.

    Runtime precision: the effective width is the POLICY's w_bits, the
    stored width is the artifact's.  A superplane store serves any even
    effective width below its stored width via plane-prefix truncation."""
    backend = prec.backend
    eff_bits = min(prec.w_bits, qw.w_bits)
    if eff_bits != qw.w_bits and not qw.msb_first:
        raise ValueError(
            f"policy asks {eff_bits}b from a fixed {qw.w_bits}b weight; "
            "runtime truncation needs a superplane store "
            "(ops.prepare_superplane)")
    if backend == "decomposed":
        planes = qw.get_planes()
        if qw.msb_first:
            planes = planes[: decompose.num_prefix_planes(eff_bits)][::-1]
        acc = decompose.decomposed_matmul(x_q, planes, eff_bits)
    elif backend == "pallas":
        acc = bitserial_matmul_pallas(x_q, qw, eff_bits=eff_bits)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    w_s = qw.eff_scale(eff_bits) if eff_bits != qw.w_bits else qw.scale
    return (acc.astype(jnp.float32) * x_s * w_s).astype(out_dtype)
