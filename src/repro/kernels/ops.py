"""Public jit'd wrappers around the Pallas kernels + backend dispatch.

This is the surface the model layers call.  A single ``matmul`` entry point
routes through one of four backends (see core.policy.BACKENDS):

  dense       bf16/f32 matmul (fp baseline)
  fake_quant  QAT fake-quantized operands, dense matmul (training path)
  decomposed  integer plane-decomposed matmul in plain HLO (serving, dry-run)
  pallas      the Pallas TPU kernels (interpret=True off-TPU)

Weights for the integer paths are prepared once into a ``QuantizedWeight``
(planes + per-channel scale) — the analogue of preloading decomposed weights
into the array.

Mixed-tier decode batches (``matmul(row_groups=, perm=)``) run FUSED by
default: one per-row-range activation quantization + ONE group-switching
plane-prefix GEMM with the dequant epilogue in its flush step
(``fused_decode_linear``), instead of one dispatch chain per tier group.
``fused=False`` keeps the per-group reference path, which the fused path is
bit-identical to (tests/test_grouped_kernel.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decompose, quant
from repro.core.policy import LayerPrecision
from repro.kernels import act_quant as act_quant_kernel
from repro.kernels import bitserial_matmul as bsm
from repro.kernels import grouped_matmul as gmm
from repro.kernels import ref

# (rows, LayerPrecision) per contiguous tier group — static, keys the trace.
RowGroups = Tuple[Tuple[int, Any], ...]
# Shared activation-quant cache: one entry per distinct quant config of ONE
# input tensor (see quantize_activations_grouped).
ActQuants = Dict[Any, Tuple[jax.Array, jax.Array]]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@dataclasses.dataclass
class QuantizedWeight:
    """Decomposed, scaled integer weight — the preloaded array contents.

    Either unpacked planes (int8 [P, K, N]; paper-faithful "one column per
    plane") or the packed layout (uint8 [K, N], all 2-bit planes of one
    weight in one byte — w_bits/8 bytes at rest, the Fig-3 preload done at
    load time; even w_bits only).

    ``msb_first=True`` marks a *superplane* store: the weight was quantized
    once at ``w_bits`` (= quant.MAX_BITS) and the planes are ordered MSB
    first, so any even effective width ``b <= w_bits`` is served at runtime
    by the first ``b/2`` planes with ``eff_scale(b)`` — no re-quantization,
    no repacking (``prepare_superplane``)."""

    planes: Optional[jax.Array]        # int8 [P, K, N] (or None if packed)
    scale: jax.Array                   # f32 [1, N] (per-channel) or scalar
    w_bits: int
    signed: bool = True
    packed: Optional[jax.Array] = None  # uint8 [K, N]
    msb_first: bool = False             # superplane store (see above)

    @property
    def kn(self) -> Tuple[int, int]:
        if self.planes is not None:
            return self.planes.shape[1], self.planes.shape[2]
        assert self.packed is not None
        return self.packed.shape[0], self.packed.shape[1]

    def get_planes(self) -> jax.Array:
        """Planes in this artifact's declared order (MSB-first iff
        ``msb_first``); unpacks the byte layout on demand."""
        if self.planes is not None:
            return self.planes
        assert self.packed is not None
        planes = unpack_planes(self.packed, self.w_bits, self.signed)
        return planes[::-1] if self.msb_first else planes

    def get_planes_msb(self) -> jax.Array:
        """Planes in MSB-first order regardless of the declared order."""
        planes = self.get_planes()
        return planes if self.msb_first else planes[::-1]

    def eff_scale(self, eff_bits: int) -> jax.Array:
        """Per-channel scale of the ``eff_bits``-truncated weight."""
        return jnp.asarray(quant.nested_scale(self.scale, self.w_bits,
                                              eff_bits))


jax.tree_util.register_dataclass(
    QuantizedWeight, data_fields=["planes", "scale", "packed"],
    meta_fields=["w_bits", "signed", "msb_first"])


def prepare_weight(w: jax.Array, prec: LayerPrecision,
                   packed: bool = False) -> QuantizedWeight:
    """Quantize (per-channel symmetric) + Table-I decompose a float weight
    at a fixed precision.

    Even widths quantize *nested*: the integer code is the LSB-truncation
    of the 8-bit code (``quant.nested_quantize``), so a weight prepared
    natively at any even width is bit-identical to the runtime plane-prefix
    truncation of the superplane store — the property that makes
    fixed-precision engines exact references for runtime tiers.  Odd widths
    (3/5/7) are never plane-prefix-truncatable, so they keep
    round-to-nearest and don't pay the nested scheme's floor bias."""
    cfg = quant.QuantConfig(bits=prec.w_bits, signed=prec.w_signed,
                            per_channel=True, channel_axis=-1)
    if prec.w_bits % 2 == 0:
        q, scale = quant.nested_quantize(w, cfg)
    else:
        q, scale = quant.quantize(w, cfg)
    planes = decompose.decompose_weights(q, prec.w_bits, signed=prec.w_signed)
    if packed and prec.w_bits in (2, 4, 6, 8):
        return QuantizedWeight(planes=None, scale=scale, w_bits=prec.w_bits,
                               signed=prec.w_signed,
                               packed=pack_planes(planes, prec.w_bits))
    return QuantizedWeight(planes=planes, scale=scale, w_bits=prec.w_bits,
                           signed=prec.w_signed)


def prepare_superplane(w: jax.Array, *, signed: bool = True,
                       packed: bool = False) -> QuantizedWeight:
    """Quantize + decompose ONCE at 8 bits into the MSB-first superplane
    store — the single preloaded artifact that serves every even runtime
    width (the paper's preload-once / serve-any-precision dataflow)."""
    cfg = quant.QuantConfig(bits=quant.MAX_BITS, signed=signed,
                            per_channel=True, channel_axis=-1)
    q8, scale = quant.quantize(w, cfg)
    planes_msb = decompose.decompose_superplanes(q8, signed=signed)
    if packed:
        # The byte layout is plane-position-indexed (field c at bits 2c), so
        # it is order-agnostic: pack from the LSB-first view.
        return QuantizedWeight(
            planes=None, scale=scale, w_bits=quant.MAX_BITS, signed=signed,
            packed=pack_planes(planes_msb[::-1], quant.MAX_BITS),
            msb_first=True)
    return QuantizedWeight(planes=planes_msb, scale=scale,
                           w_bits=quant.MAX_BITS, signed=signed,
                           msb_first=True)


def truncate_weight(qw: QuantizedWeight, eff_bits: int) -> QuantizedWeight:
    """Materialize a fixed-precision artifact from a superplane store.

    Equivalent to ``prepare_weight`` at ``eff_bits`` (bit-exact, asserted in
    tests/test_precision_tiers.py) but touches only the stored planes —
    useful for exporting one tier without the float weights."""
    if not qw.msb_first:
        raise ValueError("truncate_weight needs a superplane (msb_first) store")
    n = decompose.num_prefix_planes(eff_bits)
    scale = qw.eff_scale(eff_bits)
    if qw.packed is not None:
        planes_msb = unpack_planes(qw.packed, qw.w_bits, qw.signed)[::-1][:n]
    else:
        assert qw.planes is not None
        planes_msb = qw.planes[:n]
    planes = planes_msb[::-1]
    if qw.packed is not None:
        return QuantizedWeight(planes=None, scale=scale, w_bits=eff_bits,
                               signed=qw.signed,
                               packed=pack_planes(planes, eff_bits))
    return QuantizedWeight(planes=planes, scale=scale, w_bits=eff_bits,
                           signed=qw.signed)


def pack_planes(planes: jax.Array, w_bits: int) -> jax.Array:
    """Pack all 2-bit planes into one uint8 per weight (even w_bits only).

    Plane c occupies bits [2c, 2c+1].  HBM weight bytes become K*N instead of
    P*K*N — and for 2/4-bit, sub-byte-dense relative to int8 storage."""
    assert w_bits in (2, 4, 6, 8)
    p = planes.shape[0]
    acc = jnp.zeros(planes.shape[1:], jnp.uint8)
    for c in range(p):
        field = (planes[c].astype(jnp.int32) & 0x3).astype(jnp.uint8)
        acc = acc | (field << (2 * c))
    return acc


def unpack_planes(packed: jax.Array, w_bits: int,
                  signed: bool = True) -> jax.Array:
    """Inverse of pack_planes (oracle for the packed kernel)."""
    p = decompose.num_planes(w_bits)
    planes = []
    for c in range(p):
        field = ((packed >> (2 * c)) & 0x3).astype(jnp.int32)
        if signed and c == p - 1:
            field = jnp.where(field >= 2, field - 4, field)
        planes.append(field.astype(jnp.int8))
    return jnp.stack(planes)


def _pad_to(x: jax.Array, m: int, axis: int) -> jax.Array:
    r = x.shape[axis] % m
    if r == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, m - r)
    return jnp.pad(x, pad)


def quantize_activations(
        x: jax.Array, a_bits: int, *, signed: bool = True,
        use_pallas: Optional[bool] = None) -> Tuple[jax.Array, jax.Array]:
    """Per-row activation quantization.  x: f32 [..., K] -> (int8, scale).

    ``use_pallas=None`` routes to the fused Pallas kernel on TPU and to the
    plain-jnp oracle elsewhere (bit-identical numerics; off-TPU the kernel
    only runs interpreted, which is far slower to trace in model code).
    ``True``/``False`` force the respective path — parity is asserted in
    tests/test_kernel_parity.py."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return act_quant_pallas(x, a_bits=a_bits, signed=signed)
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    q, s = ref.act_quant_ref(x2, bits=a_bits, signed=signed)
    return q.reshape(*lead, k), s.reshape(*lead, 1)


def act_quant_pallas(
        x: jax.Array, *, a_bits: int = 8, signed: bool = True,
        interpret: Optional[bool] = None) -> Tuple[jax.Array, jax.Array]:
    """Direct Pallas activation-quant call (padded), for the serving hot path."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    lead, k = x.shape[:-1], x.shape[-1]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    bm = min(128, m) if m % 128 != 0 else 128
    x2p = _pad_to(x2, bm, 0)
    q, s = act_quant_kernel.act_quant(x2p, bits=a_bits, signed=signed, bm=bm,
                                      interpret=interpret)
    return q[:m].reshape(*lead, k), s[:m].reshape(*lead, 1)


def _group_plane_counts(qw: QuantizedWeight,
                        eff_list: Tuple[int, ...]) -> Tuple[int, ...]:
    """MSB-first plane-prefix depth per group; validates the store serves
    every requested effective width."""
    counts = []
    for eff in eff_list:
        if eff != qw.w_bits and not qw.msb_first:
            raise ValueError(
                f"effective {eff}b from a fixed {qw.w_bits}b weight needs a "
                "superplane (msb_first) store")
        if qw.msb_first:
            counts.append(decompose.num_prefix_planes(eff))
        else:
            counts.append(decompose.num_planes(qw.w_bits, qw.signed))
    return tuple(counts)


def bitserial_matmul_pallas(x_int8: jax.Array, qw: QuantizedWeight, *,
                            eff_bits: Optional[int] = None,
                            row_groups: Optional[Tuple[Tuple[int, int], ...]]
                            = None,
                            interpret: Optional[bool] = None,
                            bm: int = 128, bn: int = 128,
                            bk: int = 128) -> jax.Array:
    """Padded Pallas plane-GEMM: int8 [..., K] x planes -> int32 [..., N].

    ``eff_bits`` < qw.w_bits runtime-truncates a superplane store: the
    packed kernel reads only the MSB byte fields in place, the unpacked
    kernel receives the plane prefix — MXU passes scale with the EFFECTIVE
    width, not the stored one.

    ``row_groups`` (static tuple of ``(rows, eff_bits)``, covering x's
    leading axis) is the mixed-tier decode path: the batch is already
    sorted into contiguous tier groups and ONE group-switching kernel
    (``grouped_matmul``) serves every group from a single grid — per-row
    plane multipliers select each row's plane-prefix depth, so no per-group
    dispatch loop remains (bit-identical to per-group calls).

    ``row_groups`` always counts LEADING-axis rows.  Extra leading dims
    (e.g. the speculative verify window's ``[B, W, K]`` input) flatten to
    ``B*W`` flat rows and each group's row count scales by the static
    ``reps = W`` factor — window positions inherit their slot's tier, so
    the whole ``k+1``-token verify window runs through the same single
    grid as a 1-token decode step."""
    if row_groups is not None:
        if sum(r for r, _ in row_groups) != x_int8.shape[0]:
            raise ValueError(f"row_groups {row_groups} do not cover leading "
                             f"axis {x_int8.shape[0]}")
        interpret = (not _on_tpu()) if interpret is None else interpret
        k, n = qw.kn
        lead = x_int8.shape[:-1]
        x2 = x_int8.reshape(-1, k)
        m = x2.shape[0]
        reps = m // x_int8.shape[0]       # flat rows per leading row (static)
        counts = _group_plane_counts(qw, tuple(e for _, e in row_groups))
        plane_groups = tuple((rows * reps, p)
                             for (rows, _), p in zip(row_groups, counts))
        mult = jnp.asarray(decompose.prefix_multipliers(plane_groups))
        pmax = int(mult.shape[1])
        bm_eff = min(bm, max(8, m))
        x2 = _pad_to(_pad_to(x2, bm_eff, 0), bk, 1)
        multp = _pad_to(mult, bm_eff, 0)  # zero-multiplier rows stay inert
        if qw.packed is not None:
            wmat = _pad_to(_pad_to(qw.packed, bk, 0), bn, 1)
        else:
            wmat = _pad_to(_pad_to(qw.get_planes_msb()[:pmax], bk, 1), bn, 2)
        out = gmm.grouped_matmul(
            x2, wmat, multp, nplanes=pmax, packed=qw.packed is not None,
            store_planes=decompose.num_planes(qw.w_bits, qw.signed),
            signed=qw.signed, bm=bm_eff, bn=bn, bk=bk, interpret=interpret)
        return out[:m, :n].reshape(*lead, n)
    interpret = (not _on_tpu()) if interpret is None else interpret
    eff = qw.w_bits if eff_bits is None else eff_bits
    if eff != qw.w_bits and not qw.msb_first:
        raise ValueError(
            f"effective {eff}b from a fixed {qw.w_bits}b weight needs a "
            "superplane (msb_first) store")
    lead = x_int8.shape[:-1]
    k, n = qw.kn
    x2 = x_int8.reshape(-1, k)
    m = x2.shape[0]
    bm_eff = min(bm, max(8, m))
    x2 = _pad_to(_pad_to(x2, bm_eff, 0), bk, 1)
    if qw.packed is not None:
        packed = _pad_to(_pad_to(qw.packed, bk, 0), bn, 1)
        out = bsm.packed_bitserial_matmul(
            x2, packed, w_bits=qw.w_bits, eff_bits=eff, signed=qw.signed,
            bm=bm_eff, bn=bn, bk=bk, interpret=interpret)
    else:
        assert qw.planes is not None
        planes = qw.planes
        if qw.msb_first:
            planes = planes[: decompose.num_prefix_planes(eff)]
        planes = _pad_to(_pad_to(planes, bk, 1), bn, 2)
        out = bsm.bitserial_matmul(x2, planes, w_bits=eff,
                                   msb_first=qw.msb_first,
                                   bm=bm_eff, bn=bn, bk=bk,
                                   interpret=interpret)
    return out[:m, :n].reshape(*lead, n)


def _quantize_activations_rows(
        x: jax.Array, row_groups: RowGroups, perm: Optional[jax.Array],
        use_pallas: Optional[bool]) -> Tuple[jax.Array, jax.Array]:
    """Mixed-width per-row activation quantization (signed), full batch.

    Quantizes the UN-permuted batch in one pass — each row at its own
    ``a_bits``, carried by a per-row f32 qmax — then gathers codes and
    scales by ``perm``.  Row-wise bit-identical to the per-config
    :func:`quantize_activations` (exact max reduction, same f32 divisor),
    so the PR-3 bitwise-stability contract holds with ONE dispatch for any
    mix of activation widths."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    lead, k = x.shape[:-1], x.shape[-1]
    qmax_sorted = jnp.asarray(np.concatenate([
        np.full((rows,), float((1 << (g.a_bits - 1)) - 1), np.float32)
        for rows, g in row_groups]))
    if perm is not None:
        qmax_rows = jnp.take(qmax_sorted, jnp.argsort(perm), axis=0)
    else:
        qmax_rows = qmax_sorted
    shape = (x.shape[0],) + (1,) * (x.ndim - 1)
    qmax_full = jnp.broadcast_to(qmax_rows.reshape(shape),
                                 (*lead, 1)).reshape(-1, 1)
    x2 = x.astype(jnp.float32).reshape(-1, k)
    if use_pallas:
        m = x2.shape[0]
        bm = min(128, m) if m % 128 != 0 else 128
        x2p = _pad_to(x2, bm, 0)
        # Real qmax is always >= 1, so this only lifts zero padding rows.
        qmaxp = jnp.maximum(_pad_to(qmax_full, bm, 0), 1.0)
        q, s = act_quant_kernel.act_quant_rows(x2p, qmaxp, bm=bm,
                                               interpret=not _on_tpu())
        q, s = q[:m], s[:m]
    else:
        q, s = ref.act_quant_rows_ref(x2, qmax_full)
    qr, sr = q.reshape(*lead, k), s.reshape(*lead, 1)
    if perm is not None:
        qr = jnp.take(qr, perm, axis=0)
        sr = jnp.take(sr, perm, axis=0)
    return qr, sr


def quantize_activations_grouped(
        x: jax.Array, row_groups: RowGroups, perm: Optional[jax.Array], *,
        act_quants: Optional[ActQuants] = None,
        use_pallas: Optional[bool] = None) -> Tuple[jax.Array, jax.Array]:
    """Activation quantization for a grouped batch, returned PERMUTED
    (group-sorted).  Always quantizes the full un-permuted batch (the PR-3
    bitwise-stability contract) and only gathers results.

    One distinct (a_bits, a_signed) -> a single plain quantization; mixed
    widths (all signed) -> ONE per-row-range pass.  ``act_quants`` is an
    optional cache shared by projections reading the SAME input tensor
    (q/k/v, gate/up): the second caller reuses the first caller's codes —
    identical computation, so sharing is exact."""
    if act_quants is None:
        act_quants = {}
    configs = tuple(dict.fromkeys((g.a_bits, g.a_signed)
                                  for _, g in row_groups))
    if len(configs) == 1:
        a_bits, a_signed = configs[0]
        key: Any = ("uniform", a_bits, a_signed)
        if key not in act_quants:
            act_quants[key] = quantize_activations(
                x.astype(jnp.float32), a_bits, signed=a_signed,
                use_pallas=use_pallas)
        q, s = act_quants[key]
        if perm is not None:
            q = jnp.take(q, perm, axis=0)
            s = jnp.take(s, perm, axis=0)
        return q, s
    if not all(g.a_signed for _, g in row_groups):
        raise ValueError("mixed activation widths fuse only for signed "
                         "activations (per-row qmin = -qmax - 1)")
    key = ("rows",) + tuple((rows, g.a_bits) for rows, g in row_groups)
    if key not in act_quants:
        act_quants[key] = _quantize_activations_rows(x, row_groups, perm,
                                                     use_pallas)
    return act_quants[key]


def fused_decode_linear(x: jax.Array, qw: QuantizedWeight,
                        row_groups: RowGroups, perm: Optional[jax.Array], *,
                        act_quants: Optional[ActQuants] = None,
                        pre_quant: Optional[Tuple[jax.Array,
                                                  jax.Array]] = None,
                        out_dtype: Any = None,
                        interpret: Optional[bool] = None,
                        bm: int = 128, bn: int = 128,
                        bk: int = 128) -> jax.Array:
    """The fused mixed-tier decode hot path, in two dispatches:

      1. ONE activation quantization over the full un-permuted batch
         (per-row ranges when groups mix ``a_bits``; shared across
         projections of the same input via ``act_quants``);
      2. ONE group-switching plane-prefix GEMM whose flush step applies
         both scales (``grouped_dequant_matmul``) — the accumulator never
         leaves VMEM unscaled.

    ``pre_quant`` supplies already-quantized PERMUTED ``(codes, scales)``
    and skips step 1 — the tensor-parallel path quantizes once with a
    mesh-shared range and all-gathers the codes, then lands here so shards
    reuse this exact GEMM + dequant epilogue.

    Returns results in PERMUTED (group-sorted) order, like
    ``matmul(row_groups=)``; bit-identical to the per-group path: integer
    plane combination is exact, and the f32 dequant applies the same values
    in the same order as ``_dequant_gemm``."""
    out_dtype = x.dtype if out_dtype is None else out_dtype
    backends = tuple(dict.fromkeys(g.backend for _, g in row_groups))
    if len(backends) != 1 or backends[0] not in ("decomposed", "pallas"):
        raise ValueError("fused grouped matmul needs one integer backend "
                         f"across groups, got {backends}")
    backend = backends[0]
    if pre_quant is not None:
        x_q, x_s = pre_quant
    else:
        x_q, x_s = quantize_activations_grouped(x, row_groups, perm,
                                                act_quants=act_quants)
    k, n = qw.kn
    lead = x_q.shape[:-1]
    reps = 1
    for d in lead[1:]:
        reps *= d
    eff_list = tuple(min(g.w_bits, qw.w_bits) for _, g in row_groups)
    counts = _group_plane_counts(qw, eff_list)
    plane_groups = tuple((rows * reps, p)
                         for (rows, _), p in zip(row_groups, counts))
    mult = jnp.asarray(decompose.prefix_multipliers(plane_groups))
    pmax = int(mult.shape[1])
    # Per-ROW weight scale: each group's effective per-channel scale
    # broadcast over its rows (an exact power-of-two multiple of the stored
    # scale), so one grid dequantizes every tier correctly.
    ws = jnp.concatenate([
        jnp.broadcast_to(
            jnp.asarray(qw.eff_scale(eff) if eff != qw.w_bits else qw.scale,
                        jnp.float32).reshape(1, -1),
            (rows * reps, n))
        for (rows, _), eff in zip(row_groups, eff_list)], axis=0)
    x2 = x_q.reshape(-1, k)
    s2 = x_s.reshape(-1, 1)
    if backend == "decomposed":
        acc = decompose.decomposed_matmul_multipliers(
            x2, qw.get_planes_msb()[:pmax], mult)
        out = (acc.astype(jnp.float32) * s2 * ws).astype(out_dtype)
        return out.reshape(*lead, n)
    interpret = (not _on_tpu()) if interpret is None else interpret
    m = x2.shape[0]
    bm_eff = min(bm, max(8, m))
    x2p = _pad_to(_pad_to(x2, bm_eff, 0), bk, 1)
    multp = _pad_to(mult, bm_eff, 0)
    s2p = _pad_to(s2, bm_eff, 0)
    wsp = _pad_to(_pad_to(ws, bm_eff, 0), bn, 1)
    if qw.packed is not None:
        wmat = _pad_to(_pad_to(qw.packed, bk, 0), bn, 1)
    else:
        wmat = _pad_to(_pad_to(qw.get_planes_msb()[:pmax], bk, 1), bn, 2)
    out = gmm.grouped_dequant_matmul(
        x2p, wmat, multp, s2p, wsp, nplanes=pmax,
        packed=qw.packed is not None,
        store_planes=decompose.num_planes(qw.w_bits, qw.signed),
        signed=qw.signed, out_dtype=out_dtype,
        bm=bm_eff, bn=bn, bk=bk, interpret=interpret)
    return out[:m, :n].reshape(*lead, n)


def matmul(x: jax.Array, w: Optional[jax.Array], prec: LayerPrecision, *,
           qw: Optional[QuantizedWeight] = None,
           a_signed: Optional[bool] = None,
           row_groups: Optional[RowGroups] = None,
           perm: Optional[jax.Array] = None,
           fused: Optional[bool] = None,
           act_quants: Optional[ActQuants] = None) -> jax.Array:
    """The framework's matmul: y = x @ w under a mixed-precision policy.

    x: f32/bf16 [..., K].  w: float [K, N] (dense / fake_quant) — for the
    integer backends pass ``qw`` (prepared planes); if absent it is derived
    from ``w`` on the fly (fine under jit: constant-folded for frozen weights).

    ``row_groups`` (static tuple of ``(rows, LayerPrecision)``) is the
    mixed-tier decode-batch path: the batch's rows, viewed through the
    (traced) permutation ``perm`` (identity if None), form contiguous tier
    groups; every group runs at ITS (w_bits, a_bits) against the shared
    superplane store ``qw``, and results come back IN PERMUTED ORDER (the
    caller inverts the permutation).  Activation quantization runs on the
    full un-permuted batch — so every row's codes AND scales are bitwise
    identical to a tier-homogeneous dispatch (see :func:`_integer_matmul`
    for why that matters).  ``row_groups`` must be static (it keys the jit
    trace); ``prec`` is ignored when it is given.

    ``fused`` selects the grouped implementation: ``None`` (default) fuses
    whenever eligible (one integer backend, signed activations), ``False``
    forces the per-group reference loop, ``True`` asserts eligibility.
    ``act_quants`` optionally shares activation quantization between
    projections of the same input (exact; see
    :func:`quantize_activations_grouped`).
    """
    if row_groups is not None:
        if qw is None:
            raise ValueError("row_groups needs a prepared weight (qw)")
        total = sum(r for r, _ in row_groups)
        if total != x.shape[0]:
            raise ValueError(f"row_groups cover {total} rows, x leading "
                             f"axis is {x.shape[0]}")
        if len(row_groups) == 1:
            y = matmul(x, None, row_groups[0][1], qw=qw)
            # Keep the contract: grouped results come back in PERMUTED
            # order (gathering finished rows is exact).
            return y if perm is None else jnp.take(y, perm, axis=0)
        eligible = (
            len({g.backend for _, g in row_groups}) == 1
            and row_groups[0][1].backend in ("decomposed", "pallas")
            and all(g.a_signed for _, g in row_groups))
        use_fused = eligible if fused is None else fused
        if use_fused:
            # Raises with the precise reason if fused=True yet ineligible.
            return fused_decode_linear(x, qw, row_groups, perm,
                                       act_quants=act_quants,
                                       out_dtype=x.dtype)
        # Per-group reference path: one full-batch activation quantization
        # per distinct a-config, on the UN-permuted x (bitwise identical to
        # the homogeneous path), then one plane-prefix GEMM per group.
        quants: Dict[Tuple[int, bool], Tuple[jax.Array, jax.Array]] = {}
        for _, gprec in row_groups:
            gkey = (gprec.a_bits, gprec.a_signed)
            if gkey not in quants:
                q, s = quantize_activations(x.astype(jnp.float32),
                                            gprec.a_bits,
                                            signed=gprec.a_signed)
                if perm is not None:
                    q = jnp.take(q, perm, axis=0)
                    s = jnp.take(s, perm, axis=0)
                quants[gkey] = (q, s)
        outs = []
        off = 0
        for rows, gprec in row_groups:
            x_q, x_s = quants[(gprec.a_bits, gprec.a_signed)]
            sl = slice(off, off + rows)
            outs.append(_dequant_gemm(x_q[sl], x_s[sl], qw, gprec, x.dtype))
            off += rows
        return jnp.concatenate(outs, axis=0)
    a_signed = prec.a_signed if a_signed is None else a_signed
    backend = prec.backend

    if backend == "dense":
        assert w is not None
        return jnp.matmul(x, w.astype(x.dtype))

    if backend == "fake_quant":
        assert w is not None
        wcfg = quant.QuantConfig(bits=prec.w_bits, signed=prec.w_signed,
                                 per_channel=True, channel_axis=-1)
        acfg = quant.QuantConfig(bits=prec.a_bits, signed=a_signed,
                                 per_channel=False)
        # Quant math in f32, but cast operands back to the compute dtype
        # BEFORE the matmul: otherwise XLA all-gathers the fake-quantized
        # weights/activations in f32 (2x collective + HBM traffic) and runs
        # f32 matmuls (§Perf iteration 1 — confirmed 1.9x memory-term win).
        wq = quant.fake_quant(w.astype(jnp.float32), wcfg).astype(x.dtype)
        xq = quant.fake_quant(x.astype(jnp.float32), acfg).astype(x.dtype)
        return jnp.matmul(xq, wq)

    if qw is None:
        assert w is not None
        qw = prepare_weight(w.astype(jnp.float32), prec)
    return _integer_matmul(x, qw, prec, a_signed)


def _integer_matmul(x: jax.Array, qw: QuantizedWeight, prec: LayerPrecision,
                    a_signed: bool) -> jax.Array:
    """Shared integer path: act-quant + plane-prefix GEMM + dequant.

    Bitwise-stability note (the mixed-tier token-identity contract): the
    grouped path in :func:`matmul` must produce EXACTLY these bits per row.
    Integer codes and GEMMs are exact, but the activation scales are
    continuous — if a group quantized a sliced or gathered sub-batch, XLA
    would re-fuse the upstream normalization into that group's kernel and
    its f32 reductions could round one ulp differently.  The grouped path
    therefore quantizes the full un-permuted batch with this same graph and
    only gathers the RESULTS."""
    x_q, x_s = quantize_activations(x.astype(jnp.float32), prec.a_bits,
                                    signed=a_signed)
    return _dequant_gemm(x_q, x_s, qw, prec, x.dtype)


def _dequant_gemm(x_q: jax.Array, x_s: jax.Array, qw: QuantizedWeight,
                  prec: LayerPrecision, out_dtype: Any) -> jax.Array:
    """Plane-prefix GEMM on quantized activations + scale-out.

    Runtime precision: the effective width is the POLICY's w_bits, the
    stored width is the artifact's.  A superplane store serves any even
    effective width below its stored width via plane-prefix truncation."""
    backend = prec.backend
    eff_bits = min(prec.w_bits, qw.w_bits)
    if eff_bits != qw.w_bits and not qw.msb_first:
        raise ValueError(
            f"policy asks {eff_bits}b from a fixed {qw.w_bits}b weight; "
            "runtime truncation needs a superplane store "
            "(ops.prepare_superplane)")
    if backend == "decomposed":
        planes = qw.get_planes()
        if qw.msb_first:
            planes = planes[: decompose.num_prefix_planes(eff_bits)][::-1]
        acc = jnp.asarray(decompose.decomposed_matmul(x_q, planes, eff_bits))
    elif backend == "pallas":
        acc = bitserial_matmul_pallas(x_q, qw, eff_bits=eff_bits)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    w_s = qw.eff_scale(eff_bits) if eff_bits != qw.w_bits else qw.scale
    return (acc.astype(jnp.float32) * x_s * w_s).astype(out_dtype)


def dequant_matmul(x_q: jax.Array, x_s: jax.Array, qw: QuantizedWeight,
                   prec: LayerPrecision, out_dtype: Any) -> jax.Array:
    """Public pre-quantized entry to the plane-prefix GEMM + dequant.

    Identical to the tail of :func:`_integer_matmul` — the tensor-parallel
    path calls this after quantizing with a mesh-shared range and gathering
    codes across shards, so sharded and unsharded decode run the very same
    GEMM/dequant graph per row."""
    return _dequant_gemm(x_q, x_s, qw, prec, out_dtype)


def count_pallas_calls(jaxpr: Any) -> int:
    """Count ``pallas_call`` equations in a (Closed)Jaxpr, recursing into
    sub-jaxprs (scan/pjit/cond bodies) — the dispatch-count observability
    behind ``EngineStats.decode_dispatches``: a fused mixed-tier decode
    step's count is CONSTANT in the number of tier groups."""
    core = getattr(jaxpr, "jaxpr", jaxpr)
    count = 0
    for eqn in core.eqns:
        if eqn.primitive.name == "pallas_call":
            count += 1
        for v in eqn.params.values():
            count += _count_pallas_in_param(v)
    return count


def _count_pallas_in_param(v: Any) -> int:
    if isinstance(v, (tuple, list)):
        return sum(_count_pallas_in_param(u) for u in v)
    if hasattr(v, "eqns") or hasattr(v, "jaxpr"):
        return count_pallas_calls(v)
    return 0
