"""Pure-jnp oracles for every Pallas kernel in this package.

Each oracle is the semantic ground truth its kernel is property-tested
against (bit-exact for the integer paths)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import decompose


def bitserial_matmul_ref(x_int: jax.Array, w_planes: jax.Array,
                         w_bits: int) -> jax.Array:
    """int32 [..., N] = sum_c (x @ w_planes[c]) << 2c   (paper Eq. (1) with the
    temporal bit-loop folded into the int operand)."""
    return jnp.asarray(decompose.decomposed_matmul(x_int, w_planes, w_bits))


def packed_bitserial_matmul_ref(x_int: jax.Array, w_packed: jax.Array,
                                w_bits: int, k: int) -> jax.Array:
    """Oracle for the packed-plane kernel: unpack then decomposed matmul.

    w_packed: uint8 [ceil(K*w_bits/8)...] packed rows — see ops.pack_planes.
    Here we accept the unpacked planes directly for simplicity; packing is
    tested by pack/unpack roundtrip plus this oracle on the unpacked form.
    """
    return jnp.asarray(decompose.decomposed_matmul(x_int, w_packed, w_bits))


def quant_scale(amax: jax.Array, qmax: jax.Array | float,
                eps: float = 1e-8) -> jax.Array:
    """THE symmetric-quant scale rule: ``max(amax, eps) * (1/qmax)`` in f32.

    Reciprocal-multiply, not ``/ qmax``: XLA strength-reduces division by a
    constant under jit but not eagerly (nor for traced per-row ranges in
    :func:`act_quant_rows_ref`) — writing ``* (1/qmax)`` pins all paths to
    one bit pattern.  Mirrors kernels/act_quant.py.  Every scale in the repo
    that must agree bitwise between eager/jit or across devices (activation
    quant, the distributed wire format, compressed gradient psum) routes
    through this one expression."""
    return jnp.maximum(amax, eps) * (
        jnp.float32(1.0) / jnp.asarray(qmax, jnp.float32))


def act_quant_ref(x: jax.Array, bits: int = 8,
                  signed: bool = True) -> tuple[jax.Array, jax.Array]:
    """Per-row symmetric activation quantization oracle.

    Returns (q int8 [M,K], scale f32 [M,1])."""
    qmax = (1 << (bits - 1)) - 1 if signed else (1 << bits) - 1
    qmin = -(1 << (bits - 1)) if signed else 0
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = quant_scale(amax, qmax)
    dtype = jnp.int8 if signed else jnp.uint8   # unsigned 8-bit needs uint8
    q = jnp.clip(jnp.round(x / scale), qmin, qmax).astype(dtype)
    return q, scale.astype(jnp.float32)


def act_quant_rows_ref(x: jax.Array,
                       qmax: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-row-range quantization oracle (signed): ``qmax`` f32 [M, 1] carries
    each row's ``2^(b-1) - 1``.  Row-wise bit-identical to
    :func:`act_quant_ref` at that row's width (same f32 divisor, exact max
    reduction).  Returns (q int8 [M,K], scale f32 [M,1])."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = quant_scale(amax, qmax)
    q = jnp.clip(jnp.round(x / scale), -qmax - 1.0, qmax).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def quantized_matmul_ref(x: jax.Array, w_planes: jax.Array,
                         w_scale: jax.Array, w_bits: int,
                         a_bits: int = 8) -> jax.Array:
    """Float-in/float-out oracle: quantize acts per-row, integer decomposed
    matmul, dequantize with both scales."""
    q, s = act_quant_ref(x, bits=a_bits)
    acc = bitserial_matmul_ref(q, w_planes, w_bits)
    return acc.astype(jnp.float32) * s * w_scale
