"""Automatic mixed-precision search driver: model -> measured sensitivity
-> hardware-priced Pareto front -> servable PrecisionSchedule file.

    PYTHONPATH=src python -m repro.launch.autoprec --arch granite-3-8b \
        --reduced --choices 2 4 6 --calib-batches 2 --calib-len 16 \
        --max-divergence 0.05 --out /tmp/schedule.json

    # then serve the searched schedule (zero re-preparation, any tier):
    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
        --reduced --schedule-file /tmp/schedule.json --requests 8

Pipeline (all through the REAL quantization path — the 8-bit superplane
store with per-layer plane-prefix truncation, never a proxy):

1. prepare the superplane store once (the same artifact an engine preloads);
2. profile per-layer sensitivity at every candidate width on calibration
   batches (batched one-pass row groups unless --sequential / MoE);
3. search: greedy marginal-divergence-per-marginal-cycle + differentiable
   relaxation, priced in modeled accelerator cycles per token;
4. re-measure the front's candidates JOINTLY (the additive surrogate is
   only a surrogate), print the Pareto table, select the cheapest point
   whose measured divergence stays within --max-divergence;
5. write the selected point as the default tier (``auto``) of a
   PrecisionSchedule JSON (+ a uniform-8 ``base`` tier for A/B serving),
   with the full front recorded in the file's meta.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.autoprec import (CostModel, SearchResult, load_schedule,
                            measure_divergence, profile_sensitivity,
                            random_calibration, result_to_meta,
                            save_schedule, schedule_from_results, search)
from repro.configs import get_config, reduced_config
from repro.models.transformer import LM
from repro.serve import prepare_params


def _spread(front, k):
    """Up to k points spread evenly over the front (always includes the
    cheapest and the richest)."""
    if len(front) <= k:
        return list(front)
    idx = sorted({round(i * (len(front) - 1) / (k - 1)) for i in range(k)})
    return [front[i] for i in idx]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--choices", nargs="+", type=int, default=[2, 4, 6],
                    help="candidate per-layer weight widths (even widths "
                         "serve via plane-prefix truncation; omit 8 to "
                         "force every point below the uniform-8 baseline)")
    ap.add_argument("--a-bits", type=int, default=8)
    ap.add_argument("--backend", default="decomposed",
                    choices=["decomposed", "pallas"])
    ap.add_argument("--metric", default="kl", choices=["kl", "mse"])
    ap.add_argument("--strategy", default="both",
                    choices=["greedy", "relaxed", "both"])
    ap.add_argument("--lambdas", nargs="+", type=float, default=None,
                    help="relaxation sweep (default: auto log-spaced)")
    ap.add_argument("--calib-batches", type=int, default=2)
    ap.add_argument("--calib-batch", type=int, default=2)
    ap.add_argument("--calib-len", type=int, default=16)
    ap.add_argument("--block", type=int, default=8,
                    help="perturbations per one-pass profiling forward")
    ap.add_argument("--sequential", action="store_true",
                    help="one jitted forward per perturbation instead of "
                         "the batched one-pass profiler")
    ap.add_argument("--eval-top", type=int, default=6,
                    help="front points to re-measure jointly")
    ap.add_argument("--max-divergence", type=float, default=0.05,
                    help="selection bound on measured joint divergence")
    ap.add_argument("--out", default=None, metavar="SCHEDULE.json",
                    help="write the selected schedule here")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    # One superplane preparation serves profiling, joint evaluation AND any
    # engine later built from the emitted schedule.
    from repro.core.policy import LayerPrecision, PrecisionSchedule
    t0 = time.time()
    prep_policy = PrecisionSchedule(tiers={"base": LayerPrecision(
        w_bits=8, a_bits=args.a_bits, backend=args.backend)}).prepare_policy()
    params, qpaths = prepare_params(params, prep_policy, model,
                                    superplane=True)
    print(f"prepared {len(qpaths)} superplane weights in "
          f"{time.time()-t0:.1f}s")

    calib = random_calibration(cfg, batches=args.calib_batches,
                               batch=args.calib_batch, seq=args.calib_len,
                               seed=args.seed + 1)
    batched = None if not args.sequential else False
    t0 = time.time()
    profile = profile_sensitivity(
        model, params, calib=calib, choices=tuple(args.choices),
        a_bits=args.a_bits, metric=args.metric, backend=args.backend,
        batched=batched, block=args.block)
    print(f"profiled {len(profile.layers)} layers x "
          f"{len([b for b in profile.choices if b < 8])} widths in "
          f"{time.time()-t0:.1f}s ({args.metric})")

    cost = CostModel.for_config(cfg, a_bits=args.a_bits)
    front = search(profile.table, cost, choices=tuple(args.choices),
                   strategy=args.strategy, lambdas=args.lambdas)

    # Joint re-measurement: the surrogate ranks, the measurement decides.
    eval_pts = _spread(front, max(2, args.eval_top))
    t0 = time.time()
    measured = measure_divergence(
        model, params,
        {f"pt{i}": r.assignment for i, r in enumerate(eval_pts)},
        calib=calib, a_bits=args.a_bits, metric=args.metric,
        backend=args.backend, batched=batched)
    for i, r in enumerate(eval_pts):
        r.measured_divergence = measured[f"pt{i}"]
    print(f"jointly measured {len(eval_pts)} candidates in "
          f"{time.time()-t0:.1f}s")
    # NOTE: the front stays as pruned on the surrogate — re-pruning now
    # would compare joint measurements (a subset) against surrogates (the
    # rest) on different scales and could drop the selected point from the
    # reported/persisted table.

    uniform8 = cost.uniform_cycles(8)
    print(f"\nuniform-8 baseline: {uniform8:.1f} cycles/token, "
          f"divergence 0 by definition")
    print(f"{'strategy':>8} {'avg_bits':>8} {'cycles/tok':>10} "
          f"{'vs_8bit':>8} {'pred_div':>10} {'meas_div':>10}")
    for r in front:
        meas = f"{r.measured_divergence:.3e}" \
            if r.measured_divergence is not None else "-"
        print(f"{r.strategy:>8} {r.avg_bits:>8.2f} "
              f"{r.cycles_per_token:>10.1f} "
              f"{r.cycles_per_token/uniform8:>8.2f} "
              f"{r.pred_divergence:>10.3e} {meas:>10}")

    # Selection: cheapest measured point within the divergence budget;
    # fall back to the most accurate measured point.
    ok = [r for r in eval_pts
          if r.measured_divergence is not None
          and r.measured_divergence <= args.max_divergence]
    if ok:
        selected = min(ok, key=lambda r: r.cycles_per_token)
    else:
        selected = min(eval_pts,
                       key=lambda r: (r.measured_divergence or 0.0))
        print(f"WARNING: no candidate within --max-divergence "
              f"{args.max_divergence}; selecting the most accurate point")
    print(f"\nselected: avg_bits={selected.avg_bits:.2f} "
          f"cycles/token={selected.cycles_per_token:.1f} "
          f"({selected.cycles_per_token/uniform8:.2f}x uniform-8) "
          f"measured_div={selected.measured_divergence:.3e}")

    schedule = schedule_from_results(
        [selected], tier_names=["auto"], backend=args.backend,
        include_base=True)
    out = {"front": front, "selected": selected, "schedule": schedule,
           "profile": profile, "cost": cost, "path": args.out}
    if args.out:
        meta = {
            "arch": cfg.name, "a_bits": args.a_bits, "metric": args.metric,
            "choices": list(profile.choices),
            "calib": {"batches": args.calib_batches,
                      "batch": args.calib_batch, "seq": args.calib_len,
                      "seed": args.seed + 1},
            "uniform8_cycles_per_token": uniform8,
            "max_divergence": args.max_divergence,
            "selected": result_to_meta(selected),
            "pareto_front": [result_to_meta(r) for r in front],
        }
        save_schedule(args.out, schedule, meta=meta)
        load_schedule(args.out)      # fail fast if the file can't serve
        print(f"wrote {args.out} (tiers: {list(schedule.tier_names)}, "
              f"default {schedule.default_tier!r})")
    return out


if __name__ == "__main__":
    main()
