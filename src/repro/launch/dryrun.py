import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
    + " " + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on the
production mesh with placeholder host devices; record memory/cost/collective
analysis for the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

The XLA_FLAGS line above MUST stay the first statement — jax locks the device
count on first init.  It is process-local: smoke tests and benches never
import this module.
"""
import argparse
import dataclasses
import gzip
import json
import re
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.core.policy import uniform_policy
from repro.launch import hlo_cost
from repro.distributed import sharding_rules as rules
from repro.launch import specs as specs_mod
from repro.launch.mesh import make_production_mesh
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.serve.engine import prepare_params
from repro.train import optimizer as optim
from repro.train.step import make_serve_steps, make_train_step

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _nbytes(dtype: str, dims: str) -> int:
    n = _DTYPE_BYTES.get(dtype, 4)
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return max(len([t for t in m.group(1).split(",") if t.strip()]), 1)
    return 1


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Sum *operand* bytes of every collective op in compiled HLO text.

    Operands are printed untyped (%name), so operand bytes are derived from
    the result type(s): all-gather operand = result/group, reduce-scatter
    operand = result*group, others operand = result."""
    per_op: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    counts: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        hit = None
        for op in _COLLECTIVES:
            idx = line.find(f" {op}(")
            if idx < 0:
                idx = line.find(f" {op}-start(")
            if idx >= 0:
                hit = (op, idx)
                break
        if hit is None:
            continue
        op, idx = hit
        eq = line.find(" = ")
        if eq < 0 or eq > idx:
            continue
        result_seg = line[eq + 3: idx]
        rbytes = sum(_nbytes(m.group(1), m.group(2))
                     for m in _SHAPE_RE.finditer(result_seg))
        g = _group_size(line)
        if op == "all-gather":
            obytes = rbytes // g
        elif op == "reduce-scatter":
            obytes = rbytes * g
        else:
            obytes = rbytes
        per_op[op] += obytes
        counts[op] += 1
    return {"bytes_per_op": per_op,
            "counts": counts,
            "total_bytes": sum(per_op.values())}


def _mem_dict(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover - backend specific
        return {"error": str(e)}
    out = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, f, None)
        if v is not None:
            out[f] = int(v)
    if not out:
        out["repr"] = str(ma)
    return out


def build_cell(arch: str, shape_name: str, *, multi_pod: bool,
               backend: Optional[str], w_bits: int, a_bits: int,
               kv_bits: Optional[int], reduced: bool,
               moment_dtype: str = "bfloat16", packed: bool = False,
               accum: int = 1):
    """Returns (lowered, meta) or (None, skip_reason)."""
    cfg = reduced_config(arch) if reduced else get_config(arch)
    shape = specs_mod.SHAPES[shape_name]
    if reduced:
        shape = dataclasses.replace(
            shape, seq_len=min(shape.seq_len, 128),
            global_batch=min(shape.global_batch, 8))
    ok, reason = specs_mod.cell_applicable(cfg, shape)
    if not ok:
        return None, reason
    model = LM(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod) if not reduced else \
        jax.make_mesh((2, 2), ("data", "model"))

    with mesh:
        if shape.kind == "train":
            be = backend or "fake_quant"
            rt = Runtime(policy=uniform_policy(w_bits, a_bits, backend=be))
            ocfg = optim.OptConfig(moment_dtype=moment_dtype)
            train_step = make_train_step(model, rt, ocfg, accum_steps=accum)
            p_shapes = jax.eval_shape(model.init, jax.random.key(0))
            o_shapes = jax.eval_shape(lambda p: optim.init_state(p, ocfg),
                                      p_shapes)
            state_shapes = {"params": p_shapes, "opt": o_shapes}
            state_sh = {"params": rules.tree_shardings(mesh, p_shapes),
                        "opt": rules.tree_shardings(mesh, o_shapes)}
            batch_shapes = specs_mod.batch_specs(cfg, shape)
            batch_sh = rules.batch_shardings(mesh, batch_shapes)
            fn = jax.jit(train_step,
                         in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
            lowered = fn.lower(state_shapes, batch_shapes)
        else:
            be = backend or "decomposed"
            rt = Runtime(policy=uniform_policy(w_bits, a_bits, backend=be),
                         mode="serve")
            prefill_fn, decode_fn = make_serve_steps(model, rt)
            p_shapes = jax.eval_shape(model.init, jax.random.key(0))
            if be in ("decomposed", "pallas"):
                # Offline weight preparation: planes preloaded like the array.
                p_shapes = jax.eval_shape(
                    lambda p: prepare_params(p, rt.policy, model,
                                             packed=packed)[0], p_shapes)
            p_sh = rules.tree_shardings(mesh, p_shapes)
            b = shape.global_batch
            c_shapes = jax.eval_shape(
                lambda: model.init_cache(b, shape.seq_len, kv_bits=kv_bits))
            c_sh = rules.cache_shardings(mesh, c_shapes)
            if shape.kind == "prefill":
                tok = specs_mod.token_specs(cfg, b, shape.seq_len)
                tok_sh = rules.batch_shardings(mesh, tok)
                fn = jax.jit(
                    lambda p, c, t: prefill_fn(p, c, **t),
                    in_shardings=(p_sh, c_sh, tok_sh),
                    out_shardings=(None, c_sh),
                    donate_argnums=(1,))
                lowered = fn.lower(p_shapes, c_shapes, tok)
            else:
                tok = specs_mod.token_specs(cfg, b, 1)
                tok_sh = rules.batch_shardings(mesh, tok)
                fn = jax.jit(
                    lambda p, c, t: decode_fn(p, c, **t),
                    in_shardings=(p_sh, c_sh, tok_sh),
                    out_shardings=(None, c_sh),
                    donate_argnums=(1,))
                lowered = fn.lower(p_shapes, c_shapes, tok)

        meta = {
            "arch": cfg.name, "family": cfg.family, "shape": shape.name,
            "kind": shape.kind, "seq_len": shape.seq_len,
            "global_batch": shape.global_batch,
            "mesh": "x".join(str(s) for s in mesh.devices.shape),
            "axes": list(mesh.axis_names),
            "n_devices": int(mesh.devices.size),
            "backend": be, "w_bits": w_bits, "a_bits": a_bits,
            "kv_bits": kv_bits, "packed": packed, "accum": accum,
            "param_count": cfg.param_count(),
            "active_param_count": cfg.active_param_count(),
            "model_flops": specs_mod.model_flops(cfg, shape),
        }
        return (lowered, mesh), meta


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             backend: Optional[str] = None, w_bits: int = 4, a_bits: int = 8,
             kv_bits: Optional[int] = None, reduced: bool = False,
             dump_hlo: Optional[str] = None,
             packed: bool = False, accum: int = 1) -> Dict[str, Any]:
    t0 = time.time()
    built, meta = build_cell(arch, shape_name, multi_pod=multi_pod,
                             backend=backend, w_bits=w_bits, a_bits=a_bits,
                             kv_bits=kv_bits, reduced=reduced, packed=packed,
                             accum=accum)
    if built is None:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "skipped": True, "reason": meta}
    lowered, mesh = built
    t_lower = time.time() - t0
    with mesh:
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = _mem_dict(compiled)
    print("memory_analysis:", json.dumps(mem))          # proves it fits
    try:
        cost = dict(compiled.cost_analysis())
    except Exception as e:
        cost = {"error": str(e)}
    print("cost_analysis: flops=%s bytes=%s" % (
        cost.get("flops"), cost.get("bytes accessed")))

    hlo = compiled.as_text()
    # Loop-aware re-analysis: cost_analysis counts while bodies once; the
    # hlo_cost walker multiplies by trip counts (see launch/hlo_cost.py).
    loop_aware = hlo_cost.analyze(hlo)
    coll = loop_aware["collectives"]
    if dump_hlo:
        with gzip.open(dump_hlo, "wt") as f:
            f.write(hlo)

    res = dict(meta)
    res.update({
        "skipped": False,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops": loop_aware["flops"],
        "bytes_accessed": loop_aware["bytes"],
        "xla_cost_raw": {k: v for k, v in cost.items()
                         if isinstance(v, (int, float)) and
                         k in ("flops", "bytes accessed", "transcendentals")},
        "collectives": coll,
        "collectives_unscaled": parse_collectives(hlo),
        "memory": mem,
        "hlo_lines": hlo.count("\n"),
    })
    return res


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True,
                    choices=sorted(specs_mod.SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--backend", default=None,
                    choices=["dense", "fake_quant", "decomposed", "pallas"])
    ap.add_argument("--w-bits", type=int, default=4)
    ap.add_argument("--a-bits", type=int, default=8)
    ap.add_argument("--kv-bits", type=int, default=None)
    ap.add_argument("--packed", action="store_true",
                    help="packed plane layout (w_bits/8 bytes per weight)")
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation microbatches (train cells)")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny config on a 2x2 mesh (CI / self-test)")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--dump-hlo", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    mesh_name = ("2x16x16" if args.multi_pod else "16x16") if not args.reduced \
        else "2x2"
    stem = f"{args.arch}__{args.shape}__{mesh_name}"
    if args.backend:
        stem += f"__{args.backend}"
    if args.tag:
        stem += f"__{args.tag}"
    hlo_path = os.path.join(args.out, stem + ".hlo.gz") if args.dump_hlo else None

    res = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   backend=args.backend, w_bits=args.w_bits,
                   a_bits=args.a_bits, kv_bits=args.kv_bits,
                   reduced=args.reduced, dump_hlo=hlo_path,
                   packed=args.packed, accum=args.accum)
    out_path = os.path.join(args.out, stem + ".json")
    with open(out_path, "w") as f:
        json.dump(res, f, indent=1)
    status = "SKIP" if res.get("skipped") else "OK"
    print(f"[{status}] {stem} -> {out_path}")
    if not res.get("skipped"):
        print(f"  compile={res['compile_s']}s flops={res['flops']:.3e} "
              f"coll={res['collectives']['total_bytes']:.3e}B")


if __name__ == "__main__":
    main()
