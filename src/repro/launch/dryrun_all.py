"""Sequential orchestrator: run every (arch x shape x mesh) dry-run cell as a
separate process (one compile per process isolates XLA state and memory),
skipping cells whose result JSON already exists.

    PYTHONPATH=src python -m repro.launch.dryrun_all [--multi-pod] [--force]
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

from repro.configs import ARCHS
from repro.launch import specs as specs_mod

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def cells():
    for arch in ARCHS:
        for shape in SHAPE_ORDER:
            yield arch, shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--backend", default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--only-arch", default=None)
    args = ap.parse_args()

    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape in cells():
        if args.only_arch and arch != args.only_arch:
            continue
        stem = f"{arch}__{shape}__{mesh_name}"
        if args.backend:
            stem += f"__{args.backend}"
        if args.tag:
            stem += f"__{args.tag}"
        path = os.path.join(args.out, stem + ".json")
        if os.path.exists(path) and not args.force:
            print(f"[cached] {stem}")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--out", args.out]
        if args.multi_pod:
            cmd.append("--multi-pod")
        if args.backend:
            cmd += ["--backend", args.backend]
        if args.tag:
            cmd += ["--tag", args.tag]
        t0 = time.time()
        print(f"[run] {stem} ...", flush=True)
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
        except subprocess.TimeoutExpired:
            print(f"[TIMEOUT] {stem}")
            failures.append((stem, "timeout"))
            continue
        dt = time.time() - t0
        if r.returncode != 0:
            tail = "\n".join(r.stdout.splitlines()[-3:] +
                             r.stderr.splitlines()[-12:])
            print(f"[FAIL {dt:.0f}s] {stem}\n{tail}")
            failures.append((stem, tail[-400:]))
        else:
            lines = r.stdout.splitlines() if r.stdout else []
            info = next((l for l in reversed(lines) if l.startswith("[")), stem)
            print(f"[ok {dt:.0f}s] {info.strip()}")
    print(f"\n{len(failures)} failures")
    for stem, msg in failures:
        print(" FAILED:", stem)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
