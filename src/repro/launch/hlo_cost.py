"""Loop-aware cost analysis over compiled HLO text.

``compiled.cost_analysis()`` counts a while-loop body ONCE, so any scanned
model (layer scan, flash-attention KV scan, SSD chunk scan) is undercounted
by the trip count.  This module re-derives FLOPs / bytes / collective bytes
from the optimized HLO text, walking the computation call graph and
multiplying while-bodies by their trip counts (extracted from the loop
condition's comparison constant).  Validated against an unrolled-vs-scanned
equality test in tests/test_hlo_cost.py.

Conventions:
  * dot flops = 2 * prod(result dims) * prod(contracted lhs dims)
  * conv flops ~= 2 * prod(result dims) * prod(window sizes)  (depthwise)
  * bytes = operand + result bytes of top-level ops (fusion interiors hidden,
    matching XLA's bytes-accessed convention); while bodies scale by trips
  * collective operand bytes: all-gather result/g, reduce-scatter result*g,
    others = result bytes
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
}
_ARRAY_TYPE_RE = re.compile(r"^([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([a-z][a-z0-9\-]*)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\{\s*$")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_WINDOW_RE = re.compile(r"window=\{size=([0-9x]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
# Ops whose attached computations are trivial reducers — do not recurse.
_NO_RECURSE = {"all-reduce", "reduce-scatter", "all-reduce-start", "reduce",
               "reduce-window", "scatter", "select-and-scatter", "sort",
               "map", "reduce-scatter-start"}
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "opt-barrier"}


def _parse_array_type(s: str) -> Optional[Tuple[str, List[int]]]:
    m = _ARRAY_TYPE_RE.match(s.strip())
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


def _nbytes(t: Optional[Tuple[str, List[int]]]) -> int:
    if t is None:
        return 0
    n = _DTYPE_BYTES.get(t[0], 4)
    for d in t[1]:
        n *= d
    return n


@dataclasses.dataclass
class Op:
    name: str
    rtype_str: str
    opcode: str
    rest: str            # args + attrs (everything after the opening paren)

    @property
    def rtype(self):
        return _parse_array_type(self.rtype_str)

    def result_bytes(self) -> int:
        t = self.rtype
        if t is not None:
            return _nbytes(t)
        # tuple type: sum member arrays
        total = 0
        for m in re.finditer(r"([a-z0-9]+)\[([0-9,]*)\]", self.rtype_str):
            total += _nbytes((m.group(1),
                              [int(d) for d in m.group(2).split(",") if d]))
        return total


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})
    # f32 share of collective bytes: XLA:CPU promotes bf16 dot operands to
    # f32, so their resharding collectives move 2x the bytes a TPU would
    # (native bf16).  Tracked so the roofline can report a TPU-adjusted term.
    coll_bytes_f32: float = 0.0

    def add(self, other: "Cost", mult: float = 1.0, include_bytes: bool = True):
        self.flops += other.flops * mult
        if include_bytes:
            self.bytes += other.bytes * mult
        self.coll_bytes_f32 += other.coll_bytes_f32 * mult
        for c in COLLECTIVES:
            self.coll_bytes[c] += other.coll_bytes[c] * mult
            self.coll_counts[c] += other.coll_counts[c] * mult

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll_bytes.values())


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Op]] = {}
        self._parse(text)
        self.entry = self._find_entry(text)
        self._memo: Dict[str, Cost] = {}

    def _parse(self, text: str):
        current = None
        for line in text.splitlines():
            if not line.strip() or line.startswith("HloModule"):
                continue
            hdr = _COMP_HDR_RE.match(line)
            if hdr and " = " not in line:
                current = hdr.group(2)
                self.computations[current] = []
                continue
            if line.strip() == "}":
                continue
            m = _OP_RE.match(line)
            if m and current is not None:
                self.computations[current].append(
                    Op(m.group(1), m.group(2), m.group(3), m.group(4)))

    def _find_entry(self, text: str) -> Optional[str]:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
        if m:
            return m.group(1)
        m = re.search(r"entry_computation_layout", text)
        return next(iter(self.computations)) if self.computations else None

    # -------------------------------------------------------------- helpers
    def _types(self, comp: str) -> Dict[str, Optional[Tuple[str, List[int]]]]:
        return {op.name: op.rtype for op in self.computations.get(comp, [])}

    def _trip_count(self, cond_comp: str) -> int:
        """Loop bound from the condition's comparison constant."""
        best = 1
        for op in self.computations.get(cond_comp, []):
            for m in _CONST_INT_RE.finditer(
                    f"{op.opcode}({op.rest}" if op.opcode == "constant"
                    else op.rest):
                best = max(best, int(m.group(1)))
            if op.opcode == "constant":
                m = _CONST_INT_RE.search(f"constant({op.rest}")
                if m:
                    best = max(best, int(m.group(1)))
        return best

    def _dot_flops(self, op: Op, types) -> float:
        rt = op.rtype
        if rt is None:
            return 0.0
        result_elems = 1
        for d in rt[1]:
            result_elems *= d
        k = 1
        m = _LHS_CDIMS_RE.search(op.rest)
        operands = _OPERAND_RE.findall(op.rest.split("),")[0])
        if m and operands:
            lhs_t = types.get(operands[0])
            if lhs_t is not None:
                for idx in (int(i) for i in m.group(1).split(",") if i):
                    if idx < len(lhs_t[1]):
                        k *= lhs_t[1][idx]
        return 2.0 * result_elems * k

    def _conv_flops(self, op: Op) -> float:
        rt = op.rtype
        if rt is None:
            return 0.0
        result_elems = 1
        for d in rt[1]:
            result_elems *= d
        window = 1
        m = _WINDOW_RE.search(op.rest)
        if m:
            for s in m.group(1).split("x"):
                window *= int(s)
        return 2.0 * result_elems * window

    def _collective(self, op: Op, cost: Cost):
        base = op.opcode.replace("-start", "")
        rbytes = op.result_bytes()
        g = 1
        m = _GROUPS_RE.search(op.rest)
        if m:
            g = max(int(m.group(2)), 1)
        else:
            m = _GROUPS_BRACE_RE.search(op.rest)
            if m:
                g = max(len([t for t in m.group(1).split(",") if t.strip()]), 1)
        if base == "all-gather":
            obytes = rbytes / g
        elif base == "reduce-scatter":
            obytes = rbytes * g
        else:
            obytes = rbytes
        cost.coll_bytes[base] += obytes
        cost.coll_counts[base] += 1
        if op.rtype_str.lstrip("(").startswith("f32"):
            cost.coll_bytes_f32 += obytes

    # ----------------------------------------------------------------- cost
    def cost_of(self, comp: Optional[str] = None) -> Cost:
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = Cost()  # cycle guard
        cost = Cost()
        types = self._types(comp)
        for op in self.computations.get(comp, []):
            oc = op.opcode
            base = oc.replace("-start", "").replace("-done", "")
            if base in COLLECTIVES and not oc.endswith("-done"):
                self._collective(op, cost)
                cost.bytes += op.result_bytes()
                continue
            if oc == "while":
                m = _COND_BODY_RE.search(op.rest)
                if m:
                    trips = self._trip_count(m.group(1))
                    cost.add(self.cost_of(m.group(2)), mult=trips)
                    cost.add(self.cost_of(m.group(1)), mult=trips)
                continue
            if oc == "fusion":
                m = _CALLS_RE.search(op.rest)
                if m:
                    # flops from inside the fusion; bytes only at the call
                    # boundary (fusion interiors never touch HBM).
                    cost.add(self.cost_of(m.group(1)), include_bytes=False)
                cost.bytes += self._op_io_bytes(op, types)
                continue
            if oc in ("call", "conditional", "async-start"):
                m = _TO_APPLY_RE.search(op.rest) or _CALLS_RE.search(op.rest)
                if m:
                    cost.add(self.cost_of(m.group(1)))
                continue
            if oc == "dot":
                cost.flops += self._dot_flops(op, types)
                cost.bytes += self._op_io_bytes(op, types)
                continue
            if oc == "convolution":
                cost.flops += self._conv_flops(op)
                cost.bytes += self._op_io_bytes(op, types)
                continue
            if oc in _FREE_OPS:
                continue
            if oc in _NO_RECURSE or True:
                cost.bytes += self._op_io_bytes(op, types)
        self._memo[comp] = cost
        return cost

    def _op_io_bytes(self, op: Op, types) -> float:
        # Sliced views alias their operand (XLA buffer assignment): charge
        # only the moved bytes, not the full backing buffer — otherwise a
        # scan that dynamic-slices a stacked params/cache tensor per step
        # is billed the whole stack every iteration.
        if op.opcode in ("slice", "dynamic-slice"):
            return float(op.result_bytes())
        args = op.rest.split("), ")[0] if "), " in op.rest else op.rest
        operands = _OPERAND_RE.findall(args)
        if op.opcode == "dynamic-update-slice":
            # read + write of the updated region only (in-place update).
            if len(operands) >= 2:
                t = types.get(operands[1])
                if t is not None:
                    return 2.0 * _nbytes(t)
            return float(op.result_bytes())
        # In-place update pattern (e.g. the scan's stacked-cache update
        # fusion): an operand with exactly the result type aliases the
        # output buffer; charge only the remaining (slice-sized) operands,
        # twice (read + write of the updated region).
        rtype = op.rtype
        if op.opcode == "fusion" and rtype is not None:
            op_types = [types.get(n) for n in operands]
            if any(t == rtype for t in op_types if t is not None):
                others = sum(_nbytes(t) for t in op_types
                             if t is not None and t != rtype)
                return 2.0 * others if others else float(_nbytes(rtype))
        total = float(op.result_bytes())
        for name in operands:
            t = types.get(name)
            if t is not None:
                total += _nbytes(t)
        return total


def analyze(hlo_text: str) -> Dict[str, object]:
    mod = HloModule(hlo_text)
    cost = mod.cost_of()
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "collectives": {
            "bytes_per_op": {k: v for k, v in cost.coll_bytes.items()},
            "counts": {k: v for k, v in cost.coll_counts.items()},
            "total_bytes": cost.collective_bytes,
            "f32_bytes": cost.coll_bytes_f32,
        },
    }
