"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (data, model).
Multi-pod: 2x16x16 = 512 chips (pod, data, model) — DP across pods.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests / PP experiments (e.g. (4,), ('stage',))."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_serve_mesh(n: int):
    """1D ("model",) mesh over the first ``n`` devices for tensor-parallel
    serving (``ServeEngine(mesh=...)`` / ``repro.launch.serve --mesh N``).

    Unlike :func:`make_mesh` this slices ``jax.devices()`` explicitly, so a
    host with more devices than requested still builds an n-way mesh (the
    CI/dev pattern: 4 fake CPU devices, meshes of 1/2/4)."""
    devs = jax.devices()
    if len(devs) < n:
        raise ValueError(
            f"--mesh {n} needs {n} devices but only {len(devs)} visible; "
            "on CPU set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n} before importing jax")
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devs[:n]).reshape((n,)), ("model",))
