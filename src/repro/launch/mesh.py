"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (data, model).
Multi-pod: 2x16x16 = 512 chips (pod, data, model) — DP across pods.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests / PP experiments (e.g. (4,), ('stage',))."""
    return jax.make_mesh(tuple(shape), tuple(axes))
