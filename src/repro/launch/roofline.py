"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh) cell, in seconds:

    compute    = HLO_FLOPs       / (chips * peak_FLOP/s)
    memory     = HLO_bytes       / (chips * HBM_bw)
    collective = collective_bytes/ (chips * link_bw)

Hardware constants (TPU v5e-class, per assignment): 197 TFLOP/s bf16 per
chip (394 TOPS int8 for the decomposed integer path), 819 GB/s HBM,
~50 GB/s/link ICI.

Also reports MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) and the
usefulness ratio MODEL_FLOPS / HLO_FLOPs (remat/redundancy waste), the
dominant term, and a one-line lever per cell.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Any, Dict, List, Optional

PEAK_FLOPS_BF16 = 197e12        # per chip
PEAK_OPS_INT8 = 394e12          # decomposed integer path
HBM_BW = 819e9                  # bytes/s per chip
LINK_BW = 50e9                  # bytes/s per ICI link


def roofline_terms(cell: Dict[str, Any], *, int8_peak: bool = False
                   ) -> Optional[Dict[str, Any]]:
    if cell.get("skipped"):
        return None
    chips = cell["n_devices"]
    flops = float(cell.get("flops") or 0.0)
    byts = float(cell.get("bytes_accessed") or 0.0)
    coll = float(cell["collectives"]["total_bytes"])
    peak = PEAK_OPS_INT8 if int8_peak else PEAK_FLOPS_BF16
    # HLO flops/bytes from cost_analysis are PER-PARTITION after SPMD (the
    # module is the per-device program): divide by per-chip rates only.
    t_compute = flops / peak
    t_memory = byts / HBM_BW
    t_coll = coll / LINK_BW
    # TPU-adjusted collective term: f32 collectives exist only because
    # XLA:CPU promotes bf16 dot operands; native-bf16 TPU moves half.
    f32_coll = float(cell["collectives"].get("f32_bytes", 0.0))
    t_coll_tpu = (coll - 0.5 * f32_coll) / LINK_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    model_flops = float(cell.get("model_flops") or 0.0)
    hlo_total = flops * chips
    useful = model_flops / hlo_total if hlo_total else 0.0
    bound_time = max(terms.values())
    # Roofline fraction: useful model FLOPs per chip-second at peak vs the
    # bound term (1.0 = the dominant resource is fully spent on model math).
    frac = (model_flops / chips / peak) / bound_time if bound_time else 0.0
    return {
        **terms,
        "collective_tpu_adj_s": t_coll_tpu,
        "dominant": dominant.replace("_s", ""),
        "model_flops": model_flops,
        "hlo_flops_total": hlo_total,
        "useful_ratio": useful,
        "roofline_fraction": frac,
        "step_time_bound_s": bound_time,
    }


LEVERS = {
    "compute": "cut redundant HLO FLOPs (remat policy, fewer quant passes, "
               "bf16 cast before matmul)",
    "memory": "cut bytes: pack weight planes (w_bits/8 B/weight), quantize "
              "KV cache, fuse quant into matmul epilogue",
    "collective": "reshard to remove all-gathers (2D->1D for small dims), "
                  "overlap via latency-hiding scheduler, compress grads",
}


def load_cells(result_dir: str) -> List[Dict[str, Any]]:
    cells = []
    for path in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def format_table(cells: List[Dict[str, Any]], *, int8_peak_backends=("decomposed", "pallas")) -> str:
    rows = []
    header = ("| arch | shape | mesh | backend | compute s | memory s | "
              "collective s | dominant | MODEL/HLO | roofline frac |")
    sep = "|" + "---|" * 10
    rows.append(header)
    rows.append(sep)
    for c in cells:
        if c.get("skipped"):
            rows.append(f"| {c['arch']} | {c['shape']} | {c.get('mesh','-')} | - "
                        f"| - | - | - | SKIP | - | {c['reason'][:60]} |")
            continue
        t = roofline_terms(
            c, int8_peak=c.get("backend") in int8_peak_backends)
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {c['backend']} "
            f"| {t['compute_s']:.3e} | {t['memory_s']:.3e} "
            f"| {t['collective_s']:.3e} | **{t['dominant']}** "
            f"| {t['useful_ratio']:.2f} | {t['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="benchmarks/results/dryrun")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    cells = load_cells(args.results)
    print(format_table(cells))
    if args.json_out:
        enriched = []
        for c in cells:
            t = roofline_terms(c) if not c.get("skipped") else None
            enriched.append({**c, "roofline": t})
        with open(args.json_out, "w") as f:
            json.dump(enriched, f, indent=1)


if __name__ == "__main__":
    main()
