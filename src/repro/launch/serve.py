"""Serving driver: offline-quantize a model (Table-I planes, optionally
packed) and serve a stream of greedy-decode requests through the
continuous-batching engine (`--baseline` runs the batch-at-a-time
reference engine for comparison).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
        --w-bits 4 --kv-bits 8 --requests 8

Runtime-reconfigurable tiers (one 8-bit superplane preload, per-request
effective precision; requests round-robin over the tiers and decode in
MIXED-tier batches — one jitted step serves all tiers at once):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
        --tiers 8/8 4/4 2/2 --requests 9

Per-request KV-cache precision (one kv value per tier, aligned with
--tiers; bf16 / 8 / 4) and the tier-serialized admission baseline:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
        --tiers 8/8 4/4 2/2 --kv-tiers bf16 8 4 --requests 9
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
        --tiers 8/8 4/4 2/2 --serialize-tiers --requests 9
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core.policy import uniform_policy, uniform_schedule
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.serve.engine import (BatchServeEngine, Request, ServeEngine,
                                prepare_params)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--w-bits", type=int, default=4)
    ap.add_argument("--a-bits", type=int, default=8)
    ap.add_argument("--kv-bits", type=int, default=None)
    ap.add_argument("--packed", action="store_true")
    ap.add_argument("--backend", default="decomposed",
                    choices=["decomposed", "pallas", "dense"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--decode-chunk", type=int, default=8)
    ap.add_argument("--baseline", action="store_true",
                    help="use the batch-at-a-time reference engine")
    ap.add_argument("--tiers", nargs="+", default=None, metavar="W/A",
                    help="runtime precision tiers, e.g. --tiers 8/8 4/4 2/2: "
                         "ONE superplane preload, requests round-robin over "
                         "the tiers (even w only; overrides --w/a-bits)")
    ap.add_argument("--kv-tiers", nargs="+", default=None, metavar="KV",
                    help="per-tier KV-cache precision aligned with --tiers "
                         "(bf16, 8 or 4): ONE mixed per-slot KV arena, each "
                         "request's slot stored at its tier's kv precision")
    ap.add_argument("--serialize-tiers", action="store_true",
                    help="tier-SERIALIZED admission (one tier per decode "
                         "batch; PR-2 behaviour) instead of mixed-tier "
                         "batches — the serve_mixed_tiers comparison "
                         "baseline")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    # Flag validation BEFORE any model building (full-size configs take
    # minutes to init; a bad flag combination must fail instantly).
    schedule = None
    if args.tiers:
        if args.backend == "dense":
            ap.error("--tiers needs an integer backend")
        if args.baseline:
            ap.error("--baseline has no per-request tier switching "
                     "(it pins one tier); drop --tiers")
        kv_tiers = None
        if args.kv_tiers:
            if len(args.kv_tiers) != len(args.tiers):
                ap.error("--kv-tiers must align 1:1 with --tiers")
            if args.kv_bits is not None:
                ap.error("--kv-bits conflicts with --kv-tiers; drop one")
            try:
                kv_tiers = {t: (None if kv in ("bf16", "none") else int(kv))
                            for t, kv in zip(args.tiers, args.kv_tiers)}
            except ValueError:
                ap.error(f"--kv-tiers values must be bf16, 8 or 4, got "
                         f"{args.kv_tiers}")
        schedule = uniform_schedule(
            {t: tuple(int(b) for b in t.split("/")) for t in args.tiers},
            backend=args.backend, kv_tiers=kv_tiers)
        policy = schedule.policy_for()
    else:
        if args.kv_tiers:
            ap.error("--kv-tiers needs --tiers")
        if args.serialize_tiers:
            ap.error("--serialize-tiers needs --tiers")
        policy = uniform_policy(args.w_bits, args.a_bits,
                                backend=args.backend)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    if args.backend != "dense":
        # Weight preload: planes prepared ONCE, before any request arrives.
        # With --tiers this is the 8-bit superplane store serving them all.
        t0 = time.time()
        params, qpaths = prepare_params(params,
                                        schedule.prepare_policy()
                                        if schedule else policy,
                                        model, packed=args.packed,
                                        superplane=schedule is not None)
        kind = "superplane" if schedule else f"w{args.w_bits}"
        print(f"prepared {len(qpaths)} weights "
              f"({kind}, packed={args.packed}) "
              f"in {time.time()-t0:.1f}s")
    rt = Runtime(policy=policy, mode="serve", moe_dropless=args.reduced,
                 schedule=schedule)
    cls = BatchServeEngine if args.baseline else ServeEngine
    kw = {} if args.baseline else {"decode_chunk": args.decode_chunk,
                                   "mixed_tiers": not args.serialize_tiers}
    engine = cls(model, params, rt, max_batch=args.max_batch,
                 max_len=args.max_len, kv_bits=args.kv_bits, **kw)

    rng = np.random.default_rng(args.seed)
    tier_of = (lambda i: args.tiers[i % len(args.tiers)]) if args.tiers \
        else (lambda i: None)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=4 + i % 5),
                    max_new_tokens=1 + (args.max_new * (i % 4)) // 3,
                    tier=tier_of(i))
            for i in range(args.requests)]
    t0 = time.time()
    results = engine.run(reqs)
    dt = time.time() - t0
    toks = sum(len(v) for v in results.values())
    st = engine.stats
    print(f"served {len(reqs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")
    print(f"stats: prefills={st.prefills} decode_steps={st.decode_steps} "
          f"slot_steps={st.decode_slot_steps} chunks={st.decode_chunks}")
    if args.tiers:
        per = " ".join(f"{t}:{st.decode_steps_by_tier.get(t, 0)}"
                       for t in args.tiers)
        mode = "serialized" if args.serialize_tiers else "mixed"
        print(f"tier decode_steps ({mode}): {per} "
              f"(switches={st.tier_switches} "
              f"mixed_chunks={st.mixed_tier_chunks})")
    return results


if __name__ == "__main__":
    main()
