"""Serving driver: offline-quantize a model (Table-I planes, optionally
packed) and serve a stream of greedy-decode requests through the streaming
engine API — ``submit() -> RequestHandle`` / ``step() -> [TokenEvent]`` /
``drain()`` (`--baseline` runs the batch-at-a-time reference engine).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
        --w-bits 4 --kv-bits 8 --requests 8

Runtime-reconfigurable tiers (one 8-bit superplane preload, per-request
effective precision; requests round-robin over the tiers and decode in
MIXED-tier batches — one jitted step serves all tiers at once):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
        --tiers 8/8 4/4 2/2 --requests 9

Per-request KV-cache precision (one kv value per tier, aligned with
--tiers; bf16 / 8 / 4) and the tier-serialized admission baseline:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
        --tiers 8/8 4/4 2/2 --kv-tiers bf16 8 4 --requests 9
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
        --tiers 8/8 4/4 2/2 --serialize-tiers --requests 9

SLO-aware admission (deadline slack priced by the hwmodel's per-tier cycle
cost instead of plain FIFO; every 3rd request gets a tight deadline) and
mid-stream tier migration (the first live request is migrated to the LAST
--tiers entry after a few tokens — KV lane requantized in place, weight
plane prefix switched at the next group layout):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
        --tiers 8/8 4/4 2/2 --slo --requests 9
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
        --tiers 8/8 4/4 2/2 --kv-tiers bf16 8 4 --migrate-demo --requests 6

Overload survival on top of --slo: ``--preempt`` lets a deadlined request
that ran out of slack displace the slackest running slot (the victim's
KV/SSM slice is snapshotted host-side and later resumes prefill-free,
token-identical); ``--shed`` turns admission into overload control — a
deadline request whose projected completion exceeds modeled capacity is
refused at submit (terminal SHED status) instead of missing late:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
        --tiers 8/8 4/4 2/2 --slo --preempt --shed --requests 12

Self-speculative decoding from the plane prefix (--speculate): every
request drafts --spec-k tokens per round at the --draft-tier plane prefix
of the SAME superplane store, verifies the window in ONE batched forward
at its own tier, and rolls rejected positions back — greedy streams are
token-identical to non-speculative decoding at the verify tier.
--temperature/--top-k switch the whole stream to seeded stochastic
sampling (deterministic across eager/jit and mesh widths):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
        --tiers 8/8 4/4 --speculate --draft-tier 4/4 --spec-k 4 --requests 6
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
        --tiers 8/8 4/4 --temperature 0.8 --top-k 40 --requests 6
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core.policy import uniform_policy, uniform_schedule
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.serve import (BatchServeEngine, Request, ServeEngine, SLOPolicy,
                         prepare_params)
from repro.serve.handle import RequestStatus
from repro.telemetry import Telemetry, serve_report, write_json


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--w-bits", type=int, default=4)
    ap.add_argument("--a-bits", type=int, default=8)
    ap.add_argument("--kv-bits", type=int, default=None)
    ap.add_argument("--packed", action="store_true")
    ap.add_argument("--backend", default="decomposed",
                    choices=["decomposed", "pallas", "dense"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--decode-chunk", type=int, default=8)
    ap.add_argument("--baseline", action="store_true",
                    help="use the batch-at-a-time reference engine")
    ap.add_argument("--tiers", nargs="+", default=None, metavar="W/A",
                    help="runtime precision tiers, e.g. --tiers 8/8 4/4 2/2: "
                         "ONE superplane preload, requests round-robin over "
                         "the tiers (even w only; overrides --w/a-bits)")
    ap.add_argument("--schedule-file", default=None, metavar="SCHEDULE.json",
                    help="serve a searched PrecisionSchedule written by "
                         "repro.launch.autoprec (tiers, per-layer rules and "
                         "kv_tiers come from the file; requests round-robin "
                         "over its tiers)")
    ap.add_argument("--kv-tiers", nargs="+", default=None, metavar="KV",
                    help="per-tier KV-cache precision aligned with --tiers "
                         "(bf16, 8 or 4): ONE mixed per-slot KV arena, each "
                         "request's slot stored at its tier's kv precision")
    ap.add_argument("--serialize-tiers", action="store_true",
                    help="tier-SERIALIZED admission (one tier per decode "
                         "batch; PR-2 behaviour) instead of mixed-tier "
                         "batches — the serve_mixed_tiers comparison "
                         "baseline")
    ap.add_argument("--slo", action="store_true",
                    help="SLO-aware admission (SLOPolicy): every 3rd "
                         "request gets a tight deadline; reports per-"
                         "request queue waits and deadline misses")
    ap.add_argument("--preempt", action="store_true",
                    help="with --slo: slot preemption — a deadlined "
                         "waiting request out of slack displaces the "
                         "slackest running slot (snapshot + prefill-free, "
                         "token-identical resume)")
    ap.add_argument("--shed", action="store_true",
                    help="with --slo: admission control — shed a deadline "
                         "request at submit when its projected completion "
                         "exceeds modeled capacity (with --auto-tier it is "
                         "downtiered first if a faster tier still fits)")
    ap.add_argument("--spill-dir", default=None, metavar="DIR",
                    help="spill preempted-slot snapshots through the "
                         "checkpoint subsystem (atomic step dirs under DIR) "
                         "instead of holding them host-resident")
    ap.add_argument("--auto-tier", action="store_true",
                    help="with --slo on a tiered engine: deadline-aware "
                         "tier auto-selection — a deadlined request is "
                         "retagged at admission to the best tier whose "
                         "priced service time fits its slack")
    ap.add_argument("--migrate-demo", action="store_true",
                    help="mid-stream tier migration demo: after a few "
                         "tokens the first live request is migrated to the "
                         "last --tiers entry (requantizes its KV lane in "
                         "place; needs --tiers, mixed admission)")
    ap.add_argument("--mesh", type=int, default=None, metavar="N",
                    help="tensor-parallel serving over N devices: shard the "
                         "superplane store column-wise and the KV arena over "
                         "heads, with quantized (int8 / bit-packed) "
                         "activation gathers on the wire — token-identical "
                         "to the unsharded engine.  On CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N for fake "
                         "devices")
    ap.add_argument("--speculate", action="store_true",
                    help="self-speculative decoding: draft --spec-k tokens "
                         "per round at the --draft-tier plane prefix, "
                         "verify the window in one batched forward at each "
                         "request's own tier (greedy streams are token-"
                         "identical to non-speculative decoding; needs "
                         "--tiers, mixed admission)")
    ap.add_argument("--draft-tier", default=None, metavar="W/A",
                    help="with --speculate: the draft tier (must be one of "
                         "--tiers; default: the last, lowest-precision "
                         "--tiers entry)")
    ap.add_argument("--spec-k", type=int, default=4, metavar="K",
                    help="with --speculate: draft tokens per round "
                         "(default 4)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax, the "
                         "default); seeded, deterministic across eager/jit "
                         "and mesh widths")
    ap.add_argument("--top-k", type=int, default=0, metavar="K",
                    help="with --temperature > 0: restrict sampling to the "
                         "K highest-probability tokens (0 = full vocab)")
    ap.add_argument("--metrics", nargs="?", const="-", default=None,
                    metavar="PATH",
                    help="export the run's metrics: Prometheus text to "
                         "stdout (bare --metrics) or to PATH; a .json "
                         "suffix writes the JSON snapshot instead")
    ap.add_argument("--trace-out", default=None, metavar="TRACE.json",
                    help="write the dual-clock span trace as Chrome "
                         "trace-event JSON (loadable in Perfetto / "
                         "chrome://tracing)")
    ap.add_argument("--profile", action="store_true",
                    help="opt-in device timing: fence each prefill/decode-"
                         "chunk/spec-round dispatch (block_until_ready) and "
                         "report per-phase device seconds — bit-identical "
                         "output, adds host syncs")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    # Flag validation BEFORE any model building (full-size configs take
    # minutes to init; a bad flag combination must fail instantly).
    schedule = None
    if args.schedule_file:
        if args.tiers:
            ap.error("--schedule-file carries its own tiers; drop --tiers")
        if args.kv_tiers:
            ap.error("--schedule-file carries its own kv_tiers; drop "
                     "--kv-tiers")
        if args.backend == "dense":
            ap.error("--schedule-file needs an integer backend")
        if args.baseline:
            ap.error("--baseline has no per-request tier switching; drop "
                     "--schedule-file")
        from repro.autoprec import load_schedule
        schedule = load_schedule(args.schedule_file)
        if schedule.kv_tiers is not None and args.kv_bits is not None:
            ap.error("--kv-bits conflicts with the schedule file's kv_tiers")
        file_backends = {p.backend for p in schedule._all_precisions()}
        if file_backends != {args.backend}:
            ap.error(f"--backend {args.backend} does not match the schedule "
                     f"file's backend(s) {sorted(file_backends)}; pass the "
                     "matching --backend (or re-emit the file with "
                     "repro.launch.autoprec --backend)")
        # Downstream request/reporting logic round-robins over the loaded
        # tier names exactly like hand-written --tiers.
        args.tiers = list(schedule.tier_names)
        policy = schedule.policy_for()
    elif args.tiers:
        if args.backend == "dense":
            ap.error("--tiers needs an integer backend")
        if args.baseline:
            ap.error("--baseline has no per-request tier switching "
                     "(it pins one tier); drop --tiers")
        kv_tiers = None
        if args.kv_tiers:
            if len(args.kv_tiers) != len(args.tiers):
                ap.error("--kv-tiers must align 1:1 with --tiers")
            if args.kv_bits is not None:
                ap.error("--kv-bits conflicts with --kv-tiers; drop one")
            try:
                kv_tiers = {t: (None if kv in ("bf16", "none") else int(kv))
                            for t, kv in zip(args.tiers, args.kv_tiers)}
            except ValueError:
                ap.error(f"--kv-tiers values must be bf16, 8 or 4, got "
                         f"{args.kv_tiers}")
        schedule = uniform_schedule(
            {t: tuple(int(b) for b in t.split("/")) for t in args.tiers},
            backend=args.backend, kv_tiers=kv_tiers)
        policy = schedule.policy_for()
    else:
        if args.kv_tiers:
            ap.error("--kv-tiers needs --tiers")
        if args.serialize_tiers:
            ap.error("--serialize-tiers needs --tiers")
        policy = uniform_policy(args.w_bits, args.a_bits,
                                backend=args.backend)
    if args.migrate_demo:
        if not args.tiers or len(args.tiers) < 2:
            ap.error("--migrate-demo needs --tiers with >= 2 tiers")
        if args.serialize_tiers or args.baseline:
            ap.error("--migrate-demo needs mixed-tier admission (drop "
                     "--serialize-tiers / --baseline)")
    if args.slo and args.baseline:
        ap.error("--slo has no effect on the batch-at-a-time baseline")
    if (args.preempt or args.shed) and not args.slo:
        ap.error("--preempt/--shed are SLOPolicy overload hooks; they need "
                 "--slo")
    if args.spill_dir and not args.preempt:
        ap.error("--spill-dir only stores preempted-slot snapshots; it "
                 "needs --preempt")
    if args.auto_tier and not args.slo:
        ap.error("--auto-tier needs --slo (it is SLOPolicy's admission "
                 "hook)")
    if args.auto_tier and (schedule is None or args.serialize_tiers):
        ap.error("--auto-tier needs runtime tiers with mixed admission "
                 "(--tiers/--schedule-file, no --serialize-tiers)")
    if args.speculate:
        if not args.tiers:
            ap.error("--speculate drafts at a plane-prefix tier; it needs "
                     "--tiers (or --schedule-file)")
        if args.serialize_tiers or args.baseline:
            ap.error("--speculate needs mixed-tier admission (drop "
                     "--serialize-tiers / --baseline)")
        if args.mesh:
            ap.error("--speculate is not supported on a mesh engine yet; "
                     "drop --mesh")
        if args.spec_k < 1:
            ap.error(f"--spec-k must be >= 1, got {args.spec_k}")
        if args.draft_tier is None:
            args.draft_tier = args.tiers[-1]
        elif args.draft_tier not in args.tiers:
            ap.error(f"--draft-tier {args.draft_tier} is not one of the "
                     f"serving tiers {args.tiers}")
    elif args.draft_tier is not None:
        ap.error("--draft-tier needs --speculate")
    if args.temperature < 0.0:
        ap.error(f"--temperature must be >= 0, got {args.temperature}")
    if args.top_k < 0:
        ap.error(f"--top-k must be >= 0, got {args.top_k}")
    if args.temperature > 0.0 and args.baseline:
        ap.error("--temperature needs the continuous-batching engine; the "
                 "baseline decodes greedily (drop --baseline)")
    mesh = None
    if args.mesh:
        if args.baseline:
            ap.error("--mesh needs the continuous-batching engine; drop "
                     "--baseline")
        if args.backend == "dense":
            ap.error("--mesh shards the quantized plane store; it needs an "
                     "integer backend (decomposed/pallas)")
        from repro.launch.mesh import make_serve_mesh
        try:
            mesh = make_serve_mesh(args.mesh)   # fail fast, pre model build
        except ValueError as e:
            ap.error(str(e))

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    if args.backend != "dense":
        # Weight preload: planes prepared ONCE, before any request arrives.
        # With --tiers this is the 8-bit superplane store serving them all.
        t0 = time.time()
        params, qpaths = prepare_params(params,
                                        schedule.prepare_policy()
                                        if schedule else policy,
                                        model, packed=args.packed,
                                        superplane=schedule is not None)
        kind = "superplane" if schedule else f"w{args.w_bits}"
        print(f"prepared {len(qpaths)} weights "
              f"({kind}, packed={args.packed}) "
              f"in {time.time()-t0:.1f}s")
    rt = Runtime(policy=policy, mode="serve", moe_dropless=args.reduced,
                 schedule=schedule)
    # The driver always runs with telemetry attached (the zero-cost-when-
    # off contract matters for the library; a demo CLI can afford the
    # hooks) — the end-of-run report, --metrics and --trace-out all read
    # from it.
    tele = Telemetry(profile=args.profile)
    if args.baseline:
        engine = BatchServeEngine(model, params, rt,
                                  max_batch=args.max_batch,
                                  max_len=args.max_len, kv_bits=args.kv_bits,
                                  telemetry=tele)
    else:
        # Rules-aware tier pricing: searched schedules (per-layer rule
        # tiers over a common default) only price differently when each
        # tier's per-layer widths are MAC-weighted.
        scheduler_policy = SLOPolicy(
            schedule, auto_tier=args.auto_tier,
            mac_counts=cfg.quant_layer_macs() if schedule else None,
            preempt=args.preempt,
            # Chunk granularity: a queued request can wait up to ~2 chunks
            # before the displacement check sees it again.
            preempt_slack=2.0 * args.decode_chunk,
            shed=args.shed) \
            if args.slo else None
        engine = ServeEngine(model, params, rt, max_batch=args.max_batch,
                             max_len=args.max_len, kv_bits=args.kv_bits,
                             decode_chunk=args.decode_chunk,
                             mixed_tiers=not args.serialize_tiers,
                             scheduler_policy=scheduler_policy,
                             mesh=mesh, spill_dir=args.spill_dir,
                             telemetry=tele)
        if mesh is not None:
            tp = engine._tp
            assert tp is not None
            print(f"mesh: {tp.n}-way tensor parallel "
                  f"(kv_shards={tp.kv_shards}) over "
                  f"{[d.platform for d in mesh.devices.flat]}")

    rng = np.random.default_rng(args.seed)
    tier_of = (lambda i: args.tiers[i % len(args.tiers)]) if args.tiers \
        else (lambda i: None)
    # --slo: a deadline-skewed stream — every 3rd request is urgent (a
    # tight budget in scheduler-clock ticks); the rest are patient.  With
    # --preempt/--shed the stream reshapes into a genuine overload trace:
    # patients become LONG best-effort hogs (the canonical preemption
    # victims — a slot never frees within an urgent deadline on its own),
    # the urgent tail gets deadlines of a few chunks and arrives
    # mid-flight (below) once the hogs pin every slot, and the LAST
    # urgent request carries a budget no tier can serve inside its
    # deadline — the fail-fast shed case.
    overload = args.preempt or args.shed
    urgent_deadline = (2.5 * args.decode_chunk
                       if overload else 4.0 * args.max_new)
    urgent_ids = [i for i in range(args.requests) if i % 3 == 2]
    deadline_of = (lambda i: urgent_deadline if i % 3 == 2
                   else None if overload else 50.0 * args.max_new) \
        if args.slo else (lambda i: None)

    def budget_of(i: int) -> int:
        if not overload:
            return 1 + (args.max_new * (i % 4)) // 3
        if i % 3 == 2:
            return (3 * args.max_new if urgent_ids and i == urgent_ids[-1]
                    else min(4, args.max_new))
        return 3 * args.max_new

    sampling = None
    if args.temperature > 0.0 or args.top_k > 0:
        from repro.spec import SamplingParams
        sampling = SamplingParams(temperature=args.temperature,
                                  top_k=args.top_k, seed=args.seed)
    spec = None
    if args.speculate:
        from repro.spec import SpecConfig
        spec = SpecConfig(draft_tier=args.draft_tier, k=args.spec_k)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=4 + i % 5),
                    max_new_tokens=budget_of(i),
                    tier=tier_of(i), deadline=deadline_of(i),
                    sampling=sampling, spec=spec)
            for i in range(args.requests)]

    # The streaming loop: submit, step until drained, stream tokens
    # through the handles' events.  Overload mode holds the urgent tail
    # back until the patient burst occupies the slots.
    t0 = time.time()
    urgent_tail = [r for r in reqs
                   if r.deadline is not None and r.deadline <= urgent_deadline] \
        if args.preempt or args.shed else []
    held = {r.uid for r in urgent_tail}
    handles = [engine.submit(r) for r in reqs if r.uid not in held]
    migrated = None
    events = 0
    while engine.has_work or urgent_tail:
        events += len(engine.step())
        if urgent_tail and (engine.clock >= 2.0 * args.decode_chunk
                            or not engine.has_work):
            handles += [engine.submit(r) for r in urgent_tail]
            urgent_tail = []
        if args.migrate_demo and migrated is None:
            target = args.tiers[-1]
            for h in handles:
                if (h.status is RequestStatus.RUNNING and h.tier != target
                        and len(h.tokens) >= 2):
                    h.set_tier(target)
                    migrated = h
                    print(f"migrated uid={h.uid} -> {target} after "
                          f"{len(h.tokens)} tokens (clock {engine.clock:.0f})")
                    break
    dt = time.time() - t0
    if args.migrate_demo and migrated is None:
        print("migrate-demo: no request lived long enough to migrate — "
              "every budget fit one decode chunk; raise --max-new or "
              "lower --decode-chunk")
    results = {h.uid: h.tokens for h in handles}
    # Shed requests never reach engine.results — check the finished ones.
    assert all(results[h.uid] == engine.results[h.uid] for h in handles
               if h.status is RequestStatus.FINISHED)
    toks = sum(len(v) for v in results.values())
    print(f"served {len(reqs)} requests, {toks} tokens "
          f"({events} streamed events) in {dt:.2f}s ({toks/dt:.1f} tok/s)")
    # The per-section stat blocks this driver used to hand-format all read
    # from the telemetry registry now — the EngineStats twins plus the
    # derived gauges/histograms — so a stat prints here by being
    # registered, not by editing four format strings.
    print(serve_report(tele.registry, tiers=args.tiers,
                       mixed=not args.serialize_tiers, slo=args.slo,
                       speculate=args.speculate,
                       overload=args.preempt or args.shed))
    if args.preempt or args.shed:
        shed_uids = [h.uid for h in handles
                     if h.status is RequestStatus.SHED]
        print(f"shed_uids={shed_uids}")
    if args.profile:
        assert tele.profiler is not None
        print("profile: " + json.dumps(tele.profiler.snapshot()["phases"],
                                       sort_keys=True))
    if args.metrics is not None:
        if args.metrics == "-":
            print(tele.prometheus(), end="")
        elif args.metrics.endswith(".json"):
            prof = tele.profiler.snapshot() if tele.profiler else None
            write_json(args.metrics, tele.registry, prof)
            print(f"metrics: wrote {args.metrics}")
        else:
            with open(args.metrics, "w") as fh:
                fh.write(tele.prometheus())
            print(f"metrics: wrote {args.metrics}")
    if args.trace_out:
        tele.write_trace(args.trace_out)
        print(f"trace: wrote {args.trace_out} "
              f"({len(tele.tracer.chrome_events())} events)")
    return results


if __name__ == "__main__":
    main()
