"""Assigned input shapes and ShapeDtypeStruct stand-ins for the dry-run.

Shapes (assignment): per-arch cells over
    train_4k     seq 4096,   global_batch 256   (train_step)
    prefill_32k  seq 32768,  global_batch 32    (prefill)
    decode_32k   seq 32768,  global_batch 128   (serve_step: 1 new token,
                                                 KV cache of seq_len)
    long_500k    seq 524288, global_batch 1     (serve_step; sub-quadratic
                                                 archs only)

``input_specs`` returns weak-type-correct, shardable ShapeDtypeStructs —
no device allocation ever happens for the full configs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def cell_applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic token cost -> SSM/hybrid only
    (DESIGN.md §long_500k)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("skipped: pure full-attention arch has no sub-quadratic "
                       "path at seq 524288 (DESIGN.md §long_500k)")
    return True, ""


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Training/prefill batch stand-ins (tokens or stub-frontend embeddings)."""
    b, s = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {"labels": sds((b, s), jnp.int32)}
    if cfg.frontend == "none":
        out["tokens"] = sds((b, s), jnp.int32)
    else:
        # VLM/audio stubs: precomputed patch/frame embeddings.
        out["embeds"] = sds((b, s, cfg.d_model), jnp.bfloat16)
    return out


def token_specs(cfg: ArchConfig, batch: int, seq: int):
    if cfg.frontend == "none":
        return {"tokens": sds((batch, seq), jnp.int32)}
    return {"embeds": sds((batch, seq, cfg.d_model), jnp.bfloat16)}


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) for the cell's token
    count; decode counts one token per sequence."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens        # forward only
    tokens = shape.global_batch        # one new token per sequence
    return 2.0 * n * tokens
