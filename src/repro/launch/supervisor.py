"""Fleet supervisor: heartbeat-based straggler detection + elastic restart.

At 1000+ nodes the failure model is: hosts die, hang, or straggle.  JAX SPMD
cannot drop a participant mid-program, so the production pattern is
supervisor-level: detect (missed heartbeats / slow steps), evict, re-mesh
with the survivors, and resume from the latest checkpoint (which our
checkpoint layer restores onto ANY mesh — tests/test_distributed.py::
test_elastic_resume_across_device_counts).

This module is the single-process simulation of that control loop, used by
the launcher and validated in tests: worker processes send heartbeats; the
supervisor times out stragglers, shrinks the world, and re-issues work.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class WorkerState:
    uid: int
    last_heartbeat: float
    step: int = 0
    step_times: List[float] = dataclasses.field(default_factory=list)
    alive: bool = True

    def median_step_time(self) -> float:
        if not self.step_times:
            return 0.0
        s = sorted(self.step_times[-16:])
        return s[len(s) // 2]


@dataclasses.dataclass
class SupervisorConfig:
    heartbeat_timeout_s: float = 60.0
    # A worker whose median step time exceeds `straggler_factor` x the fleet
    # median for `straggler_patience` consecutive checks is evicted.
    straggler_factor: float = 2.0
    straggler_patience: int = 3
    min_workers: int = 1


class Supervisor:
    """Tracks worker heartbeats/step times; decides evictions + re-mesh."""

    def __init__(self, cfg: SupervisorConfig = SupervisorConfig(),
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self.workers: Dict[int, WorkerState] = {}
        self._strikes: Dict[int, int] = {}
        self.generation = 0           # bumps on every re-mesh

    # ------------------------------------------------------------ bookkeeping
    def register(self, uid: int):
        self.workers[uid] = WorkerState(uid=uid, last_heartbeat=self.clock())
        self._strikes[uid] = 0

    def heartbeat(self, uid: int, step: int, step_time_s: float):
        w = self.workers[uid]
        w.last_heartbeat = self.clock()
        w.step = step
        w.step_times.append(step_time_s)

    # --------------------------------------------------------------- policy
    def fleet_median_step(self) -> float:
        times = [w.median_step_time() for w in self.workers.values()
                 if w.alive and w.step_times]
        if not times:
            return 0.0
        times.sort()
        return times[len(times) // 2]

    def check(self) -> List[int]:
        """Returns newly-evicted worker uids (dead or persistent stragglers)."""
        now = self.clock()
        fleet = self.fleet_median_step()
        evicted = []
        alive = [w for w in self.workers.values() if w.alive]
        for w in alive:
            if len([x for x in self.workers.values() if x.alive]) \
                    <= self.cfg.min_workers:
                break
            if now - w.last_heartbeat > self.cfg.heartbeat_timeout_s:
                w.alive = False
                evicted.append(w.uid)
                continue
            if fleet > 0 and w.median_step_time() > \
                    self.cfg.straggler_factor * fleet:
                self._strikes[w.uid] += 1
                if self._strikes[w.uid] >= self.cfg.straggler_patience:
                    w.alive = False
                    evicted.append(w.uid)
            else:
                self._strikes[w.uid] = 0
        if evicted:
            self.generation += 1
        return evicted

    def alive_workers(self) -> List[int]:
        return sorted(w.uid for w in self.workers.values() if w.alive)

    def remesh_plan(self, chips_per_worker: int) -> dict:
        """The new world: survivors, their mesh, and the resume step
        (min over survivors — conservative; the checkpoint layer re-shards)."""
        alive = self.alive_workers()
        resume = min((self.workers[u].step for u in alive), default=0)
        return {
            "generation": self.generation,
            "workers": alive,
            "n_chips": len(alive) * chips_per_worker,
            "resume_step": resume,
        }
