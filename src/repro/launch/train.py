"""Production training driver: mesh-aware QAT training with fault-tolerant
checkpointing (auto-resume), grad accumulation, and optional compressed
data-parallel gradients.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
        --steps 100 --w-bits 4 --ckpt-dir /tmp/run1

On a real cluster the same entry point runs under the production mesh
(launch/mesh.py); on this host it runs single-device with the same code path.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt
from repro.configs import get_config, reduced_config
from repro.core.policy import uniform_policy
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.train import optimizer as optim
from repro.train.step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--d-model", type=int, default=None,
                    help="override width (e.g. ~100M-param example)")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--vocab", type=int, default=None)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--w-bits", type=int, default=4)
    ap.add_argument("--a-bits", type=int, default=8)
    ap.add_argument("--backend", default="fake_quant")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    import dataclasses
    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    overrides = {}
    if args.d_model:
        heads = max(4, args.d_model // 128)
        overrides.update(d_model=args.d_model, num_heads=heads,
                         num_kv_heads=max(1, heads // 4),
                         head_dim=args.d_model // heads,
                         d_ff=args.d_model * 3)
    if args.layers:
        period = len(cfg.period_pattern())
        overrides["num_layers"] = max(period, args.layers // period * period)
    if args.vocab:
        overrides["vocab_size"] = args.vocab
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    model = LM(cfg)
    rt = Runtime(policy=uniform_policy(args.w_bits, args.a_bits,
                                       backend=args.backend))
    ocfg = optim.OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                           total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, rt, ocfg,
                                      accum_steps=args.accum))
    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.batch,
        embed_dim=cfg.d_model if cfg.frontend != "none" else 0))

    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"w{args.w_bits}a{args.a_bits} backend={args.backend}")

    state = {"params": params, "opt": optim.init_state(params, ocfg)}
    start = 0
    checkpointer = None
    if args.ckpt_dir:
        checkpointer = ckpt.AsyncCheckpointer(args.ckpt_dir, keep=3)
        latest = ckpt.latest_step(args.ckpt_dir)
        if latest is not None:                  # fault-tolerant auto-resume
            state, extra = ckpt.restore(args.ckpt_dir, latest, state)
            start = extra["data_step"]
            print(f"auto-resumed from step {start}")

    t0 = time.time()
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        if cfg.frontend != "none":
            batch.pop("tokens", None)
        state, metrics = step_fn(state, batch)
        if (i + 1) % args.log_every == 0 or i == start:
            dt = (time.time() - t0) / max(i - start + 1, 1)
            print(f"step {i+1:5d} loss={float(metrics['loss']):.4f} "
                  f"ce={float(metrics['ce']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"lr={float(metrics['lr']):.2e} {dt:.2f}s/step",
                  flush=True)
        if checkpointer and (i + 1) % args.ckpt_every == 0:
            checkpointer.save(i + 1, state, extra={"data_step": i + 1})
    if checkpointer:
        checkpointer.save(args.steps, state,
                          extra={"data_step": args.steps})
        checkpointer.wait()
    print("done")
    return state


if __name__ == "__main__":
    main()
