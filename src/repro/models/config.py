"""Architecture configuration schema for all assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    rope_theta: float = 1e6
    # MoE
    moe: bool = False
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1              # MoE FF every k-th layer (others dense MLP)
    shared_expert: bool = False     # llama4-style shared expert
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm: bool = False               # attention-free (pure SSM)
    attn_every: int = 0             # hybrid: 1 attention layer per `attn_every`
    ssm_state: int = 128
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 128
    # modality frontend (stubbed: precomputed embeddings)
    frontend: str = "none"          # none | vision | audio
    dtype_str: str = "bfloat16"
    tie_embeddings: bool = False

    def __post_init__(self):
        if self.head_dim is None and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype_str]

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to 256 so embedding/head shard evenly over the mesh
        (labels stay < vocab_size; pad logits train toward -inf harmlessly)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def sub_quadratic(self) -> bool:
        """True if long-context decode is O(1)/O(layers) per token (SSM or
        hybrid with mostly-SSM layers)."""
        return self.ssm or self.attn_every > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def period_pattern(self) -> Tuple[Tuple[str, Optional[str]], ...]:
        """Per-period (mixer, ff) layer pattern; the stack scans over periods.

        mixer in {attn, mamba}; ff in {mlp, moe, None}."""
        if self.ssm and self.attn_every == 0:          # pure SSM (mamba2)
            return (("mamba", None),)
        if self.attn_every > 0:                        # hybrid (jamba 1:7)
            pat = []
            for i in range(self.attn_every):
                mixer = "attn" if i == self.attn_every - 1 else "mamba"
                ff = "moe" if (self.moe and i % self.moe_every == self.moe_every - 1) else "mlp"
                pat.append((mixer, ff))
            return tuple(pat)
        if self.moe:
            pat = []
            for i in range(self.moe_every):
                ff = "moe" if i == self.moe_every - 1 else "mlp"
                pat.append(("attn", ff))
            return tuple(pat)
        return (("attn", "mlp"),)

    @property
    def n_periods(self) -> int:
        p = len(self.period_pattern())
        assert self.num_layers % p == 0, (self.num_layers, p)
        return self.num_layers // p

    def param_count(self) -> int:
        """Approximate parameter count (for MODEL_FLOPS and roofline)."""
        d, dh = self.d_model, self.head_dim or 0
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d
        for mixer, ff in self.period_pattern() * self.n_periods:
            if mixer == "attn":
                n += d * (self.num_heads * dh) + 2 * d * (self.num_kv_heads * dh) \
                    + (self.num_heads * dh) * d
            else:
                di, ns, hh = self.d_inner, self.ssm_state, self.ssm_heads
                n += d * (2 * di + 2 * ns + hh) + di * d   # in_proj + out_proj
                n += (di + 2 * ns) * self.ssm_conv + 2 * hh + di  # conv, A, dt, D
            if ff == "mlp":
                n += 3 * d * self.d_ff
            elif ff == "moe":
                n += d * self.num_experts  # router
                n += self.num_experts * 3 * d * self.d_ff
                if self.shared_expert:
                    n += 3 * d * self.d_ff
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        n_moe_layers = sum(1 for _, ff in self.period_pattern() * self.n_periods
                           if ff == "moe")
        inactive = (self.num_experts - self.experts_per_token)
        total -= n_moe_layers * inactive * 3 * d * self.d_ff
        return total

    def quant_layer_macs(self) -> "dict[str, int]":
        """MACs per decoded token of every *quantizable* projection, keyed
        by its policy layer name (insertion order = model order).

        The names match what ``serve.engine.prepare_params`` derives from
        the param tree (``layers.pos{i}.<block>.<proj>``, plus ``lm_head``)
        — precision policies/schedules are keyed per period POSITION, so a
        name covers all ``n_periods`` stacked instances and its MAC count
        carries that multiplicity.  MoE projections count only the
        ``experts_per_token`` routed experts (the array work a token
        actually buys); routers, convs and tied embeddings are not
        quantized and are excluded, mirroring ``prepare_params``.

        This is the per-layer workload vector ``repro.autoprec.cost``
        prices precision assignments with."""
        d, dh = self.d_model, self.head_dim or 0
        n = self.n_periods
        macs: dict[str, int] = {}
        for i, (mixer, ff) in enumerate(self.period_pattern()):
            base = f"layers.pos{i}"
            if mixer == "attn":
                macs[f"{base}.attn.q_proj"] = n * d * self.num_heads * dh
                macs[f"{base}.attn.k_proj"] = n * d * self.num_kv_heads * dh
                macs[f"{base}.attn.v_proj"] = n * d * self.num_kv_heads * dh
                macs[f"{base}.attn.o_proj"] = n * self.num_heads * dh * d
            else:
                di, ns, hh = self.d_inner, self.ssm_state, self.ssm_heads
                macs[f"{base}.mamba.in_proj"] = n * d * (2 * di + 2 * ns + hh)
                macs[f"{base}.mamba.out_proj"] = n * di * d
            if ff == "mlp":
                macs[f"{base}.mlp.gate_proj"] = n * d * self.d_ff
                macs[f"{base}.mlp.up_proj"] = n * d * self.d_ff
                macs[f"{base}.mlp.down_proj"] = n * self.d_ff * d
            elif ff == "moe":
                k = self.experts_per_token
                macs[f"{base}.moe.gate_proj"] = n * k * d * self.d_ff
                macs[f"{base}.moe.up_proj"] = n * k * d * self.d_ff
                macs[f"{base}.moe.down_proj"] = n * k * self.d_ff * d
                if self.shared_expert:
                    macs[f"{base}.moe.shared.gate_proj"] = n * d * self.d_ff
                    macs[f"{base}.moe.shared.up_proj"] = n * d * self.d_ff
                    macs[f"{base}.moe.shared.down_proj"] = n * self.d_ff * d
        if not self.tie_embeddings:
            macs["lm_head"] = d * self.padded_vocab
        return macs
