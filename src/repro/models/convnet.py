"""MobileNetV2-style quantized conv net — the paper's own workload (§IV),
runnable: pointwise (1x1) convs are matmuls and route through the paper's
quantized backends; depthwise convs stay higher-precision conv ops (they are 7 %
of MACs; the hwmodel keeps them 8-bit too).

A reduced config trains on CPU in tests; `hwmodel.mobilenet` holds the
full-scale MAC/energy model.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers


@dataclasses.dataclass(frozen=True)
class ConvNetConfig:
    num_classes: int = 10
    width: int = 16                    # stem channels
    # (expansion, out_channels, stride) per inverted-residual block
    blocks: Tuple[Tuple[int, int, int], ...] = (
        (1, 16, 1), (4, 24, 2), (4, 32, 2), (4, 64, 2))
    input_hw: int = 32
    dtype_str: str = "float32"

    @property
    def dtype(self):
        return jnp.float32 if self.dtype_str == "float32" else jnp.bfloat16


def _pw_init(key, cin, cout, dtype):
    return layers.dense_init(key, cin, cout, dtype)


def _dw_init(key, ch, dtype):
    return {"w": (jax.random.normal(key, (3, 3, ch), jnp.float32)
                  * 0.5).astype(dtype)}


def _pointwise(params, x, rt, name):
    """1x1 conv == matmul over channels: the paper's MAC-array work."""
    b, h, w, c = x.shape
    y = layers.linear(params, x.reshape(b * h * w, c), rt, name)
    return y.reshape(b, h, w, -1)


def _depthwise(params, x, stride):
    ch = x.shape[-1]
    rhs = params["w"].astype(jnp.float32).reshape(3, 3, 1, ch)
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32), rhs, window_strides=(stride, stride),
        padding="SAME", feature_group_count=ch,
        dimension_numbers=("NHWC", "HWIO", "NHWC")).astype(x.dtype)


class ConvNet:
    def __init__(self, cfg: ConvNetConfig):
        self.cfg = cfg

    def init(self, key):
        cfg = self.cfg
        ks = iter(jax.random.split(key, 4 * len(cfg.blocks) + 4))
        params = {"stem": _dw_init(next(ks), 3, cfg.dtype) | {
            "proj": _pw_init(next(ks), 3, cfg.width, cfg.dtype)}}
        cin = cfg.width
        blocks = []
        for t, cout, s in cfg.blocks:
            hidden = cin * t
            blocks.append({
                "expand": _pw_init(next(ks), cin, hidden, cfg.dtype),
                "dw": _dw_init(next(ks), hidden, cfg.dtype),
                "project": _pw_init(next(ks), hidden, cout, cfg.dtype),
            })
            cin = cout
        params["blocks"] = blocks
        params["head"] = _pw_init(next(ks), cin, cfg.num_classes, cfg.dtype)
        return params

    def apply(self, params, x, rt: layers.Runtime):
        """x: [B, H, W, 3] -> logits [B, num_classes]."""
        cfg = self.cfg
        h = _depthwise(params["stem"], x, 1)
        h = jax.nn.relu6(_pointwise(params["stem"]["proj"], h, rt, "stem"))
        for i, ((t, cout, s), blk) in enumerate(zip(cfg.blocks,
                                                    params["blocks"])):
            inp = h
            h = jax.nn.relu6(_pointwise(blk["expand"], h, rt,
                                        f"blocks.{i}.expand"))
            h = jax.nn.relu6(_depthwise(blk["dw"], h, s))
            h = _pointwise(blk["project"], h, rt, f"blocks.{i}.project")
            if s == 1 and inp.shape == h.shape:
                h = h + inp
        pooled = jnp.mean(h, axis=(1, 2))
        return layers.linear(params["head"], pooled, rt, "head")
