"""Model building blocks (pure-JAX, functional): norms, RoPE, quantized
linear, flash attention (online-softmax, memory-bounded), KV caches.

Every matmul routes through ``kernels.ops.matmul`` under the layer's
``LayerPrecision`` from the model's ``PrecisionPolicy`` — the paper's
flexible 2..8-bit precision scaling as a first-class model feature.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.policy import LayerPrecision, PrecisionPolicy, PrecisionSchedule
from repro.distributed.sharding import shard
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Per-call execution context threaded through the model.

    Precision comes from ONE of two sources: a fixed ``policy`` (prepare-time
    precision, the classic path) or a ``schedule`` + ``tier`` pair (runtime-
    reconfigurable serving: the engine switches ``tier`` per decode dispatch
    via :meth:`for_tier` while the superplane weight store stays put)."""

    policy: PrecisionPolicy
    mode: str = "train"                 # train | serve
    deterministic: bool = True
    # Dropless MoE: capacity = T (no token dropping).  Exact but wasteful;
    # used for serving parity and small-scale tests.  Training uses the
    # capacity-factor path (standard token-choice with dropping).
    moe_dropless: bool = False
    schedule: Optional[PrecisionSchedule] = None
    tier: Optional[str] = None          # active tier name (schedule mode)

    def prec(self, name: str) -> LayerPrecision:
        if self.schedule is not None:
            return self.schedule.lookup(name, self.tier)
        return self.policy.lookup(name)

    def for_tier(self, tier: Optional[str]) -> "Runtime":
        """This runtime with the active tier swapped (no-op sans schedule)."""
        if self.schedule is None:
            return self
        return dataclasses.replace(self, tier=tier)


# ---------------------------------------------------------------- init utils
def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.bfloat16):
    scale = 1.0 / math.sqrt(in_dim)
    return {"w": jax.random.uniform(key, (in_dim, out_dim), jnp.float32,
                                    -scale, scale).astype(dtype)}


def linear(params, x, rt: Runtime, name: str):
    """y = x @ w under the mixed-precision policy (w may be a prepared
    QuantizedWeight for the serving path)."""
    w = params["w"]
    prec = rt.prec(name)
    if isinstance(w, ops.QuantizedWeight):
        return ops.matmul(x, None, prec.with_backend(
            prec.backend if prec.backend in ("decomposed", "pallas")
            else "decomposed"), qw=w)
    y = ops.matmul(x, w, prec)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


# --------------------------------------------------------------------- norms
def rmsnorm_init(dim: int, dtype=jnp.bfloat16):
    return {"g": jnp.ones((dim,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    # Variance in f32 (a per-token scalar: sums of squares reduce locally and
    # psum cheaply over a sharded d_model), but the normalized product stays
    # in x.dtype so the d_model all-gather feeding the next matmul moves
    # bf16, not f32 (§Perf iteration 2).
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    return x * (inv.astype(x.dtype)) * params["g"].astype(x.dtype)


def qk_headnorm(params, x, eps: float = 1e-6):
    """Per-head RMSNorm over head_dim (Qwen3-style qk_norm). x: [..., H, Dh]."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["g"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------- RoPE
def rope(x, positions, theta: float = 1e6):
    """Rotary embedding, split-half convention. x: [B, S, H, Dh]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freqs     # [B, S, half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------- flash attention
def flash_attention(q, k, v, *, causal: bool = True, block_k: int = 1024,
                    q_offset=0):
    """Online-softmax attention, memory bounded by block_k (the TPU analogue
    of streaming the KV operand; never materializes the [Sq, Sk] matrix).

    q: [B, Sq, H, Dh]; k, v: [B, Sk, KVH, Dh] with H % KVH == 0 (GQA).
    q_offset: absolute position of q[0] (for chunked prefill / decode).
    Returns [B, Sq, H, Dh] in q.dtype.
    """
    b, sq, h, dh = q.shape
    _, sk, kvh, _ = k.shape
    g = h // kvh
    scale = 1.0 / math.sqrt(dh)
    if g > 1:
        # GQA as q-head-major repeat: every tensor keeps the h axis, so TP
        # over "model" survives (a [kvh, g] reshape would break the sharding
        # and replicate the f32 accumulators on every device).
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).astype(jnp.float32)       # [b, h, sq, dh]
    qf = shard(qf, "batch", "model", None, None)

    block_k = min(block_k, sk)
    nb = -(-sk // block_k)
    pad = nb * block_k - sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = kp.reshape(b, nb, block_k, h, dh).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(b, nb, block_k, h, dh).transpose(1, 0, 2, 3, 4)
    kpos = jnp.arange(nb * block_k).reshape(nb, block_k)
    qpos = q_offset + jnp.arange(sq)

    neg = jnp.float32(-1e30)

    def body(carry, xs):
        acc, m, l = carry
        kblk, vblk, kp_blk = xs
        s = jnp.einsum("bhqd,bshd->bhqs", qf,
                       kblk.astype(jnp.float32)) * scale
        valid = kp_blk[None, :] < sk
        if causal:
            valid = valid & (qpos[:, None] >= kp_blk[None, :])
        s = jnp.where(valid[None, None], s, neg)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqs,bshd->bhqd", p, vblk.astype(jnp.float32))
        return (acc_new, m_new, l_new), None

    init = (
        jnp.zeros((b, h, sq, dh), jnp.float32),
        jnp.full((b, h, sq), neg),
        jnp.zeros((b, h, sq), jnp.float32),
    )
    (acc, m, l), _ = jax.lax.scan(jax.checkpoint(body), init, (kb, vb, kpos))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


# ------------------------------------------------------------------ KV cache
@dataclasses.dataclass
class KVCache:
    """Pre-allocated KV cache with PER-SLOT lengths; optionally stored
    quantized (kv_bits=8) with per-(position, head) scales — the paper's
    precision scaling applied to the decode memory bottleneck.

    The batch axis is a *slot* axis: every slot tracks its own fill point
    (``length[b]``), so a continuous-batching engine can reset/refill one
    slot while the others keep decoding against their caches."""

    k: jax.Array          # [B, Smax, KVH, Dh]  bf16 or int8
    v: jax.Array
    k_scale: Optional[jax.Array]   # f32 [B, Smax, KVH, 1] when quantized
    v_scale: Optional[jax.Array]
    length: jax.Array     # int32 [B] — filled positions per slot

    @property
    def quantized(self) -> bool:
        return self.k.dtype == jnp.int8

    @staticmethod
    def create(batch: int, max_len: int, kv_heads: int, head_dim: int,
               dtype=jnp.bfloat16, kv_bits: Optional[int] = None) -> "KVCache":
        shape = (batch, max_len, kv_heads, head_dim)
        lengths = jnp.zeros((batch,), jnp.int32)
        if kv_bits == 8:
            z8 = jnp.zeros(shape, jnp.int8)
            # Scales in bf16: per-(position, head) f32 scales would cost 50%
            # overhead per device once head_dim is TP-sharded (§Perf decode).
            s = jnp.ones((batch, max_len, kv_heads, 1), jnp.bfloat16)
            return KVCache(z8, z8, s, s, lengths)
        z = jnp.zeros(shape, dtype)
        return KVCache(z, z, None, None, lengths)

    def _quant(self, x):
        amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
        scale = jnp.maximum(amax, 1e-8) / 127.0
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -128, 127)
        return q.astype(jnp.int8), scale.astype(self.k_scale.dtype)

    def _lengths_after(self, start, s, new_length):
        if new_length is None:
            return jnp.zeros_like(self.length) + start + s
        return jnp.broadcast_to(new_length, self.length.shape).astype(
            self.length.dtype)

    def update(self, k_new, v_new, start, *, new_length=None) -> "KVCache":
        """Insert [B, S_new, KVH, Dh] at position `start` (scalar, traced ok).

        ``new_length`` ([B] or scalar) overrides the resulting per-slot
        lengths — used for right-padded prefill, where ``S_new`` is the
        padded length but only the first ``new_length[b]`` positions of slot
        ``b`` are real tokens."""
        idx = (0, start, 0, 0)
        ln = self._lengths_after(start, k_new.shape[1], new_length)
        if self.quantized:
            kq, ks = self._quant(k_new)
            vq, vs = self._quant(v_new)
            return KVCache(
                jax.lax.dynamic_update_slice(self.k, kq, idx),
                jax.lax.dynamic_update_slice(self.v, vq, idx),
                jax.lax.dynamic_update_slice(self.k_scale, ks, idx),
                jax.lax.dynamic_update_slice(self.v_scale, vs, idx),
                ln)
        return KVCache(
            jax.lax.dynamic_update_slice(self.k, k_new.astype(self.k.dtype), idx),
            jax.lax.dynamic_update_slice(self.v, v_new.astype(self.v.dtype), idx),
            None, None, ln)

    def append(self, k_new, v_new, active=None) -> "KVCache":
        """Masked per-slot decode write: one token per slot at that slot's
        own ``length[b]`` (a scatter, not a slice — slots sit at different
        positions).  Slots with ``active[b] == False`` are left untouched:
        neither their K/V rows nor their lengths move, so a finished slot's
        cache is frozen until the scheduler reuses it."""
        b = self.k.shape[0]
        if active is None:
            active = jnp.ones((b,), bool)
        active = active & (self.length < self.k.shape[1])   # never overflow
        idx = jnp.arange(b)
        pos = jnp.clip(self.length, 0, self.k.shape[1] - 1)

        def put(buf, val):
            cur = buf[idx, pos]
            val = jnp.where(active[(...,) + (None,) * (val.ndim - 1)],
                            val.astype(buf.dtype), cur)
            return buf.at[idx, pos].set(val)

        ln = self.length + active.astype(self.length.dtype)
        if self.quantized:
            kq, ks = self._quant(k_new)
            vq, vs = self._quant(v_new)
            return KVCache(put(self.k, kq[:, 0]), put(self.v, vq[:, 0]),
                           put(self.k_scale, ks[:, 0]),
                           put(self.v_scale, vs[:, 0]), ln)
        return KVCache(put(self.k, k_new[:, 0]), put(self.v, v_new[:, 0]),
                       None, None, ln)

    def read(self, dtype=jnp.bfloat16):
        if self.quantized:
            k = self.k.astype(dtype) * self.k_scale.astype(dtype)
            v = self.v.astype(dtype) * self.v_scale.astype(dtype)
            return k, v
        return self.k.astype(dtype), self.v.astype(dtype)


jax.tree_util.register_dataclass(
    KVCache, data_fields=["k", "v", "k_scale", "v_scale", "length"],
    meta_fields=[])


def decode_attention(q, cache: KVCache):
    """Single-step attention against a cache. q: [B, 1, H, Dh].

    Grouped (kvh, g) einsum form — no K/V repeat, operands stay in the cache
    dtype (bf16/int8-dequant) with f32 accumulation via
    preferred_element_type, so the big cache tensors are never materialized
    in f32 and the head_dim contraction runs sharded (§Perf decode iters)."""
    b, sq, h, dh = q.shape
    k, v = cache.read(q.dtype)
    sk = k.shape[1]
    kvh = k.shape[2]
    g = h // kvh
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(b, sq, kvh, g, dh)
    # Match the cache's head_dim TP sharding: the contraction then runs as
    # sharded partial sums + a 33MB score psum instead of all-gathering the
    # multi-GB K (§Perf decode iteration).
    qg = shard(qg, "batch", None, None, None, "model")
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(sk)
    # Per-slot length mask: slot b attends only its own filled positions.
    valid = pos[None, :] < cache.length[:, None]            # [B, Smax]
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(q.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh).astype(q.dtype)


# --------------------------------------------------------------- GQA attention
def attention_init(key, cfg, dtype=jnp.bfloat16):
    keys = jax.random.split(key, 4)
    d, h, kvh, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "q_proj": dense_init(keys[0], d, h * dh, dtype),
        "k_proj": dense_init(keys[1], d, kvh * dh, dtype),
        "v_proj": dense_init(keys[2], d, kvh * dh, dtype),
        "o_proj": dense_init(keys[3], h * dh, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"g": jnp.ones((dh,), dtype)}
        p["k_norm"] = {"g": jnp.ones((dh,), dtype)}
    return p


def attention_apply(params, x, rt: Runtime, cfg, name: str, *,
                    positions=None, cache: Optional[KVCache] = None,
                    cache_start=None, seq_lengths=None, active=None):
    """GQA attention with RoPE (+ optional qk_norm).  If `cache` is given,
    runs in incremental mode: S > 1 prefills the cache from position 0
    (right-padded prompts supported via ``seq_lengths`` [B], the true token
    counts); S == 1 appends one token at each slot's own fill point, with
    ``active`` [B] masking writes for finished/empty slots.

    NOTE: unlike the scalar-length seed, a multi-token call on a warm cache
    does NOT append at the fill point (per-slot lengths have no single
    append position).  Chunked prefill must pass ``cache_start`` (and gets
    the uniform-start semantics); otherwise S > 1 means prefill-from-
    scratch.  Returns (out, new_cache)."""
    b, s, d = x.shape
    h, kvh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if positions is None:
        if cache_start is not None:
            base = jnp.asarray(cache_start, jnp.int32).reshape(-1, 1)
        elif cache is not None and s == 1:
            base = cache.length[:, None]   # append at each slot's fill point
        else:
            base = jnp.zeros((1, 1), jnp.int32)    # prefill from scratch
        positions = base + jnp.arange(s)[None, :].astype(jnp.int32)
        positions = jnp.broadcast_to(positions, (b, s))

    q = linear(params["q_proj"], x, rt, f"{name}.q_proj").reshape(b, s, h, dh)
    k = linear(params["k_proj"], x, rt, f"{name}.k_proj").reshape(b, s, kvh, dh)
    v = linear(params["v_proj"], x, rt, f"{name}.v_proj").reshape(b, s, kvh, dh)
    if cfg.qk_norm:
        q = qk_headnorm(params["q_norm"], q)
        k = qk_headnorm(params["k_norm"], k)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", None, "model", None)
    k = shard(k, "batch", None, "model", None)

    new_cache = None
    if cache is not None:
        if s == 1:
            new_cache = cache.append(k, v, active=active)
            out = decode_attention(q, new_cache)
        else:
            start = 0 if cache_start is None else cache_start
            new_cache = cache.update(k, v, start, new_length=seq_lengths)
            kf, vf = new_cache.read(q.dtype)
            # q_offset = start: with right-padding, pad queries past a slot's
            # true length attend only already-written positions (causal) and
            # their outputs are discarded by the caller's length gather.
            out = flash_attention(q, kf, vf, causal=True, q_offset=start)
    else:
        out = flash_attention(q, k, v, causal=True)
    out = out.reshape(b, s, h * dh)
    return linear(params["o_proj"], out, rt, f"{name}.o_proj"), new_cache


# ----------------------------------------------------------------- SwiGLU MLP
def mlp_init(key, d_model: int, d_ff: int, dtype=jnp.bfloat16):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate_proj": dense_init(k1, d_model, d_ff, dtype),
        "up_proj": dense_init(k2, d_model, d_ff, dtype),
        "down_proj": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp_apply(params, x, rt: Runtime, name: str):
    gate = linear(params["gate_proj"], x, rt, f"{name}.gate_proj")
    up = linear(params["up_proj"], x, rt, f"{name}.up_proj")
    hidden = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    hidden = shard(hidden, "batch", None, "model")
    return linear(params["down_proj"], hidden, rt, f"{name}.down_proj")
