"""Model building blocks (pure-JAX, functional): norms, RoPE, quantized
linear, flash attention (online-softmax, memory-bounded), KV caches.

Every matmul routes through ``kernels.ops.matmul`` under the layer's
``LayerPrecision`` from the model's ``PrecisionPolicy`` — the paper's
flexible 2..8-bit precision scaling as a first-class model feature.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.policy import LayerPrecision, PrecisionPolicy, PrecisionSchedule
from repro.distributed import tp_serve
from repro.distributed.sharding import shard
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Per-call execution context threaded through the model.

    Precision comes from ONE of two sources: a fixed ``policy`` (prepare-time
    precision, the classic path) or a ``schedule`` + tier information
    (runtime-reconfigurable serving over one superplane weight store).  In
    schedule mode there are again two shapes:

    * ``tier`` — the whole batch runs at one named tier
      (:meth:`for_tier`; the tier name is a JIT-STATIC argument of the
      engine's dispatch functions);
    * ``groups`` + ``perm``/``inv_perm`` — a mixed-tier decode batch
      (:meth:`for_groups`): ``groups`` is a STATIC tuple of
      ``(tier_name, rows)`` describing contiguous tier-sorted slot groups
      (it keys the jit trace), while ``perm``/``inv_perm`` are TRACED
      int32 [B] arrays mapping batch rows into/out of that sorted order
      (they change per step without retracing).  Every projection then
      runs the grouped path (see :func:`linear`): with ``fused`` (default)
      ONE group-switching plane-prefix GEMM serves all groups
      (``ops.fused_decode_linear``); ``fused=False`` keeps the per-group
      dispatch loop as the bit-identical reference.
    """

    policy: PrecisionPolicy
    mode: str = "train"                 # train | serve
    deterministic: bool = True
    # Dropless MoE: capacity = T (no token dropping).  Exact but wasteful;
    # used for serving parity and small-scale tests.  Training uses the
    # capacity-factor path (standard token-choice with dropping).
    moe_dropless: bool = False
    schedule: Optional[PrecisionSchedule] = None
    tier: Optional[str] = None          # active tier name (schedule mode)
    groups: Optional[tuple] = None      # STATIC ((tier_name, rows), ...)
    perm: Optional[Any] = None          # TRACED int32 [B]: tier-sorted order
    inv_perm: Optional[Any] = None      # TRACED int32 [B]: inverse of perm
    fused: bool = True                  # one-kernel mixed-tier grouped GEMMs
    # Tensor-parallel context (a static tp_serve.TPConfig), set only INSIDE
    # the engine's shard_map body: params arrive as this device's shards,
    # attention sees local head counts, and o/down projections take the
    # quantized-gather path.  None (default) = the unsharded graph.
    tp: Optional[Any] = None

    def prec(self, name: str) -> LayerPrecision:
        if self.schedule is not None:
            return self.schedule.lookup(name, self.tier)
        return self.policy.lookup(name)

    def for_tier(self, tier: Optional[str]) -> "Runtime":
        """This runtime with the active tier swapped (no-op sans schedule)."""
        if self.schedule is None:
            return self
        return dataclasses.replace(self, tier=tier, groups=None, perm=None,
                                   inv_perm=None)

    def for_groups(self, groups, perm) -> "Runtime":
        """This runtime serving a mixed-tier batch.

        ``groups``: static tuple of ``(tier_name, rows)`` (tier-sorted,
        contiguous, covering the batch).  ``perm``: traced int32 [B] with
        ``perm[i]`` = the batch row that sorted position ``i`` reads from;
        the inverse permutation is derived here (inside the trace)."""
        if self.schedule is None:
            raise ValueError("mixed-tier groups need a PrecisionSchedule")
        return dataclasses.replace(self, tier=None, groups=tuple(groups),
                                   perm=perm, inv_perm=jnp.argsort(perm))

    @property
    def group_batch(self) -> int:
        """Total rows covered by ``groups`` (the slot-batch size)."""
        return sum(n for _, n in self.groups)


# ---------------------------------------------------------------- init utils
def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.bfloat16):
    scale = 1.0 / math.sqrt(in_dim)
    return {"w": jax.random.uniform(key, (in_dim, out_dim), jnp.float32,
                                    -scale, scale).astype(dtype)}


def _serve_backend(prec: LayerPrecision) -> LayerPrecision:
    """Prepared weights only run on the integer serving backends."""
    return prec.with_backend(
        prec.backend if prec.backend in ("decomposed", "pallas")
        else "decomposed")


def linear(params, x, rt: Runtime, name: str, *,
           act_quants: Optional[Dict[Any, Any]] = None):
    """y = x @ w under the mixed-precision policy (w may be a prepared
    QuantizedWeight for the serving path).

    Under a mixed-tier runtime (``rt.groups`` set) every prepared-weight
    matmul takes the per-row-group path: gather batch rows into tier-sorted
    order (``rt.perm``), run the grouped plane-prefix GEMM (one fused
    group-switching kernel when ``rt.fused``, else one GEMM per contiguous
    group) at each group's (w_bits, a_bits), and scatter back
    (``rt.inv_perm``).  The leading axis of ``x`` must be the slot-batch
    axis — true for every projection in the decode path (attention/MLP/SSM
    projections, per-expert MoE FFNs after the per-sequence dispatch, and
    the LM head).

    ``act_quants`` is a per-input activation-quant cache: projections that
    read the SAME tensor (q/k/v, gate/up) pass one shared dict so the batch
    is quantized once per distinct config instead of once per projection —
    identical computation, so sharing is exact."""
    w = params["w"]
    if isinstance(w, ops.QuantizedWeight):
        if rt.groups is not None:
            if x.shape[0] != rt.group_batch:
                raise ValueError(
                    f"{name}: mixed-tier groups cover {rt.group_batch} slots "
                    f"but x has leading axis {x.shape[0]} — grouped matmuls "
                    "require the slot-batch axis to lead")
            if len(rt.groups) == 1:       # homogeneous layout: no permuting
                tier = rt.groups[0][0]
                prec = _serve_backend(rt.schedule.lookup(name, tier))
                if rt.tp is not None and rt.tp.gathers(name):
                    return tp_serve.gathered_matmul(x, w, prec, tp=rt.tp)
                return ops.matmul(x, None, prec, qw=w)
            row_groups = tuple(
                (n, _serve_backend(rt.schedule.lookup(name, t)))
                for t, n in rt.groups)
            if rt.tp is not None and rt.tp.gathers(name):
                # Feature-sharded input: quantize with the pmax-shared
                # range, gather codes per group at its wire width, run the
                # unchanged group-switching GEMM on the local N-shard.
                yg = tp_serve.gathered_grouped_matmul(x, w, row_groups,
                                                      rt.perm, tp=rt.tp)
                return jnp.take(yg, rt.inv_perm, axis=0)
            # The permutation is applied INSIDE ops.matmul (to the already-
            # quantized codes/scales, keeping scales bitwise stable); the
            # grouped result comes back in sorted order and is scattered
            # back to slot order here.
            yg = ops.matmul(x, None, row_groups[0][1], qw=w,
                            row_groups=row_groups, perm=rt.perm,
                            fused=None if rt.fused else False,
                            act_quants=act_quants)
            return jnp.take(yg, rt.inv_perm, axis=0)
        prec = _serve_backend(rt.prec(name))
        if rt.tp is not None and rt.tp.gathers(name):
            return tp_serve.gathered_matmul(x, w, prec, tp=rt.tp)
        return ops.matmul(x, None, prec, qw=w)
    y = ops.matmul(x, w, rt.prec(name))
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


# --------------------------------------------------------------------- norms
def rmsnorm_init(dim: int, dtype=jnp.bfloat16):
    return {"g": jnp.ones((dim,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    # Variance in f32 (a per-token scalar: sums of squares reduce locally and
    # psum cheaply over a sharded d_model), but the normalized product stays
    # in x.dtype so the d_model all-gather feeding the next matmul moves
    # bf16, not f32 (§Perf iteration 2).
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    return x * (inv.astype(x.dtype)) * params["g"].astype(x.dtype)


def qk_headnorm(params, x, eps: float = 1e-6):
    """Per-head RMSNorm over head_dim (Qwen3-style qk_norm). x: [..., H, Dh]."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["g"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------- RoPE
def rope(x, positions, theta: float = 1e6):
    """Rotary embedding, split-half convention. x: [B, S, H, Dh]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freqs     # [B, S, half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------- flash attention
def flash_attention(q, k, v, *, causal: bool = True, block_k: int = 1024,
                    q_offset=0):
    """Online-softmax attention, memory bounded by block_k (the TPU analogue
    of streaming the KV operand; never materializes the [Sq, Sk] matrix).

    q: [B, Sq, H, Dh]; k, v: [B, Sk, KVH, Dh] with H % KVH == 0 (GQA).
    q_offset: absolute position of q[0] (for chunked prefill / decode).
    Returns [B, Sq, H, Dh] in q.dtype.
    """
    b, sq, h, dh = q.shape
    _, sk, kvh, _ = k.shape
    g = h // kvh
    scale = 1.0 / math.sqrt(dh)
    if g > 1:
        # GQA as q-head-major repeat: every tensor keeps the h axis, so TP
        # over "model" survives (a [kvh, g] reshape would break the sharding
        # and replicate the f32 accumulators on every device).
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).astype(jnp.float32)       # [b, h, sq, dh]
    qf = shard(qf, "batch", "model", None, None)

    block_k = min(block_k, sk)
    nb = -(-sk // block_k)
    pad = nb * block_k - sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = kp.reshape(b, nb, block_k, h, dh).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(b, nb, block_k, h, dh).transpose(1, 0, 2, 3, 4)
    kpos = jnp.arange(nb * block_k).reshape(nb, block_k)
    qpos = q_offset + jnp.arange(sq)

    neg = jnp.float32(-1e30)

    def body(carry, xs):
        acc, m, l = carry
        kblk, vblk, kp_blk = xs
        s = jnp.einsum("bhqd,bshd->bhqs", qf,
                       kblk.astype(jnp.float32)) * scale
        valid = kp_blk[None, :] < sk
        if causal:
            valid = valid & (qpos[:, None] >= kp_blk[None, :])
        s = jnp.where(valid[None, None], s, neg)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqs,bshd->bhqd", p, vblk.astype(jnp.float32))
        return (acc_new, m_new, l_new), None

    init = (
        jnp.zeros((b, h, sq, dh), jnp.float32),
        jnp.full((b, h, sq), neg),
        jnp.zeros((b, h, sq), jnp.float32),
    )
    (acc, m, l), _ = jax.lax.scan(jax.checkpoint(body), init, (kb, vb, kpos))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


# ------------------------------------------------------------------ KV cache
# Per-slot KV precision tiers: the decode-memory analogue of the weight
# plane prefix.  A cache runs in one of four storage modes:
#
#   dense     bf16 [B, S, KVH, Dh]                  (kv_bits=None)
#   int8      int8 codes + per-(pos, head) scales   (kv_bits=8)
#   int4      uint8 nibble-packed codes + scales    (kv_bits=4)
#   mixed     ONE uint8 byte-lane arena [B, S, KVH, L] serving bf16 / int8 /
#             int4-packed lanes side by side, with a per-slot tier vector
#             ``kv_bits`` int32 [B] (16 = bf16 passthrough, 8, 4) and shared
#             per-(position, head) scale rows       (kv_bits=(16, 8, 4)-ish
#             tuple of the modes the arena must serve)
#
# The mixed mode is what lets one slot arena serve requests whose
# PrecisionSchedule tier maps to different KV precisions: a slot's lane
# encodes exactly what the homogeneous cache at that kv_bits stores, so
# per-request outputs are bit-identical to a fixed-precision engine.

KV_TIER_BITS = (16, 8, 4)     # bf16 passthrough, int8, int4-packed


def _kv_lane_bytes(bits: int, head_dim: int) -> int:
    """Bytes per (position, head) lane one KV element row needs at a tier."""
    return {16: 2 * head_dim, 8: head_dim, 4: head_dim // 2}[bits]


def _kv_quant(x, bits: int, scale_dtype):
    """Symmetric per-(position, head) KV quantization (int8 codes).

    Wrapped in ``optimization_barrier``s: the scale is CONTINUOUS f32 math,
    and if XLA fuses this subgraph differently per engine (the mixed
    per-slot arena computes several candidate encodings and selects; a
    homogeneous cache computes one), its rounding can drift by one ulp and
    flip a quantization code — breaking the bit-identity between a mixed
    slot and the fixed-precision reference engine at the same kv tier.  The
    barriers pin this subgraph to one compilation in every context."""
    x = jax.lax.optimization_barrier(x.astype(jnp.float32))
    qmax = (1 << (bits - 1)) - 1
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
    return jax.lax.optimization_barrier(
        (q.astype(jnp.int8), scale.astype(scale_dtype)))


def _pack_int4(q):
    """int8 codes in [-8, 7] [..., Dh] -> uint8 nibbles [..., Dh//2]
    (element 2i in the low nibble, 2i+1 in the high nibble)."""
    u = jax.lax.bitcast_convert_type(q, jnp.uint8)
    return (u[..., 0::2] & 0xF) | ((u[..., 1::2] & 0xF) << 4)


def _unpack_int4(b):
    """Inverse of :func:`_pack_int4` (sign-extended int8 [..., Dh])."""
    lo = (b & 0xF).astype(jnp.int32)
    hi = ((b >> 4) & 0xF).astype(jnp.int32)
    both = jnp.stack([lo, hi], axis=-1).reshape(*b.shape[:-1], -1)
    return jnp.where(both >= 8, both - 16, both).astype(jnp.int8)


def _bf16_to_bytes(x):
    """bf16 [..., Dh] -> its bit pattern as uint8 [..., 2*Dh] (exact)."""
    by = jax.lax.bitcast_convert_type(x.astype(jnp.bfloat16), jnp.uint8)
    return by.reshape(*by.shape[:-2], -1)


def _bytes_to_bf16(b):
    """Inverse of :func:`_bf16_to_bytes`: uint8 [..., 2*Dh] -> bf16 [..., Dh]."""
    u = b.reshape(*b.shape[:-1], -1, 2)
    return jax.lax.bitcast_convert_type(u, jnp.bfloat16)


@dataclasses.dataclass
class KVCache:
    """Pre-allocated KV cache with PER-SLOT lengths and (optionally)
    PER-SLOT precision tiers — the paper's precision scaling applied to the
    decode memory bottleneck.

    The batch axis is a *slot* axis: every slot tracks its own fill point
    (``length[b]``) and, in mixed mode, its own storage tier
    (``kv_bits[b]``), so a continuous-batching engine can reset/refill one
    slot at a different KV precision while the others keep decoding against
    their caches.  ``kv_bits`` and all array fields are traced data;
    ``modes`` (which tiers the arena serves, descending) is static metadata
    that keys the jit trace."""

    k: jax.Array          # dense/int8: [B, Smax, KVH, Dh]; int4: [..., Dh//2]
    v: jax.Array          # uint8; mixed: uint8 byte lanes [B, Smax, KVH, L]
    k_scale: Optional[jax.Array]   # bf16 [B, Smax, KVH, 1] when quantized
    v_scale: Optional[jax.Array]
    length: jax.Array     # int32 [B] — filled positions per slot
    kv_bits: Optional[jax.Array] = None   # int32 [B] per-slot tier (mixed)
    modes: Optional[tuple] = None         # static tier set, descending

    @property
    def quantized(self) -> bool:
        """Homogeneous int8 storage."""
        return self.k.dtype == jnp.int8

    @property
    def packed4(self) -> bool:
        """Homogeneous int4 nibble-packed storage."""
        return self.k.dtype == jnp.uint8 and self.kv_bits is None

    @property
    def mixed(self) -> bool:
        """Per-slot tiered byte-lane arena."""
        return self.kv_bits is not None

    @property
    def head_dim(self) -> int:
        if self.mixed:
            lanes = self.k.shape[-1]
            return {16: lanes // 2, 8: lanes, 4: 2 * lanes}[self.modes[0]]
        if self.packed4:
            return 2 * self.k.shape[-1]
        return self.k.shape[-1]

    @staticmethod
    def create(batch: int, max_len: int, kv_heads: int, head_dim: int,
               dtype=jnp.bfloat16, kv_bits=None) -> "KVCache":
        """``kv_bits``: None (dense bf16), 8 (int8), 4 (int4-packed), or a
        tuple of tier codes from ``KV_TIER_BITS`` for the mixed per-slot
        arena (lanes sized for the widest tier; per-slot tiers start at the
        widest and are set per admission)."""
        lengths = jnp.zeros((batch,), jnp.int32)
        # Scales in bf16: per-(position, head) f32 scales would cost 50%
        # overhead per device once head_dim is TP-sharded (§Perf decode).
        s = jnp.ones((batch, max_len, kv_heads, 1), jnp.bfloat16)
        if isinstance(kv_bits, (tuple, list)):
            modes = tuple(sorted({int(m) for m in kv_bits}, reverse=True))
            if not modes or any(m not in KV_TIER_BITS for m in modes):
                raise ValueError(f"mixed kv tiers must be from "
                                 f"{KV_TIER_BITS}, got {kv_bits}")
            if head_dim % 2:
                raise ValueError("per-slot KV tiers need an even head_dim")
            lanes = max(_kv_lane_bytes(m, head_dim) for m in modes)
            z = jnp.zeros((batch, max_len, kv_heads, lanes), jnp.uint8)
            tiers = jnp.full((batch,), modes[0], jnp.int32)
            return KVCache(z, z, s, s, lengths, kv_bits=tiers, modes=modes)
        shape = (batch, max_len, kv_heads, head_dim)
        if kv_bits == 8:
            z8 = jnp.zeros(shape, jnp.int8)
            return KVCache(z8, z8, s, s, lengths)
        if kv_bits == 4:
            if head_dim % 2:
                raise ValueError("int4 KV packing needs an even head_dim")
            z4 = jnp.zeros(shape[:-1] + (head_dim // 2,), jnp.uint8)
            return KVCache(z4, z4, s, s, lengths)
        if kv_bits is not None:
            raise ValueError(f"kv_bits must be None, 8, 4 or a tier tuple, "
                             f"got {kv_bits!r}")
        z = jnp.zeros(shape, dtype)
        return KVCache(z, z, None, None, lengths)

    # ------------------------------------------------- mixed-mode encoding
    def _slot_select(self, per_mode, ndim):
        """Select each slot's candidate by its ``kv_bits`` tier code."""
        kv = self.kv_bits.reshape((-1,) + (1,) * (ndim - 1))
        out = per_mode[-1]
        for m, cand in zip(self.modes[:-1], per_mode[:-1]):
            out = jnp.where(kv == m, cand, out)
        return out

    def _encode_mixed(self, x):
        """float [..., Dh] -> (byte lanes [..., L], scale [..., 1]) with
        every slot encoded at its own tier (bit-identical to the
        homogeneous cache at that tier)."""
        lanes = self.k.shape[-1]
        bys, scs = [], []
        for m in self.modes:
            if m == 16:
                by = _bf16_to_bytes(x)
                sc = jnp.ones(x.shape[:-1] + (1,), self.k_scale.dtype)
            else:
                q, sc = _kv_quant(x, m, self.k_scale.dtype)
                by = jax.lax.bitcast_convert_type(q, jnp.uint8) if m == 8 \
                    else _pack_int4(q)
            pad = lanes - by.shape[-1]
            if pad:
                by = jnp.pad(by, [(0, 0)] * (by.ndim - 1) + [(0, pad)])
            bys.append(by)
            scs.append(sc)
        return (self._slot_select(bys, x.ndim),
                self._slot_select(scs, x.ndim))

    def _decode_mixed(self, buf, scale, dtype):
        """byte lanes [..., L] -> dequantized [..., Dh] per slot tier."""
        dh = self.head_dim
        cands = []
        for m in self.modes:
            if m == 16:
                cands.append(_bytes_to_bf16(buf[..., :2 * dh]).astype(dtype))
            elif m == 8:
                q = jax.lax.bitcast_convert_type(buf[..., :dh], jnp.int8)
                cands.append(q.astype(dtype) * scale.astype(dtype))
            else:
                q = _unpack_int4(buf[..., :dh // 2])
                cands.append(q.astype(dtype) * scale.astype(dtype))
        return self._slot_select(cands, cands[0].ndim)

    # --------------------------------------------------------------- writes
    def _lengths_after(self, start, s, new_length):
        if new_length is None:
            return jnp.zeros_like(self.length) + start + s
        return jnp.broadcast_to(new_length, self.length.shape).astype(
            self.length.dtype)

    def _encode(self, x):
        """float K or V rows -> (storage, scale-or-None) for this mode."""
        if self.mixed:
            return self._encode_mixed(x)
        if self.quantized:
            return _kv_quant(x, 8, self.k_scale.dtype)
        if self.packed4:
            q, sc = _kv_quant(x, 4, self.k_scale.dtype)
            return _pack_int4(q), sc
        return x.astype(self.k.dtype), None

    def update(self, k_new, v_new, start, *, new_length=None) -> "KVCache":
        """Insert [B, S_new, KVH, Dh] at position `start` (scalar, traced ok).

        ``new_length`` ([B] or scalar) overrides the resulting per-slot
        lengths — used for right-padded prefill, where ``S_new`` is the
        padded length but only the first ``new_length[b]`` positions of slot
        ``b`` are real tokens."""
        idx = (0, start, 0, 0)
        ln = self._lengths_after(start, k_new.shape[1], new_length)
        kq, ks = self._encode(k_new)
        vq, vs = self._encode(v_new)
        k = jax.lax.dynamic_update_slice(self.k, kq, idx)
        v = jax.lax.dynamic_update_slice(self.v, vq, idx)
        if ks is None:
            return dataclasses.replace(self, k=k, v=v, length=ln)
        return dataclasses.replace(
            self, k=k, v=v,
            k_scale=jax.lax.dynamic_update_slice(self.k_scale, ks, idx),
            v_scale=jax.lax.dynamic_update_slice(self.v_scale, vs, idx),
            length=ln)

    def append(self, k_new, v_new, active=None) -> "KVCache":
        """Masked per-slot decode write: one token per slot at that slot's
        own ``length[b]`` (a scatter, not a slice — slots sit at different
        positions).  Slots with ``active[b] == False`` are left untouched:
        neither their K/V rows nor their lengths move, so a finished slot's
        cache is frozen until the scheduler reuses it."""
        b = self.k.shape[0]
        if active is None:
            active = jnp.ones((b,), bool)
        active = active & (self.length < self.k.shape[1])   # never overflow
        idx = jnp.arange(b)
        pos = jnp.clip(self.length, 0, self.k.shape[1] - 1)

        def put(buf, val):
            cur = buf[idx, pos]
            val = jnp.where(active[(...,) + (None,) * (val.ndim - 1)],
                            val.astype(buf.dtype), cur)
            return buf.at[idx, pos].set(val)

        ln = self.length + active.astype(self.length.dtype)
        kq, ks = self._encode(k_new)
        vq, vs = self._encode(v_new)
        k, v = put(self.k, kq[:, 0]), put(self.v, vq[:, 0])
        if ks is None:
            return dataclasses.replace(self, k=k, v=v, length=ln)
        return dataclasses.replace(
            self, k=k, v=v, k_scale=put(self.k_scale, ks[:, 0]),
            v_scale=put(self.v_scale, vs[:, 0]), length=ln)

    def requantize(self, kv_bits_new) -> "KVCache":
        """Re-encode the stored K/V at new per-slot tier codes (mixed mode
        only) — the KV half of mid-stream tier migration.

        ``kv_bits_new`` is a (traced-ok) int32 tier code (16/8/4), scalar or
        [B], broadcast over the slot axis.  The result is exactly what
        :meth:`update` would have stored had the dequantized cache been
        written at the target tier in the first place: dequantize every
        lane at its CURRENT per-slot tier (through :meth:`read`'s barriered
        path), flip the tier codes, re-encode through the same `_encode`
        path.  bf16 -> bf16 is bit-exact (bitcast round-trip); narrowing
        migrations requantize through the shared ``_kv_quant`` so the
        migrated lane is bit-identical to quantizing the dequantized cache
        directly at the target precision.  Lengths and all other slots'
        data are untouched (callers migrate one slot via a slot view)."""
        if not self.mixed:
            raise ValueError("requantize() needs the mixed per-slot KV "
                             "arena (kv_bits tier codes)")
        k, v = self.read(jnp.bfloat16)
        out = dataclasses.replace(
            self, kv_bits=jnp.broadcast_to(
                jnp.asarray(kv_bits_new, self.kv_bits.dtype),
                self.kv_bits.shape))
        kq, ks = out._encode(k)
        vq, vs = out._encode(v)
        return dataclasses.replace(out, k=kq, v=vq, k_scale=ks, v_scale=vs)

    def read(self, dtype=jnp.bfloat16):
        """Dequantized (K, V) views of the whole arena.

        Quantized modes return their result through an
        ``optimization_barrier``: the dequant multiply feeds attention
        contractions, and XLA may otherwise fold the per-row scale out of
        the f32 sum (``sum(q*s*x) -> s*sum(q*x)``) in one engine's graph
        but not another's — a one-ulp reassociation that breaks mixed-vs-
        fixed-precision bit-identity.  Dense bf16 reads have no continuous
        scale and stay unbarriered."""
        if self.mixed:
            return jax.lax.optimization_barrier(
                (self._decode_mixed(self.k, self.k_scale, dtype),
                 self._decode_mixed(self.v, self.v_scale, dtype)))
        if self.quantized:
            k = self.k.astype(dtype) * self.k_scale.astype(dtype)
            v = self.v.astype(dtype) * self.v_scale.astype(dtype)
            return jax.lax.optimization_barrier((k, v))
        if self.packed4:
            k = _unpack_int4(self.k).astype(dtype) * self.k_scale.astype(dtype)
            v = _unpack_int4(self.v).astype(dtype) * self.v_scale.astype(dtype)
            return jax.lax.optimization_barrier((k, v))
        return self.k.astype(dtype), self.v.astype(dtype)


jax.tree_util.register_dataclass(
    KVCache, data_fields=["k", "v", "k_scale", "v_scale", "length",
                          "kv_bits"],
    meta_fields=["modes"])


def decode_attention(q, cache: KVCache):
    """Single-step attention against a cache. q: [B, 1, H, Dh].

    Grouped (kvh, g) einsum form — no K/V repeat, operands stay in the cache
    dtype (bf16/int8-dequant) with f32 accumulation via
    preferred_element_type, so the big cache tensors are never materialized
    in f32 and the head_dim contraction runs sharded (§Perf decode iters)."""
    b, sq, h, dh = q.shape
    k, v = cache.read(q.dtype)
    sk = k.shape[1]
    kvh = k.shape[2]
    g = h // kvh
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(b, sq, kvh, g, dh)
    # Match the cache's head_dim TP sharding: the contraction then runs as
    # sharded partial sums + a 33MB score psum instead of all-gathering the
    # multi-GB K (§Perf decode iteration).
    qg = shard(qg, "batch", None, None, None, "model")
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(sk)
    # Per-slot length mask: slot b attends only its own filled positions.
    valid = pos[None, :] < cache.length[:, None]            # [B, Smax]
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(q.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh).astype(q.dtype)


# --------------------------------------------------------------- GQA attention
def attention_init(key, cfg, dtype=jnp.bfloat16):
    keys = jax.random.split(key, 4)
    d, h, kvh, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "q_proj": dense_init(keys[0], d, h * dh, dtype),
        "k_proj": dense_init(keys[1], d, kvh * dh, dtype),
        "v_proj": dense_init(keys[2], d, kvh * dh, dtype),
        "o_proj": dense_init(keys[3], h * dh, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"g": jnp.ones((dh,), dtype)}
        p["k_norm"] = {"g": jnp.ones((dh,), dtype)}
    return p


def attention_apply(params, x, rt: Runtime, cfg, name: str, *,
                    positions=None, cache: Optional[KVCache] = None,
                    cache_start=None, seq_lengths=None, active=None,
                    verify_window: bool = False):
    """GQA attention with RoPE (+ optional qk_norm).  If `cache` is given,
    runs in incremental mode: S > 1 prefills the cache from position 0
    (right-padded prompts supported via ``seq_lengths`` [B], the true token
    counts); S == 1 appends one token at each slot's own fill point, with
    ``active`` [B] masking writes for finished/empty slots.

    NOTE: unlike the scalar-length seed, a multi-token call on a warm cache
    does NOT append at the fill point (per-slot lengths have no single
    append position).  Chunked prefill must pass ``cache_start`` (and gets
    the uniform-start semantics); otherwise S > 1 means prefill-from-
    scratch — EXCEPT under ``verify_window``, the speculative verify
    path: S > 1 tokens append at each slot's own fill point, with the
    q/k/v/o projections batched over the window (per-row quantization +
    exact integer accumulation make them bit-identical to S separate
    decode projections) and the attention core replaying ``append`` +
    ``decode_attention`` per position, so position j's output — and its
    KV write — is bit-identical to the j-th sequential decode step
    (flash_attention's blocked online softmax would NOT be: it
    reassociates the reduction).  Returns (out, new_cache)."""
    b, s, d = x.shape
    h, kvh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if rt.tp is not None:
        # Inside shard_map the q (and, when the KV heads divide, k/v)
        # projections are head-sharded: local head counts drive every
        # reshape, and GQA grouping is re-derived from the LOCAL ratio —
        # exact because a contiguous query-head slice maps onto the
        # matching KV-head slice (kv_shards) or onto the one replicated
        # MQA head (num_kv_heads == 1 fallback).
        h //= rt.tp.n
        if rt.tp.kv_shards:
            kvh //= rt.tp.n
    if positions is None:
        if cache_start is not None:
            base = jnp.asarray(cache_start, jnp.int32).reshape(-1, 1)
        elif cache is not None and (s == 1 or verify_window):
            base = cache.length[:, None]   # append at each slot's fill point
        else:
            base = jnp.zeros((1, 1), jnp.int32)    # prefill from scratch
        positions = base + jnp.arange(s)[None, :].astype(jnp.int32)
        positions = jnp.broadcast_to(positions, (b, s))

    # q/k/v read the same x: share one activation quantization (exact).
    acts: Dict[Any, Any] = {}
    q = linear(params["q_proj"], x, rt, f"{name}.q_proj",
               act_quants=acts).reshape(b, s, h, dh)
    k = linear(params["k_proj"], x, rt, f"{name}.k_proj",
               act_quants=acts).reshape(b, s, kvh, dh)
    v = linear(params["v_proj"], x, rt, f"{name}.v_proj",
               act_quants=acts).reshape(b, s, kvh, dh)
    if cfg.qk_norm:
        q = qk_headnorm(params["q_norm"], q)
        k = qk_headnorm(params["k_norm"], k)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", None, "model", None)
    k = shard(k, "batch", None, "model", None)

    new_cache = None
    if cache is not None:
        if s == 1:
            new_cache = cache.append(k, v, active=active)
            out = decode_attention(q, new_cache)
        elif verify_window:
            # Speculative verify: per-position append + decode_attention
            # replay (see docstring — the batched work happened in the
            # projections; the core stays sequential for bit-identity).
            qs = jnp.swapaxes(q, 0, 1)[:, :, None]     # [S, B, 1, H, dh]
            ks = jnp.swapaxes(k, 0, 1)[:, :, None]
            vs = jnp.swapaxes(v, 0, 1)[:, :, None]

            def vstep(c, xs):
                q_t, k_t, v_t = xs
                c2 = c.append(k_t, v_t, active=active)
                return c2, decode_attention(q_t, c2)

            new_cache, outs = jax.lax.scan(vstep, cache, (qs, ks, vs))
            out = jnp.swapaxes(outs[:, :, 0], 0, 1)    # [B, S, H, dh]
        else:
            start = 0 if cache_start is None else cache_start
            new_cache = cache.update(k, v, start, new_length=seq_lengths)
            kf, vf = new_cache.read(q.dtype)
            # q_offset = start: with right-padding, pad queries past a slot's
            # true length attend only already-written positions (causal) and
            # their outputs are discarded by the caller's length gather.
            out = flash_attention(q, kf, vf, causal=True, q_offset=start)
    else:
        out = flash_attention(q, k, v, causal=True)
    out = out.reshape(b, s, h * dh)
    return linear(params["o_proj"], out, rt, f"{name}.o_proj"), new_cache


# ----------------------------------------------------------------- SwiGLU MLP
def mlp_init(key, d_model: int, d_ff: int, dtype=jnp.bfloat16):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate_proj": dense_init(k1, d_model, d_ff, dtype),
        "up_proj": dense_init(k2, d_model, d_ff, dtype),
        "down_proj": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp_apply(params, x, rt: Runtime, name: str):
    # gate/up read the same x: share one activation quantization (exact).
    acts: Dict[Any, Any] = {}
    gate = linear(params["gate_proj"], x, rt, f"{name}.gate_proj",
                  act_quants=acts)
    up = linear(params["up_proj"], x, rt, f"{name}.up_proj", act_quants=acts)
    hidden = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    hidden = shard(hidden, "batch", None, "model")
    return linear(params["down_proj"], hidden, rt, f"{name}.down_proj")
