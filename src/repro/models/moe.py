"""Token-choice top-k Mixture-of-Experts with capacity-based dispatch.

Expert FFN weights route through the paper's quantized-matmul backends
(vmapped over the expert axis); the router stays high-precision
(DESIGN.md §Arch-applicability).  Experts shard over the mesh "model" axis
(EP) when the expert count divides it, otherwise fall back to 2D TP
sharding of the expert FFN dims — both expressed in
``distributed.sharding_rules``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import current_mesh, mesh_divides, shard
from repro.models import layers


def moe_init(key, cfg, dtype=jnp.bfloat16):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    import math
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "router": {"w": jax.random.normal(ks[0], (d, e), jnp.float32) * 0.02},
        "gate_proj": {"w": jax.random.uniform(ks[1], (e, d, f), jnp.float32,
                                              -s_in, s_in).astype(dtype)},
        "up_proj": {"w": jax.random.uniform(ks[2], (e, d, f), jnp.float32,
                                            -s_in, s_in).astype(dtype)},
        "down_proj": {"w": jax.random.uniform(ks[3], (e, f, d), jnp.float32,
                                              -s_out, s_out).astype(dtype)},
    }
    if cfg.shared_expert:
        p["shared"] = layers.mlp_init(ks[4], d, f, dtype)
    return p


def _expert_ffn(params, xe, rt: layers.Runtime, name: str):
    """xe: [E, B, C, d] -> [E, B, C, d] through per-expert SwiGLU, quantized."""
    def one(w_gate, w_up, w_down, x):
        gate = layers.linear({"w": w_gate}, x, rt, f"{name}.gate_proj")
        up = layers.linear({"w": w_up}, x, rt, f"{name}.up_proj")
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
        return layers.linear({"w": w_down}, h, rt, f"{name}.down_proj")

    return jax.vmap(one)(params["gate_proj"]["w"], params["up_proj"]["w"],
                         params["down_proj"]["w"], xe)


def moe_apply(params, x, rt: layers.Runtime, cfg, name: str,
              dropless: Optional[bool] = None):
    """Returns (y, aux_loss).  x: [B, S, d].

    Dispatch is PER SEQUENCE (vmapped over the batch dim): every scatter /
    gather carries a leading batch dimension, so GSPMD shards it over the
    data axis instead of replicating (a flat global-token scatter forces
    involuntary full rematerialization at 1M+ tokens).  Capacity is therefore
    per-sequence: C = round(S * k * cf / E).

    ``dropless`` overrides ``rt.moe_dropless`` for this call.  The
    speculative verify window passes True: a single-token decode step can
    never drop (its one token always fits capacity >= 1), so a multi-token
    window only stays bit-identical per position to sequential decoding if
    its capacity also admits every token."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    if dropless is None:
        dropless = rt.moe_dropless

    # Router in f32 (kept dense — not matmul-array work in the paper's sense).
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)                       # [B, S, E]
    top_w, top_i = jax.lax.top_k(probs, k)                        # [B, S, k]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # Load-balancing auxiliary loss (Switch-style).
    density = jnp.mean(jax.nn.one_hot(top_i[..., 0], e, dtype=jnp.float32),
                       axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(density * mean_prob)

    if dropless:
        capacity = s          # worst case: a whole sequence to one expert
    else:
        capacity = int(max(1, round(s * k * cfg.capacity_factor / e)))
    capacity = min(capacity, s)

    # Position of each (token, slot) within its expert, per sequence.
    flat_e = top_i.reshape(b, s * k)                              # [B, S*k]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)           # [B, S*k, E]
    pos = jnp.cumsum(onehot, axis=1) - onehot
    pos_in_e = jnp.take_along_axis(pos, flat_e[..., None], axis=2)[..., 0]
    keep = pos_in_e < capacity
    slot = jnp.where(keep, flat_e * capacity + pos_in_e, e * capacity)

    # Dispatch: batched scatter into [B, E*C (+1 overflow), d].
    x_rep = jnp.repeat(x, k, axis=1)                              # [B, S*k, d]
    x_rep = jnp.where(keep[..., None], x_rep, 0)
    buf = jnp.zeros((b, e * capacity + 1, d), x.dtype)
    buf = jax.vmap(lambda bf, sl, xv: bf.at[sl].add(xv))(buf, slot, x_rep)
    xe = buf[:, : e * capacity].reshape(b, e, capacity, d)
    xe = xe.transpose(1, 0, 2, 3)                                 # [E,B,C,d]
    # EP when experts divide the model axis, else keep batch-sharded with
    # d_model TP'd so the buffer never replicates.
    ep = mesh_divides(current_mesh(), e, "expert")
    xe = shard(xe, "expert", "batch", None, None) if ep \
        else shard(xe, None, "batch", None, "model")

    ye = _expert_ffn(params, xe, rt, name)
    ye = shard(ye, "expert", "batch", None, None) if ep \
        else shard(ye, None, "batch", None, "model")

    # Combine: batched gather of each slot's output, weighted.
    yr = ye.transpose(1, 0, 2, 3).reshape(b, e * capacity, d)
    pad = jnp.zeros((b, 1, d), ye.dtype)
    yr = jnp.concatenate([yr, pad], axis=1)                       # overflow row
    y_tok = jax.vmap(lambda row, sl: row[sl])(yr, slot)           # [B, S*k, d]
    y_tok = y_tok.astype(jnp.float32) * top_w.reshape(b, s * k)[..., None]
    y = y_tok.reshape(b, s, k, d).sum(axis=2).astype(x.dtype)
    y = shard(y, "batch", None, None)

    if cfg.shared_expert:
        y = y + layers.mlp_apply(params["shared"], x, rt, f"{name}.shared")
    return y, aux
