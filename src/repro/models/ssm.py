"""Mamba2 / SSD (state-space duality) block — chunked scan for train/prefill,
O(1)-state update for decode.

The paper's quantization technique applies to the in/out projections (the
matmul-array work); the SSD recurrence itself is elementwise/outer-product
work kept in f32 (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers


def ssm_init(key, cfg, dtype=jnp.bfloat16):
    d, di, ns, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * ns
    ks = jax.random.split(key, 4)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(di)
    return {
        # z | x | B | C | dt
        "in_proj": {"w": jax.random.uniform(
            ks[0], (d, 2 * di + 2 * ns + h), jnp.float32, -s_in, s_in).astype(dtype)},
        "out_proj": {"w": jax.random.uniform(
            ks[1], (di, d), jnp.float32, -s_out, s_out).astype(dtype)},
        "conv_w": jax.random.normal(ks[2], (cfg.ssm_conv, conv_ch),
                                    jnp.float32).astype(dtype) * 0.1,
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.full((h,), 0.5, jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "norm": {"g": jnp.ones((di,), dtype)},
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv1d. x: [B, L, C]; w: [W, C]."""
    width, ch = w.shape
    rhs = w[:, None, :].astype(jnp.float32)            # [W, 1, C] (WIO)
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), rhs, window_strides=(1,),
        padding=[(width - 1, 0)], feature_group_count=ch,
        dimension_numbers=("NWC", "WIO", "NWC"))
    return out + b.astype(jnp.float32)


def _ssd_chunked(xh, dt, a, bmat, cmat, d_skip, chunk: int):
    """Chunked SSD scan.  xh: [B, L, H, P]; dt: [B, L, H]; a: [H] (negative);
    bmat/cmat: [B, L, N].  Returns y: [B, L, H, P] (f32)."""
    b, l0, h, p = xh.shape
    n = bmat.shape[-1]
    q = min(chunk, l0)
    pad = (-l0) % q
    if pad:
        # Zero-pad: padded dt=0 -> dtx=0, so states and real outputs are
        # unaffected; padded outputs are sliced off below.
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    l = l0 + pad
    nc = l // q

    log_a = a[None, None, :] * dt                      # [B, L, H] f32, <= 0
    dtx = (xh.astype(jnp.float32) * dt[..., None]).astype(xh.dtype)

    def to_chunks(t):
        return t.reshape(b, nc, q, *t.shape[2:]).transpose(
            1, 0, *range(2, t.ndim + 1))

    xs = (to_chunks(log_a), to_chunks(dtx), to_chunks(bmat), to_chunks(cmat))
    tri = jnp.tril(jnp.ones((q, q), jnp.float32))

    def body(state, inputs):
        la_c, dtx_c, b_c, c_c = inputs                 # [B,Q,H],[B,Q,H,P],[B,Q,N]
        cum = jnp.cumsum(la_c, axis=1)                 # [B, Q, H] f32
        total = cum[:, -1]                             # [B, H]
        # Intra-chunk (the "duality" quadratic term, masked causal).
        scores = jnp.einsum("bin,bjn->bij", c_c, b_c,
                            preferred_element_type=jnp.float32)
        decay = jnp.exp(jnp.clip(cum[:, :, None] - cum[:, None, :], -60, 0))
        att = scores[:, :, :, None] * decay * tri[None, :, :, None]  # [B,Q,Q,H]
        # att stays f32: rounding the decay-score products to bf16 put the
        # full forward ~4e-2 off the (all-f32) O(1) decode recurrence on deep
        # hybrid stacks — the jamba decode-parity failure tracked since the
        # seed.  Only this [B,Q,Q,H] temporary pays the f32 cost; dtx and the
        # scan carry keep the compute dtype.
        y_intra = jnp.einsum("bijh,bjhp->bihp", att,
                             dtx_c.astype(jnp.float32),
                             preferred_element_type=jnp.float32)
        # Inter-chunk contribution from carried state (f32 carry).
        y_inter = jnp.einsum("bin,bhnp->bihp", c_c.astype(jnp.float32), state) \
            * jnp.exp(cum)[..., None]
        # State update.
        w = jnp.exp(jnp.clip(total[:, None] - cum, -60, 0))         # [B, Q, H]
        s_new = jnp.exp(total)[:, :, None, None] * state \
            + jnp.einsum("bjn,bjh,bjhp->bhnp", b_c.astype(jnp.float32), w,
                         dtx_c.astype(jnp.float32))
        return s_new, y_intra + y_inter

    s0 = jnp.zeros((b, h, n, p), jnp.float32)
    _, ys = jax.lax.scan(jax.checkpoint(body), s0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, l, h, p)
    y = y + d_skip[None, None, :, None] * xh.astype(jnp.float32)
    return y[:, :l0]


@dataclasses.dataclass
class SSMCache:
    conv: jax.Array    # [B, W-1, conv_ch] f32 rolling conv window
    state: jax.Array   # [B, H, N, P] f32 SSD state

    @staticmethod
    def create(batch, cfg) -> "SSMCache":
        conv_ch = cfg.d_inner + 2 * cfg.ssm_state
        return SSMCache(
            jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), jnp.float32),
            jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim),
                      jnp.float32))


jax.tree_util.register_dataclass(
    SSMCache, data_fields=["conv", "state"], meta_fields=[])


def _decode_core(params, cfg, conv_cache, state, conv_in_t, dtp_t, a, active):
    """One token of the O(1) decode recurrence.

    Shared verbatim by the single-token decode path and the speculative
    verify-window replay, so every window position is bit-identical to
    the sequential decode step it stands in for.  ``conv_cache``:
    [B, W-1, C] f32; ``state``: [B, H, N, P] f32; ``conv_in_t``:
    [B, 1, C] (compute dtype); ``dtp_t``: [B, H] f32 (softplus'd dt);
    ``a``: [H] f32 negative.  Returns (y [B, H, P] f32, new_conv,
    new_state), with ``active`` masking the cache updates (the output for
    inactive rows is garbage the caller discards, as in decode)."""
    di, ns = cfg.d_inner, cfg.ssm_state
    h, p = cfg.ssm_heads, cfg.ssm_headdim
    window = jnp.concatenate([conv_cache.astype(conv_in_t.dtype), conv_in_t],
                             axis=1)                             # [B, W, C]
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          params["conv_w"].astype(jnp.float32)) \
        + params["conv_b"].astype(jnp.float32)
    conv_out = jax.nn.silu(conv_out)[:, None, :].astype(conv_in_t.dtype)
    new_conv = window[:, 1:].astype(jnp.float32)
    if active is not None:
        new_conv = jnp.where(active[:, None, None], new_conv, conv_cache)
    xc, bc, cc = jnp.split(conv_out, [di, di + ns], axis=-1)
    xh = xc.reshape(-1, 1, h, p)
    # S' = exp(a dt) S + dt B (x)^T ; y = C.S' + D x
    la = jnp.exp(a[None, :] * dtp_t)                             # [B, H]
    dtx = xh[:, 0].astype(jnp.float32) * dtp_t[:, :, None]
    s_new = la[:, :, None, None] * state \
        + jnp.einsum("bn,bhp->bhnp", bc[:, 0].astype(jnp.float32), dtx)
    y = jnp.einsum("bn,bhnp->bhp", cc[:, 0].astype(jnp.float32), s_new) \
        + params["D"][None, :, None] * xh[:, 0].astype(jnp.float32)
    if active is not None:
        s_new = jnp.where(active[:, None, None, None], s_new, state)
    return y, new_conv, s_new


def ssm_apply(params, x, rt: layers.Runtime, cfg, name: str, *,
              cache: Optional[SSMCache] = None, seq_lengths=None,
              active=None, verify_window: bool = False):
    """Mamba2 block.  Full-sequence when cache is None (train/prefill);
    single-token state update when cache is given and S == 1.

    ``seq_lengths`` [B] marks right-padded prefill: positions >= length get
    dt = 0, so pad tokens contribute nothing to the SSD state, and the conv
    window is gathered ending at each row's true length (exact vs an
    unpadded run).  ``active`` [B] masks the decode state/conv update for
    finished slots (continuous batching).

    ``verify_window`` (cache given, S > 1) is the speculative verify
    path: the in/out projections run batched over the window (the
    grouped-GEMM savings), while the conv + SSD recurrence replays the
    EXACT single-token decode core sequentially over the S positions —
    so position j's output is bit-identical to the j-th sequential
    decode step.  The returned cache is per-step STACKED ([S, B, ...]
    leaves): SSM state rolls back by re-selection, so the engine picks
    the snapshot at each slot's accepted length
    (``slots.select_verify_step``).
    Returns (y, new_cache)."""
    b, s, d = x.shape
    di, ns, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim

    zxbcdt = layers.linear(params["in_proj"], x, rt, f"{name}.in_proj")
    # Activations stay in the compute dtype (bf16); only dt / decay / state
    # math is f32 (§Perf: an all-f32 SSD block doubles every residual-stream
    # and scan-carried tensor's HBM+collective traffic).
    z, xin, bmat, cmat, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + ns, 2 * di + 2 * ns], axis=-1)
    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)   # [B, S, di+2ns]

    a = -jnp.exp(params["A_log"])                           # [H], negative
    dtp = jax.nn.softplus(dt.astype(jnp.float32)
                          + params["dt_bias"][None, None, :])  # [B, S, H] f32
    if seq_lengths is not None and s > 1:
        # Pad positions get dt = 0 => log_a = 0 and dtx = 0: they advance
        # neither the state nor any real token's output (exact masking).
        real = jnp.arange(s)[None, :] < seq_lengths[:, None]   # [B, S]
        dtp = jnp.where(real[:, :, None], dtp, 0.0)

    new_cache = None
    if cache is not None and s == 1:
        y, new_conv, s_new = _decode_core(params, cfg, cache.conv,
                                          cache.state, conv_in, dtp[:, 0],
                                          a, active)
        y = y[:, None]                                      # [B, 1, H, P]
        new_cache = SSMCache(new_conv, s_new)
    elif cache is not None and verify_window:
        # Speculative verify: replay the decode core over the window.  The
        # in-projection above (and the out-projection below) ran batched;
        # only the O(1)-state core is sequential, and each step of it is
        # the decode step verbatim.
        steps = (jnp.swapaxes(conv_in, 0, 1)[:, :, None, :],   # [S, B, 1, C]
                 jnp.swapaxes(dtp, 0, 1))                      # [S, B, H]

        def vstep(carry, xs):
            conv_c, state_c = carry
            conv_t, dtp_t = xs
            y_t, conv_n, state_n = _decode_core(params, cfg, conv_c, state_c,
                                                conv_t, dtp_t, a, active)
            return (conv_n, state_n), (y_t, conv_n, state_n)

        _, (ys, convs, states) = jax.lax.scan(
            vstep, (cache.conv, cache.state), steps)
        y = jnp.swapaxes(ys, 0, 1)                          # [B, S, H, P]
        new_cache = SSMCache(convs, states)                 # [S, B, ...]
    else:
        conv_out = jax.nn.silu(_causal_conv(conv_in, params["conv_w"],
                                            params["conv_b"])
                               ).astype(conv_in.dtype)
        new_conv = None
        if cache is not None:
            w = cfg.ssm_conv - 1
            if seq_lengths is not None:
                # Window of the last w REAL inputs of each row: gather from a
                # zero-left-padded copy so rows shorter than w keep their
                # fresh-cache zero context.
                padded = jnp.concatenate(
                    [jnp.zeros((b, w, conv_in.shape[-1]), conv_in.dtype),
                     conv_in], axis=1)
                idx = seq_lengths[:, None] + jnp.arange(w)[None, :]   # [B, w]
                tail = jnp.take_along_axis(padded, idx[:, :, None], axis=1)
            elif s >= w:
                tail = conv_in[:, -w:]
            else:
                tail = jnp.concatenate(
                    [cache.conv[:, s:].astype(conv_in.dtype), conv_in], axis=1)
            new_conv = tail.astype(jnp.float32)

        xc, bc, cc = jnp.split(conv_out, [di, di + ns], axis=-1)
        xh = xc.reshape(b, s, h, p)
        y = _ssd_chunked(xh, dtp, a, bc, cc, params["D"], cfg.ssm_chunk)
        if cache is not None:
            # Prefill with cache: recompute final state via a 1-chunk pass is
            # implicit in _ssd_chunked's scan; rerun cheaply for the state.
            # (Prefill for SSM archs uses full-seq then state extraction.)
            new_cache = SSMCache(new_conv, _final_state(xh, dtp, a, bc))

    y = y.reshape(b, s, di).astype(x.dtype)
    gated = layers.rmsnorm(params["norm"], y) \
        * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = layers.linear(params["out_proj"], gated, rt, f"{name}.out_proj")
    return out, new_cache


def _final_state(xh, dt, a, bmat):
    """Final SSD state after a full sequence (for prefill -> decode handoff).

    ``dtx`` is rounded through the compute dtype exactly like
    :func:`_ssd_chunked` does, so the handed-off state matches the state the
    full forward actually evolved — an unrounded f32 ``dtx`` here silently
    diverged the prefill->decode path from the full forward (the jamba
    decode-parity failure tracked since the seed)."""
    b, l, h, p = xh.shape
    log_a = a[None, None, :] * dt
    cum = jnp.cumsum(log_a, axis=1)
    total = cum[:, -1]
    w = jnp.exp(jnp.clip(total[:, None] - cum, -60, 0))     # [B, L, H]
    dtx = (xh.astype(jnp.float32) * dt[..., None]).astype(xh.dtype)
    return jnp.einsum("bjn,bjh,bjhp->bhnp", bmat.astype(jnp.float32), w,
                      dtx.astype(jnp.float32))
