"""Decoder-only LM stack covering all assigned families: dense, MoE, hybrid
(Mamba+attention interleave), pure SSM, and stub-fronted VLM/audio backbones.

The stack scans over *periods* (cfg.period_pattern()) with stacked params, so
a 72-layer hybrid compiles as a 9-step scan over a static 8-layer body —
small HLO, layer-granular remat, and per-period stacked KV/SSM caches.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import layers, moe, ssm
from repro.models.config import ArchConfig


class LM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.pattern = cfg.period_pattern()

    # ------------------------------------------------------------------ init
    def _period_init(self, key):
        cfg = self.cfg
        p: Dict[str, Any] = {}
        ks = jax.random.split(key, 4 * len(self.pattern))
        for i, (mixer, ff) in enumerate(self.pattern):
            blk: Dict[str, Any] = {"mixer_norm": layers.rmsnorm_init(cfg.d_model, cfg.dtype)}
            if mixer == "attn":
                blk["attn"] = layers.attention_init(ks[4 * i], cfg, cfg.dtype)
            else:
                blk["mamba"] = ssm.ssm_init(ks[4 * i], cfg, cfg.dtype)
            if ff is not None:
                blk["ff_norm"] = layers.rmsnorm_init(cfg.d_model, cfg.dtype)
                if ff == "mlp":
                    blk["mlp"] = layers.mlp_init(ks[4 * i + 1], cfg.d_model,
                                                 cfg.d_ff, cfg.dtype)
                else:
                    blk["moe"] = moe.moe_init(ks[4 * i + 1], cfg, cfg.dtype)
            p[f"pos{i}"] = blk
        return p

    def init(self, key):
        cfg = self.cfg
        k_emb, k_per, k_head = jax.random.split(key, 3)
        period_keys = jax.random.split(k_per, cfg.n_periods)
        periods = jax.vmap(self._period_init)(period_keys)
        params = {
            "embed": {"emb": (jax.random.normal(
                k_emb, (cfg.padded_vocab, cfg.d_model), jnp.float32) * 0.02
            ).astype(cfg.dtype)},
            "periods": periods,
            "final_norm": layers.rmsnorm_init(cfg.d_model, cfg.dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = layers.dense_init(
                k_head, cfg.d_model, cfg.padded_vocab, cfg.dtype)
        return params

    # ------------------------------------------------------------- internals
    def _embed(self, params, tokens=None, embeds=None):
        if embeds is not None:
            return embeds.astype(self.cfg.dtype)
        return jnp.take(params["embed"]["emb"], tokens, axis=0)

    def _head(self, params, x, rt: layers.Runtime):
        x = layers.rmsnorm(params["final_norm"], x)
        if self.cfg.tie_embeddings:
            w = params["embed"]["emb"].T
            logits = jnp.matmul(x, w.astype(x.dtype))
        else:
            logits = layers.linear(params["lm_head"], x, rt, "lm_head")
        return shard(logits, "batch", None, "model")

    def _period_body(self, blk_params, x, rt, caches=None, seq_lengths=None,
                     active=None, verify_window=False):
        cfg = self.cfg
        new_caches: Dict[str, Any] = {}
        aux = jnp.zeros((), jnp.float32)
        for i, (mixer, ff) in enumerate(self.pattern):
            blk = blk_params[f"pos{i}"]
            c = None if caches is None else caches.get(f"pos{i}")
            h = layers.rmsnorm(blk["mixer_norm"], x)
            if mixer == "attn":
                out, nc = layers.attention_apply(
                    blk["attn"], h, rt, cfg, f"layers.pos{i}.attn", cache=c,
                    seq_lengths=seq_lengths, active=active,
                    verify_window=verify_window)
            else:
                out, nc = ssm.ssm_apply(
                    blk["mamba"], h, rt, cfg, f"layers.pos{i}.mamba", cache=c,
                    seq_lengths=seq_lengths, active=active,
                    verify_window=verify_window)
            x = x + out
            if caches is not None:
                new_caches[f"pos{i}"] = nc
            if ff is not None:
                h2 = layers.rmsnorm(blk["ff_norm"], x)
                if ff == "mlp":
                    out2 = layers.mlp_apply(blk["mlp"], h2, rt,
                                            f"layers.pos{i}.mlp")
                else:
                    # Verify windows force dropless dispatch: single-token
                    # decode never drops, so position-wise bit-identity
                    # needs every window token admitted too.
                    out2, a = moe.moe_apply(blk["moe"], h2, rt, cfg,
                                            f"layers.pos{i}.moe",
                                            dropless=True if verify_window
                                            else None)
                    aux = aux + a
                x = x + out2
        # Residual stream sharded 2D (batch x d_model): the scan carry is what
        # autodiff saves per period, so sharding d_model over "model" cuts the
        # saved-activation footprint 16x (Megatron-SP-style).
        x = shard(x, "batch", None, "model")
        return x, aux, new_caches

    def _stack(self, params, x, rt, caches=None, seq_lengths=None,
               active=None, verify_window=False):
        if caches is None:
            def body(carry, pp):
                xx, aux = carry
                xx, a, _ = self._period_body(pp, xx, rt)
                return (xx, aux + a), None

            (x, aux), _ = jax.lax.scan(
                jax.checkpoint(body), (x, jnp.zeros((), jnp.float32)),
                params["periods"])
            return x, aux, None

        def body(carry, xs):
            xx, aux = carry
            pp, pc = xs
            xx, a, nc = self._period_body(pp, xx, rt, caches=pc,
                                          seq_lengths=seq_lengths,
                                          active=active,
                                          verify_window=verify_window)
            return (xx, aux + a), nc

        (x, aux), new_caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), (params["periods"], caches))
        return x, aux, new_caches

    # ---------------------------------------------------------------- public
    def forward(self, params, rt: layers.Runtime, tokens=None, embeds=None):
        """Full-sequence forward (training / no-cache prefill).
        Returns (logits [B, S, V], aux_loss)."""
        x = self._embed(params, tokens, embeds)
        x = shard(x, "batch", None, None)
        x, aux, _ = self._stack(params, x, rt)
        return self._head(params, x, rt), aux

    def init_cache(self, batch: int, max_len: int, kv_bits=None):
        """Per-period stacked caches for every cache-bearing position.

        ``kv_bits``: None (bf16), 8 (int8), 4 (int4-packed), or a tuple of
        tier codes (e.g. ``(16, 8, 4)``) for the per-slot mixed KV arena —
        see :meth:`repro.models.layers.KVCache.create`."""
        cfg = self.cfg
        single: Dict[str, Any] = {}
        for i, (mixer, _) in enumerate(self.pattern):
            if mixer == "attn":
                single[f"pos{i}"] = layers.KVCache.create(
                    batch, max_len, cfg.num_kv_heads, cfg.head_dim,
                    dtype=cfg.dtype, kv_bits=kv_bits)
            else:
                single[f"pos{i}"] = ssm.SSMCache.create(batch, cfg)
        return jax.tree.map(
            lambda a: jnp.zeros((cfg.n_periods,) + a.shape, a.dtype), single)

    def prefill(self, params, rt, caches, tokens=None, embeds=None,
                seq_lengths=None):
        """Run the prompt through the stack, filling caches from position 0.

        ``seq_lengths`` [B] supports right-padded batches: per-slot cache
        lengths are set to the true token counts, pad positions contribute
        nothing to any cache state, and the returned logits are gathered at
        each row's last REAL position.  Without it, the whole row is real
        and the last position is used (seed behaviour).

        Prefill always (re)fills caches from position 0 — a second prefill
        call on the same caches overwrites them rather than appending
        (chunked prefill is not supported through this entrypoint; see
        ``layers.attention_apply``'s ``cache_start``).
        Returns (last-real-position logits [B, 1, V], new caches)."""
        x = self._embed(params, tokens, embeds)
        x = shard(x, "batch", None, None)
        x, _, new_caches = self._stack(params, x, rt, caches=caches,
                                       seq_lengths=seq_lengths)
        if seq_lengths is None:
            last = x[:, -1:]
        else:
            idx = jnp.clip(seq_lengths.astype(jnp.int32) - 1, 0, x.shape[1] - 1)
            last = jnp.take_along_axis(
                x, idx[:, None, None].astype(jnp.int32), axis=1)
        return self._head(params, last, rt), new_caches

    def decode_step(self, params, rt, caches, tokens=None, embeds=None,
                    active=None):
        """One-token decode against filled caches.  ``active`` [B] masks all
        cache writes (KV append / SSM state) for finished or empty slots so
        a continuous-batching engine can keep them frozen in the batch.
        Returns (logits [B, 1, V], new caches)."""
        x = self._embed(params, tokens, embeds)
        x, _, new_caches = self._stack(params, x, rt, caches=caches,
                                       active=active)
        return self._head(params, x, rt), new_caches

    def verify_step(self, params, rt, caches, tokens, active=None):
        """Multi-token speculative verify: teacher-forced decode of a
        ``[B, W]`` window at each active slot's own fill point.

        ONE batched forward — every projection (and the LM head) runs
        over all W positions at once through the same grouped GEMMs as
        decode — whose position-j output is bit-identical to the j-th of
        W sequential :meth:`decode_step` calls (the attention/SSM cores
        replay the exact decode recurrences internally; see
        ``layers.attention_apply(verify_window=True)`` /
        ``ssm.ssm_apply(verify_window=True)``).  ``active`` [B] masks
        every cache write, so plain slots sharing the batch flow through
        untouched.  KV caches come back appended by W (the engine rolls
        rejected positions back by a length truncation —
        ``slots.truncate_kv_lengths``); SSM caches come back per-step
        STACKED ([S, B, ...] leaves) for rollback by re-selection
        (``slots.select_verify_step``).
        Returns (logits [B, W, V], new caches)."""
        x = self._embed(params, tokens)
        x, _, new_caches = self._stack(params, x, rt, caches=caches,
                                       active=active, verify_window=True)
        return self._head(params, x, rt), new_caches
