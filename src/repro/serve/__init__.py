"""Serving stack: slot-based continuous batching with preloaded weight
planes.

``engine``    — ServeEngine (continuous batching) + BatchServeEngine
                (batch-at-a-time reference) + prepare_params (weight preload)
``scheduler`` — host-side FIFO admission over fixed slots
``slots``     — per-slot cache arena views (reset/refill one slot in place)
``request``   — the Request dataclass
"""
from repro.serve.engine import (BatchServeEngine, EngineStats, Request,
                                ServeEngine, prepare_params)
from repro.serve.scheduler import ANY_TIER, Scheduler, SlotState
from repro.serve.slots import SlotArena

__all__ = ["ANY_TIER", "BatchServeEngine", "EngineStats", "Request",
           "ServeEngine", "prepare_params", "Scheduler", "SlotState",
           "SlotArena"]
