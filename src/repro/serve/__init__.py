"""Serving stack: a streaming API over slot-based continuous batching with
preloaded weight planes.

The public surface is the :class:`Engine` protocol — ``submit(request) ->
RequestHandle``, ``step() -> list[TokenEvent]``, ``drain()``, plus the
blocking ``run`` wrapper — implemented by both engines:

``engine``    — ``ServeEngine`` (continuous batching, mixed-tier decode,
                mid-stream tier migration) + ``BatchServeEngine``
                (batch-at-a-time reference) + ``prepare_params`` (weight
                preload) + the ``Engine`` protocol itself
``handle``    — ``RequestHandle`` (token iterator/callback, terminal
                status, ``set_tier``), ``TokenEvent``, ``RequestStatus``
``scheduler`` — host-side admission over fixed slots with pluggable
                ``SchedulerPolicy`` (``FIFOPolicy`` default, deadline-aware
                ``SLOPolicy`` with optional preemption/shedding/per-tenant
                fairness under overload)
``slots``     — per-slot cache arena views (reset/refill/requantize one
                slot in place)
``request``   — the ``Request`` dataclass (uid, prompt, budget, tier,
                deadline, tenant, sampling, spec)

Sampling and self-speculative decoding (``repro.spec``) plug in through
two request fields re-exported here: ``SamplingParams`` (seeded
temperature / top-k selection inside the jitted decode chunk) and
``SpecConfig`` (draft k tokens at a plane-prefix tier, verify the window
at the request's own tier in one batched forward).

Overload control rides the same surface: ``ServeEngine.preempt(uid)``
suspends a RUNNING request into a host-side ``SuspendedState`` (optionally
spilled via ``repro.checkpoint``) for prefill-free, token-identical
resumption; ``Engine.cancel(uid)`` aborts queued/suspended requests; shed
requests land in the terminal ``RequestStatus.SHED``.
"""
from repro.serve.engine import (BatchServeEngine, Engine, EngineStats,
                                Request, ServeEngine, SuspendedState,
                                prepare_params)
from repro.serve.handle import RequestHandle, RequestStatus, TokenEvent
from repro.serve.scheduler import (ANY_TIER, FIFOPolicy, Scheduler,
                                   SchedulerPolicy, SLOPolicy, SlotState)
from repro.serve.slots import SlotArena
from repro.spec import SamplingParams, SpecConfig

__all__ = ["ANY_TIER", "BatchServeEngine", "Engine", "EngineStats",
           "FIFOPolicy", "Request", "RequestHandle", "RequestStatus",
           "SLOPolicy", "SamplingParams", "SchedulerPolicy", "Scheduler",
           "ServeEngine", "SlotArena", "SlotState", "SpecConfig",
           "SuspendedState", "TokenEvent", "prepare_params"]
