"""Batched serving engine: slot-based continuous batching with jit'd
prefill/decode and quantized weights (the paper's inference path).

Weights are prepared ONCE into decomposed integer planes
(``prepare_params``) — the analogue of preloading the array — then every
matmul in prefill/decode runs the plane-decomposed integer path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import PrecisionPolicy
from repro.kernels import ops
from repro.models.layers import Runtime
from repro.models.transformer import LM


def prepare_params(params, policy: PrecisionPolicy, model: LM,
                   packed: bool = False):
    """Quantize + decompose every policy-covered projection weight offline.

    Returns a params pytree where 2D projection weights are replaced by
    QuantizedWeight planes (embeddings/norms stay dense)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    quantized_paths = []
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        is_proj = path.endswith("['w']") and leaf.ndim >= 2 \
            and "embed" not in path and "router" not in path \
            and "conv" not in path
        if is_proj:
            name = _path_to_layer_name(path)
            prec = policy.lookup(name)
            if leaf.ndim == 2:
                qw = ops.prepare_weight(leaf.astype(jnp.float32), prec,
                                        packed=packed)
                out.append(qw)
                quantized_paths.append(path)
                continue
            # Stacked (periods / experts) weights: vmap preparation over
            # leading dims.
            lead = leaf.shape[:-2]
            w2 = leaf.reshape((-1,) + leaf.shape[-2:]).astype(jnp.float32)
            qws = jax.vmap(lambda w: ops.prepare_weight(w, prec,
                                                        packed=packed))(w2)
            qws = jax.tree.map(
                lambda a: a.reshape(lead + a.shape[1:]), qws)
            out.append(qws)
            quantized_paths.append(path)
            continue
        out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out), quantized_paths


def _path_to_layer_name(path: str) -> str:
    # "['periods']['pos0']['attn']['q_proj']['w']" -> "layers.pos0.attn.q_proj"
    parts = [p.strip("'") for p in path.strip("[]").split("][")]
    if parts and parts[0] == "periods":
        parts = ["layers"] + parts[1:]
    if parts and parts[-1] == "w":
        parts = parts[:-1]
    return ".".join(parts)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # [S] int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None


class ServeEngine:
    """Fixed-slot continuous batching: admit up to `max_batch` requests,
    prefill the batch, greedy-decode until every slot finishes, refill."""

    def __init__(self, model: LM, params, rt: Runtime, *, max_batch: int = 8,
                 max_len: int = 512, kv_bits: Optional[int] = None):
        self.model = model
        self.rt = rt
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.kv_bits = kv_bits
        self._prefill = jax.jit(
            lambda p, c, t: model.prefill(p, rt, c, tokens=t))
        self._decode = jax.jit(
            lambda p, c, t: model.decode_step(p, rt, c, tokens=t))

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        results: Dict[int, List[int]] = {}
        queue = list(requests)
        while queue:
            batch = queue[: self.max_batch]
            queue = queue[self.max_batch:]
            results.update(self._run_batch(batch))
        return results

    def _run_batch(self, batch: List[Request]) -> Dict[int, List[int]]:
        b = len(batch)
        plen = max(len(r.prompt) for r in batch)
        prompts = np.zeros((b, plen), np.int32)
        for i, r in enumerate(batch):
            prompts[i, plen - len(r.prompt):] = r.prompt  # left-pad
        caches = self.model.init_cache(b, self.max_len, kv_bits=self.kv_bits)
        logits, caches = self._prefill(self.params, caches,
                                       jnp.asarray(prompts))
        max_new = max(r.max_new_tokens for r in batch)
        outs = [[] for _ in range(b)]
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        for step in range(max_new):
            for i, r in enumerate(batch):
                if step < r.max_new_tokens:
                    outs[i].append(int(tok[i]))
            logits, caches = self._decode(self.params, caches, tok[:, None])
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return {r.uid: outs[i][: r.max_new_tokens]
                for i, r in enumerate(batch)}
