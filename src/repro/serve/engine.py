"""Slot-based continuous-batching serving engines behind the streaming API.

The paper's dataflow is "serial activation input, parallel weight
preloaded": decomposed weight planes stay resident while activations stream
through.  The engine mirrors that end to end:

* **Incremental core** — the public surface is ``submit(request) ->
  RequestHandle`` / ``step() -> list[TokenEvent]`` / ``drain()``:
  requests enter any time, every scheduling round returns the tokens it
  emitted, and handles stream them (iterator + callback) as they arrive.
  ``run(requests)`` is a thin compatibility wrapper (submit all, drain,
  collect) and is token-identical to the historical blocking API.
* **Weight preload** — at construction the float params are converted ONCE
  into the ``QuantizedWeight`` plane pytree (``prepare_params``); that
  prepared pytree is the engine's only weight representation.
* **Runtime precision tiers** — with a ``PrecisionSchedule`` on the
  Runtime, the preload is a single 8-bit MSB-first *superplane* store and
  every decode dispatch picks effective (w_bits, a_bits) tiers by
  plane-prefix truncation.  Switching tiers costs zero weight
  re-preparation (``PREPARE_CALLS`` counts preparations — it must not move
  after construction).
* **Mixed-tier decode batches** — slots are tier-tagged: admission fills
  ANY free slot, and each decode chunk derives a per-step group layout from
  the occupied slots' tiers — a jit-STATIC tuple of ``(tier, rows)`` sorted
  by tier, plus a TRACED permutation mapping batch rows into that order.
  Every projection then runs one plane-prefix GEMM per group, so one jitted
  decode step serves slots at 8/6/4/2 bits simultaneously (see
  ``models.layers.linear``).  ``mixed_tiers=False`` keeps the PR-2
  tier-serialized admission (one tier per decode batch) as the baseline.
* **Mid-stream tier migration** — ``RequestHandle.set_tier(name)`` moves a
  LIVE request to another tier: the slot's KV lane is requantized in place
  (``slots.migrate_kv_tier`` — one jitted dequantize/re-encode through the
  nested-quantization path, bit-identical to quantizing the dequantized
  cache directly at the target precision) and the weight plane prefix
  switches at the next group-layout derivation.  QUEUED requests are
  simply re-tagged.
* **Pluggable admission** — WHICH waiting request takes a freed slot is a
  ``SchedulerPolicy``: ``FIFOPolicy`` (default, bit-identical to the
  historical behaviour) or ``SLOPolicy`` (deadline slack vs. the hwmodel's
  per-tier cycle cost; see ``serve/scheduler.py``).
* **Overload survival** — ``SLOPolicy`` extensions turn admission into
  overload control: ``preempt=True`` displaces the slackest RUNNING slot
  when a deadlined waiting request runs out of slack (``Engine.preempt``
  snapshots the slot's KV/SSM slice + host decode state into a
  ``SuspendedState`` — optionally spilled through ``repro.checkpoint`` —
  and the request later resumes prefill-free, token-identical, in ANY
  slot); ``shed=True`` refuses (or, with ``auto_tier``, downtiers)
  deadline requests whose projected completion exceeds modeled capacity;
  ``tenant_weights`` ages weighted tenants' queued requests faster so one
  tenant's burst cannot starve another's.  ``Engine.cancel`` aborts
  QUEUED/SUSPENDED requests without leaking scheduler state.
* **Per-request KV precision** — a schedule with ``kv_tiers`` allocates one
  mixed per-slot KV arena: each admitted request's slot stores K/V at its
  tier's precision (bf16 / int8 / int4-packed lanes, per-slot scale rows).
* **Persistent decode state** — a fixed-slot cache arena
  (:mod:`repro.serve.slots`): per-slot KV lengths and SSM states live in one
  pre-allocated pytree across the whole request stream.
* **On-device decode loop** — the inner loop is ONE jitted multi-step
  ``jax.lax.scan`` over a chunk of decode steps with an active-slot mask and
  masked cache writes; the host only admits/retires requests between
  chunks, so per-token dispatch overhead is off the critical path.

Jit-static vs traced (the contract everything above hangs on): tier names,
group layouts, chunk lengths and prompt buckets are STATIC (they key
traces: at most |layouts| x decode_chunk decode entries); slot indices,
token ids, budgets, the group permutation, per-slot KV tier codes and the
migration target code are TRACED (they change every step/migration without
retracing).

The scheduler clock: every engine counts decode steps executed
(``Engine.clock``); submission times, queue waits and ``Request.deadline``
are priced in these ticks, keeping SLO admission fully deterministic.
"""
from __future__ import annotations

import dataclasses
from typing import (Any, Dict, List, Mapping, Optional, Protocol, Sequence,
                    Set, Tuple, runtime_checkable)

import jax
import jax.numpy as jnp
import numpy as np
import numpy.typing as npt
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import checkpoint as checkpoint_lib
from repro.core.policy import PrecisionPolicy
from repro.distributed import sharding_rules, tp_serve
from repro.distributed.sharding import shard_map
from repro.kernels import ops
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.serve import slots as slots_lib
from repro.serve.handle import RequestHandle, RequestStatus, TokenEvent
from repro.serve.request import Request
from repro.serve.scheduler import (RunningEntry, Scheduler, SchedulerPolicy,
                                   SLOPolicy)
from repro.spec import sampling as sampling_lib
from repro.spec import speculate as spec_lib

__all__ = ["Request", "RequestHandle", "RequestStatus", "TokenEvent",
           "Engine", "ServeEngine", "BatchServeEngine", "EngineStats",
           "SuspendedState", "prepare_params", "PREPARE_CALLS"]

# Mixed-tier group layout: the jit-STATIC tuple of (tier name, rows) runs
# describing a tier-sorted decode batch (see Runtime.for_groups).
GroupLayout = Tuple[Tuple[str, int], ...]

# Global weight-preparation counter: every prepare_params call (one quantize+
# decompose sweep over the params) bumps it.  The runtime-tier contract —
# zero re-preparation after engine construction — is asserted against this
# in tests and the serve_precision_tiers / serve_mixed_tiers benchmarks.
PREPARE_CALLS = 0


def prepare_params(params: Any, policy: PrecisionPolicy, model: LM,
                   packed: bool = False,
                   superplane: bool = False) -> Tuple[Any, List[str]]:
    """Quantize + decompose every policy-covered projection weight offline.

    Returns a params pytree where 2D projection weights are replaced by
    QuantizedWeight planes (embeddings/norms stay dense).  ``superplane``
    prepares the runtime-reconfigurable store instead: 8-bit MSB-first
    planes regardless of the policy's per-layer w_bits (which then acts per
    decode dispatch via plane-prefix truncation)."""
    global PREPARE_CALLS
    PREPARE_CALLS += 1

    def prep(leaf: Any, prec: Any) -> Any:
        if superplane:
            return ops.prepare_superplane(leaf, signed=prec.w_signed,
                                          packed=packed)
        return ops.prepare_weight(leaf, prec, packed=packed)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    quantized_paths: List[str] = []
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        is_proj = path.endswith("['w']") and leaf.ndim >= 2 \
            and "embed" not in path and "router" not in path \
            and "conv" not in path
        if is_proj:
            name = _path_to_layer_name(path)
            prec = policy.lookup(name)
            if leaf.ndim == 2:
                qw = prep(leaf.astype(jnp.float32), prec)
                out.append(qw)
                quantized_paths.append(path)
                continue
            # Stacked (periods / experts) weights: vmap preparation over
            # leading dims.
            lead = leaf.shape[:-2]
            w2 = leaf.reshape((-1,) + leaf.shape[-2:]).astype(jnp.float32)
            qws = jax.vmap(lambda w: prep(w, prec))(w2)
            qws = jax.tree.map(
                lambda a: a.reshape(lead + a.shape[1:]), qws)
            out.append(qws)
            quantized_paths.append(path)
            continue
        out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out), quantized_paths


def _path_to_layer_name(path: str) -> str:
    # "['periods']['pos0']['attn']['q_proj']['w']" -> "layers.pos0.attn.q_proj"
    parts = [p.strip("'") for p in path.strip("[]").split("][")]
    if parts and parts[0] == "periods":
        parts = ["layers"] + parts[1:]
    if parts and parts[-1] == "w":
        parts = parts[:-1]
    return ".".join(parts)


def _validate_request(request: Request, max_len: int,
                      seen_uids: Set[int]) -> None:
    """The admission contract both engines share (one place to change):
    non-empty prompt, positive decode budget, fits the arena, fresh uid."""
    plen = len(request.prompt)
    if plen == 0:
        raise ValueError(f"request {request.uid}: empty prompt")
    if request.max_new_tokens < 1:
        raise ValueError(f"request {request.uid}: max_new_tokens must be "
                         f">= 1, got {request.max_new_tokens}")
    if plen + request.max_new_tokens > max_len:
        raise ValueError(
            f"request {request.uid}: prompt ({plen}) + max_new_tokens "
            f"({request.max_new_tokens}) exceeds max_len {max_len}")
    if request.uid in seen_uids:
        raise ValueError(f"request uid {request.uid} already submitted "
                         "(results are keyed by uid)")


def _params_prepared(params: Any) -> bool:
    return any(isinstance(l, ops.QuantizedWeight) for l in jax.tree.leaves(
        params, is_leaf=lambda x: isinstance(x, ops.QuantizedWeight)))


def _ensure_prepared(params: Any, rt: Runtime, model: LM,
                     packed: bool) -> Tuple[Any, List[str]]:
    """Weight preload shared by both engines: prepare the plane pytree once
    at construction unless the caller already did.  Returns (params, paths
    of QuantizedWeight leaves).  A Runtime carrying a PrecisionSchedule gets
    the superplane store (one 8-bit preload serving every tier)."""
    if rt.schedule is not None:
        if not _params_prepared(params):
            return prepare_params(params, rt.schedule.prepare_policy(), model,
                                  packed=packed, superplane=True)
    else:
        backend = rt.policy.default.backend
        if backend in ("decomposed", "pallas") and not _params_prepared(params):
            return prepare_params(params, rt.policy, model, packed=packed)
    paths = [jax.tree_util.keystr(kp) for kp, l in
             jax.tree_util.tree_flatten_with_path(
                 params, is_leaf=lambda x: isinstance(
                     x, ops.QuantizedWeight))[0]
             if isinstance(l, ops.QuantizedWeight)]
    return params, paths


@dataclasses.dataclass
class EngineStats:
    """Work accounting (the utilization story of the refactor).

    Tier accounting under mixed-tier batches: a decode step that serves
    several tiers at once counts its ``n_steps`` toward EVERY tier with an
    occupied slot (``decode_steps_by_tier``), while ``tokens_by_tier``
    counts only each tier's own active slot-steps.  ``tier_switches`` only
    moves in tier-serialized mode (mixed batches never switch);
    ``mixed_tier_chunks`` counts dispatches whose batch held >= 2 tiers.
    ``tier_migrations`` counts successful mid-stream ``set_tier`` calls on
    RUNNING requests; ``kv_migrations`` counts the subset that requantized
    a live KV lane (the tiers mapped to different KV precisions).

    Overload-control accounting: ``preemptions`` counts RUNNING slots
    suspended (snapshot + evict), ``resumes`` the prefill-free
    re-admissions of suspended requests (equal once the engine drains —
    every suspension either resumes or is cancelled), ``sheds`` the
    requests refused by admission control or cancelled by the caller, and
    ``spill_bytes`` the snapshot bytes persisted through the checkpoint
    spill path (0 when suspensions stay host-resident).
    ``time_slice_preemptions`` counts the voluntary yields of best-effort
    slots under ``SLOPolicy(time_slice=N)``.

    Speculative-decoding accounting (``Request.spec``): a round of draft
    depth k counts ``k`` draft-tier decode steps (``spec_draft_steps``)
    plus ONE verify window forward (``spec_verify_steps``) — both also
    roll into ``decode_steps`` (k+1 clock ticks per round).
    ``spec_drafted`` counts proposed draft tokens, ``spec_accepted`` the
    drafts that survived verification, and ``spec_emitted`` every token a
    speculative round emitted (accepted drafts + correction/bonus
    tokens), so ``spec_accepted / spec_drafted`` is the acceptance rate
    and ``spec_verify_steps / spec_emitted`` the verify-tier steps per
    emitted token (< 1 iff speculation beats plain decoding)."""

    prefills: int = 0
    prefill_tokens: int = 0        # real (unpadded) prompt tokens prefilled
    decode_steps: int = 0          # jitted model decode steps executed
    decode_chunks: int = 0         # jitted multi-step calls dispatched
    decode_slot_steps: int = 0     # sum over steps of active slots (useful)
    decode_idle_slot_steps: int = 0  # masked-out slot-steps (waste bound)
    tier_switches: int = 0         # decode-phase precision changes (serialized)
    mixed_tier_chunks: int = 0     # chunks serving >= 2 tiers in one batch
    tier_migrations: int = 0       # mid-stream set_tier on RUNNING requests
    kv_migrations: int = 0         # ... of which requantized a live KV lane
    tier_autoselects: int = 0      # deadline-driven admission-time retags
    preemptions: int = 0           # RUNNING slots suspended (snapshot+evict)
    resumes: int = 0               # prefill-free re-admissions of suspensions
    sheds: int = 0                 # admission-control refusals + cancels
    spill_bytes: int = 0           # snapshot bytes persisted via checkpoint
    time_slice_preemptions: int = 0  # voluntary best-effort time-slice yields
    spec_rounds: int = 0           # speculative rounds dispatched
    spec_draft_steps: int = 0      # draft-tier decode steps (k per round)
    spec_verify_steps: int = 0     # verify window forwards (1 per round)
    spec_drafted: int = 0          # draft tokens proposed (k per spec slot)
    spec_accepted: int = 0         # drafts accepted by verification
    spec_emitted: int = 0          # tokens emitted by speculative rounds
    layout_cache_hits: int = 0     # group-layout derivations skipped (cache)
    layout_cache_misses: int = 0   # group-layout derivations performed
    decode_steps_by_tier: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    tokens_by_tier: Dict[str, int] = dataclasses.field(default_factory=dict)
    # Dispatch-count observability (ServeEngine(count_dispatches=True)):
    # per group layout, the jaxpr ``pallas_call`` count of ONE jitted decode
    # step — with the fused grouped kernel this is CONSTANT in the number of
    # tier groups (asserted in tests/test_grouped_kernel.py).
    decode_dispatches: Dict[GroupLayout, int] = dataclasses.field(
        default_factory=dict)


@dataclasses.dataclass
class SuspendedState:
    """Host-side snapshot of one preempted request (``ServeEngine.preempt``).

    Everything a prefill-free resume needs: the request, the tokens already
    emitted, the decode budget still owed, the last emitted token (the next
    decode step's input), and the slot's batch-1 cache pytree — KV lanes
    (with their per-slot tier codes and lengths), scale rows, and SSM state
    — exactly as :func:`repro.serve.slots.slot_view` cut it from the arena.
    The snapshot is slot-agnostic: resume may write it into ANY free slot.

    ``cache`` holds the host (numpy) pytree, or None once the snapshot was
    spilled to disk through :mod:`repro.checkpoint` (``spill_step`` then
    names the checkpoint step under the engine's ``spill_dir``).
    ``nbytes`` is the snapshot's byte footprint either way.  ``draws`` is
    the slot's sampling draw counter at suspension — the resumed stream
    continues the request's private PRNG stream exactly where it stopped
    (token-identical to the uninterrupted sampled run)."""

    request: Request
    tokens: List[int]
    remaining: int
    last_token: int
    cache: Optional[Any]
    spill_step: Optional[int] = None
    nbytes: int = 0
    draws: int = 0


class _DeferredErrors:
    """Shared callback-error deferral: a raising user ``on_token`` callback
    must not abort a scheduling round midway (that would desync host slot
    bookkeeping from the already-advanced device state).  Engines route
    callback exceptions here (``RequestHandle._push(defer=...)``) and
    re-raise the FIRST one at the end of the round via
    :meth:`_raise_deferred` — engine-internal errors are never captured
    and propagate immediately."""

    _deferred_error: Optional[BaseException] = None

    def _defer_error(self, err: BaseException) -> None:
        if self._deferred_error is None:
            self._deferred_error = err

    def _raise_deferred(self) -> None:
        """Re-raise the first callback error of the round, once the
        round's host bookkeeping is complete and consistent."""
        if self._deferred_error is not None:
            err, self._deferred_error = self._deferred_error, None
            raise err


@runtime_checkable
class Engine(Protocol):
    """The serving surface both engines implement (see module docstring).

    ``submit`` validates + enqueues one request and returns its streaming
    handle; ``step`` runs one scheduling round and returns the tokens it
    emitted; ``drain`` steps until idle and returns every finished
    request's tokens; ``run`` is the blocking compatibility wrapper
    (submit all, drain, collect — token-identical to the historical API).
    ``clock`` is the deterministic scheduler clock (decode steps executed)
    every submission time, queue wait and ``Request.deadline`` is priced
    in.  ``cancel`` drops a request that has not finished running (QUEUED,
    or SUSPENDED on engines that preempt), flipping its handle to the
    terminal SHED state and releasing every scheduler entry it held."""

    def submit(self, request: Request) -> RequestHandle: ...

    def step(self) -> List[TokenEvent]: ...

    def drain(self) -> Dict[int, List[int]]: ...

    def run(self, requests: Sequence[Request]) -> Dict[int, List[int]]: ...

    def retire(self, uid: int) -> List[int]: ...

    def cancel(self, uid: int) -> None: ...

    @property
    def has_work(self) -> bool: ...

    @property
    def clock(self) -> float: ...


class ServeEngine(_DeferredErrors):
    """Continuous batching over ``max_batch`` persistent slots.

    Accepts a request stream (``submit`` any time; ``run`` a list for the
    blocking form); freed slots are re-prefilled individually against the
    shared cache arena while the other slots' caches stay untouched, and
    the decode inner loop is a single jitted multi-step scan
    (``decode_chunk`` steps per dispatch) with per-slot active masking.

    ``scheduler_policy`` picks WHICH waiting request takes a freed slot
    (``FIFOPolicy`` default; ``SLOPolicy`` for deadline-aware admission).

    With a ``PrecisionSchedule`` on the runtime, ``mixed_tiers`` selects the
    admission shape:

    * ``True`` (default) — tier-tagged slots: any free slot takes the
      policy's pick regardless of tier, and each decode chunk runs the
      occupied tiers TOGETHER via the per-row-group matmul path (a static
      ``(tier, rows)`` layout + a traced slot permutation, derived from
      ``SlotArena.tiers`` each step).  Only this mode supports mid-stream
      ``RequestHandle.set_tier`` on RUNNING requests.
    * ``False`` — the tier-serialized baseline: a decode batch runs at ONE
      tier and admission is restricted to matching requests (kept for the
      ``serve_mixed_tiers`` benchmark comparison).

    Constructor args that select jit behaviour (``decode_chunk``,
    ``prompt_bucket``, ``packed``, the schedule's tier/kv-mode sets) are
    static; everything that varies per request flows through traced
    arrays."""

    def __init__(self, model: LM, params: Any, rt: Runtime, *,
                 max_batch: int = 8, max_len: int = 512,
                 kv_bits: Optional[int] = None, decode_chunk: int = 8,
                 prompt_bucket: int = 8, packed: bool = False,
                 mixed_tiers: bool = True,
                 fused_decode: bool = True,
                 count_dispatches: bool = False,
                 scheduler_policy: Optional[SchedulerPolicy] = None,
                 mesh: Optional[Any] = None,
                 spill_dir: Optional[str] = None,
                 telemetry: Optional[Any] = None) -> None:
        self.model = model
        # ``fused_decode`` selects the mixed-tier grouped-matmul
        # implementation: one group-switching kernel (default) vs the
        # per-group dispatch loop (bit-identical reference).
        self.rt = dataclasses.replace(rt, fused=fused_decode)
        self.fused_decode = fused_decode
        self.count_dispatches = count_dispatches
        self.max_batch = max_batch
        self.max_len = max_len
        self.kv_bits = kv_bits
        self.decode_chunk = max(1, decode_chunk)
        self.prompt_bucket = max(1, prompt_bucket)
        self.mixed_tiers = mixed_tiers
        # Weight preload: the prepared plane pytree is the engine's ONLY
        # weight representation (prepared here unless already prepared).
        # With a PrecisionSchedule this is the 8-bit superplane store; every
        # tier below decodes against it with zero further preparation.
        self.params, self.quantized_paths = _ensure_prepared(
            params, rt, model, packed)
        self.schedule = rt.schedule
        # Tier-serialized mode only: the tier the decode batch currently
        # runs at; admission is restricted to it while any slot is occupied.
        self._active_tier: Optional[str] = None
        self._last_tier: Optional[str] = None

        # KV arena mode: a schedule with kv_tiers gets the mixed per-slot
        # arena (one byte-lane store serving every declared KV precision);
        # otherwise the engine-wide kv_bits applies to all slots.
        arena_kv: Any = kv_bits
        self._mixed_kv = False
        if self.schedule is not None and self.schedule.kv_tiers is not None:
            if kv_bits is not None:
                raise ValueError(
                    "kv_bits conflicts with the schedule's kv_tiers (per-"
                    "request KV precision); drop one of the two")
            arena_kv = self.schedule.kv_modes
            self._mixed_kv = True
        self.arena = slots_lib.SlotArena(model, max_batch, max_len,
                                         kv_bits=arena_kv)
        # Tensor-parallel serving (mesh=): shard the superplane store N-wise
        # and the KV arena over heads, validate divisibility, and place both
        # trees before any dispatch.  The jitted prefill/decode/migrate
        # functions below are then wrapped in shard_map with the quantized
        # collectives from distributed/tp_serve — token-identical to the
        # unsharded engine (the TP grouped path always runs the fused GEMM,
        # so ``fused_decode`` only affects the unsharded reference).
        self.mesh = mesh
        self._tp: Optional[tp_serve.TPConfig] = None
        if mesh is not None:
            self._tp = self._init_mesh_placement(mesh)
        self.scheduler = Scheduler(max_batch, policy=scheduler_policy)
        self.stats = EngineStats()
        # Observability (repro.telemetry.Telemetry, duck-typed so serve
        # never imports the telemetry package).  The contract: EVERY hook
        # call below is guarded by ``telemetry is not None`` and the engine
        # itself never fences — a telemetry-None engine runs the decode hot
        # loop with zero added host syncs, allocations, or hook calls.
        self.telemetry = telemetry
        if telemetry is not None:
            telemetry.attach_engine(
                num_slots=max_batch, schedule=self.schedule,
                mac_counts=model.cfg.quant_layer_macs()
                if self.schedule is not None else None)
        # Group-layout memo: slot-tier vector -> (groups, perm).  Recurring
        # mixed-batch layouts (the steady state) skip the per-step Python
        # sort; hits/misses are surfaced on EngineStats.
        self._layout_cache: Dict[Tuple[Optional[str], ...],
                                 Tuple[GroupLayout,
                                       npt.NDArray[np.int32]]] = {}
        self.handles: Dict[int, RequestHandle] = {}
        self._seen_uids: Set[int] = set()
        # Preemption state: uid -> host snapshot of the suspended slot.
        # ``spill_dir`` routes snapshots through the checkpoint subsystem
        # (async atomic step dirs) instead of holding them host-resident.
        self._suspended: Dict[int, SuspendedState] = {}
        self._spill_dir = spill_dir
        self._spiller: Optional[Any] = None        # lazy AsyncCheckpointer
        self._spill_counter = 0                    # monotonic spill step ids
        self._slot_template_cache: Optional[Any] = None
        self._in_round = False                     # guards preempt() reentry
        # Host-mirrored per-slot decode state.
        self._tok: npt.NDArray[np.int32] = np.zeros((max_batch,), np.int32)
        self._remaining: npt.NDArray[np.int32] = np.zeros((max_batch,),
                                                          np.int32)
        # Per-slot sampling state (repro.spec.sampling), mirrored on host
        # and passed traced into every decode dispatch: raw request PRNG
        # keys, draw counters, temperature, top-k.  Greedy slots keep
        # temperature 0 and never advance their counter, so the sampled
        # streams are pure functions of (seed, draw index) — independent
        # of slot assignment, batch composition and chunk boundaries.
        self._key: npt.NDArray[np.uint32] = np.zeros((max_batch, 2),
                                                     np.uint32)
        self._draws: npt.NDArray[np.int32] = np.zeros((max_batch,), np.int32)
        self._temp: npt.NDArray[np.float32] = np.zeros((max_batch,),
                                                       np.float32)
        self._topk: npt.NDArray[np.int32] = np.zeros((max_batch,), np.int32)
        # Time-slice fairness bookkeeping: the scheduler-clock tick each
        # slot's CURRENT occupancy began (set at admission AND at resume).
        self._slice_start: Dict[int, float] = {}
        mixed_kv = self._mixed_kv

        def prefill_slot(params: Any, caches: Any, slot: Any, tokens: Any,
                         length: Any, kv_code: Any, key: Any, temp: Any,
                         topk: Any, tier: Optional[str] = None,
                         tp: Optional[tp_serve.TPConfig] = None
                         ) -> Tuple[Any, Any]:
            """Admit one request: reset slot, prefill its prompt (right-
            padded to a bucket), write the batch-1 cache back into the
            arena.  ``tier`` is STATIC (retraces only per prompt bucket x
            tier); ``slot``, ``tokens``, ``length``, ``kv_code`` (the
            slot's KV tier, 16/8/4) and the sampling scalars (``key``
            uint32 [2], ``temp``, ``topk`` — draw counter 0 selects the
            request's FIRST token) are traced.  ``tp`` (static) is set
            only when called inside the mesh wrapper's shard_map body."""
            rt_eff = self.rt.for_tier(tier)
            if tp is not None:
                rt_eff = dataclasses.replace(rt_eff, tp=tp)
            sub = slots_lib.slot_view(caches, slot)
            sub = jax.tree.map(jnp.zeros_like, sub)     # per-slot reset
            if mixed_kv:
                sub = slots_lib.fill_kv_tier(sub, kv_code)
            logits, sub = self.model.prefill(
                params, rt_eff, sub, tokens=tokens,
                seq_lengths=length.reshape(1))
            caches = slots_lib.slot_write(caches, sub, slot)
            tok, _ = sampling_lib.sample_tokens(
                logits[:, -1], key[None, :], jnp.zeros((1,), jnp.int32),
                temp.reshape(1), topk.reshape(1))
            return tok[0], caches

        def decode_chunk_fn(params: Any, caches: Any, tok: Any,
                            remaining: Any, perm: Any, n_steps: int,
                            tier: Optional[str] = None,
                            groups: Optional[GroupLayout] = None,
                            tp: Optional[tp_serve.TPConfig] = None,
                            sampling: Optional[Tuple[Any, Any, Any, Any]]
                            = None) -> Any:
            """The single jitted inner loop: ``n_steps`` decode steps as one
            lax.scan with an active mask.  A slot's budget hitting zero
            freezes its cache (masked writes) THAT step; its lane still
            flows through the matmuls (dense batch) but produces no state
            change and no emitted token.

            Precision selection — both STATIC (they key the trace):
            ``groups`` (mixed-tier mode) is the ``(tier, rows)`` layout of
            the tier-sorted batch, served in ONE step via per-row-group
            plane-prefix GEMMs; ``tier`` (serialized mode) runs the whole
            batch at one tier.  ``perm`` (traced) maps batch rows into the
            sorted group order and changes per chunk without retracing.

            ``sampling`` — the traced ``(keys [B,2] uint32, draws [B]
            i32, temperature [B] f32, top_k [B] i32)`` tuple — moves
            token selection into the scan (``spec.sampling``): rows with
            temperature 0 still take the raw-logits argmax exactly, so a
            greedy batch stays bit-identical to the legacy path.  The
            engine always passes it; ``None`` keeps the historical
            trace/signature for direct lowering callers
            (``decode_dispatch_count`` and HLO-inspection tests) and
            returns the legacy 5-tuple without draw state."""
            if groups is not None:
                rt_eff = self.rt.for_groups(groups, perm)
            else:
                rt_eff = self.rt.for_tier(tier)
            if tp is not None:
                rt_eff = dataclasses.replace(rt_eff, tp=tp)

            if sampling is None:
                def step(carry: Any, _: Any) -> Any:
                    tok, caches, remaining = carry
                    active = remaining > 0
                    logits, caches = self.model.decode_step(
                        params, rt_eff, caches, tokens=tok[:, None],
                        active=active)
                    nxt = jnp.argmax(logits[:, -1],
                                     axis=-1).astype(jnp.int32)
                    tok = jnp.where(active, nxt, tok)
                    remaining = remaining - active.astype(jnp.int32)
                    return (tok, caches, remaining), (tok, active)

                (tok, caches, remaining), (toks, actives) = jax.lax.scan(
                    step, (tok, caches, remaining), None, length=n_steps)
                return caches, tok, remaining, toks, actives

            keys, draws, temp, topk = sampling

            def sstep(carry: Any, _: Any) -> Any:
                tok, caches, remaining, draws = carry
                active = remaining > 0
                logits, caches = self.model.decode_step(
                    params, rt_eff, caches, tokens=tok[:, None],
                    active=active)
                nxt, draws = sampling_lib.sample_tokens(
                    logits[:, -1], keys, draws, temp, topk, active=active)
                tok = jnp.where(active, nxt, tok)
                remaining = remaining - active.astype(jnp.int32)
                return (tok, caches, remaining, draws), (tok, active)

            (tok, caches, remaining, draws), (toks, actives) = jax.lax.scan(
                sstep, (tok, caches, remaining, draws), None, length=n_steps)
            return caches, tok, remaining, draws, toks, actives

        def spec_round_fn(params: Any, caches: Any, tok: Any,
                          remaining: Any, perm_draft: Any, perm_verify: Any,
                          spec_mask: Any,
                          sampling: Tuple[Any, Any, Any, Any], k: int,
                          draft_groups: GroupLayout,
                          verify_groups: GroupLayout) -> Any:
            """One speculative round: k chained draft steps at the draft
            layout, then ONE multi-token verify forward at the normal
            layout, acceptance, and cache rollback — all inside one jit.

            Draft phase: spec slots (``spec_mask``) decode at their draft
            tier (``draft_groups`` retags just their rows — a plane
            prefix of the same preloaded store, zero re-preparation)
            WITHOUT consuming budget; plain slots sharing the batch run
            these k steps as ordinary decode steps (their tokens/actives
            come back in ``dtoks``/``dact``).  The spec slots' draft-tier
            cache writes are then discarded (``slots.merge_slots``).

            Verify phase: the (k+1)-token window ``[t0, d1..dk]`` runs
            through ``model.verify_step`` — one batched forward whose
            position-j logits are bit-identical to sequential decode.
            Acceptance is rejection sampling against the verify-tier
            distributions (greedy rows degenerate to exact prefix match),
            ``e = min(m+1, remaining)`` tokens emit, and the KV/SSM lanes
            of rejected positions roll back (length truncation +
            stacked-step re-selection).  ``k`` and the two layouts are
            STATIC; masks, budgets, permutations and sampling state are
            traced."""
            keys, draws, temp, topk = sampling
            rt_draft = self.rt.for_groups(draft_groups, perm_draft)
            rt_verify = self.rt.for_groups(verify_groups, perm_verify)
            orig = caches
            tok0 = tok

            def draft_step(carry: Any, _: Any) -> Any:
                tok, caches, remaining, draws = carry
                active = remaining > 0
                plain_active = active & (~spec_mask)
                logits, caches = self.model.decode_step(
                    params, rt_draft, caches, tokens=tok[:, None],
                    active=active)
                row = logits[:, -1]
                qp = sampling_lib.sampling_probs(row, temp, topk)
                nxt, draws = sampling_lib.sample_tokens(
                    row, keys, draws, temp, topk, active=active)
                tok = jnp.where(active, nxt, tok)
                # Spec slots draft beyond their budget accounting: they
                # spend ``remaining`` only at emission (verify) time.
                remaining = remaining - plain_active.astype(jnp.int32)
                return (tok, caches, remaining, draws), (tok, plain_active,
                                                         qp)

            (tok, caches, remaining, draws), (dtoks, dact, qps) = \
                jax.lax.scan(draft_step, (tok, caches, remaining, draws),
                             None, length=k)

            # Discard the spec slots' draft-tier cache writes; plain slots
            # keep theirs (their draft-phase steps were real decode steps).
            caches = slots_lib.merge_slots(caches, orig, spec_mask)

            drafts = jnp.swapaxes(dtoks, 0, 1)                   # [B, k]
            window = jnp.concatenate([tok0[:, None], drafts], axis=1)
            vlogits, caches = self.model.verify_step(
                params, rt_verify, caches, tokens=window, active=spec_mask)

            batch, width = window.shape                  # width == k + 1
            p = sampling_lib.sampling_probs(
                vlogits.reshape(batch * width, -1),
                jnp.repeat(temp, width),
                jnp.repeat(topk, width)).reshape(batch, width, -1)
            q = jnp.swapaxes(qps, 0, 1)                       # [B, k, V]
            m = spec_lib.accept_counts(drafts, q, p, keys, draws)
            corr = spec_lib.correction_tokens(q, p, m, keys, draws)
            emit = spec_lib.emission_window(drafts, corr, m)
            e = jnp.where(spec_mask, jnp.minimum(m + 1, remaining), 0)

            # Rollback: rewind the KV lengths of rejected window positions
            # and re-select each slot's SSM state at its last emitted
            # position (plain rows: e == 0, mask False, stacked entries
            # all equal their pre-verify state — untouched either way).
            last_idx = jnp.clip(e - 1, 0, width - 1)
            caches = slots_lib.truncate_kv_lengths(
                caches, jnp.int32(width) - e, spec_mask)
            caches = slots_lib.select_verify_step(caches, last_idx)

            last = jnp.take_along_axis(emit, last_idx[:, None],
                                       axis=1)[:, 0]
            tok = jnp.where(spec_mask, last, tok)
            remaining = remaining - e
            spec_sampled = spec_mask & (temp > jnp.float32(0.0))
            draws = draws + jnp.where(
                spec_sampled,
                jnp.int32(spec_lib.accept_draw_events(k)), 0)
            return (caches, tok, remaining, draws, dtoks, dact, emit, e, m)

        # Un-jitted handle kept for trace-only introspection
        # (decode_dispatch_count): jax.make_jaxpr stages the step without
        # running it.  NOTE: it traces the UNSHARDED graph (tp=None) even
        # on a mesh engine — dispatch counts are a per-device property of
        # the kernels, not of the collectives around them.
        self._decode_chunk_fn = decode_chunk_fn
        # Speculative rounds run unsharded only (submit rejects spec on a
        # mesh engine with a clean error).
        self._spec_round = jax.jit(
            spec_round_fn,
            static_argnames=("k", "draft_groups", "verify_groups"))
        if self.mesh is None:
            self._prefill_slot = jax.jit(prefill_slot,
                                         static_argnames=("tier",))
            self._decode_chunk = jax.jit(decode_chunk_fn,
                                         static_argnames=("n_steps", "tier",
                                                          "groups"))
            # Mid-stream KV migration: one jitted requantize serves every
            # (slot, from-tier, to-tier) combination — slot and code are
            # traced.
            self._migrate_kv = jax.jit(slots_lib.migrate_kv_tier)
            # Preemption primitives: cut one slot out of the arena as a
            # batch-1 cache / write a snapshot back into ANY slot — both
            # with the slot index traced (one trace serves every slot).
            self._snapshot_slot = jax.jit(slots_lib.slot_view)
            self._restore_slot = jax.jit(slots_lib.slot_write)
        else:
            (self._prefill_slot, self._decode_chunk, self._migrate_kv,
             self._snapshot_slot, self._restore_slot) = self._mesh_wrap(
                 prefill_slot, decode_chunk_fn)

    # --------------------------------------------------------------- mesh TP
    def _init_mesh_placement(self, mesh: Any) -> tp_serve.TPConfig:
        """Validate the mesh against the model, derive the static TP
        context, and place the prepared store + slot arena.

        Every sharded weight is N-sharded on its last axis; the KV arena
        shards over KV heads when they divide, else (MQA ``num_kv_heads ==
        1``) stays replicated with only query heads sharded.  Divisibility
        is exact-or-error: a non-dividing axis raises here, at
        construction, not mid-stream."""
        if "model" not in mesh.axis_names:
            raise ValueError("serve TP needs a mesh with a 'model' axis, "
                             f"got axes {mesh.axis_names}")
        n = int(mesh.shape["model"])
        cfg = self.model.cfg
        if cfg.num_heads and cfg.num_heads % n != 0:
            raise ValueError(
                f"serve TP: num_heads={cfg.num_heads} does not divide "
                f"across {n} devices")
        kv_shards = bool(cfg.num_kv_heads) and cfg.num_kv_heads % n == 0
        if cfg.num_kv_heads and not kv_shards and cfg.num_kv_heads != 1:
            raise ValueError(
                f"serve TP: num_kv_heads={cfg.num_kv_heads} neither "
                f"divides across {n} devices nor is 1 (the replicated-MQA "
                "fallback)")
        tp = tp_serve.TPConfig(n=n, kv_shards=kv_shards)

        def flat_specs(tree: Any, spec_fn: Any) -> Tuple[Any, Any]:
            flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
            specs = tuple(
                spec_fn(jax.tree_util.keystr(kp), leaf, n=n,
                        kv_shards=kv_shards) for kp, leaf in flat)
            return specs, treedef

        self._p_specs, self._p_def = flat_specs(
            self.params, sharding_rules.serve_tp_param_spec)
        self._c_specs, self._c_def = flat_specs(
            self.arena.caches, sharding_rules.serve_tp_cache_spec)

        def place(tree: Any, specs: Any, treedef: Any) -> Any:
            shardings = jax.tree_util.tree_unflatten(
                treedef, [NamedSharding(mesh, s) for s in specs])
            return jax.device_put(tree, shardings)

        self.params = place(self.params, self._p_specs, self._p_def)
        self.arena.caches = place(self.arena.caches, self._c_specs,
                                  self._c_def)
        return tp

    def _mesh_wrap(self, prefill_slot: Any,
                   decode_chunk_fn: Any) -> Tuple[Any, Any, Any]:
        """Build the jitted shard_map twins of prefill/decode/migrate.

        The wrappers keep the EXACT call signatures ``step()`` /
        ``_admit_free_slots()`` / ``_set_tier()`` use, so the scheduling
        loop is mesh-oblivious: params/caches are flattened to leaf tuples
        (shard_map specs ride the flat tuples — no spec-filled dataclass
        containers), the body re-builds the trees and runs the same inner
        functions with ``tp`` set, and cache shards come back still
        sharded (out_specs = in_specs) so the arena never materializes
        unsharded."""
        mesh, tp = self.mesh, self._tp
        p_specs, p_def = self._p_specs, self._p_def
        c_specs, c_def = self._c_specs, self._c_def
        unflatten = jax.tree_util.tree_unflatten
        rep = P()

        def sharded_prefill(params: Any, caches: Any, slot: Any,
                            tokens: Any, length: Any, kv_code: Any,
                            key: Any, temp: Any, topk: Any,
                            tier: Optional[str] = None) -> Tuple[Any, Any]:
            fp = tuple(jax.tree.leaves(params))
            fc = tuple(jax.tree.leaves(caches))

            def body(fp: Any, fc: Any, slot: Any, tokens: Any, length: Any,
                     kv_code: Any, key: Any, temp: Any,
                     topk: Any) -> Tuple[Any, Any]:
                tok, out_c = prefill_slot(
                    unflatten(p_def, fp), unflatten(c_def, fc), slot,
                    tokens, length, kv_code, key, temp, topk, tier=tier,
                    tp=tp)
                return tok, tuple(jax.tree.leaves(out_c))

            tok, fc2 = shard_map(
                body, mesh=mesh,
                in_specs=(p_specs, c_specs, rep, rep, rep, rep, rep, rep,
                          rep),
                out_specs=(rep, c_specs), check_vma=False)(
                    fp, fc, slot, tokens, length, kv_code, key, temp, topk)
            return tok, unflatten(c_def, fc2)

        def sharded_decode(params: Any, caches: Any, tok: Any,
                           remaining: Any, perm: Any, n_steps: int,
                           tier: Optional[str] = None,
                           groups: Optional[GroupLayout] = None,
                           sampling: Optional[Tuple[Any, Any, Any, Any]]
                           = None) -> Any:
            fp = tuple(jax.tree.leaves(params))
            fc = tuple(jax.tree.leaves(caches))

            if sampling is None:        # legacy trace (lowering callers)
                def body(fp: Any, fc: Any, tok: Any, remaining: Any,
                         perm: Any) -> Any:
                    out_c, tok2, rem2, toks, act = decode_chunk_fn(
                        unflatten(p_def, fp), unflatten(c_def, fc), tok,
                        remaining, perm, n_steps, tier, groups, tp=tp)
                    return (tuple(jax.tree.leaves(out_c)), tok2, rem2,
                            toks, act)

                fc2, tok2, rem2, toks, act = shard_map(
                    body, mesh=mesh,
                    in_specs=(p_specs, c_specs, rep, rep, rep),
                    out_specs=(c_specs, rep, rep, rep, rep),
                    check_vma=False)(fp, fc, tok, remaining, perm)
                return unflatten(c_def, fc2), tok2, rem2, toks, act

            # Sampling state is replicated (a single ``rep`` prefix-spec
            # covers the whole tuple): every device computes the identical
            # threefry draws, so the sampled stream is mesh-width
            # independent by construction.
            def sbody(fp: Any, fc: Any, tok: Any, remaining: Any,
                      perm: Any, sampling: Any) -> Any:
                out_c, tok2, rem2, draws, toks, act = decode_chunk_fn(
                    unflatten(p_def, fp), unflatten(c_def, fc), tok,
                    remaining, perm, n_steps, tier, groups, tp=tp,
                    sampling=sampling)
                return (tuple(jax.tree.leaves(out_c)), tok2, rem2, draws,
                        toks, act)

            fc2, tok2, rem2, draws, toks, act = shard_map(
                sbody, mesh=mesh,
                in_specs=(p_specs, c_specs, rep, rep, rep, rep),
                out_specs=(c_specs, rep, rep, rep, rep, rep),
                check_vma=False)(fp, fc, tok, remaining, perm, sampling)
            return unflatten(c_def, fc2), tok2, rem2, draws, toks, act

        def sharded_migrate(caches: Any, slot: Any, code: Any) -> Any:
            fc = tuple(jax.tree.leaves(caches))

            def body(fc: Any, slot: Any, code: Any) -> Any:
                out = slots_lib.migrate_kv_tier(unflatten(c_def, fc), slot,
                                                code)
                return tuple(jax.tree.leaves(out))

            fc2 = shard_map(body, mesh=mesh, in_specs=(c_specs, rep, rep),
                            out_specs=c_specs, check_vma=False)(
                                fc, slot, code)
            return unflatten(c_def, fc2)

        # Preemption twins: slot_view/slot_write slice the SLOT axis, which
        # is never sharded, so the cache leaf specs apply to the batch-1
        # sub-tree unchanged — snapshots come back sharded exactly like the
        # arena (device_get then assembles the global snapshot), and a host
        # snapshot restores onto any slot with the arena staying sharded.
        def sharded_snapshot(caches: Any, slot: Any) -> Any:
            fc = tuple(jax.tree.leaves(caches))

            def body(fc: Any, slot: Any) -> Any:
                sub = slots_lib.slot_view(unflatten(c_def, fc), slot)
                return tuple(jax.tree.leaves(sub))

            fs = shard_map(body, mesh=mesh, in_specs=(c_specs, rep),
                           out_specs=c_specs, check_vma=False)(fc, slot)
            return unflatten(c_def, fs)

        def sharded_restore(caches: Any, sub: Any, slot: Any) -> Any:
            fc = tuple(jax.tree.leaves(caches))
            fs = tuple(jax.tree.leaves(sub))

            def body(fc: Any, fs: Any, slot: Any) -> Any:
                out = slots_lib.slot_write(unflatten(c_def, fc),
                                           unflatten(c_def, fs), slot)
                return tuple(jax.tree.leaves(out))

            fc2 = shard_map(body, mesh=mesh,
                            in_specs=(c_specs, c_specs, rep),
                            out_specs=c_specs, check_vma=False)(fc, fs, slot)
            return unflatten(c_def, fc2)

        return (jax.jit(sharded_prefill, static_argnames=("tier",)),
                jax.jit(sharded_decode,
                        static_argnames=("n_steps", "tier", "groups")),
                jax.jit(sharded_migrate),
                jax.jit(sharded_snapshot),
                jax.jit(sharded_restore))

    # ----------------------------------------------------- dispatch counting
    def decode_dispatch_count(self, *, groups: Optional[GroupLayout] = None,
                              tier: Optional[str] = None,
                              n_steps: int = 1) -> int:
        """Pallas dispatches of ONE jitted decode chunk for a given layout.

        Traces the decode step (``jax.make_jaxpr`` — nothing executes, no
        device work) and counts ``pallas_call`` equations, recursing into
        the scan body.  With the fused grouped path this count is CONSTANT
        in the number of tier groups; the per-group path pays one GEMM
        dispatch chain per group.  Keys ``EngineStats.decode_dispatches``
        when ``count_dispatches=True``."""
        perm = jnp.arange(self.max_batch, dtype=jnp.int32)

        def chunk(p: Any, c: Any, t: Any, r: Any, pm: Any) -> Any:
            return self._decode_chunk_fn(p, c, t, r, pm, n_steps, tier,
                                         groups)

        closed = jax.make_jaxpr(chunk)(
            self.params, self.arena.caches, jnp.asarray(self._tok),
            jnp.asarray(self._remaining), perm)
        return ops.count_pallas_calls(closed)

    # ------------------------------------------------------------------ clock
    @property
    def clock(self) -> float:
        """Deterministic scheduler clock: decode steps executed so far.
        Submission times, queue waits and ``Request.deadline`` are priced
        in these ticks."""
        return float(self.stats.decode_steps)

    @property
    def has_work(self) -> bool:
        """True while anything waits or decodes."""
        return self.scheduler.has_work

    def _sync_telemetry(self) -> None:
        """Mirror EngineStats into the telemetry registry (called after
        every state-changing op so the twins are ALWAYS consistent — the
        fuzz harness asserts equality after each operation)."""
        if self.telemetry is not None:
            self.telemetry.sync_stats(
                self.stats, queue_depth=self.scheduler.queue_depth)

    # ----------------------------------------------------------------- intake
    def submit(self, request: Request) -> RequestHandle:
        """Queue one request; returns its streaming :class:`RequestHandle`.

        Host-side: validates against engine limits.  On a tiered engine the
        queued copy always carries a concrete tier name (the schedule's
        default when the caller left it None).

        With an overload-controlling policy (``SLOPolicy(shed=True)``) the
        policy's admission decision runs HERE, before anything is queued: a
        deadline request whose projected completion exceeds modeled
        capacity is refused — its handle comes back already in the terminal
        SHED state (fail fast beats a guaranteed miss) — or, with
        ``auto_tier``, downtiered to the fastest-fitting tier (counted in
        ``EngineStats.tier_autoselects`` like any deadline-driven retag)."""
        _validate_request(request, self.max_len, self._seen_uids)
        if self.schedule is None:
            if request.tier is not None:
                raise ValueError(
                    f"request {request.uid}: tier {request.tier!r} on an "
                    "engine without a PrecisionSchedule")
            request = dataclasses.replace(request)
        else:
            # Normalize onto a copy: every QUEUED request carries a concrete
            # tier name, but the caller's object stays untouched.
            if request.tier is not None \
                    and request.tier not in self.schedule.tiers:
                raise ValueError(
                    f"request {request.uid}: unknown tier {request.tier!r}; "
                    f"engine serves {sorted(self.schedule.tiers)}")
            request = dataclasses.replace(
                request, tier=request.tier or self.schedule.default_tier)
        if request.sampling is not None:
            request.sampling.validate()
        if request.spec is not None:
            spec = request.spec
            spec.validate()
            if self.schedule is None:
                raise ValueError(
                    f"request {request.uid}: speculative decoding needs an "
                    "engine with a PrecisionSchedule (the draft tier is a "
                    "plane prefix of the superplane store)")
            if spec.draft_tier not in self.schedule.tiers:
                raise ValueError(
                    f"request {request.uid}: unknown draft tier "
                    f"{spec.draft_tier!r}; engine serves "
                    f"{sorted(self.schedule.tiers)}")
            if not self.mixed_tiers:
                raise ValueError(
                    f"request {request.uid}: speculative decoding needs "
                    "mixed_tiers=True (draft rows are retagged in the "
                    "decode group layout)")
            if self.mesh is not None:
                raise ValueError(
                    f"request {request.uid}: speculative decoding is not "
                    "supported on a mesh engine; submit without spec or "
                    "use an unsharded engine")
        self._seen_uids.add(request.uid)
        handle = RequestHandle(request, self, submitted_at=self.clock)
        self.handles[request.uid] = handle
        pol = self.scheduler.policy
        if isinstance(pol, SLOPolicy) and pol.shed:
            decision = pol.admission_decision(
                request, list(self.scheduler.waiting), self._running_info(),
                self.max_batch, self.scheduler.submitted_at, self.clock)
            if decision == "shed":
                handle._mark_shed(self.clock)
                self.stats.sheds += 1
                if self.telemetry is not None:
                    self.telemetry.on_submit(handle, ticks=self.clock)
                    self.telemetry.on_shed(handle, ticks=self.clock)
                self._sync_telemetry()
                return handle
            if decision != "admit":
                request.tier = decision        # our normalized copy
                self.stats.tier_autoselects += 1
        # Handle and scheduler share the SAME (normalized) Request object,
        # so a QUEUED set_tier re-tags the queue entry in place.
        self.scheduler.submit(request, now=self.clock)
        if self.telemetry is not None:
            self.telemetry.on_submit(handle, ticks=self.clock)
        self._sync_telemetry()
        return handle

    # -------------------------------------------------------------- migration
    def _set_tier(self, handle: RequestHandle, tier: str) -> None:
        """Move one request to another tier (``RequestHandle.set_tier``).

        QUEUED: re-tag the waiting request (it re-prices for SLO admission
        and prefills at the new tier).  RUNNING (mixed-tier mode only): if
        the tiers map to different KV precisions, requantize the slot's
        live KV lane in place (jitted; bit-identical to quantizing the
        slot's dequantized cache directly at the target precision), then
        re-tag the slot — the weight plane prefix switches at the next
        group-layout derivation.  FINISHED: error."""
        if self.schedule is None:
            raise ValueError("set_tier needs an engine with a "
                             "PrecisionSchedule")
        if tier not in self.schedule.tiers:
            raise ValueError(f"unknown tier {tier!r}; engine serves "
                             f"{sorted(self.schedule.tiers)}")
        if handle.done:
            raise RuntimeError(
                f"request {handle.uid} already {handle.status.value}; "
                "cannot migrate its tier")
        old = handle.request.tier
        if tier == old:
            return
        if handle.status is RequestStatus.SUSPENDED:
            raise RuntimeError(
                f"request {handle.uid} is suspended; its KV snapshot is "
                "pinned at its tier — let it resume (or cancel it) first")
        if handle.status is RequestStatus.QUEUED:
            handle.request.tier = tier      # shared with the queue entry
            return
        # RUNNING: live-slot migration.
        if not self.mixed_tiers:
            raise RuntimeError(
                "mid-stream tier migration needs mixed_tiers=True (a "
                "serialized decode batch runs one tier at a time)")
        slot = handle.slot
        assert slot is not None
        kv_migrated = False
        t0 = self.telemetry.wall() if self.telemetry is not None else 0.0
        if self._mixed_kv:
            new_code = self.schedule.kv_code_for(tier)
            if new_code != self.schedule.kv_code_for(old):
                self.arena.caches = self._migrate_kv(
                    self.arena.caches, jnp.int32(slot), jnp.int32(new_code))
                self.stats.kv_migrations += 1
                kv_migrated = True
        handle.request.tier = tier          # shared with the SlotState
        self.arena.tiers[slot] = tier
        self.stats.tier_migrations += 1
        if self.telemetry is not None:
            self.telemetry.on_migrate(
                uid=handle.uid, old_tier=old, new_tier=tier, kv=kv_migrated,
                ticks=self.clock, t0=t0 if kv_migrated else None,
                fence=self.arena.caches if kv_migrated else None)
        self._sync_telemetry()

    # ------------------------------------------------------------- preemption
    @property
    def suspended(self) -> Dict[int, SuspendedState]:
        """Read-only view of the live suspensions (uid -> snapshot)."""
        return dict(self._suspended)

    def _running_info(self) -> List[RunningEntry]:
        """The RUNNING slots as the overload-control hooks price them:
        ``(slot, request, decode tokens still owed, submission tick)``."""
        return [(slot, s.request, int(s.remaining),
                 self.handles[s.uid].submitted_at)
                for slot, s in self.scheduler.occupied()]

    def preempt(self, uid: int) -> SuspendedState:
        """Suspend a RUNNING request, freeing its slot.

        The slot's KV lane slice is cut out of the arena as a batch-1
        cache (``slot_view`` — every leaf, so the recurrent/SSM state
        rows, the KV tier code and the cache length ride along), pulled to
        host memory, and bundled with the host decode state (emitted
        tokens, owed budget, last emitted token — the next decode input)
        into a slot-agnostic :class:`SuspendedState`.  With ``spill_dir``
        the snapshot is persisted through the checkpoint subsystem
        (async, atomic step dirs) and dropped from host memory.

        The request re-enters the waiting queue at its ORIGINAL submission
        tick — a preemption never extends its deadline budget — and its
        handle flips to SUSPENDED.  Re-admission is prefill-free
        (``slot_write`` into whichever slot frees up) and the resumed
        stream is token-identical to the uninterrupted run.

        Preemption is only legal BETWEEN scheduling rounds: calling this
        from inside ``step()`` (e.g. an ``on_token`` callback) raises —
        mid-round the device cache has already advanced past the host
        token bookkeeping, so a snapshot there would tear the state."""
        if self._in_round:
            raise RuntimeError(
                "preempt() called from inside a scheduling round (e.g. an "
                "on_token callback); preemption is only legal between "
                "engine.step() calls")
        handle = self.handles.get(uid)
        if handle is None:
            raise KeyError(f"unknown uid {uid}")
        if handle.status is not RequestStatus.RUNNING:
            raise RuntimeError(
                f"request {uid} is {handle.status.value}; only RUNNING "
                "requests can be preempted")
        slot = handle.slot
        assert slot is not None
        state = self.scheduler.evict(slot)
        sub = self._snapshot_slot(self.arena.caches, jnp.int32(slot))
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), sub)
        nbytes = int(sum(leaf.nbytes for leaf in jax.tree.leaves(host)))
        sus = SuspendedState(
            request=state.request, tokens=list(state.tokens),
            remaining=int(state.remaining),
            last_token=int(self._tok[slot]), cache=host, nbytes=nbytes,
            draws=int(self._draws[slot]))
        if self._spill_dir is not None:
            sus = self._spill(sus)
        self._suspended[uid] = sus
        self.arena.tiers[slot] = None
        handle._mark_suspended()
        pol = self.scheduler.policy
        if isinstance(pol, SLOPolicy):
            # Re-pricing: a half-served stream owes only its remainder.
            pol.remaining_tokens[uid] = sus.remaining
        self.scheduler.submit(state.request, now=handle.submitted_at)
        self.stats.preemptions += 1
        if self.telemetry is not None:
            self.telemetry.on_suspend(handle, ticks=self.clock)
        self._sync_telemetry()
        return sus

    def _policy_preempt(self) -> None:
        """Run the policy's displacement rule between rounds
        (``SLOPolicy(preempt=True)``): while a deadlined waiting request
        is out of slack, free slots cannot absorb the queue, and a
        strictly-slacker RUNNING victim exists, suspend the victim.  The
        strict-inequality rule in :meth:`SLOPolicy.preempt_victim`
        guarantees termination (each displaced request re-enters the queue
        with MORE slack than the one it yielded to)."""
        pol = self.scheduler.policy
        if not isinstance(pol, SLOPolicy) or not pol.preempt:
            return
        for _ in range(self.max_batch):      # safety bound, never binding
            waiting = list(self.scheduler.waiting)
            urgent = [r for r in waiting if r.deadline is not None
                      and pol.weighted_slack(r, self.scheduler.submitted_at,
                                             self.clock) <= pol.preempt_slack]
            if len(self.scheduler.free_slots()) >= len(urgent):
                return             # this round's admission absorbs the urgent
            victim = pol.preempt_victim(
                waiting, self._running_info(),
                self.scheduler.submitted_at, self.clock)
            if victim is None:
                return
            self.preempt(victim)

    def _resume_into(self, slot: int, req: Request,
                     sus: SuspendedState) -> None:
        """Prefill-free re-admission: write the snapshot's batch-1 cache
        into the (freshly admitted, possibly different) slot and restore
        the host decode state exactly where preemption cut it."""
        cache = sus.cache if sus.cache is not None else self._unspill(sus)
        self.arena.caches = self._restore_slot(
            self.arena.caches, cache, jnp.int32(slot))
        self.arena.tiers[slot] = req.tier
        state = self.scheduler.slots[slot]
        assert state is not None
        state.tokens = list(sus.tokens)
        state.remaining = sus.remaining
        self._tok[slot] = sus.last_token
        self._remaining[slot] = sus.remaining
        self._load_sampling_state(slot, req, draws=sus.draws)
        self._slice_start[slot] = self.clock
        pol = self.scheduler.policy
        if isinstance(pol, SLOPolicy):
            pol.remaining_tokens.pop(req.uid, None)
        self.handles[req.uid]._mark_admitted(slot, self.clock)
        self.stats.resumes += 1
        if self.telemetry is not None:
            self.telemetry.on_admit(self.handles[req.uid], slot=slot,
                                    ticks=self.clock, resumed=True)

    def _load_sampling_state(self, slot: int, req: Request, *,
                             draws: int) -> None:
        """Load one slot's host-mirrored sampling state from its request
        (admission and resume share this): the raw request key, the draw
        counter (0 at fresh admission, the snapshot's at resume — the
        stream continues exactly where it stopped), temperature and
        top-k.  Greedy requests (no sampling / temperature 0) keep the
        all-zero state and never consume randomness."""
        sp = req.sampling
        seed = sp.seed if sp is not None else 0
        self._key[slot] = sampling_lib.request_key(seed)
        self._temp[slot] = np.float32(sp.temperature if sp is not None
                                      else 0.0)
        self._topk[slot] = sp.top_k if sp is not None else 0
        self._draws[slot] = draws

    def _slot_template(self) -> Any:
        """Shape/dtype skeleton of one slot's cache slice (restore target
        for spilled snapshots) — evaluated abstractly, cached."""
        if self._slot_template_cache is None:
            self._slot_template_cache = jax.eval_shape(
                lambda c: slots_lib.slot_view(c, jnp.int32(0)),
                self.arena.caches)
        return self._slot_template_cache

    def _spill(self, sus: SuspendedState) -> SuspendedState:
        """Persist a snapshot through the checkpoint subsystem and drop it
        from host memory.  ``keep=0`` disables the checkpointer's GC —
        live spills must never be collected out from under their
        suspended requests; :meth:`_unspill` removes each step dir as its
        request resumes."""
        assert self._spill_dir is not None
        if self._spiller is None:
            self._spiller = checkpoint_lib.AsyncCheckpointer(
                self._spill_dir, keep=0)
        step = self._spill_counter
        self._spill_counter += 1
        self._spiller.save(step, sus.cache, extra={
            "uid": sus.request.uid, "tokens": sus.tokens,
            "remaining": sus.remaining, "last_token": sus.last_token,
            "tier": sus.request.tier})
        self.stats.spill_bytes += sus.nbytes
        return dataclasses.replace(sus, cache=None, spill_step=step)

    def _unspill(self, sus: SuspendedState) -> Any:
        """Read a spilled snapshot back (waiting out the async writer) and
        delete its step dir — resumed spills do not accumulate on disk."""
        assert self._spiller is not None and sus.spill_step is not None \
            and self._spill_dir is not None
        self._spiller.wait()
        tree, _ = checkpoint_lib.restore(self._spill_dir, sus.spill_step,
                                         target=self._slot_template())
        checkpoint_lib.remove(self._spill_dir, sus.spill_step)
        return tree

    def cancel(self, uid: int) -> None:
        """Abort a QUEUED or SUSPENDED request: drop its queue entry (and
        its submission-clock entry — cancellation must not leak scheduler
        state), discard any snapshot/spill, and flip its handle to the
        terminal SHED state with whatever tokens it had streamed.

        RUNNING requests cannot be cancelled directly — preempt first (the
        slot state must be detached from the device before it can be
        discarded); already-terminal requests raise."""
        handle = self.handles.get(uid)
        if handle is None:
            raise KeyError(f"unknown uid {uid}")
        if handle.done:
            raise RuntimeError(
                f"request {uid} already {handle.status.value}")
        if handle.status is RequestStatus.RUNNING:
            raise RuntimeError(
                f"request {uid} is running; preempt it first (cancel only "
                "drops queued/suspended state)")
        self.scheduler.cancel(uid)
        sus = self._suspended.pop(uid, None)
        if sus is not None and sus.spill_step is not None:
            assert self._spiller is not None and self._spill_dir is not None
            self._spiller.wait()
            checkpoint_lib.remove(self._spill_dir, sus.spill_step)
        pol = self.scheduler.policy
        if isinstance(pol, SLOPolicy):
            pol.remaining_tokens.pop(uid, None)
        handle._mark_shed(self.clock)
        self.stats.sheds += 1
        if self.telemetry is not None:
            self.telemetry.on_shed(handle, ticks=self.clock)
        self._sync_telemetry()

    # ------------------------------------------------------------- scheduling
    def _bucket_pad(self,
                    prompt: npt.NDArray[np.int32]) -> Tuple[Any, int]:
        """Right-pad to the next bucket multiple (few jit retraces)."""
        plen = len(prompt)
        bucket = -(-plen // self.prompt_bucket) * self.prompt_bucket
        bucket = min(bucket, self.max_len)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :plen] = prompt
        return padded, plen

    def _emit_token(self, state: Any, token: int, tier: Optional[str],
                    speculative: bool = False) -> TokenEvent:
        """Record one emitted token on slot state + handle; returns the
        event.  ``final`` fires on the request's last owed token and flips
        its handle to FINISHED.

        ``tier`` is the tier the token was DECODED at (snapshotted at
        dispatch): a ``set_tier`` issued from an on_token callback
        mid-round must not relabel the round's remaining, already-computed
        tokens.  ``speculative`` marks tokens emitted by a speculative
        round (accepted drafts + corrections — all verified at ``tier``).
        A callback that raises is deferred to the end of the round
        (``_raise_deferred``) so slot bookkeeping stays in sync with the
        device state."""
        index = len(state.tokens)
        state.emit(token)
        sp = state.request.sampling
        event = TokenEvent(uid=state.uid, token=token, index=index,
                           tier=tier, final=state.done,
                           sampled=sp is not None and sp.temperature > 0.0,
                           speculative=speculative)
        self.handles[state.uid]._push(event, self.clock,
                                      defer=self._defer_error)
        if self.telemetry is not None:
            self.telemetry.on_token(event, ticks=self.clock)
        return event

    def _admit_free_slots(self) -> List[TokenEvent]:
        """Fill free slots from the waiting queue and prefill each admitted
        request individually (mixed-tier mode: the policy's pick into ANY
        slot; serialized mode: only requests matching the active tier).
        Returns the prefill-emitted first tokens as events.

        A SUSPENDED request that wins a slot resumes instead of
        prefilling: its snapshot is written back into the slot and its
        decode state picks up exactly where preemption cut it (no event —
        its already-emitted tokens were streamed before suspension)."""
        events: List[TokenEvent] = []
        for slot in self.scheduler.free_slots():
            if self.schedule is None or self.mixed_tiers:
                req = self.scheduler.admit(slot, now=self.clock)
            else:
                if self._active_tier is None:
                    # Idle decode batch: the policy's next pick chooses the
                    # next tier (FIFO across tier groups by default).
                    pick = self.scheduler.peek(now=self.clock)
                    if pick is None:
                        break
                    nxt = pick.tier
                    if self.stats.decode_chunks:
                        self.stats.tier_switches += nxt != self._last_tier
                    self._active_tier = nxt
                req = self.scheduler.admit(slot, tier=self._active_tier,
                                           now=self.clock)
            if req is None:
                break
            sus = self._suspended.pop(req.uid, None)
            if sus is not None:
                self._resume_into(slot, req, sus)
                continue
            self._auto_select_tier(req)
            padded, plen = self._bucket_pad(np.asarray(req.prompt))
            kv_code = self.schedule.kv_code_for(req.tier) \
                if self._mixed_kv else 0
            self._load_sampling_state(slot, req, draws=0)
            t0 = self.telemetry.wall() if self.telemetry is not None else 0.0
            tok, self.arena.caches = self._prefill_slot(
                self.params, self.arena.caches, jnp.int32(slot),
                jnp.asarray(padded), jnp.int32(plen), jnp.int32(kv_code),
                jnp.asarray(self._key[slot]),
                jnp.float32(self._temp[slot]),
                jnp.int32(self._topk[slot]), tier=req.tier)
            self.arena.tiers[slot] = req.tier
            self.stats.prefills += 1
            self.stats.prefill_tokens += plen
            if self.telemetry is not None:
                self.telemetry.on_prefill(
                    uid=req.uid, tier=req.tier, prompt_len=plen, t0=t0,
                    ticks=self.clock, fence=self.arena.caches)
            # The first token was draw event 0 (sampled rows only).
            if self._temp[slot] > 0.0:
                self._draws[slot] = 1
            self._slice_start[slot] = self.clock
            first = int(tok)
            state = self.scheduler.slots[slot]
            assert state is not None
            self.handles[req.uid]._mark_admitted(slot, self.clock)
            if self.telemetry is not None:
                self.telemetry.on_admit(self.handles[req.uid], slot=slot,
                                        ticks=self.clock)
            events.append(self._emit_token(state, first,
                                           req.tier))  # token 1 of max_new
            self._tok[slot] = first
            self._remaining[slot] = state.remaining
        return events

    def _auto_select_tier(self, req: Request) -> None:
        """Deadline-aware tier auto-selection at admission
        (``SLOPolicy(auto_tier=True)``): retag the just-admitted request —
        the same request-object retag a QUEUED ``set_tier`` performs, and
        still before its slot prefills, so the new tier drives the prefill
        dispatch, the slot's weight plane prefix AND its KV lane precision.
        Mixed-tier admission only: a serialized batch is pinned to its
        active tier.  Best-effort requests (no deadline) keep their
        requested tier."""
        pol = self.scheduler.policy
        if (self.schedule is None or not self.mixed_tiers
                or not isinstance(pol, SLOPolicy) or not pol.auto_tier):
            return
        tier = pol.select_tier(req, self.handles[req.uid].submitted_at,
                               self.clock)
        if tier is not None and tier != req.tier \
                and tier in self.schedule.tiers:
            req.tier = tier          # shared with handle and queue copy
            self.stats.tier_autoselects += 1

    def _release_done(self) -> None:
        """Release exhausted slots and clear their arena tier tags."""
        for slot in self.scheduler.release_done():
            self.arena.tiers[slot] = None

    def _group_layout(self, tiers: Optional[Sequence[Optional[str]]] = None
                      ) -> Tuple[GroupLayout, npt.NDArray[np.int32]]:
        """Derive the per-step mixed-tier layout from the slot tier tags.

        Returns ``(groups, perm)``: ``groups`` is the jit-STATIC tuple of
        ``(tier, rows)`` in schedule tier order (free slots ride along in
        the default tier's group — their lanes are masked anyway), ``perm``
        the TRACED int32 [B] slot order realizing it.  The jit key space is
        the set of tier multisets over ``max_batch`` slots, not the set of
        slot assignments.

        ``tiers`` overrides the arena's tier vector (same length) — the
        speculative draft phase derives its layout from a copy with the
        spec slots retagged to their draft tiers.

        Derivations are memoized on the slot-tier vector
        (``EngineStats.layout_cache_hits`` / ``layout_cache_misses``): the
        steady state of a serving loop repeats a handful of layouts, so the
        per-step host work collapses to one dict lookup."""
        schedule = self.schedule
        assert schedule is not None
        if tiers is None:
            tiers = self.arena.tiers
        cache_key = tuple(tiers)
        cached = self._layout_cache.get(cache_key)
        if cached is not None:
            self.stats.layout_cache_hits += 1
            return cached
        self.stats.layout_cache_misses += 1
        rank = {t: i for i, t in enumerate(schedule.tier_names)}
        default = schedule.default_tier
        slot_tiers = [t if t is not None else default for t in tiers]
        order = sorted(range(self.max_batch),
                       key=lambda s: (rank[slot_tiers[s]], s))
        groups: List[List[Any]] = []
        for s in order:
            t = slot_tiers[s]
            if groups and groups[-1][0] == t:
                groups[-1][1] += 1
            else:
                groups.append([t, 1])
        layout = (tuple((t, n) for t, n in groups),
                  np.asarray(order, np.int32))
        self._layout_cache[cache_key] = layout
        return layout

    # ------------------------------------------------------------------- run
    def step(self) -> List[TokenEvent]:
        """One scheduling round: admit into free slots, then run one jitted
        decode chunk (serving the occupied slots' tiers together in mixed
        mode, or the single active tier in serialized mode) and account its
        tokens.  Returns every token emitted this round (prefill first
        tokens + decode tokens, in emission order); an idle engine returns
        ``[]`` without dispatching anything.

        With ``SLOPolicy(preempt=True)`` the policy's displacement rule
        runs FIRST (between rounds — the only point a snapshot is
        coherent), so displaced slots free before admission fills the
        round's batch; ``_in_round`` then pins preemption out for the rest
        of the round (an ``on_token`` callback calling ``preempt`` would
        tear host state from the already-advanced device cache)."""
        if self.schedule is not None and not self.mixed_tiers:
            if not self.scheduler.occupied():
                if self._active_tier is not None:  # keep across idle steps
                    self._last_tier = self._active_tier
                self._active_tier = None           # batch drained: re-tier
        self._time_slice_preempt()
        self._policy_preempt()
        self._in_round = True
        try:
            return self._step_round()
        finally:
            self._in_round = False
            self._sync_telemetry()

    def _time_slice_preempt(self) -> None:
        """Time-slice fairness (``SLOPolicy(time_slice=N)``): between
        rounds, voluntarily preempt best-effort (deadline-free) RUNNING
        slots whose current slice has run at least N scheduler ticks while
        other requests wait.  Victims re-enter the queue aged as if
        submitted NOW (scheduler-side only — the handle keeps its true
        ``submitted_at``, so ``queue_wait`` semantics are untouched), so
        the waiting requests they yielded to win the FIFO/age tie-break
        and a two-request ping-pong cannot livelock the batch.  At most
        ``len(waiting)`` victims per round: slices never free more slots
        than there is demand for."""
        pol = self.scheduler.policy
        if not isinstance(pol, SLOPolicy) or pol.time_slice is None:
            return
        n_waiting = len(self.scheduler.waiting)
        if n_waiting == 0:
            return
        expired = [(self._slice_start.get(slot, self.clock), state.uid)
                   for slot, state in self.scheduler.occupied()
                   if state.request.deadline is None
                   and self.clock - self._slice_start.get(slot, self.clock)
                   >= pol.time_slice]
        expired.sort()                     # oldest slice first
        for _, uid in expired[:n_waiting]:
            self.preempt(uid)
            self.scheduler.submitted_at[uid] = self.clock
            self.stats.time_slice_preemptions += 1

    def _sampling_args(self) -> Tuple[Any, Any, Any, Any]:
        """The traced sampling-state tuple every decode dispatch takes."""
        return (jnp.asarray(self._key), jnp.asarray(self._draws),
                jnp.asarray(self._temp), jnp.asarray(self._topk))

    def _step_round(self) -> List[TokenEvent]:
        """The round body (see :meth:`step`): admit, decode, account."""
        events = self._admit_free_slots()
        self._release_done()                       # max_new_tokens == 1 cases
        occupied = self.scheduler.occupied()
        if not occupied:
            self._raise_deferred()
            return events
        if any(s.request.spec is not None for _, s in occupied):
            return self._spec_dispatch(occupied, events)
        # Trim the chunk so a tail of all-finished steps is never dispatched
        # (keyed per distinct length: at most decode_chunk jit entries).
        n_steps = int(min(self.decode_chunk,
                          max(s.remaining for _, s in occupied)))
        tele = self.telemetry
        groups: Optional[GroupLayout]
        if self.schedule is not None and self.mixed_tiers:
            groups, perm = self._group_layout()
            tier = None
            # A profiling telemetry wants the per-layout dispatch counts
            # too (same jaxpr counting, same memo dict).
            want_counts = self.count_dispatches or (
                tele is not None and tele.profiler is not None)
            if want_counts and groups not in self.stats.decode_dispatches:
                self.stats.decode_dispatches[groups] = \
                    self.decode_dispatch_count(groups=groups)
        else:
            groups, perm = None, np.zeros((self.max_batch,), np.int32)
            tier = self._active_tier
        t0 = tele.wall() if tele is not None else 0.0
        ticks0 = self.clock
        (self.arena.caches, tok, remaining, draws, toks, actives) = \
            self._decode_chunk(self.params, self.arena.caches,
                               jnp.asarray(self._tok),
                               jnp.asarray(self._remaining),
                               jnp.asarray(perm), n_steps=n_steps,
                               tier=tier, groups=groups,
                               sampling=self._sampling_args())
        self._tok = np.array(tok)            # copies: host arrays stay writable
        self._remaining = np.array(remaining)
        self._draws = np.array(draws)
        toks = np.asarray(toks)                   # [n_steps, B]
        actives = np.asarray(actives)
        self.stats.decode_chunks += 1
        self.stats.decode_steps += n_steps
        self.stats.decode_slot_steps += int(actives.sum())
        self.stats.decode_idle_slot_steps += int((~actives).sum())
        if self.schedule is not None:
            occupied_tiers = {self.arena.tiers[slot]
                              for slot, _ in occupied} if self.mixed_tiers \
                else {tier}
            self.stats.mixed_tier_chunks += len(occupied_tiers) > 1
            for t in occupied_tiers:
                assert t is not None    # tiered engines tag occupied slots
                by_tier = self.stats.decode_steps_by_tier
                by_tier[t] = by_tier.get(t, 0) + n_steps
            tk = self.stats.tokens_by_tier
            for slot, _ in occupied:
                t = self.arena.tiers[slot] if self.mixed_tiers else tier
                assert t is not None
                tk[t] = tk.get(t, 0) + int(actives[:, slot].sum())
        if tele is not None:
            # Free lanes carry tier None (priced at the schedule default —
            # the dense batch dispatches them either way).
            lanes = [(self.arena.tiers[s], int(actives[:, s].sum()))
                     for s in range(self.max_batch)]
            tele.on_decode_chunk(
                t0=t0, ticks0=ticks0, ticks_end=self.clock,
                n_steps=n_steps, lanes=lanes, groups=groups,
                fence=self.arena.caches,
                dispatches=self.stats.decode_dispatches.get(groups)
                if groups is not None else None)
        # Emission in true stream order (step-major): per-request order is
        # identical to the historical slot-major loop.  Event tiers are the
        # tiers the chunk DISPATCHED at (a set_tier from a callback must
        # not relabel tokens already computed at the old width).
        if self.schedule is None:
            etier: Dict[int, Optional[str]] = {s_: None for s_, _ in occupied}
        elif self.mixed_tiers:
            etier = {s_: self.arena.tiers[s_] for s_, _ in occupied}
        else:
            etier = {s_: tier for s_, _ in occupied}
        for s in range(n_steps):
            for slot, state in occupied:
                if actives[s, slot]:
                    events.append(self._emit_token(state, int(toks[s, slot]),
                                                   etier[slot]))
        self._release_done()
        self._raise_deferred()
        return events

    def _spec_dispatch(self, occupied: List[Tuple[int, Any]],
                       events: List[TokenEvent]) -> List[TokenEvent]:
        """One speculative scheduling round (any occupied slot with
        ``Request.spec`` routes the whole round here).

        Host side of ``spec_round_fn``: derive the draft layout (spec
        slots retagged to their draft tiers — zero weight re-preparation,
        the draft model is a plane prefix of the store), run the jitted
        round (k draft steps + ONE verify window forward + rollback), then
        emit — plain slots' draft-phase tokens step-major first (for them
        those were ordinary decode steps), then each spec slot's accepted
        window.  The scheduler clock advances k+1 ticks (k draft + 1
        verify).  Slots with different ``k`` share the round at the
        largest ``k`` (drafting deeper is harmless; acceptance is exact
        either way)."""
        spec_states = [(slot, s) for slot, s in occupied
                       if s.request.spec is not None]
        k = max(s.request.spec.k for _, s in spec_states)
        width = k + 1
        spec_mask = np.zeros((self.max_batch,), bool)
        draft_tiers = list(self.arena.tiers)
        for slot, s in spec_states:
            spec_mask[slot] = True
            draft_tiers[slot] = s.request.spec.draft_tier
        draft_groups, perm_d = self._group_layout(tiers=draft_tiers)
        verify_groups, perm_v = self._group_layout()
        tele = self.telemetry
        t0 = tele.wall() if tele is not None else 0.0
        ticks0 = self.clock
        (self.arena.caches, tok, remaining, draws, dtoks, dact, win, e,
         m) = self._spec_round(
            self.params, self.arena.caches, jnp.asarray(self._tok),
            jnp.asarray(self._remaining), jnp.asarray(perm_d),
            jnp.asarray(perm_v), jnp.asarray(spec_mask),
            self._sampling_args(), k=k, draft_groups=draft_groups,
            verify_groups=verify_groups)
        self._tok = np.array(tok)
        self._remaining = np.array(remaining)
        self._draws = np.array(draws)
        dtoks = np.asarray(dtoks)                       # [k, B]
        dact = np.asarray(dact)                         # [k, B]
        win = np.asarray(win)                           # [B, k+1]
        e = np.asarray(e)
        m = np.asarray(m)
        n_spec = len(spec_states)
        self.stats.decode_chunks += 1
        self.stats.decode_steps += width
        self.stats.spec_rounds += 1
        self.stats.spec_draft_steps += k
        self.stats.spec_verify_steps += 1
        self.stats.spec_drafted += k * n_spec
        self.stats.spec_accepted += int(
            np.minimum(m[spec_mask], e[spec_mask]).sum())
        self.stats.spec_emitted += int(e[spec_mask].sum())
        # Slot-step accounting identity (decode_slot_steps +
        # decode_idle_slot_steps == decode_steps * max_batch): spec slots
        # are busy all k+1 steps, plain slots their active draft steps.
        busy = int(dact.sum()) + width * n_spec
        self.stats.decode_slot_steps += busy
        self.stats.decode_idle_slot_steps += width * self.max_batch - busy
        by_tier = self.stats.decode_steps_by_tier
        draft_occ = {draft_tiers[slot] for slot, _ in occupied}
        verify_occ = {self.arena.tiers[slot] for slot, _ in occupied}
        for t in draft_occ:
            assert t is not None
            by_tier[t] = by_tier.get(t, 0) + k
        for t in verify_occ:
            assert t is not None
            by_tier[t] = by_tier.get(t, 0) + 1
        self.stats.mixed_tier_chunks += len(draft_occ | verify_occ) > 1
        tk = self.stats.tokens_by_tier
        for slot, _ in occupied:
            t = self.arena.tiers[slot]
            assert t is not None
            n = int(dact[:, slot].sum())
            if spec_mask[slot]:
                n += int(e[slot])
            if n:
                tk[t] = tk.get(t, 0) + n
        if tele is not None:
            # Spec slots are busy all k draft steps AND the verify step;
            # plain slots decode normally through the draft phase only.
            draft_lanes = [
                (draft_tiers[s], k if spec_mask[s]
                 else int(dact[:, s].sum())) for s in range(self.max_batch)]
            verify_lanes = [(self.arena.tiers[s], 1 if spec_mask[s] else 0)
                            for s in range(self.max_batch)]
            tele.on_spec_round(
                t0=t0, ticks0=ticks0, ticks_end=self.clock, k=k,
                draft_lanes=draft_lanes, verify_lanes=verify_lanes,
                fence=self.arena.caches, args={"n_spec": n_spec})
        # Emission: plain slots step-major through the draft phase, then
        # each spec slot's verified window (decoded AT the verify tier).
        etier = {slot: self.arena.tiers[slot] for slot, _ in occupied}
        for s_i in range(k):
            for slot, state in occupied:
                if dact[s_i, slot]:
                    events.append(self._emit_token(
                        state, int(dtoks[s_i, slot]), etier[slot]))
        for slot, state in spec_states:
            for j in range(int(e[slot])):
                events.append(self._emit_token(
                    state, int(win[slot, j]), etier[slot],
                    speculative=True))
        self._release_done()
        self._raise_deferred()
        return events

    def drain(self) -> Dict[int, List[int]]:
        """Step until idle; returns {uid: tokens} for every finished
        request (the streaming loop's terminal collect)."""
        while self.has_work:
            self.step()
        return dict(self.scheduler.finished)

    def run(self, requests: Sequence[Request]) -> Dict[int, List[int]]:
        """Blocking compatibility wrapper over the incremental core:
        submit every request, drain, collect — token-identical to the
        historical batch API.  A request shed at admission maps to its
        (empty) partial stream rather than raising."""
        for r in requests:
            self.submit(r)
        finished = self.drain()
        return {r.uid: finished.get(r.uid, list(self.handles[r.uid].tokens))
                for r in requests}

    def retire(self, uid: int) -> List[int]:
        """Drop a terminal (FINISHED or SHED) request's host state — its
        handle (buffered events + tokens), its results entry, and its uid
        reservation — and return the tokens (a SHED request's partial
        stream).

        This is the long-running server's bound on per-request host
        memory: handles and finished-token lists otherwise live for the
        engine's lifetime.  A retired uid may be submitted again."""
        handle = self.handles.get(uid)
        if handle is None:
            raise KeyError(f"unknown uid {uid}")
        if not handle.done:
            raise RuntimeError(f"request {uid} is {handle.status.value}; "
                               "only FINISHED/SHED requests can be retired")
        tokens = self.scheduler.finished.pop(uid, None)
        if tokens is None:
            tokens = list(handle.tokens)     # SHED: whatever was streamed
        # SHED requests may still own suspended-state residue (a request
        # cancelled while SUSPENDED frees it in cancel(); this is the
        # belt-and-braces path so retiring EVERY terminal request provably
        # leaves the engine empty — the fuzz harness asserts exactly that).
        sus = self._suspended.pop(uid, None)
        if sus is not None and sus.spill_step is not None:
            assert self._spiller is not None and self._spill_dir is not None
            self._spiller.wait()
            checkpoint_lib.remove(self._spill_dir, sus.spill_step)
        pol = self.scheduler.policy
        if isinstance(pol, SLOPolicy):
            pol.remaining_tokens.pop(uid, None)
        del self.handles[uid]
        self._seen_uids.discard(uid)
        return tokens

    @property
    def results(self) -> Dict[int, List[int]]:
        return dict(self.scheduler.finished)


@dataclasses.dataclass
class _BatchState:
    """Host state of the batch the reference engine currently decodes."""

    batch: List[Request]
    caches: Any
    tok: Any                      # [B] int32 device array
    outs: List[List[int]]
    step_idx: int
    max_new: int


class BatchServeEngine(_DeferredErrors):
    """Reference batch-at-a-time baseline (the seed's scheduling): admit up
    to ``max_batch`` requests, prefill them together, decode EVERY slot for
    the batch-wide ``max_new_tokens``, then refill the whole batch.

    Implements the same incremental ``submit`` / ``step`` / ``drain``
    surface as :class:`ServeEngine` (one ``step`` = one batch-wide decode
    step, starting a new batch when idle), with ``run`` as the blocking
    wrapper — so the :class:`Engine` protocol covers both.  Kept for parity
    tests and benchmarks: its outputs are exact per request (right-padded
    prefill with per-row true lengths), but finished slots keep burning
    decode steps until the batch max — the waste the continuous-batching
    engine eliminates.

    On a tiered runtime the baseline runs EVERY request at ONE fixed tier
    (``tier`` pins it; the schedule's default otherwise) — it has no
    per-request switching, and ``RequestHandle.set_tier`` on its handles
    always raises.  Its KV cache follows that tier's ``kv_tiers``
    precision when the schedule declares one (and ``kv_bits`` was left
    None), which makes it the fixed-precision reference for the mixed
    per-slot KV arena."""

    def __init__(self, model: LM, params: Any, rt: Runtime, *,
                 max_batch: int = 8, max_len: int = 512,
                 kv_bits: Optional[int] = None, packed: bool = False,
                 tier: Optional[str] = None,
                 telemetry: Optional[Any] = None) -> None:
        self.model = model
        if rt.schedule is not None and tier is not None \
                and tier not in rt.schedule.tiers:
            raise ValueError(f"unknown tier {tier!r}; engine serves "
                             f"{sorted(rt.schedule.tiers)}")
        self.tier_name: Optional[str] = None
        if rt.schedule is not None:
            if kv_bits is None:
                kv_bits = rt.schedule.kv_bits_for(tier)
            self.tier_name = tier if tier is not None \
                else rt.schedule.default_tier
            rt = rt.for_tier(tier)
        self.rt = rt
        self.params, _ = _ensure_prepared(params, rt, model, packed)
        self.max_batch = max_batch
        self.max_len = max_len
        self.kv_bits = kv_bits
        self.stats = EngineStats()
        # Minimal telemetry (lifecycle + stat twins; no device spans): the
        # baseline exists for parity runs, and ``--baseline --metrics``
        # should still export.
        self.telemetry = telemetry
        if telemetry is not None:
            telemetry.attach_engine(num_slots=max_batch,
                                    schedule=rt.schedule)
        self.handles: Dict[int, RequestHandle] = {}
        self.results: Dict[int, List[int]] = {}
        self._queue: List[Request] = []
        self._seen_uids: Set[int] = set()
        self._active: Optional[_BatchState] = None
        self._prefill = jax.jit(
            lambda p, c, t, ln: model.prefill(p, rt, c, tokens=t,
                                              seq_lengths=ln))
        self._decode = jax.jit(
            lambda p, c, t: model.decode_step(p, rt, c, tokens=t))

    # ------------------------------------------------------------------ clock
    @property
    def clock(self) -> float:
        """Scheduler clock: decode steps executed (same units as
        ServeEngine's)."""
        return float(self.stats.decode_steps)

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or self._active is not None

    # ----------------------------------------------------------------- intake
    def submit(self, request: Request) -> RequestHandle:
        """Queue one request (same admission contract as ServeEngine —
        :func:`_validate_request`); returns its handle.  Batches form in
        submission order, ``max_batch`` at a time, whenever ``step`` finds
        no active batch."""
        _validate_request(request, self.max_len, self._seen_uids)
        if request.spec is not None:
            raise ValueError(
                f"request {request.uid}: speculative decoding needs "
                "ServeEngine (the reference baseline has no draft/verify "
                "dispatch)")
        if request.sampling is not None:
            request.sampling.validate()
            if request.sampling.temperature > 0.0:
                raise ValueError(
                    f"request {request.uid}: temperature sampling needs "
                    "ServeEngine (the reference baseline decodes greedily); "
                    "temperature=0.0 SamplingParams are accepted as greedy")
        self._seen_uids.add(request.uid)
        handle = RequestHandle(request, self, submitted_at=self.clock)
        self.handles[request.uid] = handle
        self._queue.append(request)
        if self.telemetry is not None:
            self.telemetry.on_submit(handle, ticks=self.clock)
            self.telemetry.sync_stats(self.stats,
                                      queue_depth=len(self._queue))
        return handle

    def _set_tier(self, handle: RequestHandle, tier: str) -> None:
        raise RuntimeError(
            "BatchServeEngine pins one tier for every request; per-request "
            "tier migration needs ServeEngine (mixed_tiers=True)")

    def cancel(self, uid: int) -> None:
        """Abort a QUEUED request (the reference baseline has no
        preemption, so only not-yet-batched requests can be cancelled);
        flips its handle to the terminal SHED state."""
        handle = self.handles.get(uid)
        if handle is None:
            raise KeyError(f"unknown uid {uid}")
        if handle.done:
            raise RuntimeError(
                f"request {uid} already {handle.status.value}")
        if handle.status is not RequestStatus.QUEUED:
            raise RuntimeError(
                f"request {uid} is {handle.status.value}; BatchServeEngine "
                "can only cancel QUEUED requests (no preemption)")
        self._queue = [r for r in self._queue if r.uid != uid]
        handle._mark_shed(self.clock)
        self.stats.sheds += 1
        if self.telemetry is not None:
            self.telemetry.on_shed(handle, ticks=self.clock)
            self.telemetry.sync_stats(self.stats,
                                      queue_depth=len(self._queue))

    # ------------------------------------------------------------------- run
    def _start_batch(self) -> None:
        """Form + prefill the next batch (up to ``max_batch`` requests in
        submission order)."""
        batch = self._queue[: self.max_batch]
        self._queue = self._queue[self.max_batch:]
        b = len(batch)
        plen = max(len(r.prompt) for r in batch)
        prompts = np.zeros((b, plen), np.int32)
        lengths = np.zeros((b,), np.int32)
        for i, r in enumerate(batch):
            prompts[i, :len(r.prompt)] = r.prompt    # right-pad
            lengths[i] = len(r.prompt)
        caches = self.model.init_cache(b, self.max_len, kv_bits=self.kv_bits)
        t0 = self.telemetry.wall() if self.telemetry is not None else 0.0
        logits, caches = self._prefill(self.params, caches,
                                       jnp.asarray(prompts),
                                       jnp.asarray(lengths))
        self.stats.prefills += b
        self.stats.prefill_tokens += int(lengths.sum())
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        if self.telemetry is not None:
            # One batch-wide prefill dispatch (uid -1 = whole batch).
            self.telemetry.on_prefill(uid=-1, tier=self.tier_name,
                                      prompt_len=int(lengths.sum()), t0=t0,
                                      ticks=self.clock, fence=caches)
        for i, r in enumerate(batch):
            self.handles[r.uid]._mark_admitted(i, self.clock)
            if self.telemetry is not None:
                self.telemetry.on_admit(self.handles[r.uid], slot=i,
                                        ticks=self.clock)
        self._active = _BatchState(
            batch=batch, caches=caches, tok=tok,
            outs=[[] for _ in range(b)], step_idx=0,
            max_new=max(r.max_new_tokens for r in batch))

    def step(self) -> List[TokenEvent]:
        """One batch-wide decode step (starting a new batch when idle):
        emit the current token for every request still owed one, then
        advance the whole batch — finished slots keep burning decode work
        until the batch max (the baseline's defining waste).  Returns the
        emitted tokens; ``[]`` when fully idle."""
        if self._active is None:
            if not self._queue:
                return []
            self._start_batch()
        a = self._active
        assert a is not None
        events: List[TokenEvent] = []
        for i, r in enumerate(a.batch):
            if a.step_idx < r.max_new_tokens:
                token = int(a.tok[i])
                a.outs[i].append(token)
                event = TokenEvent(uid=r.uid, token=token, index=a.step_idx,
                                   tier=self.tier_name,
                                   final=a.step_idx == r.max_new_tokens - 1)
                events.append(event)
                self.handles[r.uid]._push(event, self.clock,
                                          defer=self._defer_error)
                if self.telemetry is not None:
                    self.telemetry.on_token(event, ticks=self.clock)
        ticks0 = self.clock
        t0 = self.telemetry.wall() if self.telemetry is not None else 0.0
        logits, a.caches = self._decode(self.params, a.caches, a.tok[:, None])
        a.tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        self.stats.decode_steps += 1
        self.stats.decode_slot_steps += len(a.batch)
        a.step_idx += 1
        if self.telemetry is not None:
            # Every batch lane burns the step (the baseline's defining
            # waste is visible as utilization 1.0 only while all requests
            # are still owed tokens).
            self.telemetry.on_decode_chunk(
                t0=t0, ticks0=ticks0, ticks_end=self.clock, n_steps=1,
                lanes=[(self.tier_name, 1) for _ in a.batch],
                fence=a.caches)
            self.telemetry.sync_stats(self.stats,
                                      queue_depth=len(self._queue))
        if a.step_idx >= a.max_new:
            for i, r in enumerate(a.batch):
                self.results[r.uid] = a.outs[i][: r.max_new_tokens]
            self._active = None
        self._raise_deferred()
        return events

    def drain(self) -> Dict[int, List[int]]:
        """Step until idle; returns {uid: tokens} for finished requests."""
        while self.has_work:
            self.step()
        return dict(self.results)

    def run(self, requests: Sequence[Request]) -> Dict[int, List[int]]:
        """Serve the list batch-at-a-time (blocking wrapper over
        submit/step/drain); returns {uid: tokens}.

        Validation is all-or-nothing (the historical contract): a bad
        request anywhere in the list raises before ANY of them is queued
        or its uid burned."""
        seen = set(self._seen_uids)
        for r in requests:
            _validate_request(r, self.max_len, seen)
            seen.add(r.uid)
        for r in requests:
            self.submit(r)
        finished = self.drain()
        return {r.uid: finished[r.uid] for r in requests}

    def retire(self, uid: int) -> List[int]:
        """Drop a terminal request's host state and release its uid (same
        contract as :meth:`ServeEngine.retire`)."""
        handle = self.handles.get(uid)
        if handle is None:
            raise KeyError(f"unknown uid {uid}")
        if not handle.done:
            raise RuntimeError(f"request {uid} is {handle.status.value}; "
                               "only FINISHED/SHED requests can be retired")
        tokens = self.results.pop(uid, None)
        if tokens is None:
            tokens = list(handle.tokens)     # SHED before batching: empty
        del self.handles[uid]
        self._seen_uids.discard(uid)
        return tokens
