"""Slot-based continuous-batching serving engine.

The paper's dataflow is "serial activation input, parallel weight
preloaded": decomposed weight planes stay resident while activations stream
through.  The engine mirrors that end to end:

* **Weight preload** — at construction the float params are converted ONCE
  into the ``QuantizedWeight`` plane pytree (``prepare_params``); that
  prepared pytree is the engine's only weight representation.
* **Runtime precision tiers** — with a ``PrecisionSchedule`` on the
  Runtime, the preload is a single 8-bit MSB-first *superplane* store and
  every decode dispatch picks effective (w_bits, a_bits) tiers by
  plane-prefix truncation.  Switching tiers costs zero weight
  re-preparation (``PREPARE_CALLS`` counts preparations — it must not move
  after construction).
* **Mixed-tier decode batches** — slots are tier-tagged: admission fills
  ANY free slot (plain FIFO), and each decode chunk derives a per-step
  group layout from the occupied slots' tiers — a jit-STATIC tuple of
  ``(tier, rows)`` sorted by tier, plus a TRACED permutation mapping batch
  rows into that order.  Every projection then runs one plane-prefix GEMM
  per group, so one jitted decode step serves slots at 8/6/4/2 bits
  simultaneously (see ``models.layers.linear``).  ``mixed_tiers=False``
  keeps the PR-2 tier-serialized admission (one tier per decode batch) as
  the comparison baseline.
* **Per-request KV precision** — a schedule with ``kv_tiers`` allocates one
  mixed per-slot KV arena: each admitted request's slot stores K/V at its
  tier's precision (bf16 / int8 / int4-packed lanes, per-slot scale rows),
  so a low tier shrinks its decode-memory footprint along with its
  weight-plane reads.
* **Persistent decode state** — a fixed-slot cache arena
  (:mod:`repro.serve.slots`): per-slot KV lengths and SSM states live in one
  pre-allocated pytree across the whole request stream.
* **Per-slot admission** — a freed slot is re-prefilled individually
  (:mod:`repro.serve.scheduler`); occupied slots keep decoding untouched.
* **On-device decode loop** — the inner loop is ONE jitted multi-step
  ``jax.lax.scan`` over a chunk of decode steps with an active-slot mask and
  masked cache writes; the host only admits/retires requests between
  chunks, so per-token dispatch overhead is off the critical path.

A slot stops consuming decode work the step its budget is exhausted (the
active mask), unlike batch-at-a-time scheduling where every slot decodes
until the batch-wide max (see :class:`BatchServeEngine`, kept as the
reference baseline).

Jit-static vs traced (the contract everything above hangs on): tier names,
group layouts, chunk lengths and prompt buckets are STATIC (they key
traces: at most |layouts| x decode_chunk decode entries); slot indices,
token ids, budgets, the group permutation and per-slot KV tier codes are
TRACED (they change every step without retracing).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import PrecisionPolicy
from repro.kernels import ops
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.serve import slots as slots_lib
from repro.serve.request import Request
from repro.serve.scheduler import Scheduler

__all__ = ["Request", "ServeEngine", "BatchServeEngine", "EngineStats",
           "prepare_params", "PREPARE_CALLS"]

# Global weight-preparation counter: every prepare_params call (one quantize+
# decompose sweep over the params) bumps it.  The runtime-tier contract —
# zero re-preparation after engine construction — is asserted against this
# in tests and the serve_precision_tiers / serve_mixed_tiers benchmarks.
PREPARE_CALLS = 0


def prepare_params(params, policy: PrecisionPolicy, model: LM,
                   packed: bool = False, superplane: bool = False):
    """Quantize + decompose every policy-covered projection weight offline.

    Returns a params pytree where 2D projection weights are replaced by
    QuantizedWeight planes (embeddings/norms stay dense).  ``superplane``
    prepares the runtime-reconfigurable store instead: 8-bit MSB-first
    planes regardless of the policy's per-layer w_bits (which then acts per
    decode dispatch via plane-prefix truncation)."""
    global PREPARE_CALLS
    PREPARE_CALLS += 1

    def prep(leaf, prec):
        if superplane:
            return ops.prepare_superplane(leaf, signed=prec.w_signed,
                                          packed=packed)
        return ops.prepare_weight(leaf, prec, packed=packed)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    quantized_paths = []
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        is_proj = path.endswith("['w']") and leaf.ndim >= 2 \
            and "embed" not in path and "router" not in path \
            and "conv" not in path
        if is_proj:
            name = _path_to_layer_name(path)
            prec = policy.lookup(name)
            if leaf.ndim == 2:
                qw = prep(leaf.astype(jnp.float32), prec)
                out.append(qw)
                quantized_paths.append(path)
                continue
            # Stacked (periods / experts) weights: vmap preparation over
            # leading dims.
            lead = leaf.shape[:-2]
            w2 = leaf.reshape((-1,) + leaf.shape[-2:]).astype(jnp.float32)
            qws = jax.vmap(lambda w: prep(w, prec))(w2)
            qws = jax.tree.map(
                lambda a: a.reshape(lead + a.shape[1:]), qws)
            out.append(qws)
            quantized_paths.append(path)
            continue
        out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out), quantized_paths


def _path_to_layer_name(path: str) -> str:
    # "['periods']['pos0']['attn']['q_proj']['w']" -> "layers.pos0.attn.q_proj"
    parts = [p.strip("'") for p in path.strip("[]").split("][")]
    if parts and parts[0] == "periods":
        parts = ["layers"] + parts[1:]
    if parts and parts[-1] == "w":
        parts = parts[:-1]
    return ".".join(parts)


def _params_prepared(params) -> bool:
    return any(isinstance(l, ops.QuantizedWeight) for l in jax.tree.leaves(
        params, is_leaf=lambda x: isinstance(x, ops.QuantizedWeight)))


def _ensure_prepared(params, rt: Runtime, model: LM, packed: bool):
    """Weight preload shared by both engines: prepare the plane pytree once
    at construction unless the caller already did.  Returns (params, paths
    of QuantizedWeight leaves).  A Runtime carrying a PrecisionSchedule gets
    the superplane store (one 8-bit preload serving every tier)."""
    if rt.schedule is not None:
        if not _params_prepared(params):
            return prepare_params(params, rt.schedule.prepare_policy(), model,
                                  packed=packed, superplane=True)
    else:
        backend = rt.policy.default.backend
        if backend in ("decomposed", "pallas") and not _params_prepared(params):
            return prepare_params(params, rt.policy, model, packed=packed)
    paths = [jax.tree_util.keystr(kp) for kp, l in
             jax.tree_util.tree_flatten_with_path(
                 params, is_leaf=lambda x: isinstance(
                     x, ops.QuantizedWeight))[0]
             if isinstance(l, ops.QuantizedWeight)]
    return params, paths


@dataclasses.dataclass
class EngineStats:
    """Work accounting (the utilization story of the refactor).

    Tier accounting under mixed-tier batches: a decode step that serves
    several tiers at once counts its ``n_steps`` toward EVERY tier with an
    occupied slot (``decode_steps_by_tier``), while ``tokens_by_tier``
    counts only each tier's own active slot-steps.  ``tier_switches`` only
    moves in tier-serialized mode (mixed batches never switch);
    ``mixed_tier_chunks`` counts dispatches whose batch held >= 2 tiers."""

    prefills: int = 0
    prefill_tokens: int = 0        # real (unpadded) prompt tokens prefilled
    decode_steps: int = 0          # jitted model decode steps executed
    decode_chunks: int = 0         # jitted multi-step calls dispatched
    decode_slot_steps: int = 0     # sum over steps of active slots (useful)
    decode_idle_slot_steps: int = 0  # masked-out slot-steps (waste bound)
    tier_switches: int = 0         # decode-phase precision changes (serialized)
    mixed_tier_chunks: int = 0     # chunks serving >= 2 tiers in one batch
    decode_steps_by_tier: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    tokens_by_tier: Dict[str, int] = dataclasses.field(default_factory=dict)


class ServeEngine:
    """Continuous batching over ``max_batch`` persistent slots.

    Accepts a request stream (``submit`` any time, or ``run`` a list);
    freed slots are re-prefilled individually against the shared cache
    arena while the other slots' caches stay untouched, and the decode
    inner loop is a single jitted multi-step scan (``decode_chunk`` steps
    per dispatch) with per-slot active masking.

    With a ``PrecisionSchedule`` on the runtime, ``mixed_tiers`` selects the
    admission policy:

    * ``True`` (default) — tier-tagged slots: any free slot takes the FIFO
      head regardless of tier, and each decode chunk runs the occupied
      tiers TOGETHER via the per-row-group matmul path (a static
      ``(tier, rows)`` layout + a traced slot permutation, derived from
      ``SlotArena.tiers`` each step).
    * ``False`` — the tier-serialized baseline: a decode batch runs at ONE
      tier and admission is restricted to matching requests (kept for the
      ``serve_mixed_tiers`` benchmark comparison).

    Constructor args that select jit behaviour (``decode_chunk``,
    ``prompt_bucket``, ``packed``, the schedule's tier/kv-mode sets) are
    static; everything that varies per request flows through traced
    arrays."""

    def __init__(self, model: LM, params, rt: Runtime, *, max_batch: int = 8,
                 max_len: int = 512, kv_bits: Optional[int] = None,
                 decode_chunk: int = 8, prompt_bucket: int = 8,
                 packed: bool = False, mixed_tiers: bool = True):
        self.model = model
        self.rt = rt
        self.max_batch = max_batch
        self.max_len = max_len
        self.kv_bits = kv_bits
        self.decode_chunk = max(1, decode_chunk)
        self.prompt_bucket = max(1, prompt_bucket)
        self.mixed_tiers = mixed_tiers
        # Weight preload: the prepared plane pytree is the engine's ONLY
        # weight representation (prepared here unless already prepared).
        # With a PrecisionSchedule this is the 8-bit superplane store; every
        # tier below decodes against it with zero further preparation.
        self.params, self.quantized_paths = _ensure_prepared(
            params, rt, model, packed)
        self.schedule = rt.schedule
        # Tier-serialized mode only: the tier the decode batch currently
        # runs at; admission is restricted to it while any slot is occupied.
        self._active_tier: Optional[str] = None
        self._last_tier: Optional[str] = None

        # KV arena mode: a schedule with kv_tiers gets the mixed per-slot
        # arena (one byte-lane store serving every declared KV precision);
        # otherwise the engine-wide kv_bits applies to all slots.
        arena_kv = kv_bits
        self._mixed_kv = False
        if self.schedule is not None and self.schedule.kv_tiers is not None:
            if kv_bits is not None:
                raise ValueError(
                    "kv_bits conflicts with the schedule's kv_tiers (per-"
                    "request KV precision); drop one of the two")
            arena_kv = self.schedule.kv_modes
            self._mixed_kv = True
        self.arena = slots_lib.SlotArena(model, max_batch, max_len,
                                         kv_bits=arena_kv)
        self.scheduler = Scheduler(max_batch)
        self.stats = EngineStats()
        self._seen_uids: set = set()
        # Host-mirrored per-slot decode state.
        self._tok = np.zeros((max_batch,), np.int32)
        self._remaining = np.zeros((max_batch,), np.int32)
        mixed_kv = self._mixed_kv

        def prefill_slot(params, caches, slot, tokens, length, kv_code,
                         tier=None):
            """Admit one request: reset slot, prefill its prompt (right-
            padded to a bucket), write the batch-1 cache back into the
            arena.  ``tier`` is STATIC (retraces only per prompt bucket x
            tier); ``slot``, ``tokens``, ``length`` and ``kv_code`` (the
            slot's KV tier, 16/8/4) are traced."""
            rt_eff = self.rt.for_tier(tier)
            sub = slots_lib.slot_view(caches, slot)
            sub = jax.tree.map(jnp.zeros_like, sub)     # per-slot reset
            if mixed_kv:
                sub = slots_lib.fill_kv_tier(sub, kv_code)
            logits, sub = self.model.prefill(
                params, rt_eff, sub, tokens=tokens,
                seq_lengths=length.reshape(1))
            caches = slots_lib.slot_write(caches, sub, slot)
            tok = jnp.argmax(logits[0, -1], axis=-1).astype(jnp.int32)
            return tok, caches

        def decode_chunk_fn(params, caches, tok, remaining, perm, n_steps,
                            tier=None, groups=None):
            """The single jitted inner loop: ``n_steps`` decode steps as one
            lax.scan with an active mask.  A slot's budget hitting zero
            freezes its cache (masked writes) THAT step; its lane still
            flows through the matmuls (dense batch) but produces no state
            change and no emitted token.

            Precision selection — both STATIC (they key the trace):
            ``groups`` (mixed-tier mode) is the ``(tier, rows)`` layout of
            the tier-sorted batch, served in ONE step via per-row-group
            plane-prefix GEMMs; ``tier`` (serialized mode) runs the whole
            batch at one tier.  ``perm`` (traced) maps batch rows into the
            sorted group order and changes per chunk without retracing."""
            if groups is not None:
                rt_eff = self.rt.for_groups(groups, perm)
            else:
                rt_eff = self.rt.for_tier(tier)

            def step(carry, _):
                tok, caches, remaining = carry
                active = remaining > 0
                logits, caches = self.model.decode_step(
                    params, rt_eff, caches, tokens=tok[:, None],
                    active=active)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                tok = jnp.where(active, nxt, tok)
                remaining = remaining - active.astype(jnp.int32)
                return (tok, caches, remaining), (tok, active)

            (tok, caches, remaining), (toks, actives) = jax.lax.scan(
                step, (tok, caches, remaining), None, length=n_steps)
            return caches, tok, remaining, toks, actives

        self._prefill_slot = jax.jit(prefill_slot,
                                     static_argnames=("tier",))
        self._decode_chunk = jax.jit(decode_chunk_fn,
                                     static_argnames=("n_steps", "tier",
                                                      "groups"))

    # ----------------------------------------------------------------- intake
    def submit(self, request: Request) -> None:
        """Queue one request (host-side; validates against engine limits).

        On a tiered engine the queued copy always carries a concrete tier
        name (the schedule's default when the caller left it None)."""
        plen = len(request.prompt)
        if plen == 0:
            raise ValueError(f"request {request.uid}: empty prompt")
        if request.max_new_tokens < 1:
            raise ValueError(f"request {request.uid}: max_new_tokens must be "
                             f">= 1, got {request.max_new_tokens}")
        if plen + request.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {request.uid}: prompt ({plen}) + max_new_tokens "
                f"({request.max_new_tokens}) exceeds max_len {self.max_len}")
        if request.uid in self._seen_uids:
            raise ValueError(f"request uid {request.uid} already submitted "
                             "(results are keyed by uid)")
        if self.schedule is None:
            if request.tier is not None:
                raise ValueError(
                    f"request {request.uid}: tier {request.tier!r} on an "
                    "engine without a PrecisionSchedule")
        else:
            # Normalize onto a copy: every QUEUED request carries a concrete
            # tier name, but the caller's object stays untouched.
            if request.tier is not None \
                    and request.tier not in self.schedule.tiers:
                raise ValueError(
                    f"request {request.uid}: unknown tier {request.tier!r}; "
                    f"engine serves {sorted(self.schedule.tiers)}")
            request = dataclasses.replace(
                request, tier=request.tier or self.schedule.default_tier)
        self._seen_uids.add(request.uid)
        self.scheduler.submit(request)

    def _bucket_pad(self, prompt: np.ndarray):
        """Right-pad to the next bucket multiple (few jit retraces)."""
        plen = len(prompt)
        bucket = -(-plen // self.prompt_bucket) * self.prompt_bucket
        bucket = min(bucket, self.max_len)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :plen] = prompt
        return padded, plen

    def _admit_free_slots(self) -> None:
        """Fill free slots from the waiting queue and prefill each admitted
        request individually (mixed-tier mode: plain FIFO into ANY slot;
        serialized mode: only requests matching the active tier)."""
        for slot in self.scheduler.free_slots():
            if self.schedule is None or self.mixed_tiers:
                req = self.scheduler.admit(slot)
            else:
                if self._active_tier is None:
                    # Idle decode batch: the oldest waiting request picks
                    # the next tier (FIFO across tier groups).
                    nxt = self.scheduler.next_tier()
                    if nxt is None:
                        break
                    if self.stats.decode_chunks:
                        self.stats.tier_switches += nxt != self._last_tier
                    self._active_tier = nxt
                req = self.scheduler.admit(slot, tier=self._active_tier)
            if req is None:
                break
            padded, plen = self._bucket_pad(np.asarray(req.prompt))
            kv_code = self.schedule.kv_code_for(req.tier) \
                if self._mixed_kv else 0
            tok, self.arena.caches = self._prefill_slot(
                self.params, self.arena.caches, jnp.int32(slot),
                jnp.asarray(padded), jnp.int32(plen), jnp.int32(kv_code),
                tier=req.tier)
            self.arena.tiers[slot] = req.tier
            self.stats.prefills += 1
            self.stats.prefill_tokens += plen
            first = int(tok)
            state = self.scheduler.slots[slot]
            state.emit(first)                     # token 1 of max_new
            self._tok[slot] = first
            self._remaining[slot] = state.remaining

    def _release_done(self) -> None:
        """Release exhausted slots and clear their arena tier tags."""
        for slot in self.scheduler.release_done():
            self.arena.tiers[slot] = None

    def _group_layout(self):
        """Derive the per-step mixed-tier layout from the slot tier tags.

        Returns ``(groups, perm)``: ``groups`` is the jit-STATIC tuple of
        ``(tier, rows)`` in schedule tier order (free slots ride along in
        the default tier's group — their lanes are masked anyway), ``perm``
        the TRACED int32 [B] slot order realizing it.  The jit key space is
        the set of tier multisets over ``max_batch`` slots, not the set of
        slot assignments."""
        rank = {t: i for i, t in enumerate(self.schedule.tier_names)}
        default = self.schedule.default_tier
        slot_tiers = [t if t is not None else default
                      for t in self.arena.tiers]
        order = sorted(range(self.max_batch),
                       key=lambda s: (rank[slot_tiers[s]], s))
        groups: List[List[Any]] = []
        for s in order:
            t = slot_tiers[s]
            if groups and groups[-1][0] == t:
                groups[-1][1] += 1
            else:
                groups.append([t, 1])
        return (tuple((t, n) for t, n in groups),
                np.asarray(order, np.int32))

    # ------------------------------------------------------------------- run
    def step(self) -> None:
        """One scheduling round: admit into free slots, then run one jitted
        decode chunk (serving the occupied slots' tiers together in mixed
        mode, or the single active tier in serialized mode) and account its
        tokens."""
        if self.schedule is not None and not self.mixed_tiers:
            if not self.scheduler.occupied():
                if self._active_tier is not None:  # keep across idle steps
                    self._last_tier = self._active_tier
                self._active_tier = None           # batch drained: re-tier
        self._admit_free_slots()
        self._release_done()                       # max_new_tokens == 1 cases
        occupied = self.scheduler.occupied()
        if not occupied:
            return
        # Trim the chunk so a tail of all-finished steps is never dispatched
        # (keyed per distinct length: at most decode_chunk jit entries).
        n_steps = int(min(self.decode_chunk,
                          max(s.remaining for _, s in occupied)))
        if self.schedule is not None and self.mixed_tiers:
            groups, perm = self._group_layout()
            tier = None
        else:
            groups, perm = None, np.zeros((self.max_batch,), np.int32)
            tier = self._active_tier
        (self.arena.caches, tok, remaining, toks, actives) = \
            self._decode_chunk(self.params, self.arena.caches,
                               jnp.asarray(self._tok),
                               jnp.asarray(self._remaining),
                               jnp.asarray(perm), n_steps=n_steps,
                               tier=tier, groups=groups)
        self._tok = np.array(tok)            # copies: host arrays stay writable
        self._remaining = np.array(remaining)
        toks = np.asarray(toks)                   # [n_steps, B]
        actives = np.asarray(actives)
        self.stats.decode_chunks += 1
        self.stats.decode_steps += n_steps
        self.stats.decode_slot_steps += int(actives.sum())
        self.stats.decode_idle_slot_steps += int((~actives).sum())
        if self.schedule is not None:
            occupied_tiers = {self.arena.tiers[slot]
                              for slot, _ in occupied} if self.mixed_tiers \
                else {tier}
            self.stats.mixed_tier_chunks += len(occupied_tiers) > 1
            for t in occupied_tiers:
                by_tier = self.stats.decode_steps_by_tier
                by_tier[t] = by_tier.get(t, 0) + n_steps
            tk = self.stats.tokens_by_tier
            for slot, _ in occupied:
                t = self.arena.tiers[slot] if self.mixed_tiers else tier
                tk[t] = tk.get(t, 0) + int(actives[:, slot].sum())
        for slot, state in occupied:
            for s in range(n_steps):
                if actives[s, slot]:
                    state.emit(int(toks[s, slot]))
        self._release_done()

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Serve a request list to completion (streaming entrypoint:
        ``submit`` + repeated ``step`` + ``results``)."""
        for r in requests:
            self.submit(r)
        while self.scheduler.has_work:
            self.step()
        return {uid: self.scheduler.finished[uid]
                for uid in (r.uid for r in requests)}

    @property
    def results(self) -> Dict[int, List[int]]:
        return dict(self.scheduler.finished)


class BatchServeEngine:
    """Reference batch-at-a-time baseline (the seed's scheduling): admit up
    to ``max_batch`` requests, prefill them together, decode EVERY slot for
    the batch-wide ``max_new_tokens``, then refill the whole batch.

    Kept for parity tests and benchmarks: its outputs are exact per request
    (right-padded prefill with per-row true lengths), but finished slots
    keep burning decode steps until the batch max — the waste the
    continuous-batching engine eliminates.

    On a tiered runtime the baseline runs EVERY request at ONE fixed tier
    (``tier`` pins it; the schedule's default otherwise) — it has no
    per-request switching.  Its KV cache follows that tier's ``kv_tiers``
    precision when the schedule declares one (and ``kv_bits`` was left
    None), which makes it the fixed-precision reference for the mixed
    per-slot KV arena."""

    def __init__(self, model: LM, params, rt: Runtime, *, max_batch: int = 8,
                 max_len: int = 512, kv_bits: Optional[int] = None,
                 packed: bool = False, tier: Optional[str] = None):
        self.model = model
        if rt.schedule is not None and tier is not None \
                and tier not in rt.schedule.tiers:
            raise ValueError(f"unknown tier {tier!r}; engine serves "
                             f"{sorted(rt.schedule.tiers)}")
        if rt.schedule is not None:
            if kv_bits is None:
                kv_bits = rt.schedule.kv_bits_for(tier)
            rt = rt.for_tier(tier)
        self.rt = rt
        self.params, _ = _ensure_prepared(params, rt, model, packed)
        self.max_batch = max_batch
        self.max_len = max_len
        self.kv_bits = kv_bits
        self.stats = EngineStats()
        self._prefill = jax.jit(
            lambda p, c, t, ln: model.prefill(p, rt, c, tokens=t,
                                              seq_lengths=ln))
        self._decode = jax.jit(
            lambda p, c, t: model.decode_step(p, rt, c, tokens=t))

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Serve the list batch-at-a-time; returns {uid: tokens}."""
        for r in requests:   # same admission contract as ServeEngine.submit
            if len(r.prompt) == 0:
                raise ValueError(f"request {r.uid}: empty prompt")
            if r.max_new_tokens < 1:
                raise ValueError(f"request {r.uid}: max_new_tokens must be "
                                 f">= 1, got {r.max_new_tokens}")
            if len(r.prompt) + r.max_new_tokens > self.max_len:
                raise ValueError(
                    f"request {r.uid}: prompt ({len(r.prompt)}) + "
                    f"max_new_tokens ({r.max_new_tokens}) exceeds max_len "
                    f"{self.max_len}")
        results: Dict[int, List[int]] = {}
        queue = list(requests)
        while queue:
            batch = queue[: self.max_batch]
            queue = queue[self.max_batch:]
            results.update(self._run_batch(batch))
        return results

    def _run_batch(self, batch: List[Request]) -> Dict[int, List[int]]:
        b = len(batch)
        plen = max(len(r.prompt) for r in batch)
        prompts = np.zeros((b, plen), np.int32)
        lengths = np.zeros((b,), np.int32)
        for i, r in enumerate(batch):
            prompts[i, :len(r.prompt)] = r.prompt    # right-pad
            lengths[i] = len(r.prompt)
        caches = self.model.init_cache(b, self.max_len, kv_bits=self.kv_bits)
        logits, caches = self._prefill(self.params, caches,
                                       jnp.asarray(prompts),
                                       jnp.asarray(lengths))
        self.stats.prefills += b
        self.stats.prefill_tokens += int(lengths.sum())
        max_new = max(r.max_new_tokens for r in batch)
        outs = [[] for _ in range(b)]
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        for step in range(max_new):
            for i, r in enumerate(batch):
                if step < r.max_new_tokens:
                    outs[i].append(int(tok[i]))
            logits, caches = self._decode(self.params, caches, tok[:, None])
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            self.stats.decode_steps += 1
            self.stats.decode_slot_steps += b
        return {r.uid: outs[i][: r.max_new_tokens]
                for i, r in enumerate(batch)}
