"""Slot-based continuous-batching serving engine.

The paper's dataflow is "serial activation input, parallel weight
preloaded": decomposed weight planes stay resident while activations stream
through.  The engine mirrors that end to end:

* **Weight preload** — at construction the float params are converted ONCE
  into the ``QuantizedWeight`` plane pytree (``prepare_params``); that
  prepared pytree is the engine's only weight representation.
* **Runtime precision tiers** — with a ``PrecisionSchedule`` on the
  Runtime, the preload is a single 8-bit MSB-first *superplane* store and
  every decode dispatch picks an effective (w_bits, a_bits) tier by
  plane-prefix truncation: requests carry a tier, the scheduler groups
  compatible tiers into a decode batch, and switching tiers costs zero
  weight re-preparation (``PREPARE_CALLS`` counts preparations — it must
  not move after construction).
* **Persistent decode state** — a fixed-slot cache arena
  (:mod:`repro.serve.slots`): per-slot KV lengths and SSM states live in one
  pre-allocated pytree across the whole request stream.
* **Per-slot admission** — a freed slot is re-prefilled individually
  (:mod:`repro.serve.scheduler`); occupied slots keep decoding untouched.
* **On-device decode loop** — the inner loop is ONE jitted multi-step
  ``jax.lax.scan`` over a chunk of decode steps with an active-slot mask and
  masked cache writes; the host only admits/retires requests between
  chunks, so per-token dispatch overhead is off the critical path.

A slot stops consuming decode work the step its budget is exhausted (the
active mask), unlike batch-at-a-time scheduling where every slot decodes
until the batch-wide max (see :class:`BatchServeEngine`, kept as the
reference baseline).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import PrecisionPolicy
from repro.kernels import ops
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.serve import slots as slots_lib
from repro.serve.request import Request
from repro.serve.scheduler import ANY_TIER, Scheduler

__all__ = ["Request", "ServeEngine", "BatchServeEngine", "EngineStats",
           "prepare_params", "PREPARE_CALLS"]

# Global weight-preparation counter: every prepare_params call (one quantize+
# decompose sweep over the params) bumps it.  The runtime-tier contract —
# zero re-preparation after engine construction — is asserted against this
# in tests and the serve_precision_tiers benchmark.
PREPARE_CALLS = 0


def prepare_params(params, policy: PrecisionPolicy, model: LM,
                   packed: bool = False, superplane: bool = False):
    """Quantize + decompose every policy-covered projection weight offline.

    Returns a params pytree where 2D projection weights are replaced by
    QuantizedWeight planes (embeddings/norms stay dense).  ``superplane``
    prepares the runtime-reconfigurable store instead: 8-bit MSB-first
    planes regardless of the policy's per-layer w_bits (which then acts per
    decode dispatch via plane-prefix truncation)."""
    global PREPARE_CALLS
    PREPARE_CALLS += 1

    def prep(leaf, prec):
        if superplane:
            return ops.prepare_superplane(leaf, signed=prec.w_signed,
                                          packed=packed)
        return ops.prepare_weight(leaf, prec, packed=packed)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    quantized_paths = []
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        is_proj = path.endswith("['w']") and leaf.ndim >= 2 \
            and "embed" not in path and "router" not in path \
            and "conv" not in path
        if is_proj:
            name = _path_to_layer_name(path)
            prec = policy.lookup(name)
            if leaf.ndim == 2:
                qw = prep(leaf.astype(jnp.float32), prec)
                out.append(qw)
                quantized_paths.append(path)
                continue
            # Stacked (periods / experts) weights: vmap preparation over
            # leading dims.
            lead = leaf.shape[:-2]
            w2 = leaf.reshape((-1,) + leaf.shape[-2:]).astype(jnp.float32)
            qws = jax.vmap(lambda w: prep(w, prec))(w2)
            qws = jax.tree.map(
                lambda a: a.reshape(lead + a.shape[1:]), qws)
            out.append(qws)
            quantized_paths.append(path)
            continue
        out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out), quantized_paths


def _path_to_layer_name(path: str) -> str:
    # "['periods']['pos0']['attn']['q_proj']['w']" -> "layers.pos0.attn.q_proj"
    parts = [p.strip("'") for p in path.strip("[]").split("][")]
    if parts and parts[0] == "periods":
        parts = ["layers"] + parts[1:]
    if parts and parts[-1] == "w":
        parts = parts[:-1]
    return ".".join(parts)


def _params_prepared(params) -> bool:
    return any(isinstance(l, ops.QuantizedWeight) for l in jax.tree.leaves(
        params, is_leaf=lambda x: isinstance(x, ops.QuantizedWeight)))


def _ensure_prepared(params, rt: Runtime, model: LM, packed: bool):
    """Weight preload shared by both engines: prepare the plane pytree once
    at construction unless the caller already did.  Returns (params, paths
    of QuantizedWeight leaves).  A Runtime carrying a PrecisionSchedule gets
    the superplane store (one 8-bit preload serving every tier)."""
    if rt.schedule is not None:
        if not _params_prepared(params):
            return prepare_params(params, rt.schedule.prepare_policy(), model,
                                  packed=packed, superplane=True)
    else:
        backend = rt.policy.default.backend
        if backend in ("decomposed", "pallas") and not _params_prepared(params):
            return prepare_params(params, rt.policy, model, packed=packed)
    paths = [jax.tree_util.keystr(kp) for kp, l in
             jax.tree_util.tree_flatten_with_path(
                 params, is_leaf=lambda x: isinstance(
                     x, ops.QuantizedWeight))[0]
             if isinstance(l, ops.QuantizedWeight)]
    return params, paths


@dataclasses.dataclass
class EngineStats:
    """Work accounting (the utilization story of the refactor)."""

    prefills: int = 0
    prefill_tokens: int = 0        # real (unpadded) prompt tokens prefilled
    decode_steps: int = 0          # jitted model decode steps executed
    decode_chunks: int = 0         # jitted multi-step calls dispatched
    decode_slot_steps: int = 0     # sum over steps of active slots (useful)
    decode_idle_slot_steps: int = 0  # masked-out slot-steps (waste bound)
    tier_switches: int = 0         # decode-phase precision changes
    decode_steps_by_tier: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    tokens_by_tier: Dict[str, int] = dataclasses.field(default_factory=dict)


class ServeEngine:
    """Continuous batching over ``max_batch`` persistent slots.

    Accepts a request stream (``submit`` any time, or ``run`` a list);
    freed slots are re-prefilled individually against the shared cache
    arena while the other slots' caches stay untouched, and the decode
    inner loop is a single jitted multi-step scan (``decode_chunk`` steps
    per dispatch) with per-slot active masking."""

    def __init__(self, model: LM, params, rt: Runtime, *, max_batch: int = 8,
                 max_len: int = 512, kv_bits: Optional[int] = None,
                 decode_chunk: int = 8, prompt_bucket: int = 8,
                 packed: bool = False):
        self.model = model
        self.rt = rt
        self.max_batch = max_batch
        self.max_len = max_len
        self.kv_bits = kv_bits
        self.decode_chunk = max(1, decode_chunk)
        self.prompt_bucket = max(1, prompt_bucket)
        # Weight preload: the prepared plane pytree is the engine's ONLY
        # weight representation (prepared here unless already prepared).
        # With a PrecisionSchedule this is the 8-bit superplane store; every
        # tier below decodes against it with zero further preparation.
        self.params, self.quantized_paths = _ensure_prepared(
            params, rt, model, packed)
        self.schedule = rt.schedule
        # The tier the decode batch currently runs at (schedule mode only):
        # admission is restricted to this tier while any slot is occupied.
        self._active_tier: Optional[str] = None
        self._last_tier: Optional[str] = None

        self.arena = slots_lib.SlotArena(model, max_batch, max_len,
                                         kv_bits=kv_bits)
        self.scheduler = Scheduler(max_batch)
        self.stats = EngineStats()
        self._seen_uids: set = set()
        # Host-mirrored per-slot decode state.
        self._tok = np.zeros((max_batch,), np.int32)
        self._remaining = np.zeros((max_batch,), np.int32)

        def prefill_slot(params, caches, slot, tokens, length, tier=None):
            """Admit one request: reset slot, prefill its prompt (right-
            padded to a bucket), write the batch-1 cache back into the
            arena.  Retraces only per (prompt bucket x tier)."""
            rt_eff = self.rt.for_tier(tier)
            sub = slots_lib.slot_view(caches, slot)
            sub = jax.tree.map(jnp.zeros_like, sub)     # per-slot reset
            logits, sub = self.model.prefill(
                params, rt_eff, sub, tokens=tokens,
                seq_lengths=length.reshape(1))
            caches = slots_lib.slot_write(caches, sub, slot)
            tok = jnp.argmax(logits[0, -1], axis=-1).astype(jnp.int32)
            return tok, caches

        def decode_chunk_fn(params, caches, tok, remaining, n_steps,
                            tier=None):
            """The single jitted inner loop: ``n_steps`` decode steps as one
            lax.scan with an active mask.  A slot's budget hitting zero
            freezes its cache (masked writes) THAT step; its lane still
            flows through the matmuls (dense batch) but produces no state
            change and no emitted token.  ``tier`` (static) selects the
            effective precision: the same weight store, a different plane
            prefix / activation depth — at most tiers x decode_chunk traces."""
            rt_eff = self.rt.for_tier(tier)

            def step(carry, _):
                tok, caches, remaining = carry
                active = remaining > 0
                logits, caches = self.model.decode_step(
                    params, rt_eff, caches, tokens=tok[:, None],
                    active=active)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                tok = jnp.where(active, nxt, tok)
                remaining = remaining - active.astype(jnp.int32)
                return (tok, caches, remaining), (tok, active)

            (tok, caches, remaining), (toks, actives) = jax.lax.scan(
                step, (tok, caches, remaining), None, length=n_steps)
            return caches, tok, remaining, toks, actives

        self._prefill_slot = jax.jit(prefill_slot,
                                     static_argnames=("tier",))
        self._decode_chunk = jax.jit(decode_chunk_fn,
                                     static_argnames=("n_steps", "tier"))

    # ----------------------------------------------------------------- intake
    def submit(self, request: Request) -> None:
        plen = len(request.prompt)
        if plen == 0:
            raise ValueError(f"request {request.uid}: empty prompt")
        if request.max_new_tokens < 1:
            raise ValueError(f"request {request.uid}: max_new_tokens must be "
                             f">= 1, got {request.max_new_tokens}")
        if plen + request.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {request.uid}: prompt ({plen}) + max_new_tokens "
                f"({request.max_new_tokens}) exceeds max_len {self.max_len}")
        if request.uid in self._seen_uids:
            raise ValueError(f"request uid {request.uid} already submitted "
                             "(results are keyed by uid)")
        if self.schedule is None:
            if request.tier is not None:
                raise ValueError(
                    f"request {request.uid}: tier {request.tier!r} on an "
                    "engine without a PrecisionSchedule")
        else:
            # Normalize onto a copy: every QUEUED request carries a concrete
            # tier name, but the caller's object stays untouched.
            if request.tier is not None \
                    and request.tier not in self.schedule.tiers:
                raise ValueError(
                    f"request {request.uid}: unknown tier {request.tier!r}; "
                    f"engine serves {sorted(self.schedule.tiers)}")
            request = dataclasses.replace(
                request, tier=request.tier or self.schedule.default_tier)
        self._seen_uids.add(request.uid)
        self.scheduler.submit(request)

    def _bucket_pad(self, prompt: np.ndarray):
        """Right-pad to the next bucket multiple (few jit retraces)."""
        plen = len(prompt)
        bucket = -(-plen // self.prompt_bucket) * self.prompt_bucket
        bucket = min(bucket, self.max_len)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :plen] = prompt
        return padded, plen

    def _admit_free_slots(self) -> None:
        for slot in self.scheduler.free_slots():
            if self.schedule is None:
                req = self.scheduler.admit(slot)
            else:
                if self._active_tier is None:
                    # Idle decode batch: the oldest waiting request picks
                    # the next tier (FIFO across tier groups).
                    nxt = self.scheduler.next_tier()
                    if nxt is None:
                        break
                    if self.stats.decode_chunks:
                        self.stats.tier_switches += nxt != self._last_tier
                    self._active_tier = nxt
                req = self.scheduler.admit(slot, tier=self._active_tier)
            if req is None:
                break
            padded, plen = self._bucket_pad(np.asarray(req.prompt))
            tok, self.arena.caches = self._prefill_slot(
                self.params, self.arena.caches, jnp.int32(slot),
                jnp.asarray(padded), jnp.int32(plen), tier=req.tier)
            self.stats.prefills += 1
            self.stats.prefill_tokens += plen
            first = int(tok)
            state = self.scheduler.slots[slot]
            state.emit(first)                     # token 1 of max_new
            self._tok[slot] = first
            self._remaining[slot] = state.remaining

    # ------------------------------------------------------------------- run
    def step(self) -> None:
        """One scheduling round: admit into free slots, then run one jitted
        decode chunk (at the active precision tier, if tiered) and account
        its tokens."""
        if not self.scheduler.occupied():
            if self._active_tier is not None:     # keep across idle steps
                self._last_tier = self._active_tier
            self._active_tier = None              # batch drained: re-tier
        self._admit_free_slots()
        self.scheduler.release_done()             # max_new_tokens == 1 cases
        occupied = self.scheduler.occupied()
        if not occupied:
            return
        # Trim the chunk so a tail of all-finished steps is never dispatched
        # (keyed per distinct length: at most decode_chunk jit entries).
        n_steps = int(min(self.decode_chunk,
                          max(s.remaining for _, s in occupied)))
        (self.arena.caches, tok, remaining, toks, actives) = \
            self._decode_chunk(self.params, self.arena.caches,
                               jnp.asarray(self._tok),
                               jnp.asarray(self._remaining), n_steps=n_steps,
                               tier=self._active_tier)
        self._tok = np.array(tok)            # copies: host arrays stay writable
        self._remaining = np.array(remaining)
        toks = np.asarray(toks)                   # [n_steps, B]
        actives = np.asarray(actives)
        self.stats.decode_chunks += 1
        self.stats.decode_steps += n_steps
        self.stats.decode_slot_steps += int(actives.sum())
        self.stats.decode_idle_slot_steps += int((~actives).sum())
        if self._active_tier is not None:
            by_tier = self.stats.decode_steps_by_tier
            by_tier[self._active_tier] = \
                by_tier.get(self._active_tier, 0) + n_steps
            tk = self.stats.tokens_by_tier
            tk[self._active_tier] = \
                tk.get(self._active_tier, 0) + int(actives.sum())
        for slot, state in occupied:
            for s in range(n_steps):
                if actives[s, slot]:
                    state.emit(int(toks[s, slot]))
        self.scheduler.release_done()

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Serve a request list to completion (streaming entrypoint:
        ``submit`` + repeated ``step`` + ``results``)."""
        for r in requests:
            self.submit(r)
        while self.scheduler.has_work:
            self.step()
        return {uid: self.scheduler.finished[uid]
                for uid in (r.uid for r in requests)}

    @property
    def results(self) -> Dict[int, List[int]]:
        return dict(self.scheduler.finished)


class BatchServeEngine:
    """Reference batch-at-a-time baseline (the seed's scheduling): admit up
    to ``max_batch`` requests, prefill them together, decode EVERY slot for
    the batch-wide ``max_new_tokens``, then refill the whole batch.

    Kept for parity tests and benchmarks: its outputs are exact per request
    (right-padded prefill with per-row true lengths), but finished slots
    keep burning decode steps until the batch max — the waste the
    continuous-batching engine eliminates."""

    def __init__(self, model: LM, params, rt: Runtime, *, max_batch: int = 8,
                 max_len: int = 512, kv_bits: Optional[int] = None,
                 packed: bool = False, tier: Optional[str] = None):
        self.model = model
        if rt.schedule is not None and tier is not None \
                and tier not in rt.schedule.tiers:
            raise ValueError(f"unknown tier {tier!r}; engine serves "
                             f"{sorted(rt.schedule.tiers)}")
        # The baseline runs EVERY request at one fixed tier (it has no
        # per-request switching); ``tier`` pins it, default tier otherwise.
        rt = rt.for_tier(tier) if rt.schedule is not None else rt
        self.rt = rt
        self.params, _ = _ensure_prepared(params, rt, model, packed)
        self.max_batch = max_batch
        self.max_len = max_len
        self.kv_bits = kv_bits
        self.stats = EngineStats()
        self._prefill = jax.jit(
            lambda p, c, t, ln: model.prefill(p, rt, c, tokens=t,
                                              seq_lengths=ln))
        self._decode = jax.jit(
            lambda p, c, t: model.decode_step(p, rt, c, tokens=t))

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        for r in requests:   # same admission contract as ServeEngine.submit
            if len(r.prompt) == 0:
                raise ValueError(f"request {r.uid}: empty prompt")
            if r.max_new_tokens < 1:
                raise ValueError(f"request {r.uid}: max_new_tokens must be "
                                 f">= 1, got {r.max_new_tokens}")
            if len(r.prompt) + r.max_new_tokens > self.max_len:
                raise ValueError(
                    f"request {r.uid}: prompt ({len(r.prompt)}) + "
                    f"max_new_tokens ({r.max_new_tokens}) exceeds max_len "
                    f"{self.max_len}")
        results: Dict[int, List[int]] = {}
        queue = list(requests)
        while queue:
            batch = queue[: self.max_batch]
            queue = queue[self.max_batch:]
            results.update(self._run_batch(batch))
        return results

    def _run_batch(self, batch: List[Request]) -> Dict[int, List[int]]:
        b = len(batch)
        plen = max(len(r.prompt) for r in batch)
        prompts = np.zeros((b, plen), np.int32)
        lengths = np.zeros((b,), np.int32)
        for i, r in enumerate(batch):
            prompts[i, :len(r.prompt)] = r.prompt    # right-pad
            lengths[i] = len(r.prompt)
        caches = self.model.init_cache(b, self.max_len, kv_bits=self.kv_bits)
        logits, caches = self._prefill(self.params, caches,
                                       jnp.asarray(prompts),
                                       jnp.asarray(lengths))
        self.stats.prefills += b
        self.stats.prefill_tokens += int(lengths.sum())
        max_new = max(r.max_new_tokens for r in batch)
        outs = [[] for _ in range(b)]
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        for step in range(max_new):
            for i, r in enumerate(batch):
                if step < r.max_new_tokens:
                    outs[i].append(int(tok[i]))
            logits, caches = self._decode(self.params, caches, tok[:, None])
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            self.stats.decode_steps += 1
            self.stats.decode_slot_steps += b
        return {r.uid: outs[i][: r.max_new_tokens]
                for i, r in enumerate(batch)}
