"""Request handles: the streaming half of the serving API.

``Engine.submit`` returns a :class:`RequestHandle` — the caller's view of
one in-flight request.  The handle exposes

* **streamed tokens** — ``handle.tokens`` (everything emitted so far), an
  iterator (``for tok in handle`` drives ``engine.step()`` until the next
  token arrives), and a callback hook (``handle.on_token(fn)``);
* **terminal status** — ``handle.status`` walks ``QUEUED -> RUNNING ->
  FINISHED`` (with ``SUSPENDED`` excursions under preemption and ``SHED``
  as the overload-control terminal); ``handle.result()`` drives the engine
  to completion and returns the full token list;
* **mid-stream tier migration** — ``handle.set_tier(name)`` re-prices a
  QUEUED request or migrates a RUNNING slot (weight plane-prefix switch at
  the next group-layout derivation + an in-place requantization of the
  slot's live KV lane).

Everything here is host-side bookkeeping: handles never touch traced
state directly — they delegate to the engine that minted them.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Iterator, List, Optional, Protocol

from repro.serve.request import Request


class RequestStatus(enum.Enum):
    """Lifecycle of a submitted request (host-side).

    ``QUEUED -> RUNNING -> FINISHED`` is the happy path.  Under overload
    control two more states appear: ``SUSPENDED`` (the request was
    preempted — its slot state lives in a host-side ``SuspendedState`` and
    it waits in the queue for prefill-free re-admission; it may bounce
    ``RUNNING -> SUSPENDED -> RUNNING`` any number of times) and ``SHED``
    (terminal: admission control refused the request, or the caller
    cancelled it before it finished — its token stream is whatever was
    emitted before the cut)."""

    QUEUED = "queued"        # waiting for a slot
    RUNNING = "running"      # occupies a slot (prefilled, decoding)
    SUSPENDED = "suspended"  # preempted; snapshot held, waiting to resume
    FINISHED = "finished"    # budget exhausted; tokens complete
    SHED = "shed"            # terminal: shed by admission control/cancelled


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """One emitted token, as returned by ``Engine.step()``.

    ``index`` is the token's 0-based position in the request's output
    stream; ``final`` marks the request's last token (its handle flips to
    FINISHED the moment this event is pushed).  ``tier`` is the precision
    tier the token was decoded at (None on untiered engines) — under
    mid-stream migration, successive events of one request may carry
    different tiers.  ``sampled`` is True when the token came from the
    request's temperature/top-k sampler rather than greedy argmax;
    ``speculative`` marks tokens emitted by a speculative round (accepted
    drafts and correction tokens — all verified at ``tier``, never the
    draft tier)."""

    uid: int
    token: int
    index: int
    tier: Optional[str]
    final: bool
    sampled: bool = False
    speculative: bool = False


class _HandleEngine(Protocol):
    """What a handle needs from the engine that minted it."""

    @property
    def has_work(self) -> bool: ...

    def step(self) -> List[TokenEvent]: ...

    def _set_tier(self, handle: "RequestHandle", tier: str) -> None: ...


class RequestHandle:
    """Caller-facing view of one submitted request (see module docstring).

    Handles are minted by ``Engine.submit`` — never construct one directly
    outside tests.  All clocks (``submitted_at`` / ``admitted_at`` /
    ``finished_at``) are in the engine's scheduler-clock units (decode
    steps), the same units ``Request.deadline`` is priced in."""

    def __init__(self, request: Request, engine: _HandleEngine, *,
                 submitted_at: float = 0.0) -> None:
        self.request = request
        self._engine = engine
        self.status = RequestStatus.QUEUED
        self.tokens: List[int] = []
        self.events: List[TokenEvent] = []
        self.submitted_at = submitted_at
        self.admitted_at: Optional[float] = None
        self.first_token_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        self.slot: Optional[int] = None
        self._callbacks: List[Callable[[TokenEvent], None]] = []

    # ------------------------------------------------------------- identity
    @property
    def uid(self) -> int:
        return self.request.uid

    @property
    def tier(self) -> Optional[str]:
        """The request's CURRENT tier (tracks mid-stream migrations)."""
        return self.request.tier

    @property
    def done(self) -> bool:
        """True once the request reached a terminal state (FINISHED, or
        SHED by admission control / ``Engine.cancel``)."""
        return self.status in (RequestStatus.FINISHED, RequestStatus.SHED)

    @property
    def queue_wait(self) -> Optional[float]:
        """Scheduler-clock ticks from submission to FIRST admission (None
        while QUEUED/SHED-before-admission).  Preempt/resume cycles do not
        move it: it measures the initial time-to-first-token wait."""
        if self.admitted_at is None:
            return None
        return self.admitted_at - self.submitted_at

    @property
    def ttft(self) -> Optional[float]:
        """Scheduler-clock ticks from submission to the FIRST emitted
        token (None until one streams).  With prefill emitting token 1 at
        admission this usually equals ``queue_wait``; the two diverge only
        for resumed streams, whose first token predates any suspension."""
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.submitted_at

    # ------------------------------------------------------------ streaming
    def on_token(self, callback: Callable[[TokenEvent], None]) -> None:
        """Register a per-token callback.

        Already-buffered events are replayed synchronously at registration,
        so a late subscriber sees the identical stream; subsequent events
        fire from inside ``engine.step()`` as they are emitted."""
        self._callbacks.append(callback)
        for ev in self.events:
            callback(ev)

    def __iter__(self) -> Iterator[int]:
        """Yield the request's tokens, driving ``engine.step()`` whenever
        the next token has not been produced yet (pull-based streaming)."""
        i = 0
        while True:
            while i >= len(self.tokens) and not self.done:
                if not self._engine.has_work:
                    raise RuntimeError(
                        f"request {self.uid}: engine idle but request not "
                        f"finished (status {self.status.value})")
                self._engine.step()
            if i < len(self.tokens):
                yield self.tokens[i]
                i += 1
            else:
                return

    def result(self) -> List[int]:
        """Drive the engine until this request FINISHES; return its tokens."""
        while not self.done:
            if not self._engine.has_work:
                raise RuntimeError(
                    f"request {self.uid}: engine idle but request not "
                    f"finished (status {self.status.value})")
            self._engine.step()
        return list(self.tokens)

    # ------------------------------------------------------------ migration
    def set_tier(self, tier: str) -> None:
        """Change this request's precision tier mid-stream.

        QUEUED: the waiting request is re-tagged (and re-priced for SLO
        admission).  RUNNING: the slot's KV lane is requantized in place at
        the new tier's KV precision and the weight plane prefix switches at
        the engine's next group-layout derivation.  FINISHED: error."""
        self._engine._set_tier(self, tier)

    # ------------------------------------------------------------- internal
    def _mark_admitted(self, slot: int, now: float) -> None:
        self.status = RequestStatus.RUNNING
        self.slot = slot
        if self.admitted_at is None:     # resumes keep the FIRST admission
            self.admitted_at = now

    def _mark_suspended(self) -> None:
        self.status = RequestStatus.SUSPENDED
        self.slot = None

    def _mark_shed(self, now: float) -> None:
        self.status = RequestStatus.SHED
        self.slot = None
        self.finished_at = now

    def _push(self, event: TokenEvent, now: float,
              defer: Optional[Callable[[BaseException], None]] = None
              ) -> None:
        """Engine-side: record one emitted token and fire callbacks.

        ALL handle bookkeeping (buffering, the FINISHED transition) happens
        before any callback runs, and ONLY user-callback exceptions are
        routed through ``defer`` (engines re-raise them at the end of the
        scheduling round, once host state is consistent) — an
        engine-internal bookkeeping error still propagates immediately
        rather than being masked by an unrelated callback failure."""
        self.events.append(event)
        self.tokens.append(event.token)
        if self.first_token_at is None:
            self.first_token_at = now
        if event.final:
            self.status = RequestStatus.FINISHED
            self.slot = None
            self.finished_at = now
        for cb in self._callbacks:
            if defer is None:
                cb(event)
            else:
                try:
                    cb(event)
                except Exception as err:
                    defer(err)

    def __repr__(self) -> str:
        return (f"RequestHandle(uid={self.uid}, status={self.status.value}, "
                f"tier={self.tier!r}, tokens={len(self.tokens)})")
