"""Serving request type (shared by scheduler and engine)."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # [S] int32
    max_new_tokens: int = 16     # total tokens returned (>= 1; results come
                                 # from ServeEngine.run / .results)
    tier: str = None             # precision tier name (engines with a
                                 # PrecisionSchedule; None = default tier /
                                 # no tiering.  The engine normalizes this
                                 # at submit time.)
