"""Serving request type (shared by scheduler, handles and engines)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import numpy.typing as npt

from repro.spec.sampling import SamplingParams
from repro.spec.speculate import SpecConfig


@dataclasses.dataclass
class Request:
    """One generation request (pure host data — never traced itself; the
    engine moves its prompt/budget into traced arrays at admission).

    ``tier`` names the precision tier on engines with a
    ``PrecisionSchedule`` (None = the schedule's default tier; must stay
    None on untiered engines).  The engine normalizes it onto a queued copy
    at submit time, and the tier drives BOTH the slot's weight plane-prefix
    width and — when the schedule declares ``kv_tiers`` — the slot's
    KV-cache storage precision.  A live request's tier can later be changed
    through its :class:`~repro.serve.handle.RequestHandle` (``set_tier``),
    which migrates the slot's KV lane in place.

    ``deadline`` is the request's SLO budget, measured in the engine's
    scheduler clock (decode steps) FROM SUBMISSION: the request should
    finish within ``deadline`` clock ticks of being submitted.  None means
    best-effort.  Only :class:`~repro.serve.scheduler.SLOPolicy` consults
    it; the default FIFO admission ignores deadlines entirely.

    ``tenant`` names the traffic source for per-tenant fairness under
    overload: :class:`~repro.serve.scheduler.SLOPolicy` built with
    ``tenant_weights`` ages a weighted tenant's queued requests faster
    (weighted slack), so one tenant's burst cannot starve another's.
    None (or an unlisted name) means weight 1.0 — plain unweighted
    scheduling.

    ``sampling`` selects temperature / top-k sampling for this request
    (:class:`~repro.spec.sampling.SamplingParams`).  None (or
    ``temperature == 0``) is exact greedy — bit-identical to the argmax
    path.  Sampled streams are deterministic functions of the request's
    seed alone: batch composition, chunking, and mesh width do not move
    them.

    ``spec`` turns on self-speculative decoding
    (:class:`~repro.spec.speculate.SpecConfig`): the slot drafts ``k``
    tokens per round at ``spec.draft_tier`` (a plane prefix of the same
    preloaded weights) and verifies the window at the request's own tier
    in one batched forward.  Greedy speculative output is token-identical
    to non-speculative decoding at the verify tier; sampled speculative
    output preserves the sampling distribution (rejection sampling) but
    follows a different draw path than a non-speculative run."""

    uid: int
    prompt: npt.NDArray[np.int32]  # [S] int32
    max_new_tokens: int = 16       # total tokens returned (>= 1; stream via
                                   # the RequestHandle, or Engine.run)
    tier: Optional[str] = None     # precision tier name (see class docstring)
    deadline: Optional[float] = None   # SLO budget in scheduler-clock ticks
    tenant: Optional[str] = None   # traffic source (per-tenant fair slack)
    sampling: Optional[SamplingParams] = None   # None = greedy (argmax)
    spec: Optional[SpecConfig] = None   # None = plain decoding
