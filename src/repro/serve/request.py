"""Serving request type (shared by scheduler, handles and engines)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import numpy.typing as npt


@dataclasses.dataclass
class Request:
    """One generation request (pure host data — never traced itself; the
    engine moves its prompt/budget into traced arrays at admission).

    ``tier`` names the precision tier on engines with a
    ``PrecisionSchedule`` (None = the schedule's default tier; must stay
    None on untiered engines).  The engine normalizes it onto a queued copy
    at submit time, and the tier drives BOTH the slot's weight plane-prefix
    width and — when the schedule declares ``kv_tiers`` — the slot's
    KV-cache storage precision.  A live request's tier can later be changed
    through its :class:`~repro.serve.handle.RequestHandle` (``set_tier``),
    which migrates the slot's KV lane in place.

    ``deadline`` is the request's SLO budget, measured in the engine's
    scheduler clock (decode steps) FROM SUBMISSION: the request should
    finish within ``deadline`` clock ticks of being submitted.  None means
    best-effort.  Only :class:`~repro.serve.scheduler.SLOPolicy` consults
    it; the default FIFO admission ignores deadlines entirely.

    ``tenant`` names the traffic source for per-tenant fairness under
    overload: :class:`~repro.serve.scheduler.SLOPolicy` built with
    ``tenant_weights`` ages a weighted tenant's queued requests faster
    (weighted slack), so one tenant's burst cannot starve another's.
    None (or an unlisted name) means weight 1.0 — plain unweighted
    scheduling."""

    uid: int
    prompt: npt.NDArray[np.int32]  # [S] int32
    max_new_tokens: int = 16       # total tokens returned (>= 1; stream via
                                   # the RequestHandle, or Engine.run)
    tier: Optional[str] = None     # precision tier name (see class docstring)
    deadline: Optional[float] = None   # SLO budget in scheduler-clock ticks
    tenant: Optional[str] = None   # traffic source (per-tenant fair slack)
