"""Serving request type (shared by scheduler and engine)."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request (pure host data — never traced itself; the
    engine moves its prompt/budget into traced arrays at admission).

    ``tier`` names the precision tier on engines with a
    ``PrecisionSchedule`` (None = the schedule's default tier; must stay
    None on untiered engines).  The engine normalizes it onto a queued copy
    at submit time, and the tier drives BOTH the slot's weight plane-prefix
    width and — when the schedule declares ``kv_tiers`` — the slot's
    KV-cache storage precision."""

    uid: int
    prompt: np.ndarray           # [S] int32
    max_new_tokens: int = 16     # total tokens returned (>= 1; results come
                                 # from ServeEngine.run / .results)
    tier: str = None             # precision tier name (see class docstring)
