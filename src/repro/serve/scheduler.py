"""Host-side admission scheduling for slot-based continuous batching.

Pure bookkeeping, no JAX (everything here is host state; nothing is traced):
a waiting queue plus per-slot state (which request occupies the slot, tokens
emitted so far, decode budget remaining).  The engine asks for free slots
after every decode chunk and admits waiting requests into them — occupied
slots are never re-prefilled.

Admission *policy* — which waiting request takes a freed slot — is
pluggable via the :class:`SchedulerPolicy` protocol:

* :class:`FIFOPolicy` (default) reproduces the historical behaviour
  bit-identically: the oldest compatible request wins.
* :class:`SLOPolicy` is deadline-aware: it weighs each candidate's slack
  (``Request.deadline`` vs. queue age) against an estimated service time
  priced by the hwmodel's per-tier cycle cost
  (``hwmodel.energy.tier_cost``), admitting the tightest-slack request
  first (earliest-deadline-first with a service-time estimate).  Requests
  without a deadline are best-effort: they fall back to FIFO order among
  themselves and yield to any deadlined candidate.  Overload control is
  opt-in on the same policy: ``preempt=True`` names a RUNNING victim to
  displace when a queued deadline request's slack runs out
  (:meth:`SLOPolicy.preempt_victim`), ``shed=True`` refuses (or, with
  ``auto_tier``, downtiers) requests whose projected completion exceeds
  the modeled capacity (:meth:`SLOPolicy.admission_decision`), and
  ``tenant_weights`` ages a weighted tenant's requests faster so one
  tenant's burst cannot starve another's.

Tier *constraints* are orthogonal to policy: the mixed-tier engine admits
any tier into any slot (``admit(slot)``), while the tier-SERIALIZED
baseline constrains admission to the one tier its decode batch currently
runs at (``admit(slot, tier=...)`` — other tiers keep their queue
position).  The policy then chooses among the constraint-compatible
candidates.

All clocks (``now`` / ``submitted_at`` / deadlines) are in the engine's
scheduler-clock units — decode steps executed — so scheduling is fully
deterministic and host-wall-clock free.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import (Deque, Dict, List, Mapping, Optional, Protocol, Sequence,
                    Tuple, Union)

from repro.serve.request import Request


@dataclasses.dataclass
class SlotState:
    """One occupied decode slot (host bookkeeping: the request, its emitted
    tokens, and the decode budget still owed)."""

    request: Request
    tokens: List[int] = dataclasses.field(default_factory=list)
    remaining: int = 0     # decode tokens still owed to this request

    @property
    def uid(self) -> int:
        return self.request.uid

    def emit(self, token: int) -> None:
        self.tokens.append(token)
        self.remaining -= 1

    @property
    def done(self) -> bool:
        return self.remaining <= 0


class _AnyTier:
    """Sentinel type for ``admit(tier=ANY_TIER)`` (no tier constraint)."""

    def __repr__(self) -> str:   # pragma: no cover - debugging nicety
        return "ANY_TIER"


ANY_TIER = _AnyTier()   # admit()/peek() sentinel: no tier constraint
TierFilter = Union[str, None, _AnyTier]

# One RUNNING slot as the overload-control hooks see it:
# (slot index, request, decode tokens still owed, submission tick).
RunningEntry = Tuple[int, Request, int, float]


class SchedulerPolicy(Protocol):
    """Admission policy: pick which waiting request takes a freed slot.

    ``candidates`` is the tier-constraint-compatible subset of the waiting
    queue IN QUEUE (submission) ORDER; ``submitted_at`` maps uid -> the
    scheduler-clock tick the request was submitted at; ``now`` is the
    current scheduler clock.  Return an index into ``candidates`` (None
    only when it is empty).  Policies are pure host-side functions of the
    queue — they never touch traced state."""

    def select(self, candidates: Sequence[Request],
               submitted_at: Mapping[int, float],
               now: float) -> Optional[int]: ...


class FIFOPolicy:
    """Strict first-in-first-out admission (the historical default):
    the oldest compatible request takes the slot."""

    def select(self, candidates: Sequence[Request],
               submitted_at: Mapping[int, float],
               now: float) -> Optional[int]:
        return 0 if candidates else None


class SLOPolicy:
    """Deadline-aware admission: earliest effective deadline first.

    Each candidate is scored by its *slack*::

        slack = (submitted_at + deadline) - now - est_service
        est_service = max_new_tokens * cost(tier)

    where ``cost(tier)`` is the tier's relative per-token service cost
    derived from the hwmodel's cycle model
    (:func:`repro.hwmodel.energy.relative_tier_costs`: cycles/MAC,
    normalized so the cheapest tier costs 1.0) — a high-precision request
    occupies the modeled array longer per token, so its deadline bites
    earlier.  The tightest-slack candidate wins; ties break FIFO.
    Deadline-less requests have infinite slack (best-effort): they keep
    FIFO order among themselves and always yield to deadlined candidates.

    ``tier_costs`` can be passed directly (tier name -> relative cost) or
    derived from a :class:`~repro.core.policy.PrecisionSchedule`; untiered
    requests (tier None) cost ``default_cost``.  ``mac_counts`` (layer ->
    MACs per token, e.g. ``ArchConfig.quant_layer_macs()``) makes the
    schedule-derived pricing rules-aware — required for tiers that differ
    only in per-layer rules (searched ``repro.autoprec`` schedules) to
    price differently at all.

    ``auto_tier=True`` additionally enables deadline-aware tier
    *auto-selection* at admission (:meth:`select_tier`): a deadlined
    request whose tier's priced service time no longer fits its remaining
    slack is retagged — the same request-object retag path a QUEUED
    ``set_tier`` takes — to the highest-quality tier that still fits
    (necessarily faster), so a tight-deadline request is admitted at a
    faster tier instead of missing its deadline at the requested one.
    Requests whose tier meets the deadline, and best-effort requests,
    keep their requested tier.

    Overload control (all opt-in, consumed by ``ServeEngine``):

    * ``preempt=True`` — :meth:`preempt_victim` names a RUNNING request to
      displace when a queued deadline request's weighted slack drops to
      ``preempt_slack`` (default 0.0) or below AND no slot frees naturally
      in time.  The victim is the lowest-priority RUNNING request (largest
      remaining-service slack; best-effort first, lightest tenant first)
      and must hold STRICTLY more slack than the urgent request — equal
      urgency never thrashes.
    * ``shed=True`` — :meth:`admission_decision` projects a new deadline
      request's completion against modeled capacity (outranking queued +
      non-displaceable running work, priced by the tier costs, divided
      over the slots) and answers ``"admit"``, ``"shed"``, or (with
      ``auto_tier``) a faster tier name to downtier to.  Best-effort
      requests are always admitted — they wait instead of being refused.
    * ``time_slice=N`` (scheduler ticks, >= 1) — time-slice fairness for
      best-effort (deadline-free) requests: the engine voluntarily
      preempts a best-effort RUNNING slot whose current slice has run N
      or more ticks whenever other requests are waiting, so a stream of
      long best-effort requests round-robins instead of holding slots
      until completion.  Victims are re-aged as freshly submitted on the
      scheduler side only (``queue_wait`` semantics untouched), which
      sends them to the back of the FIFO tie-break.  Deadlined requests
      are never sliced — their urgency is already priced by slack.
    * ``tenant_weights`` (tenant name -> weight >= 1.0) — per-tenant
      fairness: a weighted tenant's queued requests age faster
      (``weighted_slack`` subtracts ``(weight-1) * queue_age``), so its
      deadlines tighten sooner and its best-effort requests win FIFO ties
      against heavier backlogs.  Unlisted tenants (and ``tenant=None``)
      weigh 1.0, which makes every formula collapse to the unweighted
      one.

    ``remaining_tokens`` (uid -> tokens still owed) is maintained by the
    engine for SUSPENDED requests so their re-admission slack and service
    estimates price only the REMAINING work, not the original budget."""

    def __init__(self, schedule: Optional[object] = None, *,
                 tier_costs: Optional[Dict[str, float]] = None,
                 default_cost: float = 1.0,
                 auto_tier: bool = False,
                 mac_counts: Optional[Mapping[str, float]] = None,
                 preempt: bool = False,
                 preempt_slack: float = 0.0,
                 shed: bool = False,
                 tenant_weights: Optional[Mapping[str, float]] = None,
                 time_slice: Optional[int] = None
                 ) -> None:
        if tier_costs is None and schedule is not None:
            from repro.hwmodel.energy import relative_tier_costs
            tier_costs = relative_tier_costs(schedule, mac_counts=mac_counts)
        self.tier_costs: Dict[str, float] = dict(tier_costs or {})
        self.default_cost = float(default_cost)
        self.auto_tier = bool(auto_tier)
        self.preempt = bool(preempt)
        self.preempt_slack = float(preempt_slack)
        self.shed = bool(shed)
        self.tenant_weights: Dict[str, float] = dict(tenant_weights or {})
        for tenant, w in self.tenant_weights.items():
            if w < 1.0:
                raise ValueError(f"tenant {tenant!r}: weight {w} < 1.0 "
                                 "(weights only ever ACCELERATE aging)")
        if time_slice is not None and int(time_slice) < 1:
            raise ValueError(f"time_slice must be >= 1 scheduler tick, got "
                             f"{time_slice}")
        self.time_slice: Optional[int] = \
            None if time_slice is None else int(time_slice)
        # uid -> decode tokens still owed; stamped by the engine when it
        # suspends a request, cleared at resume/cancel.  Lets slack and
        # service estimates price partially-served requests correctly.
        self.remaining_tokens: Dict[int, int] = {}

    def cost(self, tier: Optional[str]) -> float:
        """Relative per-token service cost of a tier (cheapest == 1.0)."""
        if tier is None:
            return self.default_cost
        return self.tier_costs.get(tier, self.default_cost)

    def weight(self, tenant: Optional[str]) -> float:
        """Fairness weight of a tenant (1.0 unless listed)."""
        if tenant is None:
            return 1.0
        return self.tenant_weights.get(tenant, 1.0)

    def est_service(self, request: Request) -> float:
        """Estimated REMAINING service time in scheduler-clock ticks
        (suspended requests price only the tokens still owed)."""
        owed = self.remaining_tokens.get(request.uid,
                                         request.max_new_tokens)
        return owed * self.cost(request.tier)

    def slack(self, request: Request, submitted_at: Mapping[int, float],
              now: float) -> float:
        """Scheduler-clock ticks to spare before the request's deadline
        (infinite for best-effort requests)."""
        if request.deadline is None:
            return math.inf
        due = submitted_at.get(request.uid, now) + request.deadline
        return due - now - self.est_service(request)

    def weighted_slack(self, request: Request,
                       submitted_at: Mapping[int, float],
                       now: float) -> float:
        """Tenant-fair slack: a weighted tenant's queue age counts
        ``weight`` times, so its deadlines tighten faster.  Identical to
        :meth:`slack` at weight 1.0 (and for best-effort requests, whose
        slack stays infinite — their fairness rides the select tie-break
        instead)."""
        s = self.slack(request, submitted_at, now)
        if not math.isfinite(s):
            return s
        age = now - submitted_at.get(request.uid, now)
        return s - (self.weight(request.tenant) - 1.0) * age

    def select(self, candidates: Sequence[Request],
               submitted_at: Mapping[int, float],
               now: float) -> Optional[int]:
        if not candidates:
            return None

        def key(i: int) -> Tuple[float, float, int]:
            r = candidates[i]
            # Best-effort ties order on WEIGHTED age (== submission order
            # when no weights are configured, so unweighted behaviour is
            # bit-identical to the historical key); the final tie-break is
            # the QUEUE position, so equal requests stay strictly FIFO.
            age = now - submitted_at.get(r.uid, now)
            return (self.weighted_slack(r, submitted_at, now),
                    -self.weight(r.tenant) * age, i)

        return min(range(len(candidates)), key=key)

    # ------------------------------------------------------ overload control
    def preempt_victim(self, waiting: Sequence[Request],
                       running: Sequence[RunningEntry],
                       submitted_at: Mapping[int, float],
                       now: float) -> Optional[int]:
        """Uid of the RUNNING request to displace, or None.

        Fires only when (a) some queued deadline request's weighted slack
        has dropped to ``preempt_slack`` or below, (b) no slot frees
        naturally within that slack (the shortest remaining budget among
        running slots, in ticks), and (c) some RUNNING request holds
        STRICTLY more slack than the urgent one — best-effort streams
        (infinite slack) are the canonical victims, lightest tenant and
        longest remaining stream first.  Remaining service of the victim
        is priced like any queued request's, so a resumed victim re-enters
        admission with the correct residual estimate."""
        if not self.preempt or not running:
            return None
        urgent_slack = math.inf
        for r in waiting:
            if r.deadline is None:
                continue
            urgent_slack = min(urgent_slack,
                               self.weighted_slack(r, submitted_at, now))
        if urgent_slack > self.preempt_slack:
            return None
        free_in = min(rem for _, _, rem, _ in running)
        if free_in <= max(urgent_slack, 0.0):
            return None            # a slot frees in time on its own

        def victim_key(entry: RunningEntry) -> Tuple[float, float, int, int]:
            slot, req, rem, tick = entry
            if req.deadline is None:
                s = math.inf
            else:
                s = (tick + req.deadline) - now - rem * self.cost(req.tier)
            return (s, -self.weight(req.tenant), rem, -slot)

        victim = max(running, key=victim_key)
        if victim_key(victim)[0] <= urgent_slack:
            return None            # nobody is strictly lower priority
        return victim[1].uid

    def admission_decision(self, request: Request,
                           waiting: Sequence[Request],
                           running: Sequence[RunningEntry],
                           num_slots: int,
                           submitted_at: Mapping[int, float],
                           now: float) -> str:
        """Admission control at submit time: ``"admit"``, ``"shed"``, or a
        tier name to downtier to (``auto_tier`` only).

        Capacity model: work that OUTRANKS the incoming request — queued
        requests with tighter-or-equal weighted slack, plus running work
        that cannot be displaced (deadlined streams with tighter slack;
        everything running when ``preempt`` is off) — must be served
        first.  Projected wait is that outranking service divided over the
        slots; the request is feasible at a tier iff wait + its priced
        service fits the deadline budget.  Best-effort requests are always
        admitted (they wait; preemption protects the urgent ones from
        them), so shedding only ever refuses work that would MISS."""
        if not self.shed or request.deadline is None:
            return "admit"
        budget = float(request.deadline)     # submitted at ``now``
        s_req = budget - self.est_service(request)
        ahead = 0.0
        for r in waiting:
            if self.weighted_slack(r, submitted_at, now) <= s_req:
                ahead += self.est_service(r)
        for _, req, rem, tick in running:
            service = rem * self.cost(req.tier)
            if req.deadline is None:
                if not self.preempt:
                    ahead += service
                continue               # displaceable best-effort stream
            run_slack = (tick + req.deadline) - now - service
            if run_slack <= s_req or not self.preempt:
                ahead += service
        wait = ahead / max(num_slots, 1)

        def feasible(cost: float) -> bool:
            return wait + request.max_new_tokens * cost <= budget

        if feasible(self.cost(request.tier)):
            return "admit"
        if self.auto_tier and self.tier_costs:
            fits = [t for t in self.tier_costs if feasible(self.tier_costs[t])]
            if fits:
                return max(fits, key=lambda t: (self.tier_costs[t], t))
        return "shed"

    def select_tier(self, request: Request, submitted_at_tick: float,
                    now: float) -> Optional[str]:
        """Deadline-aware tier auto-selection (``auto_tier`` mode).

        Keep the request's own tier while its estimated service time
        (``max_new_tokens * cost``) fits the remaining budget
        (``submitted_at + deadline - now``); otherwise the
        highest-quality (highest-cost) tier that does — necessarily a
        FASTER one, since feasibility is monotone in cost — and the
        cheapest (fastest) tier when none fits, so a late request at
        least finishes as early as possible.  Requests are never upgraded
        above their requested tier: auto-selection trades quality for the
        deadline, not the reverse.  ``None`` (keep the requested tier)
        for best-effort requests or when no tier costs are known.  Ties
        break on the tier name so selection is deterministic."""
        if request.deadline is None or not self.tier_costs:
            return None
        budget = submitted_at_tick + request.deadline - now

        def fits(tier: str) -> bool:
            return request.max_new_tokens * self.tier_costs[tier] <= budget

        cur = request.tier
        if cur is not None and cur in self.tier_costs and fits(cur):
            return cur
        feasible = [t for t in self.tier_costs if fits(t)]
        if feasible:
            return max(feasible, key=lambda t: (self.tier_costs[t], t))
        return min(self.tier_costs, key=lambda t: (self.tier_costs[t], t))


class Scheduler:
    """Policy-driven admission over a fixed number of slots.

    Tier-agnostic by default (mixed-tier engines fill any slot from the
    queue); ``admit(slot, tier=...)`` restricts candidates to one tier for
    the serialized baseline.  WHICH compatible candidate wins is the
    ``policy``'s call (:class:`FIFOPolicy` unless configured otherwise)."""

    def __init__(self, num_slots: int,
                 policy: Optional[SchedulerPolicy] = None) -> None:
        self.num_slots = num_slots
        self.policy: SchedulerPolicy = policy if policy is not None \
            else FIFOPolicy()
        self.waiting: Deque[Request] = deque()
        self.submitted_at: Dict[int, float] = {}
        self.slots: List[Optional[SlotState]] = [None] * num_slots
        self.finished: Dict[int, List[int]] = {}

    # -------------------------------------------------------------- queueing
    def submit(self, request: Request, now: float = 0.0) -> None:
        """Append to the waiting queue, stamping the submission clock.

        ``submitted_at`` entries exist only while a request WAITS (policies
        price queue age, nothing else); admission prunes them, so the dict
        never outgrows the queue in a long-running server."""
        self.waiting.append(request)
        self.submitted_at[request.uid] = now

    @property
    def queue_depth(self) -> int:
        """Requests currently waiting for a slot (the telemetry gauge's
        source of truth)."""
        return len(self.waiting)

    def free_slots(self) -> List[int]:
        """Indices of currently unoccupied slots."""
        return [i for i, s in enumerate(self.slots) if s is None]

    def _candidates(self, tier: TierFilter) -> List[int]:
        """Queue indices compatible with the tier constraint, queue order."""
        if isinstance(tier, _AnyTier):
            return list(range(len(self.waiting)))
        return [i for i, r in enumerate(self.waiting) if r.tier == tier]

    def _pick(self, tier: TierFilter, now: float) -> Optional[int]:
        """Queue index of the policy's choice among compatible candidates."""
        idxs = self._candidates(tier)
        if not idxs:
            return None
        chosen = self.policy.select([self.waiting[i] for i in idxs],
                                    self.submitted_at, now)
        return None if chosen is None else idxs[chosen]

    def peek(self, tier: TierFilter = ANY_TIER,
             now: float = 0.0) -> Optional[Request]:
        """The request the policy WOULD admit next (no state change) — what
        an idle tier-serialized engine uses to choose its next tier."""
        idx = self._pick(tier, now)
        return None if idx is None else self.waiting[idx]

    def admit(self, slot: int, tier: TierFilter = ANY_TIER,
              now: float = 0.0) -> Optional[Request]:
        """Pop the policy's choice of *compatible* waiting request into
        ``slot``.

        ``tier=ANY_TIER`` considers the whole queue; a tier name restricts
        candidates to THAT tier (requests of other tiers keep their queue
        position and wait for their tier's decode phase).  Returns None if
        no compatible request waits."""
        occupant = self.slots[slot]
        if occupant is not None:
            raise ValueError(f"slot {slot} is occupied (uid {occupant.uid})")
        idx = self._pick(tier, now)
        if idx is None:
            return None
        req = self.waiting[idx]
        del self.waiting[idx]
        self.submitted_at.pop(req.uid, None)   # only waiting requests age
        self.slots[slot] = SlotState(request=req,
                                     remaining=req.max_new_tokens)
        return req

    def cancel(self, uid: int) -> bool:
        """Drop a WAITING request: remove it from the queue AND its
        submission-clock entry.  Returns True when the uid was queued.

        This is the QUEUED-cancellation leak fix: before it existed, the
        only path that pruned ``submitted_at`` was admission, so a request
        abandoned while still QUEUED kept its clock entry (and queue slot)
        for the engine's lifetime — ``has_work`` never drained."""
        for i, r in enumerate(self.waiting):
            if r.uid == uid:
                del self.waiting[i]
                self.submitted_at.pop(uid, None)
                return True
        self.submitted_at.pop(uid, None)
        return False

    # ------------------------------------------------------------- lifecycle
    def occupied(self) -> List[Tuple[int, SlotState]]:
        """(slot index, state) for every occupied slot."""
        return [(i, s) for i, s in enumerate(self.slots) if s is not None]

    def evict(self, slot: int) -> SlotState:
        """Free an occupied slot WITHOUT recording it finished — the
        preemption half of :meth:`release`.  Returns the evicted state
        (request, emitted tokens, remaining budget) so the engine can
        snapshot it into a ``SuspendedState`` and later re-enqueue the
        request for prefill-free resumption."""
        state = self.slots[slot]
        if state is None:
            raise ValueError(f"slot {slot} is already free")
        self.slots[slot] = None
        return state

    def release(self, slot: int) -> SlotState:
        """Free a finished slot, recording its output tokens."""
        state = self.slots[slot]
        if state is None:
            raise ValueError(f"slot {slot} is already free")
        self.slots[slot] = None
        self.finished[state.uid] = state.tokens
        return state

    def release_done(self) -> List[int]:
        """Release every slot whose budget is exhausted; returns slot ids."""
        freed = []
        for i, s in self.occupied():
            if s.done:
                self.release(i)
                freed.append(i)
        return freed

    @property
    def has_work(self) -> bool:
        """True while anything waits or decodes."""
        return bool(self.waiting) or any(s is not None for s in self.slots)
