"""Host-side admission scheduler for slot-based continuous batching.

Pure bookkeeping, no JAX (everything here is host state; nothing is traced):
a FIFO waiting queue plus per-slot state (which request occupies the slot,
tokens emitted so far, decode budget remaining).  The engine asks for free
slots after every decode chunk and admits waiting requests into them —
occupied slots are never re-prefilled.

Precision-tiered serving (``Request.tier``): the default engine admits
MIXED tiers — any free slot takes the FIFO head and the decode batch serves
the occupied tiers together via per-row-group matmuls, so admission here is
plain ``admit(slot)``.  The tier-constrained form (``admit(slot, tier=...)``
— FIFO within a tier, requests of other tiers keep their queue position) is
what the tier-SERIALIZED baseline mode uses, where a decode batch runs at
one precision at a time.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from repro.serve.request import Request


@dataclasses.dataclass
class SlotState:
    """One occupied decode slot (host bookkeeping: the request, its emitted
    tokens, and the decode budget still owed)."""

    request: Request
    tokens: List[int] = dataclasses.field(default_factory=list)
    remaining: int = 0     # decode tokens still owed to this request

    @property
    def uid(self) -> int:
        return self.request.uid

    def emit(self, token: int) -> None:
        self.tokens.append(token)
        self.remaining -= 1

    @property
    def done(self) -> bool:
        return self.remaining <= 0


ANY_TIER = object()   # admit() sentinel: no tier constraint (strict FIFO)


class Scheduler:
    """FIFO admission over a fixed number of slots.

    Tier-agnostic by default (mixed-tier engines fill any slot from the
    FIFO head); ``admit(slot, tier=...)`` restricts admission to one tier
    for the serialized baseline."""

    def __init__(self, num_slots: int):
        self.num_slots = num_slots
        self.waiting: Deque[Request] = deque()
        self.slots: List[Optional[SlotState]] = [None] * num_slots
        self.finished: Dict[int, List[int]] = {}

    # -------------------------------------------------------------- queueing
    def submit(self, request: Request) -> None:
        """Append to the FIFO waiting queue."""
        self.waiting.append(request)

    def free_slots(self) -> List[int]:
        """Indices of currently unoccupied slots."""
        return [i for i, s in enumerate(self.slots) if s is None]

    def next_tier(self) -> Optional[str]:
        """Tier of the oldest waiting request (None when queue empty or the
        request carries no tier) — what an idle engine should switch to."""
        return self.waiting[0].tier if self.waiting else None

    def admit(self, slot: int, tier=ANY_TIER) -> Optional[Request]:
        """Pop the next *compatible* waiting request into ``slot``.

        ``tier=ANY_TIER`` takes the FIFO head; a tier name takes the oldest
        waiting request of THAT tier (requests of other tiers keep their
        queue position and wait for their tier's decode phase).  Returns
        None if no compatible request waits."""
        if self.slots[slot] is not None:
            raise ValueError(f"slot {slot} is occupied (uid "
                             f"{self.slots[slot].uid})")
        if tier is ANY_TIER:
            if not self.waiting:
                return None
            req = self.waiting.popleft()
        else:
            idx = next((i for i, r in enumerate(self.waiting)
                        if r.tier == tier), None)
            if idx is None:
                return None
            req = self.waiting[idx]
            del self.waiting[idx]
        self.slots[slot] = SlotState(request=req,
                                     remaining=req.max_new_tokens)
        return req

    # ------------------------------------------------------------- lifecycle
    def occupied(self) -> List[Tuple[int, SlotState]]:
        """(slot index, state) for every occupied slot."""
        return [(i, s) for i, s in enumerate(self.slots) if s is not None]

    def release(self, slot: int) -> SlotState:
        """Free a finished slot, recording its output tokens."""
        state = self.slots[slot]
        if state is None:
            raise ValueError(f"slot {slot} is already free")
        self.slots[slot] = None
        self.finished[state.uid] = state.tokens
        return state

    def release_done(self) -> List[int]:
        """Release every slot whose budget is exhausted; returns slot ids."""
        freed = []
        for i, s in self.occupied():
            if s.done:
                self.release(i)
                freed.append(i)
        return freed

    @property
    def has_work(self) -> bool:
        """True while anything waits or decodes."""
        return bool(self.waiting) or any(s is not None for s in self.slots)
