"""Fixed-slot cache arena for continuous batching.

The model's cache pytree (``LM.init_cache``) stacks every leaf as
``[n_periods, B, ...]``: axis 1 is the slot axis.  This module provides the
slot-granular views the engine needs — extract one slot as a batch-1 cache,
write a batch-1 cache back into its slot, reset a slot — all as pure
functions usable under ``jax.jit`` with a TRACED slot index, so admitting a
request into slot ``i`` never touches any other slot's K/V rows, lengths,
or SSM state.

Besides the device-side cache pytree the arena keeps a host-side per-slot
**tier vector** (``SlotArena.tiers``): which precision tier currently
occupies each slot.  The engine derives the per-step mixed-tier group
layout (a jit-static tuple) from it, while the matching per-slot KV
precision lives ON DEVICE as traced data (``KVCache.kv_bits``, set at
admission via :func:`fill_kv_tier`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import KVCache
from repro.models.ssm import SSMCache

SLOT_AXIS = 1   # cache leaves are [n_periods, B, ...]


def slot_view(caches: Any, slot: Any) -> Any:
    """Extract slot ``slot`` as a batch-1 cache pytree (traced-index ok).

    Slicing EVERY leaf on the slot axis makes the view self-contained: the
    KV tier codes and per-period lengths ride along with the lanes, so the
    same view doubles as the preemption snapshot (``ServeEngine.preempt``)
    — restoring it into ANY free slot via :func:`slot_write` reproduces
    the suspended request's decode state exactly, whatever its KV tier."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=SLOT_AXIS),
        caches)


def slot_write(caches: Any, sub: Any, slot: Any) -> Any:
    """Write a batch-1 cache pytree back into slot ``slot`` (the KV
    migration scratch path and the preemption restore path)."""
    def put(a: Any, s: Any) -> Any:
        idx = [0] * a.ndim
        idx[SLOT_AXIS] = slot
        return jax.lax.dynamic_update_slice(a, s.astype(a.dtype), tuple(idx))
    return jax.tree.map(put, caches, sub)


def slot_reset(caches: Any, slot: Any) -> Any:
    """Zero one slot's cache state (lengths included) in place of the pytree."""
    zero = jax.tree.map(lambda a: jnp.zeros_like(
        jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=SLOT_AXIS)), caches)
    return slot_write(caches, zero, slot)


def merge_slots(updated: Any, original: Any, keep_original: Any) -> Any:
    """Per-slot cache merge: the masked twin of :func:`slot_write`.

    Rows where ``keep_original[b]`` is True come back with their ORIGINAL
    state on every leaf; other rows keep ``updated``.  This is the
    draft-discard half of speculative rollback: after the draft phase the
    speculative slots' low-tier KV/SSM writes are dropped wholesale
    (their lanes rewind to the pre-round state) while the plain slots in
    the same batch keep their real decode progress.  Both trees must be
    the arena layout (every leaf ``[n_periods, B, ...]``)."""
    batch = keep_original.shape[0]

    def one(new: Any, old: Any) -> Any:
        shape = (1, batch) + (1,) * (new.ndim - 2)
        return jnp.where(keep_original.reshape(shape), old, new)

    return jax.tree.map(one, updated, original)


def truncate_kv_lengths(caches: Any, rollback: Any, mask: Any) -> Any:
    """Masked KV length truncation: the masked-truncate twin of
    :func:`slot_view`.

    Shortens slot ``b``'s fill point by ``rollback[b]`` positions where
    ``mask[b]`` is True (traced-ok), leaving the K/V rows themselves in
    place: entries past the new length are invisible to
    ``decode_attention`` (its validity mask is ``pos < length``) and are
    overwritten by the next appends, so a length rewind IS the rollback.
    Used after the speculative verify forward to drop the KV of rejected
    draft positions.  No-op for SSM caches (their rollback is a state
    re-selection, :func:`select_verify_step`)."""

    def one(c: Any) -> Any:
        if isinstance(c, KVCache):
            delta = jnp.where(mask, rollback, 0).astype(c.length.dtype)
            shape = (1,) * (c.length.ndim - 1) + (-1,)
            return dataclasses.replace(
                c, length=jnp.maximum(c.length - delta.reshape(shape), 0))
        return c

    return jax.tree.map(one, caches,
                        is_leaf=lambda c: isinstance(c, (KVCache, SSMCache)))


def select_verify_step(caches: Any, step_index: Any) -> Any:
    """Collapse verify-stacked SSM caches to one step per slot.

    The multi-token verify forward returns SSM caches with a per-step
    window axis (leaves ``[n_periods, W, B, ...]`` — one conv/state
    snapshot per window position, because SSM state can only roll back
    by re-selection, not by a length rewind).  This picks snapshot
    ``step_index[b]`` (traced-ok int32 ``[B]``) for every slot and
    restores the arena layout ``[n_periods, B, ...]``.  Slots that were
    inactive during verify carry their pre-round state at every
    snapshot, so any index is correct for them."""

    def one(c: Any) -> Any:
        if isinstance(c, SSMCache):
            def sel(a: Any) -> Any:
                idx = step_index.reshape((1, 1, -1) + (1,) * (a.ndim - 3))
                return jnp.take_along_axis(a, idx.astype(jnp.int32),
                                           axis=1)[:, 0]
            return dataclasses.replace(c, conv=sel(c.conv),
                                       state=sel(c.state))
        return c

    return jax.tree.map(one, caches,
                        is_leaf=lambda c: isinstance(c, (KVCache, SSMCache)))


def fill_kv_tier(caches: Any, code: Any) -> Any:
    """Set every mixed-mode KVCache's per-slot tier lane(s) to ``code``.

    ``code`` is a (traced-ok) int32 tier code (16 = bf16, 8, 4).  Applied to
    a batch-1 slot view right before prefill, then written back with the
    rest of the slot state, so the admitted request's K/V rows quantize at
    ITS tier from the first prefill write on.  No-op for caches without
    per-slot tiers (SSM caches, homogeneous KV modes)."""
    def one(c: Any) -> Any:
        if isinstance(c, KVCache) and c.kv_bits is not None:
            return dataclasses.replace(
                c, kv_bits=jnp.zeros_like(c.kv_bits) + code)
        return c
    return jax.tree.map(one, caches,
                        is_leaf=lambda c: isinstance(c, KVCache))


def migrate_kv_tier(caches: Any, slot: Any, code: Any) -> Any:
    """Requantize ONE slot's live KV lane at a new tier code, in place of
    the arena pytree (the KV half of mid-stream tier migration).

    ``slot`` and ``code`` (16 = bf16, 8, 4) are traced-ok int32 scalars, so
    one jitted instance serves every (slot, from-tier, to-tier) migration.
    The slot's lanes are dequantized at their CURRENT tier and re-encoded
    at ``code`` through :meth:`repro.models.layers.KVCache.requantize` —
    bit-identical to quantizing the dequantized cache directly at the
    target precision.  Lengths, SSM state and every other slot are
    untouched.  No-op for caches without per-slot tiers."""
    sub = slot_view(caches, slot)

    def one(c: Any) -> Any:
        if isinstance(c, KVCache) and c.mixed:
            return c.requantize(code)
        return c

    sub = jax.tree.map(one, sub, is_leaf=lambda c: isinstance(c, KVCache))
    return slot_write(caches, sub, slot)


class SlotArena:
    """Owns the arena cache pytree: ``max_slots`` persistent decode slots
    sharing one pre-allocated KV/SSM cache, each with an independent fill
    point (per-slot ``KVCache.length``).

    ``kv_bits`` follows :meth:`KVCache.create`: None / 8 / 4 for
    homogeneous storage, or a tuple of tier codes for the mixed per-slot
    arena.  ``tiers`` is the host-side slot -> tier-name vector the engine
    maintains at admit/release time (None = slot free)."""

    def __init__(self, model: Any, max_slots: int, max_len: int,
                 kv_bits: Any = None) -> None:
        self.max_slots = max_slots
        self.max_len = max_len
        self.kv_bits = kv_bits
        self.caches: Any = model.init_cache(max_slots, max_len,
                                            kv_bits=kv_bits)
        self.tiers: List[Optional[str]] = [None] * max_slots
