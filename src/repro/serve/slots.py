"""Fixed-slot cache arena for continuous batching.

The model's cache pytree (``LM.init_cache``) stacks every leaf as
``[n_periods, B, ...]``: axis 1 is the slot axis.  This module provides the
slot-granular views the engine needs — extract one slot as a batch-1 cache,
write a batch-1 cache back into its slot, reset a slot — all as pure
functions usable under ``jax.jit`` with a TRACED slot index, so admitting a
request into slot ``i`` never touches any other slot's K/V rows, lengths,
or SSM state.

Besides the device-side cache pytree the arena keeps a host-side per-slot
**tier vector** (``SlotArena.tiers``): which precision tier currently
occupies each slot.  The engine derives the per-step mixed-tier group
layout (a jit-static tuple) from it, while the matching per-slot KV
precision lives ON DEVICE as traced data (``KVCache.kv_bits``, set at
admission via :func:`fill_kv_tier`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import KVCache

SLOT_AXIS = 1   # cache leaves are [n_periods, B, ...]


def slot_view(caches: Any, slot: Any) -> Any:
    """Extract slot ``slot`` as a batch-1 cache pytree (traced-index ok).

    Slicing EVERY leaf on the slot axis makes the view self-contained: the
    KV tier codes and per-period lengths ride along with the lanes, so the
    same view doubles as the preemption snapshot (``ServeEngine.preempt``)
    — restoring it into ANY free slot via :func:`slot_write` reproduces
    the suspended request's decode state exactly, whatever its KV tier."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=SLOT_AXIS),
        caches)


def slot_write(caches: Any, sub: Any, slot: Any) -> Any:
    """Write a batch-1 cache pytree back into slot ``slot`` (the KV
    migration scratch path and the preemption restore path)."""
    def put(a: Any, s: Any) -> Any:
        idx = [0] * a.ndim
        idx[SLOT_AXIS] = slot
        return jax.lax.dynamic_update_slice(a, s.astype(a.dtype), tuple(idx))
    return jax.tree.map(put, caches, sub)


def slot_reset(caches: Any, slot: Any) -> Any:
    """Zero one slot's cache state (lengths included) in place of the pytree."""
    zero = jax.tree.map(lambda a: jnp.zeros_like(
        jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=SLOT_AXIS)), caches)
    return slot_write(caches, zero, slot)


def fill_kv_tier(caches: Any, code: Any) -> Any:
    """Set every mixed-mode KVCache's per-slot tier lane(s) to ``code``.

    ``code`` is a (traced-ok) int32 tier code (16 = bf16, 8, 4).  Applied to
    a batch-1 slot view right before prefill, then written back with the
    rest of the slot state, so the admitted request's K/V rows quantize at
    ITS tier from the first prefill write on.  No-op for caches without
    per-slot tiers (SSM caches, homogeneous KV modes)."""
    def one(c: Any) -> Any:
        if isinstance(c, KVCache) and c.kv_bits is not None:
            return dataclasses.replace(
                c, kv_bits=jnp.zeros_like(c.kv_bits) + code)
        return c
    return jax.tree.map(one, caches,
                        is_leaf=lambda c: isinstance(c, KVCache))


def migrate_kv_tier(caches: Any, slot: Any, code: Any) -> Any:
    """Requantize ONE slot's live KV lane at a new tier code, in place of
    the arena pytree (the KV half of mid-stream tier migration).

    ``slot`` and ``code`` (16 = bf16, 8, 4) are traced-ok int32 scalars, so
    one jitted instance serves every (slot, from-tier, to-tier) migration.
    The slot's lanes are dequantized at their CURRENT tier and re-encoded
    at ``code`` through :meth:`repro.models.layers.KVCache.requantize` —
    bit-identical to quantizing the dequantized cache directly at the
    target precision.  Lengths, SSM state and every other slot are
    untouched.  No-op for caches without per-slot tiers."""
    sub = slot_view(caches, slot)

    def one(c: Any) -> Any:
        if isinstance(c, KVCache) and c.mixed:
            return c.requantize(code)
        return c

    sub = jax.tree.map(one, sub, is_leaf=lambda c: isinstance(c, KVCache))
    return slot_write(caches, sub, slot)


class SlotArena:
    """Owns the arena cache pytree: ``max_slots`` persistent decode slots
    sharing one pre-allocated KV/SSM cache, each with an independent fill
    point (per-slot ``KVCache.length``).

    ``kv_bits`` follows :meth:`KVCache.create`: None / 8 / 4 for
    homogeneous storage, or a tuple of tier codes for the mixed per-slot
    arena.  ``tiers`` is the host-side slot -> tier-name vector the engine
    maintains at admit/release time (None = slot free)."""

    def __init__(self, model: Any, max_slots: int, max_len: int,
                 kv_bits: Any = None) -> None:
        self.max_slots = max_slots
        self.max_len = max_len
        self.kv_bits = kv_bits
        self.caches: Any = model.init_cache(max_slots, max_len,
                                            kv_bits=kv_bits)
        self.tiers: List[Optional[str]] = [None] * max_slots
