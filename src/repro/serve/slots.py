"""Fixed-slot cache arena for continuous batching.

The model's cache pytree (``LM.init_cache``) stacks every leaf as
``[n_periods, B, ...]``: axis 1 is the slot axis.  This module provides the
slot-granular views the engine needs — extract one slot as a batch-1 cache,
write a batch-1 cache back into its slot, reset a slot — all as pure
functions usable under ``jax.jit`` with a traced slot index, so admitting a
request into slot ``i`` never touches any other slot's K/V rows, lengths,
or SSM state.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

SLOT_AXIS = 1   # cache leaves are [n_periods, B, ...]


def slot_view(caches, slot):
    """Extract slot ``slot`` as a batch-1 cache pytree (traced-index ok)."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=SLOT_AXIS),
        caches)


def slot_write(caches, sub, slot):
    """Write a batch-1 cache pytree back into slot ``slot``."""
    def put(a, s):
        idx = [0] * a.ndim
        idx[SLOT_AXIS] = slot
        return jax.lax.dynamic_update_slice(a, s.astype(a.dtype), tuple(idx))
    return jax.tree.map(put, caches, sub)


def slot_reset(caches, slot):
    """Zero one slot's cache state (lengths included) in place of the pytree."""
    zero = jax.tree.map(lambda a: jnp.zeros_like(
        jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=SLOT_AXIS)), caches)
    return slot_write(caches, zero, slot)


class SlotArena:
    """Owns the arena cache pytree: ``max_slots`` persistent decode slots
    sharing one pre-allocated KV/SSM cache, each with an independent fill
    point (per-slot ``KVCache.length``)."""

    def __init__(self, model, max_slots: int, max_len: int,
                 kv_bits: Optional[int] = None):
        self.max_slots = max_slots
        self.max_len = max_len
        self.kv_bits = kv_bits
        self.caches: Any = model.init_cache(max_slots, max_len,
                                            kv_bits=kv_bits)
