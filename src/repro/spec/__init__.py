"""Speculative decoding from the plane prefix.

Two layers:

* :mod:`repro.spec.sampling` — temperature / top-k / greedy token
  selection with a threaded PRNG key, deterministic across eager/jit and
  across mesh widths, designed to run INSIDE the jitted decode chunk.
* :mod:`repro.spec.speculate` — self-speculation: the 2/4-bit plane
  prefix of the superplane store drafts k tokens, the 8-bit tier
  verifies the window in one batched forward, and the acceptance rule
  (exact prefix match for greedy, rejection sampling for sampled mode)
  decides how many tokens to emit and how far to roll the KV arena back.

Both are pure array modules: the engine integration lives in
``repro.serve.engine``.
"""
from repro.spec.sampling import SamplingParams, sample_tokens, sampling_probs
from repro.spec.speculate import SpecConfig, accept_counts, correction_tokens

__all__ = [
    "SamplingParams",
    "SpecConfig",
    "accept_counts",
    "correction_tokens",
    "sample_tokens",
    "sampling_probs",
]
