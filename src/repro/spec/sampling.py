"""Sampling inside the jitted decode chunk.

Temperature / top-k / greedy token selection with a threaded PRNG key.
The serving invariants this module is built around:

* **Deterministic across eager/jit and across mesh widths.**  Every draw
  derives from the request's own key (``jax.random.PRNGKey(seed)``)
  folded with a per-slot draw counter and a purpose tag.  Threefry is
  counter-based, so the sampled stream depends only on
  ``(seed, draw_index, tag)`` — never on slot assignment, batch
  composition, chunk boundaries, or the mesh layout (sampling state is
  replicated on a mesh).  The per-token math is elementwise + argmax,
  which XLA does not reassociate, so eager and jit agree bitwise.
* **Greedy is exact.**  Rows with ``temperature == 0`` take
  ``jnp.argmax`` over the raw logits — the same reduction the
  pre-sampling engine used — so greedy serving stays token-identical.
* **Reciprocal-multiply scale math.**  Temperature is applied as an
  explicit f32 reciprocal multiply (``logits * (1/t)``), the same
  discipline the fused decode path uses for dequant scales: both eager
  and jit then run the identical multiply instead of one of them
  strength-reducing a division.

Draw counters advance once per draw EVENT (not per emitted token): a
speculative round burns extra accept/residual draws, and a rejected
round's redraw must see fresh randomness.  Counters only advance for
rows that actually sample (``temperature > 0`` and active), so a greedy
request never consumes randomness and a sampled request's stream is a
pure function of how many tokens it has drawn.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Purpose tags folded into each draw's subkey, so the token draw, the
# speculative accept draw, and the residual/bonus draw at the same
# counter value are independent streams.
TAG_TOKEN = 0
TAG_ACCEPT = 1
TAG_RESIDUAL = 2

# Guard value for the temperature reciprocal on greedy rows (their
# sampled branch is discarded by the final ``where``; the guard only
# keeps the dead branch finite).
_MIN_TEMP = 1e-6


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration.

    ``temperature == 0`` (the default) is exact greedy — bit-identical
    to the argmax path that predates this module.  ``top_k == 0`` means
    no top-k restriction.  ``seed`` names the request's private PRNG
    stream; two requests with the same seed, prompt, and tier sample
    identical tokens regardless of what else shares the batch.
    """

    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    def validate(self) -> None:
        if self.temperature < 0.0:
            raise ValueError(
                f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")


def request_key(seed: int) -> np.ndarray:
    """Host-side raw threefry key for a request seed (uint32 ``[2]``)."""
    return np.asarray(jax.random.PRNGKey(seed), dtype=np.uint32)


def fold_events(keys: jax.Array, draws: jax.Array, tag: int) -> jax.Array:
    """Per-slot subkey for draw event ``draws[b]`` with purpose ``tag``.

    ``keys``: uint32 ``[B, 2]`` raw request keys; ``draws``: int32
    ``[B]`` draw counters.  Returns uint32 ``[B, 2]`` subkeys.
    """

    def one(key: jax.Array, counter: jax.Array) -> jax.Array:
        return jax.random.fold_in(jax.random.fold_in(key, counter), tag)

    return jax.vmap(one)(keys, draws)


def scale_logits(logits: jax.Array, temperature: jax.Array) -> jax.Array:
    """Temperature via explicit f32 reciprocal multiply (``x * (1/t)``)."""
    x = logits.astype(jnp.float32)
    t = jnp.maximum(temperature.astype(jnp.float32), jnp.float32(_MIN_TEMP))
    inv_t = jnp.float32(1.0) / t
    return x * inv_t[:, None]


def mask_top_k(scaled: jax.Array, top_k: jax.Array) -> jax.Array:
    """Keep each row's ``top_k[b]`` largest logits, others to ``-inf``.

    ``top_k[b] <= 0`` keeps the whole row.  Ties at the k-th value are
    all kept (the mask is a value threshold, not an index cutoff).
    """
    vocab = scaled.shape[-1]
    k_eff = jnp.where(top_k > 0, top_k, vocab).astype(jnp.int32)
    k_eff = jnp.clip(k_eff, 1, vocab)
    sorted_desc = -jnp.sort(-scaled, axis=-1)
    thresh = jnp.take_along_axis(sorted_desc, (k_eff - 1)[:, None], axis=-1)
    return jnp.where(scaled < thresh, -jnp.inf, scaled)


def gumbel_argmax(keys: jax.Array, logits: jax.Array) -> jax.Array:
    """One categorical draw per row via the Gumbel-max trick.

    ``keys``: uint32 ``[B, 2]`` subkeys (one per row), ``logits``: f32
    ``[B, V]`` (may contain ``-inf``).  Elementwise + argmax only, so
    eager and jit agree bitwise.
    """

    def one(key: jax.Array, row: jax.Array) -> jax.Array:
        u = jax.random.uniform(key, row.shape, jnp.float32,
                               minval=float(np.finfo(np.float32).tiny),
                               maxval=1.0)
        return jnp.argmax(row - jnp.log(-jnp.log(u))).astype(jnp.int32)

    return jax.vmap(one)(keys, logits)


def sample_tokens(logits: jax.Array, keys: jax.Array, draws: jax.Array,
                  temperature: jax.Array, top_k: jax.Array,
                  active: Optional[jax.Array] = None,
                  ) -> Tuple[jax.Array, jax.Array]:
    """Select one token per row; the decode-scan selection step.

    ``logits``: ``[B, V]`` (any float dtype); ``keys``: uint32
    ``[B, 2]``; ``draws``: int32 ``[B]`` draw counters; ``temperature``:
    f32 ``[B]``; ``top_k``: int32 ``[B]``; ``active``: optional bool
    ``[B]`` — inactive rows neither sample nor advance their counter.

    Returns ``(tokens [B] int32, new_draws [B] int32)``.  Rows with
    ``temperature == 0`` return the raw-logits argmax exactly.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    sampled_rows = temperature > jnp.float32(0.0)
    if active is not None:
        sampled_rows = sampled_rows & active
    masked = mask_top_k(scale_logits(logits, temperature), top_k)
    drawn = gumbel_argmax(fold_events(keys, draws, TAG_TOKEN), masked)
    tokens = jnp.where(sampled_rows, drawn, greedy)
    return tokens, draws + sampled_rows.astype(jnp.int32)


def sampling_probs(logits: jax.Array, temperature: jax.Array,
                   top_k: jax.Array) -> jax.Array:
    """The post-temperature/top-k next-token distribution, f32 ``[B, V]``.

    Rows with ``temperature == 0`` are a point mass at the raw-logits
    argmax, so greedy requests flow through the speculative acceptance
    rule as the degenerate (deterministic) case of rejection sampling.
    """
    vocab = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1)
    masked = mask_top_k(scale_logits(logits, temperature), top_k)
    probs = jax.nn.softmax(masked, axis=-1)
    point = jax.nn.one_hot(greedy, vocab, dtype=jnp.float32)
    sampled_rows = (temperature > jnp.float32(0.0))[:, None]
    return jnp.where(sampled_rows, probs, point)
