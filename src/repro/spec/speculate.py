"""Self-speculative decoding: configuration + acceptance logic.

The superplane store's MSB-first decomposition makes a low-precision
draft model a free LSB-truncation of the 8-bit weights already in
memory, so draft and verify are the SAME engine at two plane-prefix
depths.  A speculative round is:

1. **Draft** — k chained single-token decode steps at the draft tier
   (the existing grouped GEMM runs draft rows and plain rows in one
   mixed-tier batch); the draft KV writes are discarded afterwards.
2. **Verify** — ONE multi-token forward of the (k+1)-token window
   ``[t0, d1..dk]`` at the verify tier, appending verify-tier KV at the
   same arena lanes.
3. **Accept** — the functions in this module: leading-prefix acceptance
   by rejection sampling (``accept_counts``), the correction/bonus token
   from the residual distribution (``correction_tokens``), and the
   emitted window (``emission_window``).

Greedy requests flow through the SAME code path as the degenerate case:
:func:`repro.spec.sampling.sampling_probs` gives them point-mass
distributions, so the accept draw compares ``u < 1`` (draft matches the
verify argmax) or ``u < 0`` (it does not), and the residual distribution
collapses to a point mass at the verify argmax — the emitted window is
exactly ``argmax(verify_logits)[:, :e]``, token-identical to sequential
greedy decoding at the verify tier by construction.

Everything here is pure array math on distributions the engine already
computed; no model calls, no weight preparation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.spec import sampling

_TINY = float(np.finfo(np.float32).tiny)


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Per-request speculative-decoding configuration.

    ``draft_tier`` names the schedule tier that drafts (e.g. ``"2/2"``
    or ``"4/4"`` — a plane prefix of the preloaded store, so drafting
    needs zero extra weight storage).  ``k`` is the draft depth: each
    round drafts ``k`` tokens and verifies the ``k+1``-token window in
    one batched forward.  When slots with different ``k`` share a batch
    the round runs at the largest ``k`` (drafting deeper than requested
    is harmless — acceptance is exact either way).
    """

    draft_tier: str
    k: int = 4

    def validate(self) -> None:
        if self.k < 1:
            raise ValueError(f"spec k must be >= 1, got {self.k}")


def _per_position_uniform(keys: jax.Array, counters: jax.Array,
                          tag: int) -> jax.Array:
    """One uniform draw per (row, position): ``counters`` is ``[B, k]``."""
    batch, k = counters.shape
    flat_keys = jnp.repeat(keys, k, axis=0)
    sub = sampling.fold_events(flat_keys, counters.reshape(-1), tag)

    def one(key: jax.Array) -> jax.Array:
        return jax.random.uniform(key, (), jnp.float32)

    return jax.vmap(one)(sub).reshape(batch, k)


def accept_counts(drafts: jax.Array, draft_probs: jax.Array,
                  verify_probs: jax.Array, keys: jax.Array,
                  draws: jax.Array) -> jax.Array:
    """Leading accepted drafts per row, by rejection sampling.

    ``drafts``: int32 ``[B, k]``; ``draft_probs``: f32 ``[B, k, V]``
    (each draft step's post-temperature/top-k distribution);
    ``verify_probs``: f32 ``[B, k+1, V]`` (the verify window's);
    ``keys``/``draws``: the sampling key state (draw counters are read,
    not advanced — the caller advances them by ``k`` for sampled rows).

    Position j accepts with probability ``min(1, p_j(d_j) / q_j(d_j))``;
    the count is the length of the accepted prefix.  For greedy rows the
    point-mass distributions make this exact prefix match against the
    verify argmax.
    """
    k = drafts.shape[1]
    p_at_d = jnp.take_along_axis(verify_probs[:, :k], drafts[..., None],
                                 axis=-1)[..., 0]
    q_at_d = jnp.take_along_axis(draft_probs, drafts[..., None],
                                 axis=-1)[..., 0]
    inv_q = jnp.float32(1.0) / jnp.maximum(q_at_d, jnp.float32(_TINY))
    ratio = p_at_d * inv_q
    counters = draws[:, None] + jnp.arange(k, dtype=jnp.int32)[None, :]
    u = _per_position_uniform(keys, counters, sampling.TAG_ACCEPT)
    accept = u < jnp.minimum(ratio, jnp.float32(1.0))
    return jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=1), axis=1)


def correction_tokens(draft_probs: jax.Array, verify_probs: jax.Array,
                      m: jax.Array, keys: jax.Array,
                      draws: jax.Array) -> jax.Array:
    """The token emitted at the stop position of each row.

    At the first rejected position (``m < k``) this samples the residual
    distribution ``normalize(max(p_m - q_m, 0))``; when every draft was
    accepted (``m == k``) the draft distribution is void and it samples
    the bonus token from ``p_k`` directly (the zero-padded ``q`` row
    makes both one expression).  Greedy rows get the verify argmax at
    the stop position exactly — their residual is a point mass, so the
    gumbel draw cannot move it.  Returns int32 ``[B]``; the caller
    advances ``draws`` by one for sampled rows.
    """
    q_ext = jnp.pad(draft_probs, ((0, 0), (0, 1), (0, 0)))
    stop = m[:, None, None]
    p_stop = jnp.take_along_axis(verify_probs, stop, axis=1)[:, 0]
    q_stop = jnp.take_along_axis(q_ext, stop, axis=1)[:, 0]
    residual = jnp.maximum(p_stop - q_stop, jnp.float32(0.0))
    z = jnp.sum(residual, axis=-1, keepdims=True)
    inv_z = jnp.float32(1.0) / jnp.maximum(z, jnp.float32(_TINY))
    dist = residual * inv_z
    sub = sampling.fold_events(keys, draws, sampling.TAG_RESIDUAL)
    return sampling.gumbel_argmax(sub, jnp.log(dist))


def emission_window(drafts: jax.Array, correction: jax.Array,
                    m: jax.Array) -> jax.Array:
    """The round's emission candidates, int32 ``[B, k+1]``.

    Positions ``< m`` are the accepted drafts, position ``m`` is the
    correction/bonus token; later positions are never emitted (the
    engine takes the first ``e = min(m + 1, remaining)`` tokens, so a
    budget-capped row emits accepted drafts only).
    """
    k = drafts.shape[1]
    idx = jnp.arange(k + 1, dtype=jnp.int32)[None, :]
    drafts_pad = jnp.pad(drafts, ((0, 0), (0, 1)))
    return jnp.where(idx < m[:, None], drafts_pad,
                     jnp.where(idx == m[:, None], correction[:, None], 0))


def accept_draw_events(k: int) -> int:
    """Draw events a sampled row burns per round beyond its k token
    draws: k accept draws + 1 residual/bonus draw."""
    return k + 1


__all__ = [
    "SpecConfig",
    "accept_counts",
    "accept_draw_events",
    "correction_tokens",
    "emission_window",
]
