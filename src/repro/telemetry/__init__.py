"""repro.telemetry — spans, metrics and trace export for the serving stack.

The package is a sensor layer over :class:`repro.serve.engine.ServeEngine`
with two contracts, both enforced by ``tests/test_telemetry.py``:

* **zero-cost-when-off** — a ``telemetry=None`` engine (the default) runs
  the decode hot loop with ZERO additional host syncs, allocations, or
  hook calls (the module-level :data:`HOOK_CALLS` spy counts every hook
  entry, and the engine itself never calls ``jax.block_until_ready`` —
  only the opt-in :class:`~repro.telemetry.profile.DeviceProfiler` does);
* **bitwise stability when on** — every hook observes after the fact;
  enabling telemetry (even with device profiling) leaves every stream
  token-identical.

Composition (one object, four concerns):

* :class:`~repro.telemetry.metrics.MetricsRegistry` — typed counters /
  gauges / fixed-bucket histograms, auto-twinned with ``EngineStats``;
* :class:`~repro.telemetry.trace.Tracer` — dual-clock spans exported as
  Chrome trace-event JSON (Perfetto-loadable);
* :class:`~repro.telemetry.profile.DeviceProfiler` — opt-in
  (``Telemetry(profile=True)``) fenced device timing per dispatch phase;
* exporters in :mod:`repro.telemetry.export` — Prometheus text, JSON
  snapshot, and the consolidated serving report.

The modeled-cycle utilization gauge is the paper's utilization claim made
observable: every dispatched decode lane is priced in absolute array
cycles per token at its tier
(:func:`repro.hwmodel.energy.tier_cycles_per_token`), and the gauge is
the ratio of cycles that served an active request to cycles the
dispatches occupied in total.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

from repro.telemetry.export import (parse_prometheus, serve_report, to_json,
                                    to_prometheus, write_json)
from repro.telemetry.metrics import (SECONDS_BUCKETS, TICK_BUCKETS, Counter,
                                     Gauge, Histogram, Metric,
                                     MetricsRegistry, format_group_layout,
                                     slot_utilization, spec_acceptance_rate,
                                     sync_engine_stats)
from repro.telemetry.profile import DeviceProfiler
from repro.telemetry.trace import ENGINE_TRACK, PID, Tracer

__all__ = ["Telemetry", "HOOK_CALLS", "Counter", "Gauge", "Histogram",
           "Metric", "MetricsRegistry", "Tracer", "DeviceProfiler",
           "TICK_BUCKETS", "SECONDS_BUCKETS", "ENGINE_TRACK", "PID",
           "format_group_layout", "sync_engine_stats", "slot_utilization",
           "spec_acceptance_rate", "to_prometheus", "parse_prometheus",
           "to_json", "write_json", "serve_report"]

# Spy counter: EVERY Telemetry hook entry bumps it.  The zero-cost-when-off
# test drains a telemetry-None engine and asserts this never moved — the
# cheapest possible proof that the hot loop took no observability branches.
HOOK_CALLS = 0

# One telemetry lane: (tier name or None for an idle/masked lane,
# active steps the lane served within the dispatch).
Lane = Tuple[Optional[str], int]


def _bump() -> None:
    global HOOK_CALLS
    HOOK_CALLS += 1


@dataclasses.dataclass
class _RequestRecord:
    """Per-request latency bookkeeping (dual clock, host-side only)."""

    tier: Optional[str]
    deadline: Optional[float]
    submit_ticks: float
    submit_wall: float
    admitted: bool = False
    first_ticks: Optional[float] = None
    first_wall: Optional[float] = None
    last_ticks: float = 0.0
    last_wall: float = 0.0
    n_tokens: int = 0


class Telemetry:
    """The facade a :class:`~repro.serve.engine.ServeEngine` accepts as
    ``telemetry=``.  Construct with ``profile=True`` to also fence and
    time device dispatches (a real host sync per dispatch — opt-in)."""

    def __init__(self, *, profile: bool = False) -> None:
        self.registry = MetricsRegistry()
        self.tracer = Tracer()
        self.profiler: Optional[DeviceProfiler] = \
            DeviceProfiler() if profile else None
        self._requests: Dict[int, _RequestRecord] = {}
        self._num_slots = 0
        self._default_tier: Optional[str] = None
        self._cycles_per_token: Dict[str, float] = {}
        self._useful_cycles = 0.0
        self._issued_cycles = 0.0
        r = self.registry
        self.queue_wait = r.histogram(
            "serve_queue_wait_ticks",
            "submit -> first admission, scheduler ticks", unit="ticks")
        self.ttft_ticks = r.histogram(
            "serve_ttft_ticks", "submit -> first token, scheduler ticks",
            unit="ticks")
        self.ttft_seconds = r.histogram(
            "serve_ttft_seconds", "submit -> first token, wall seconds",
            unit="s", buckets=SECONDS_BUCKETS)
        self.tpot_ticks = r.histogram(
            "serve_tpot_ticks", "mean ticks per output token after the "
            "first", unit="ticks")
        self.tpot_seconds = r.histogram(
            "serve_tpot_seconds", "mean wall seconds per output token "
            "after the first", unit="s", buckets=SECONDS_BUCKETS)
        self.deadline_misses = r.counter(
            "serve_deadline_misses",
            "requests that finished past submit + deadline (ticks)")
        r.gauge("serve_queue_depth", "requests waiting for a slot")
        r.gauge("serve_slot_utilization",
                "decode_slot_steps / (decode_steps * num_slots)")
        r.gauge("serve_modeled_cycle_utilization",
                "modeled array cycles serving active lanes / cycles "
                "dispatched (tier_cycles_per_token pricing)")
        r.gauge("serve_spec_acceptance_rate", "spec_accepted / spec_drafted")

    # ------------------------------------------------------------ plumbing
    def wall(self) -> float:
        """Wall seconds since the tracer epoch (the span clock)."""
        return self.tracer.now()

    def attach_engine(self, *, num_slots: int, schedule: Any = None,
                      mac_counts: Optional[Mapping[str, float]] = None
                      ) -> None:
        """Called by the engine at construction: slot count for the
        utilization denominator, and (when serving a PrecisionSchedule)
        the per-tier cycles/token price list for modeled-cycle
        utilization."""
        self._num_slots = num_slots
        if schedule is not None:
            from repro.hwmodel.energy import tier_cycles_per_token
            self._cycles_per_token = dict(
                tier_cycles_per_token(schedule, mac_counts))
            self._default_tier = str(schedule.default_tier)

    def _cycles(self, tier: Optional[str]) -> float:
        name = tier if tier is not None else self._default_tier
        if name is None:
            return 1.0
        return self._cycles_per_token.get(name, 1.0)

    def _account(self, lanes: Sequence[Lane], n_steps: int) -> None:
        """Price one dispatch: every lane occupies ``n_steps`` modeled
        steps at its tier (idle lanes at the default tier — the array is
        dispatched either way), of which ``active`` served a request."""
        for tier, active in lanes:
            cyc = self._cycles(tier)
            self._issued_cycles += n_steps * cyc
            self._useful_cycles += active * cyc

    # ----------------------------------------------------- request lifecycle
    def on_submit(self, handle: Any, *, ticks: float) -> None:
        _bump()
        req = handle.request
        self._requests[int(req.uid)] = _RequestRecord(
            tier=req.tier, deadline=req.deadline,
            submit_ticks=ticks, submit_wall=self.wall())
        self.tracer.request_phase(int(req.uid), "queued", ticks=ticks)

    def on_shed(self, handle: Any, *, ticks: float) -> None:
        _bump()
        uid = int(handle.request.uid)
        self.tracer.request_end(uid, "shed", ticks=ticks)
        self.tracer.engine_instant("shed", ticks=ticks, args={"uid": uid})
        self._requests.pop(uid, None)

    def on_admit(self, handle: Any, *, slot: int, ticks: float,
                 resumed: bool = False) -> None:
        _bump()
        uid = int(handle.request.uid)
        self.tracer.request_phase(uid, "running", ticks=ticks)
        if resumed:
            self.tracer.engine_instant("resume", ticks=ticks,
                                       args={"uid": uid})
        rec = self._requests.get(uid)
        if rec is not None and not rec.admitted:
            rec.admitted = True
            if not resumed:
                self.queue_wait.observe(ticks - rec.submit_ticks)

    def on_suspend(self, handle: Any, *, ticks: float) -> None:
        _bump()
        uid = int(handle.request.uid)
        self.tracer.request_phase(uid, "suspended", ticks=ticks)
        self.tracer.engine_instant("preempt", ticks=ticks,
                                   args={"uid": uid})

    def on_token(self, event: Any, *, ticks: float) -> None:
        _bump()
        uid = int(event.uid)
        rec = self._requests.get(uid)
        if rec is None:
            return
        now = self.wall()
        if rec.first_ticks is None:
            rec.first_ticks = ticks
            rec.first_wall = now
            self.ttft_ticks.observe(ticks - rec.submit_ticks)
            self.ttft_seconds.observe(now - rec.submit_wall)
        rec.n_tokens += 1
        rec.last_ticks = ticks
        rec.last_wall = now
        if event.final:
            n = max(rec.n_tokens - 1, 1)
            assert rec.first_wall is not None
            self.tpot_ticks.observe((rec.last_ticks - rec.first_ticks) / n)
            self.tpot_seconds.observe((rec.last_wall - rec.first_wall) / n)
            if rec.deadline is not None \
                    and ticks - rec.submit_ticks > float(rec.deadline):
                self.deadline_misses.inc()
            self.tracer.request_end(uid, "finished", ticks=ticks)
            self._requests.pop(uid, None)

    # ------------------------------------------------------- dispatch spans
    def on_prefill(self, *, uid: int, tier: Optional[str], prompt_len: int,
                   t0: float, ticks: float, fence: Any = None) -> None:
        _bump()
        if self.profiler is not None and fence is not None:
            self.profiler.fence(fence)
        self.tracer.dispatch(
            "prefill", t0, ticks=ticks, ticks_end=ticks,
            args={"uid": uid, "tier": tier, "prompt_len": prompt_len})
        if self.profiler is not None:
            self.profiler.record("prefill", self.wall() - t0)

    def on_decode_chunk(self, *, t0: float, ticks0: float, ticks_end: float,
                        n_steps: int, lanes: Sequence[Lane],
                        groups: Any = None, fence: Any = None,
                        dispatches: Optional[int] = None) -> None:
        _bump()
        if self.profiler is not None and fence is not None:
            self.profiler.fence(fence)
        layout = format_group_layout(tuple(groups)) if groups else ""
        self.tracer.dispatch(
            "decode_chunk", t0, ticks=ticks0, ticks_end=ticks_end,
            args={"n_steps": n_steps, "layout": layout,
                  "active_lanes": sum(1 for _, a in lanes if a)})
        self._account(lanes, n_steps)
        if self.profiler is not None:
            self.profiler.record("decode_chunk", self.wall() - t0)
            if dispatches is not None and layout:
                self.profiler.record_dispatch_count(layout, dispatches)

    def on_spec_round(self, *, t0: float, ticks0: float, ticks_end: float,
                      k: int, draft_lanes: Sequence[Lane],
                      verify_lanes: Sequence[Lane],
                      fence: Any = None,
                      args: Optional[Dict[str, Any]] = None) -> None:
        _bump()
        if self.profiler is not None and fence is not None:
            self.profiler.fence(fence)
        merged: Dict[str, Any] = {"k": k}
        merged.update(args or {})
        self.tracer.dispatch("spec_round", t0, ticks=ticks0,
                             ticks_end=ticks_end, args=merged)
        self._account(draft_lanes, k)
        self._account(verify_lanes, 1)
        if self.profiler is not None:
            self.profiler.record("spec_round", self.wall() - t0)

    def on_migrate(self, *, uid: int, old_tier: Optional[str],
                   new_tier: str, kv: bool, ticks: float,
                   t0: Optional[float] = None, fence: Any = None) -> None:
        _bump()
        if self.profiler is not None and fence is not None:
            self.profiler.fence(fence)
        self.tracer.engine_instant(
            "migrate", ticks=ticks,
            args={"uid": uid, "from": old_tier, "to": new_tier, "kv": kv})
        if self.profiler is not None and t0 is not None:
            self.profiler.record("migrate_kv", self.wall() - t0)

    # ------------------------------------------------------------- syncing
    def sync_stats(self, stats: Any,
                   queue_depth: Optional[int] = None) -> None:
        """Mirror ``EngineStats`` into the registry and refresh the derived
        gauges.  The engine calls this after every state-changing op, so
        the fuzz harness can assert twin equality at any point."""
        _bump()
        sync_engine_stats(self.registry, stats)
        r = self.registry
        r.gauge("serve_slot_utilization").set(
            slot_utilization(stats, self._num_slots))
        util = self._useful_cycles / self._issued_cycles \
            if self._issued_cycles else 0.0
        r.gauge("serve_modeled_cycle_utilization").set(util)
        r.gauge("serve_spec_acceptance_rate").set(spec_acceptance_rate(stats))
        if queue_depth is not None:
            r.gauge("serve_queue_depth").set(float(queue_depth))

    # ------------------------------------------------------------- exports
    def snapshot(self) -> Dict[str, Any]:
        """JSON-able dump: metrics (+ device-profile phases when on)."""
        prof = self.profiler.snapshot() if self.profiler is not None else None
        return to_json(self.registry, prof)

    def prometheus(self) -> str:
        return to_prometheus(self.registry)

    def write_trace(self, path: str) -> None:
        self.tracer.write(path)
