"""Exporters: Prometheus text exposition, JSON snapshot, and the serving
report (the one human-readable summary ``launch/serve.py`` prints).

The Prometheus renderer follows the text exposition format (``# HELP`` /
``# TYPE`` headers, ``name{label="v"} value`` samples; histograms as
cumulative ``_bucket{le=...}`` series plus ``_sum``/``_count``) closely
enough that :func:`parse_prometheus` — a minimal parser of the same
format — round-trips every sample bit-exactly, which
``tests/test_telemetry.py`` asserts.  Metric names keep their registry
names verbatim (no ``_total`` suffix rewriting) so the round-trip and the
``EngineStats`` twin assertions need no name mapping.
"""
from __future__ import annotations

import json
import math
import re
from typing import Any, Dict, Optional, Sequence, Tuple

from repro.telemetry.metrics import Histogram, MetricsRegistry

__all__ = ["to_prometheus", "parse_prometheus", "to_json", "write_json",
           "serve_report"]

# One parsed sample set: metric name -> {sorted (label, value) tuple: value}.
ParsedSamples = Dict[str, Dict[Tuple[Tuple[str, str], ...], float]]

_NAME_RE = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*")
_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)$")
_LABEL_RE = re.compile(r'(?P<k>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<v>(?:[^"\\]'
                       r'|\\.)*)"')


def _escape(value: str) -> str:
    return value.replace("\\", "\\\\").replace('"', '\\"') \
        .replace("\n", "\\n")


def _unescape(value: str) -> str:
    return value.replace("\\n", "\n").replace('\\"', '"') \
        .replace("\\\\", "\\")


def _fmt_labels(names: Sequence[str], values: Sequence[str]) -> str:
    if not names:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"' for k, v in zip(names, values))
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(v)


def to_prometheus(registry: MetricsRegistry) -> str:
    """Render the registry in Prometheus text exposition format."""
    lines = []
    for m in registry:
        if not _NAME_RE.fullmatch(m.name):
            raise ValueError(f"invalid metric name {m.name!r}")
        if m.help:
            # HELP payloads escape only backslash and newline (the
            # exposition-format rule; quotes stay raw outside labels).
            help_text = m.help.replace("\\", "\\\\").replace("\n", "\\n")
            lines.append(f"# HELP {m.name} {help_text}")
        lines.append(f"# TYPE {m.name} {m.kind}")
        if isinstance(m, Histogram):
            cum = 0
            for upper, n in zip(m.uppers, m.counts):
                cum += n
                lines.append(f'{m.name}_bucket{{le="{_fmt_value(upper)}"}}'
                             f" {float(cum)!r}")
            lines.append(f'{m.name}_bucket{{le="+Inf"}} {float(m.count)!r}')
            lines.append(f"{m.name}_sum {m.sum!r}")
            lines.append(f"{m.name}_count {float(m.count)!r}")
        else:
            series = m.series()
            if not series and not m.labels:
                series = {(): 0.0}
            for key, value in sorted(series.items()):
                lines.append(f"{m.name}{_fmt_labels(m.labels, key)} "
                             f"{_fmt_value(value)}")
    return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> ParsedSamples:
    """Minimal text-exposition parser (the round-trip test's other half).

    Returns ``{metric name: {((label, value), ...) sorted: sample}}``;
    ``# HELP``/``# TYPE`` comment lines are skipped, histogram series
    appear under their ``_bucket``/``_sum``/``_count`` sample names."""
    out: ParsedSamples = {}
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {lineno}: unparseable sample {raw!r}")
        labels: Tuple[Tuple[str, str], ...] = ()
        if m.group("labels"):
            labels = tuple(sorted(
                (lm.group("k"), _unescape(lm.group("v")))
                for lm in _LABEL_RE.finditer(m.group("labels"))))
        out.setdefault(m.group("name"), {})[labels] = \
            float(m.group("value"))
    return out


def to_json(registry: MetricsRegistry,
            profile: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """JSON snapshot: the registry dump plus (optionally) the device
    profiler's per-phase timings."""
    out: Dict[str, Any] = {"metrics": registry.snapshot()}
    if profile is not None:
        out["profile"] = profile
    return out


def write_json(path: str, registry: MetricsRegistry,
               profile: Optional[Dict[str, Any]] = None) -> None:
    with open(path, "w") as fh:
        json.dump(to_json(registry, profile), fh, indent=2, sort_keys=True)
        fh.write("\n")


# ------------------------------------------------------------ serve report
def _hist_line(registry: MetricsRegistry, name: str, unit: str) -> str:
    h = registry.get(name)
    if not isinstance(h, Histogram) or h.count == 0:
        return "n/a"
    return (f"p50={h.quantile(0.5):.3g} p99={h.quantile(0.99):.3g} {unit} "
            f"(n={h.count})")


def serve_report(registry: MetricsRegistry, *,
                 tiers: Optional[Sequence[str]] = None,
                 mixed: bool = True, slo: bool = False,
                 speculate: bool = False, overload: bool = False) -> str:
    """The consolidated serving report (replaces the four hand-rolled
    ``print`` blocks ``launch/serve.py`` used to carry).

    Every number is read back out of the registry — the EngineStats twin
    counters, the derived utilization gauges and the latency histograms —
    so a new stat surfaces here by being registered, not by editing
    per-section format strings.  Sections beyond the summary appear only
    when their feature was on (same conditions the prints had)."""
    v = registry.value
    lines = [
        "stats: "
        f"prefills={v('serve_prefills'):.0f} "
        f"decode_steps={v('serve_decode_steps'):.0f} "
        f"slot_steps={v('serve_decode_slot_steps'):.0f} "
        f"chunks={v('serve_decode_chunks'):.0f} "
        f"slot_util={v('serve_slot_utilization'):.2f} "
        f"modeled_cycle_util={v('serve_modeled_cycle_utilization'):.2f}",
        "latency: "
        f"ttft {_hist_line(registry, 'serve_ttft_ticks', 'ticks')}; "
        f"tpot {_hist_line(registry, 'serve_tpot_ticks', 'ticks/tok')}; "
        f"queue_wait {_hist_line(registry, 'serve_queue_wait_ticks', 'ticks')}"
    ]
    if tiers:
        per = " ".join(
            f"{t}:{v('serve_decode_steps_by_tier', tier=t):.0f}"
            for t in tiers)
        mode = "mixed" if mixed else "serialized"
        lines.append(
            f"tier decode_steps ({mode}): {per} "
            f"(switches={v('serve_tier_switches'):.0f} "
            f"mixed_chunks={v('serve_mixed_tier_chunks'):.0f} "
            f"migrations={v('serve_tier_migrations'):.0f} "
            f"kv_migrations={v('serve_kv_migrations'):.0f})")
    if slo:
        lines.append(
            "slo: queue_wait "
            f"{_hist_line(registry, 'serve_queue_wait_ticks', 'ticks')}, "
            f"deadline_misses={v('serve_deadline_misses'):.0f}, "
            f"tier_autoselects={v('serve_tier_autoselects'):.0f}")
    if speculate:
        drafted = v("serve_spec_drafted")
        emitted = v("serve_spec_emitted")
        vpt = v("serve_spec_verify_steps") / emitted if emitted \
            else float("nan")
        lines.append(
            f"speculate: rounds={v('serve_spec_rounds'):.0f} "
            f"accepted={v('serve_spec_accepted'):.0f}/{drafted:.0f} "
            f"({v('serve_spec_acceptance_rate'):.0%}) "
            f"emitted={emitted:.0f} verify_steps/token={vpt:.2f}")
    if overload:
        lines.append(
            f"overload: preemptions={v('serve_preemptions'):.0f} "
            f"resumes={v('serve_resumes'):.0f} "
            f"sheds={v('serve_sheds'):.0f} "
            f"spill_bytes={v('serve_spill_bytes'):.0f} "
            f"time_slice_preemptions="
            f"{v('serve_time_slice_preemptions'):.0f}")
    return "\n".join(lines)
