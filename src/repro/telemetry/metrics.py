"""Typed metric primitives + the serving metric registry.

Three metric kinds, all host-side and allocation-light:

* :class:`Counter` — monotone totals (``inc``), with a ``set`` escape
  hatch used ONLY by the :class:`~repro.serve.engine.EngineStats` twin
  sync (the engine's dataclass counters stay the source of truth; the
  registry mirrors them so exporters and the report function never
  hand-list fields).
* :class:`Gauge` — last-write-wins instantaneous values (queue depth,
  utilization ratios).
* :class:`Histogram` — FIXED bucket upper bounds: ``observe`` does one
  bisect + three adds, so p50/p99 come out of the bucket counts without
  ever storing samples (the zero-allocation-per-observation contract of
  the telemetry layer).

Every metric may declare label names; series are keyed by the label-value
tuple.  :func:`sync_engine_stats` derives the twin counters automatically
from ``dataclasses.fields`` — a new ``EngineStats`` field becomes a new
``serve_<field>`` series with no telemetry change (and the fuzz harness
asserts the twins stay equal after every engine op).

Derived serving metrics (the paper's utilization story):

* slot utilization — ``decode_slot_steps / (decode_steps * num_slots)``;
* modeled-cycle utilization — useful MACs priced by
  :func:`repro.hwmodel.energy.tier_cycles_per_token` against the cycles
  the dispatched decode lanes occupied (see
  :meth:`repro.telemetry.Telemetry.on_decode_chunk`);
* speculative acceptance rate — ``spec_accepted / spec_drafted``.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Any, Dict, Iterator, List, Mapping, Optional, Sequence, \
    Tuple, Union

__all__ = ["Counter", "Gauge", "Histogram", "Metric", "MetricsRegistry",
           "TICK_BUCKETS", "SECONDS_BUCKETS", "format_group_layout",
           "sync_engine_stats", "slot_utilization", "spec_acceptance_rate"]

LabelKey = Tuple[str, ...]

# Scheduler-clock histograms: powers of two up to 1024 ticks cover every
# serving trace the benchmarks run (one tick = one decode step).
TICK_BUCKETS: Tuple[float, ...] = tuple(float(2 ** i) for i in range(11))
# Wall-clock histograms: ~log-spaced 100us .. 30s.
SECONDS_BUCKETS: Tuple[float, ...] = (
    1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 0.1, 0.3, 1.0, 3.0, 10.0, 30.0)


def _label_key(declared: LabelKey, labels: Mapping[str, str]) -> LabelKey:
    if set(labels) != set(declared):
        raise ValueError(f"expected labels {declared}, got "
                         f"{tuple(sorted(labels))}")
    return tuple(str(labels[name]) for name in declared)


class _Series:
    """Shared label-series bookkeeping of Counter and Gauge."""

    kind = "untyped"

    def __init__(self, name: str, help: str, *, unit: str = "",
                 labels: LabelKey = ()) -> None:
        self.name = name
        self.help = help
        self.unit = unit
        self.labels = tuple(labels)
        self._values: Dict[LabelKey, float] = {}

    def get(self, **labels: str) -> float:
        return self._values.get(_label_key(self.labels, labels), 0.0)

    def series(self) -> Dict[LabelKey, float]:
        """Label-value tuple -> current value (unlabeled: key ``()``)."""
        return dict(self._values)

    def _set(self, value: float, labels: Mapping[str, str]) -> None:
        self._values[_label_key(self.labels, labels)] = value


class Counter(_Series):
    """Monotone counter (optionally labeled)."""

    kind = "counter"

    def inc(self, value: float = 1.0, **labels: str) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name}: negative inc {value}")
        key = _label_key(self.labels, labels)
        self._values[key] = self._values.get(key, 0.0) + value

    def set(self, value: float, **labels: str) -> None:
        """Twin sync only: mirror an externally-owned monotone total."""
        self._set(value, labels)


class Gauge(_Series):
    """Instantaneous value (optionally labeled)."""

    kind = "gauge"

    def set(self, value: float, **labels: str) -> None:
        self._set(value, labels)


class Histogram:
    """Fixed-bucket histogram: quantiles without stored samples.

    ``buckets`` are finite upper bounds (ascending); an implicit +Inf
    bucket catches the overflow.  ``quantile`` linearly interpolates
    inside the winning bucket (the +Inf bucket degenerates to the last
    finite bound), which is exactly the Prometheus ``histogram_quantile``
    estimator."""

    kind = "histogram"

    def __init__(self, name: str, help: str, *, unit: str = "",
                 buckets: Sequence[float] = TICK_BUCKETS) -> None:
        if not buckets or list(buckets) != sorted(set(buckets)):
            raise ValueError(f"histogram {name}: buckets must be a "
                             f"strictly ascending non-empty sequence, got "
                             f"{list(buckets)}")
        if any(math.isinf(b) for b in buckets):
            raise ValueError(f"histogram {name}: +Inf bucket is implicit")
        self.name = name
        self.help = help
        self.unit = unit
        self.labels: LabelKey = ()
        self.uppers: Tuple[float, ...] = tuple(float(b) for b in buckets)
        self.counts: List[int] = [0] * (len(self.uppers) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        self.counts[bisect.bisect_left(self.uppers, value)] += 1
        self.sum += value
        self.count += 1

    def quantile(self, q: float) -> float:
        """Estimated q-quantile (q in [0, 1]); 0.0 on an empty histogram."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum = 0.0
        for i, n in enumerate(self.counts):
            if n == 0:
                continue
            if cum + n >= target:
                if i >= len(self.uppers):      # overflow bucket
                    return self.uppers[-1]
                lo = self.uppers[i - 1] if i else 0.0
                frac = (target - cum) / n
                return lo + frac * (self.uppers[i] - lo)
            cum += n
        return self.uppers[-1]

    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


Metric = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Name-keyed, insertion-ordered registry of typed metrics.

    Registration is idempotent per (name, kind): ``counter(name, ...)``
    returns the existing series on re-registration, so the engine sync
    and the exporters can both "declare" metrics without coordination.
    A kind clash (the same name registered as two kinds) raises."""

    def __init__(self) -> None:
        self._metrics: Dict[str, Metric] = {}

    def _register(self, metric: Metric) -> Metric:
        have = self._metrics.get(metric.name)
        if have is not None:
            if have.kind != metric.kind:
                raise ValueError(
                    f"metric {metric.name!r} already registered as "
                    f"{have.kind}, re-registered as {metric.kind}")
            return have
        self._metrics[metric.name] = metric
        return metric

    def counter(self, name: str, help: str = "", *, unit: str = "",
                labels: LabelKey = ()) -> Counter:
        got = self._register(Counter(name, help, unit=unit, labels=labels))
        assert isinstance(got, Counter)
        return got

    def gauge(self, name: str, help: str = "", *, unit: str = "",
              labels: LabelKey = ()) -> Gauge:
        got = self._register(Gauge(name, help, unit=unit, labels=labels))
        assert isinstance(got, Gauge)
        return got

    def histogram(self, name: str, help: str = "", *, unit: str = "",
                  buckets: Sequence[float] = TICK_BUCKETS) -> Histogram:
        got = self._register(Histogram(name, help, unit=unit,
                                       buckets=buckets))
        assert isinstance(got, Histogram)
        return got

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    def value(self, name: str, **labels: str) -> float:
        """Current value of a counter/gauge series (0.0 when absent)."""
        metric = self._metrics.get(name)
        if metric is None:
            return 0.0
        if isinstance(metric, Histogram):
            raise TypeError(f"{name} is a histogram; use get(name).quantile")
        return metric.get(**labels)

    def __iter__(self) -> Iterator[Metric]:
        return iter(self._metrics.values())

    def __len__(self) -> int:
        return len(self._metrics)

    def snapshot(self) -> Dict[str, Any]:
        """JSON-able dump: every series of every metric, plus histogram
        bucket counts and the p50/p99 estimates."""
        out: Dict[str, Any] = {}
        for m in self._metrics.values():
            if isinstance(m, Histogram):
                out[m.name] = {
                    "kind": m.kind, "unit": m.unit, "help": m.help,
                    "buckets": list(m.uppers), "counts": list(m.counts),
                    "sum": m.sum, "count": m.count,
                    "p50": m.quantile(0.5), "p99": m.quantile(0.99),
                }
            else:
                out[m.name] = {
                    "kind": m.kind, "unit": m.unit, "help": m.help,
                    "labels": list(m.labels),
                    "series": {",".join(k) if k else "": v
                               for k, v in m.series().items()},
                }
        return out


# ------------------------------------------------------- EngineStats twins
# EngineStats dict fields keyed by tier name -> labeled counter.
_TIER_DICT_FIELDS = ("decode_steps_by_tier", "tokens_by_tier")


def format_group_layout(layout: Tuple[Tuple[str, int], ...]) -> str:
    """Stable label text of a mixed-tier group layout:
    ``(("8/8", 2), ("4/4", 1))`` -> ``"8/8x2+4/4x1"``."""
    return "+".join(f"{tier}x{rows}" for tier, rows in layout)


def sync_engine_stats(registry: MetricsRegistry, stats: Any,
                      prefix: str = "serve_") -> None:
    """Mirror an ``EngineStats`` into the registry (the twin sync).

    Field discovery is ``dataclasses.fields`` — every int field becomes
    the counter ``<prefix><field>``, the per-tier dicts become
    tier-labeled counters, and ``decode_dispatches`` (GroupLayout ->
    pallas-call count) becomes a layout-labeled gauge.  ``stats`` is
    duck-typed (any counters dataclass) so the telemetry package never
    imports the engine."""
    for f in dataclasses.fields(stats):
        v = getattr(stats, f.name)
        if isinstance(v, int):
            registry.counter(prefix + f.name,
                             f"EngineStats.{f.name} twin").set(float(v))
        elif f.name in _TIER_DICT_FIELDS:
            c = registry.counter(prefix + f.name,
                                 f"EngineStats.{f.name} twin",
                                 labels=("tier",))
            for tier_name, n in v.items():
                c.set(float(n), tier=str(tier_name))
        elif f.name == "decode_dispatches":
            g = registry.gauge(prefix + "decode_dispatches",
                               "pallas dispatches of one jitted decode "
                               "step, per group layout",
                               labels=("layout",))
            for layout, n in v.items():
                g.set(float(n), layout=format_group_layout(layout))


# -------------------------------------------------------- derived metrics
def slot_utilization(stats: Any, num_slots: int) -> float:
    """``decode_slot_steps / (decode_steps * num_slots)`` — the fraction
    of dispatched decode lanes that produced a token (1.0 = every lane of
    every step was an active request)."""
    total = stats.decode_steps * num_slots
    return stats.decode_slot_steps / total if total else 0.0


def spec_acceptance_rate(stats: Any) -> float:
    """``spec_accepted / spec_drafted`` (0.0 before any speculative round)."""
    return (stats.spec_accepted / stats.spec_drafted
            if stats.spec_drafted else 0.0)
