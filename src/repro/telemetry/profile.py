"""Opt-in device-timing hooks (``Telemetry(profile=True)``).

JAX dispatch is asynchronous: the wall time around a jitted call measures
enqueue cost, not device work.  The profiler closes that gap by FENCING —
``jax.block_until_ready`` on the dispatch's result tree — before taking
the end timestamp, so each recorded phase duration covers the device
computation.  Fencing is a real host sync, which is exactly why device
timing is opt-in and lives here rather than in the default-on span layer:
with the profiler off (or telemetry off entirely) the engine performs
ZERO ``block_until_ready`` calls (asserted by
``tests/test_telemetry.py``), and fencing never changes computed bits —
it only waits for them.

Phases mirror the engine's jitted dispatch sites: ``prefill``,
``decode_chunk``, ``spec_round``, ``migrate_kv``.  The profiler also
carries the per-layout jaxpr pallas-dispatch counts the engine already
derives through ``ServeEngine.decode_dispatch_count`` (a profiling
engine counts every layout it dispatches, exactly like
``count_dispatches=True``), so one snapshot answers both "how long did
decode chunks take on device" and "how many kernels does one step
launch".
"""
from __future__ import annotations

from typing import Any, Dict

import jax

__all__ = ["DeviceProfiler"]


class DeviceProfiler:
    """Per-phase device timing accumulator (see module docstring)."""

    def __init__(self) -> None:
        self.phase_seconds: Dict[str, float] = {}
        self.phase_calls: Dict[str, int] = {}
        # Group-layout label -> pallas_call count of one jitted decode
        # step (from the engine's jaxpr counting).
        self.dispatch_counts: Dict[str, int] = {}

    def fence(self, tree: Any) -> None:
        """Block until every array in ``tree`` is computed (device sync)."""
        jax.block_until_ready(tree)

    def record(self, phase: str, seconds: float) -> None:
        self.phase_seconds[phase] = \
            self.phase_seconds.get(phase, 0.0) + seconds
        self.phase_calls[phase] = self.phase_calls.get(phase, 0) + 1

    def record_dispatch_count(self, layout_label: str, count: int) -> None:
        self.dispatch_counts[layout_label] = count

    def snapshot(self) -> Dict[str, Any]:
        """JSON-able per-phase totals, call counts and mean seconds."""
        return {
            "phases": {
                phase: {
                    "calls": self.phase_calls.get(phase, 0),
                    "total_s": total,
                    "mean_s": total / max(self.phase_calls.get(phase, 1), 1),
                }
                for phase, total in sorted(self.phase_seconds.items())
            },
            "decode_dispatches": dict(self.dispatch_counts),
        }
