"""Span tracing on the serving engine's dual clock, exported as Chrome
trace-event JSON (loadable in Perfetto / ``chrome://tracing``).

The engine has TWO clocks and every span carries both:

* the **deterministic scheduler clock** — decode steps executed
  (``ServeEngine.clock``), the units requests' deadlines and queue waits
  are priced in.  It is bit-stable across runs, so span *ordering* and
  tick-denominated durations are reproducible.
* **wall time** — a monotonic ``time.perf_counter`` offset from the
  tracer's epoch.  It drives the Chrome ``ts``/``dur`` microsecond fields
  (Perfetto's timeline axis) and is the only part of a trace that varies
  run to run.

Track taxonomy (one Chrome *thread* per track, all in pid 1):

* track 0, ``engine`` — one complete ("X") span per jitted dispatch:
  ``prefill``, ``decode_chunk``, ``spec_round``; instant ("i") events for
  ``migrate``, ``preempt``, ``resume``, ``shed``.
* track ``uid + 1``, ``req <uid>`` — the request lifecycle as contiguous
  phase spans ``queued`` / ``running`` / ``suspended`` (QUEUED -> RUNNING
  -> SUSPENDED/... transitions close one span and open the next), closed
  by a terminal ``finished`` or ``shed`` instant.

Export sorts events by (tid, ts): ``ts`` is monotone per track, which
``tests/test_telemetry.py`` validates against the trace-event schema.
"""
from __future__ import annotations

import json
import time
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["Tracer", "ENGINE_TRACK", "PID"]

PID = 1
ENGINE_TRACK = 0


class Tracer:
    """Dual-clock span recorder (see module docstring).

    All methods are host-side appends — no locks, no device interaction.
    ``now()`` returns wall seconds since the tracer's epoch; span ``args``
    always include the scheduler-clock ticks so the deterministic timeline
    can be reconstructed from the trace alone."""

    def __init__(self) -> None:
        self._epoch = time.perf_counter()
        self._events: List[Dict[str, Any]] = []
        self._track_names: Dict[int, str] = {}
        # uid -> (phase name, phase start wall-us, phase start ticks)
        self._open_phase: Dict[int, Tuple[str, float, float]] = {}

    # ------------------------------------------------------------- clocks
    def now(self) -> float:
        """Wall seconds since the tracer epoch (monotonic)."""
        return time.perf_counter() - self._epoch

    def _us(self, wall_s: float) -> float:
        return wall_s * 1e6

    # ------------------------------------------------------------- tracks
    def _ensure_track(self, tid: int, name: str) -> None:
        if tid not in self._track_names:
            self._track_names[tid] = name

    def _request_track(self, uid: int) -> int:
        tid = uid + 1
        self._ensure_track(tid, f"req {uid}")
        return tid

    # -------------------------------------------------------------- spans
    def complete(self, tid: int, name: str, start_s: float, end_s: float,
                 *, cat: str = "serve", args: Optional[Dict[str, Any]] = None
                 ) -> None:
        """One complete ("X") span on a track, in tracer-epoch seconds."""
        self._events.append({
            "name": name, "ph": "X", "pid": PID, "tid": tid, "cat": cat,
            "ts": self._us(start_s),
            "dur": max(self._us(end_s) - self._us(start_s), 0.0),
            "args": dict(args or {}),
        })

    def instant(self, tid: int, name: str, *, cat: str = "serve",
                args: Optional[Dict[str, Any]] = None) -> None:
        self._events.append({
            "name": name, "ph": "i", "pid": PID, "tid": tid, "cat": cat,
            "ts": self._us(self.now()), "s": "t",
            "args": dict(args or {}),
        })

    def dispatch(self, name: str, start_s: float, *, ticks: float,
                 ticks_end: float,
                 args: Optional[Dict[str, Any]] = None) -> None:
        """One engine-track dispatch span ending NOW, stamped with both
        clocks (``ticks``/``ticks_end`` are scheduler-clock)."""
        self._ensure_track(ENGINE_TRACK, "engine")
        merged: Dict[str, Any] = {"ticks": ticks, "ticks_end": ticks_end}
        merged.update(args or {})
        self.complete(ENGINE_TRACK, name, start_s, self.now(),
                      cat="dispatch", args=merged)

    def engine_instant(self, name: str, *, ticks: float,
                       args: Optional[Dict[str, Any]] = None) -> None:
        self._ensure_track(ENGINE_TRACK, "engine")
        merged: Dict[str, Any] = {"ticks": ticks}
        merged.update(args or {})
        self.instant(ENGINE_TRACK, name, args=merged)

    # --------------------------------------------------- request lifecycle
    def request_phase(self, uid: int, phase: str, *, ticks: float) -> None:
        """Transition a request's lifecycle track into ``phase``: the open
        phase span (if any) closes at NOW and the new one opens."""
        tid = self._request_track(uid)
        self._close_phase(uid, tid, ticks)
        self._open_phase[uid] = (phase, self.now(), ticks)

    def request_end(self, uid: int, terminal: str, *, ticks: float) -> None:
        """Close the request's open phase and stamp the terminal instant
        (``finished`` or ``shed``)."""
        tid = self._request_track(uid)
        self._close_phase(uid, tid, ticks)
        self.instant(tid, terminal, cat="lifecycle",
                     args={"ticks": ticks})

    def _close_phase(self, uid: int, tid: int, ticks: float) -> None:
        open_ = self._open_phase.pop(uid, None)
        if open_ is not None:
            phase, start_s, ticks0 = open_
            self.complete(tid, phase, start_s, self.now(), cat="lifecycle",
                          args={"ticks": ticks0, "ticks_end": ticks})

    # ------------------------------------------------------------- export
    def chrome_events(self) -> List[Dict[str, Any]]:
        """The Chrome trace-event list: process/thread metadata first, then
        the recorded events sorted by (tid, ts) — monotone ts per track.
        Open request phases are NOT closed (export is non-destructive)."""
        meta: List[Dict[str, Any]] = [{
            "name": "process_name", "ph": "M", "pid": PID, "tid": 0,
            "args": {"name": "repro.serve"},
        }]
        for tid in sorted(self._track_names):
            meta.append({"name": "thread_name", "ph": "M", "pid": PID,
                         "tid": tid,
                         "args": {"name": self._track_names[tid]}})
        body = sorted(self._events, key=lambda e: (e["tid"], e["ts"]))
        return meta + body

    def write(self, path: str) -> None:
        """Dump ``{"traceEvents": [...]}`` JSON (the Perfetto-loadable
        container form of the trace-event format)."""
        with open(path, "w") as fh:
            json.dump({"traceEvents": self.chrome_events(),
                       "displayTimeUnit": "ms"}, fh)
            fh.write("\n")
