"""AdamW (pure JAX) with schedule, clipping, and configurable moment dtype.

Moments live in the same sharding as their parameters, so under the 2D
(FSDP x TP) rules the optimizer state is fully distributed (ZeRO-like).
``moment_dtype=bfloat16`` halves optimizer HBM for the 300B+ configs
(recorded in EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"       # float32 | bfloat16


def lr_at(cfg: OptConfig, step):
    """Linear warmup -> cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def init_state(params, cfg: OptConfig) -> Dict[str, Any]:
    dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(params, grads, state, cfg: OptConfig):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"]
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32 = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g
        v32 = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * g * g
        update = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        # Decoupled weight decay on matrices only (ndim >= 2).
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (update + wd * p.astype(jnp.float32))
        return (newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    # Unzip the 3-tuples.
    leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.unflatten(treedef, [l[0] for l in leaves])
    new_m = jax.tree.unflatten(treedef, [l[1] for l in leaves])
    new_v = jax.tree.unflatten(treedef, [l[2] for l in leaves])
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
