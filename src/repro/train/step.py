"""Train/serve step factories — the functions the launcher jits with
in/out shardings.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.train import optimizer as optim


def cross_entropy(logits, labels, mask=None):
    """Mean next-token CE in f32.  logits: [B, S, V] (any float dtype)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def make_loss_fn(model: LM, rt: Runtime, aux_weight: float = 0.01):
    def loss_fn(params, batch: Dict[str, Any]):
        logits, aux = model.forward(
            params, rt,
            tokens=batch.get("tokens") if "embeds" not in batch else None,
            embeds=batch.get("embeds"))
        ce = cross_entropy(logits, batch["labels"], batch.get("mask"))
        loss = ce + aux_weight * aux
        return loss, {"loss": loss, "ce": ce, "aux": aux}
    return loss_fn


def make_train_step(model: LM, rt: Runtime, opt_cfg: optim.OptConfig,
                    accum_steps: int = 1, aux_weight: float = 0.01):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params": ..., "opt": ...}.  With accum_steps > 1 the batch
    leading dim is split into microbatches reduced by a scan (grad
    accumulation for memory-bound training)."""
    loss_fn = make_loss_fn(model, rt, aux_weight)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if accum_steps == 1:
            (_, metrics), grads = grad_fn(params, batch)
            return grads, metrics

        def micro(carry, mb):
            g_acc, m_acc = carry
            (_, metrics), grads = grad_fn(params, mb)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, grads)
            m_acc = jax.tree.map(lambda a, b: a + b, m_acc, metrics)
            return (g_acc, m_acc), None

        micro_batch = jax.tree.map(
            lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps,
                                *x.shape[1:]), batch)
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        m0 = {"loss": jnp.zeros(()), "ce": jnp.zeros(()), "aux": jnp.zeros(())}
        (grads, metrics), _ = jax.lax.scan(micro, (g0, m0), micro_batch)
        inv = 1.0 / accum_steps
        return (jax.tree.map(lambda g: g * inv, grads),
                jax.tree.map(lambda m: m * inv, metrics))

    def train_step(state, batch):
        grads, metrics = compute_grads(state["params"], batch)
        params, opt, opt_metrics = optim.apply_updates(
            state["params"], grads, state["opt"], opt_cfg)
        metrics.update(opt_metrics)
        return {"params": params, "opt": opt}, metrics

    return train_step


def make_serve_steps(model: LM, rt: Runtime):
    """Returns (prefill_fn, decode_fn) for the serving engine / dry-run.

    decode_fn(params, caches, tokens|embeds) -> (logits [B,1,V], caches) —
    this is what the decode_* and long_* dry-run cells lower."""

    def prefill_fn(params, caches, tokens=None, embeds=None):
        return model.prefill(params, rt, caches, tokens=tokens, embeds=embeds)

    def decode_fn(params, caches, tokens=None, embeds=None):
        return model.decode_step(params, rt, caches, tokens=tokens,
                                 embeds=embeds)

    return prefill_fn, decode_fn
