"""Optional-hypothesis shim for the property-based test files.

``hypothesis`` is a dev-only dependency (see requirements-dev.txt).  When it
is missing, the property tests must SKIP instead of breaking collection for
the whole suite — each file also carries a deterministic non-hypothesis
fallback case so the contract under test keeps at least one executable
check.

Usage in a test module::

    from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - CI installs it
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        """Replace the test with an argument-free skipping stub (so pytest
        never tries to resolve the strategy parameters as fixtures)."""
        def deco(f):
            @pytest.mark.skip(reason="hypothesis not installed "
                                     "(pip install -r requirements-dev.txt)")
            def _skipped():
                pass
            _skipped.__name__ = f.__name__
            _skipped.__doc__ = f.__doc__
            return _skipped
        return deco

    def settings(*_args, **_kwargs):
        def deco(f):
            return f
        return deco

    class _NullStrategies:
        """st.anything(...) -> None; only ever consumed by the stub given."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _NullStrategies()
