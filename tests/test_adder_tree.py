"""CSA split-path adder tree functional contract (paper §III-C)."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import adder_tree


@given(st.lists(st.lists(st.integers(-4, 3), min_size=64, max_size=64),
                min_size=1, max_size=8))
@settings(max_examples=50, deadline=None)
def test_split_tree_equals_sum(rows):
    p = np.asarray(rows, np.int32)
    got = adder_tree.csa_tree_sum(p, axis=-1)
    assert np.array_equal(np.asarray(got), p.sum(-1))


@given(st.lists(st.integers(0, 3), min_size=8, max_size=64))
@settings(max_examples=30, deadline=None)
def test_unsigned_msb_path_quiet(vals):
    """Unsigned products (2-bit, in [0,3]) leave the MSB path all-zero —
    the mechanism behind the Table-II unsigned power saving."""
    p = np.asarray(vals, np.int32)
    msb, low2 = adder_tree.split_products(p)
    assert np.asarray(msb).sum() == 0
    assert float(adder_tree.msb_path_activity(p)) == 0.0
    assert np.array_equal(np.asarray(adder_tree.csa_tree_sum(p)), p.sum())


def test_split_tree_equals_sum_deterministic():
    """Non-hypothesis fallback: seeded sweep of the same contract."""
    rng = np.random.default_rng(0)
    for rows in (1, 3, 8):
        p = rng.integers(-4, 4, size=(rows, 64)).astype(np.int32)
        got = adder_tree.csa_tree_sum(p, axis=-1)
        assert np.array_equal(np.asarray(got), p.sum(-1))
    u = rng.integers(0, 4, size=(16,)).astype(np.int32)
    msb, _ = adder_tree.split_products(u)
    assert np.asarray(msb).sum() == 0


def test_signed_msb_weight_is_minus_four():
    p = np.asarray([-4], np.int32)
    msb, low2 = adder_tree.split_products(p)
    assert int(np.asarray(msb)[0]) == 1 and int(np.asarray(low2)[0]) == 0
    assert int(np.asarray(adder_tree.csa_tree_sum(p))) == -4
