"""Flash attention vs the naive softmax oracle (property-based)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.layers import KVCache, decode_attention, flash_attention


def naive_attention(q, k, v, causal=True, q_offset=0):
    b, sq, h, dh = q.shape
    _, sk, kvh, _ = k.shape
    g = h // kvh
    kf = np.repeat(np.asarray(k, np.float32), g, axis=2)
    vf = np.repeat(np.asarray(v, np.float32), g, axis=2)
    qf = np.asarray(q, np.float32)
    s = np.einsum("bqhd,bshd->bhqs", qf, kf) / math.sqrt(dh)
    if causal:
        qpos = q_offset + np.arange(sq)[:, None]
        kpos = np.arange(sk)[None, :]
        s = np.where(qpos >= kpos, s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return np.einsum("bhqs,bshd->bqhd", p, vf)


@given(sq=st.integers(1, 9), sk_extra=st.integers(0, 7),
       h=st.sampled_from([2, 4]), g=st.sampled_from([1, 2]),
       block_k=st.sampled_from([2, 3, 8]), seed=st.integers(0, 999))
@settings(max_examples=25, deadline=None)
def test_flash_matches_naive(sq, sk_extra, h, g, block_k, seed):
    rng = np.random.default_rng(seed)
    kvh = h // g if h % g == 0 else h
    sk = sq + sk_extra
    dh = 8
    q = rng.normal(size=(2, sq, h, dh)).astype(np.float32)
    k = rng.normal(size=(2, sk, kvh, dh)).astype(np.float32)
    v = rng.normal(size=(2, sk, kvh, dh)).astype(np.float32)
    q_offset = sk - sq           # q appended at the end (prefill chunking)
    got = np.asarray(flash_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=True,
        block_k=block_k, q_offset=q_offset), np.float32)
    want = naive_attention(q, k, v, causal=True, q_offset=q_offset)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("sq,sk,h,g,block_k", [
    (1, 8, 2, 1, 3), (5, 5, 4, 2, 2), (9, 16, 4, 2, 8)])
def test_flash_matches_naive_deterministic(sq, sk, h, g, block_k):
    """Non-hypothesis fallback: fixed shape sweep of the same oracle."""
    rng = np.random.default_rng(sq * 100 + sk)
    kvh = h // g
    dh = 8
    q = rng.normal(size=(2, sq, h, dh)).astype(np.float32)
    k = rng.normal(size=(2, sk, kvh, dh)).astype(np.float32)
    v = rng.normal(size=(2, sk, kvh, dh)).astype(np.float32)
    q_offset = sk - sq
    got = np.asarray(flash_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=True,
        block_k=block_k, q_offset=q_offset), np.float32)
    want = naive_attention(q, k, v, causal=True, q_offset=q_offset)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


def test_flash_noncausal():
    rng = np.random.default_rng(0)
    q = rng.normal(size=(1, 5, 2, 8)).astype(np.float32)
    k = rng.normal(size=(1, 11, 2, 8)).astype(np.float32)
    v = rng.normal(size=(1, 11, 2, 8)).astype(np.float32)
    got = np.asarray(flash_attention(jnp.asarray(q), jnp.asarray(k),
                                     jnp.asarray(v), causal=False,
                                     block_k=4), np.float32)
    want = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


def test_decode_attention_matches_naive():
    rng = np.random.default_rng(1)
    b, sk, kvh, dh, g = 2, 12, 2, 8, 2
    h = kvh * g
    cache = KVCache.create(b, max_len=16, kv_heads=kvh, head_dim=dh,
                           dtype=jnp.float32)
    k = rng.normal(size=(b, sk, kvh, dh)).astype(np.float32)
    v = rng.normal(size=(b, sk, kvh, dh)).astype(np.float32)
    cache = cache.update(jnp.asarray(k), jnp.asarray(v), 0)
    q = rng.normal(size=(b, 1, h, dh)).astype(np.float32)
    got = np.asarray(decode_attention(jnp.asarray(q), cache), np.float32)
    want = naive_attention(q, k, v, causal=True, q_offset=sk - 1)
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=1e-3)


def test_kv_cache_quantization_error_bounded():
    rng = np.random.default_rng(2)
    cache = KVCache.create(1, 8, 2, 16, kv_bits=8)
    k = rng.normal(size=(1, 8, 2, 16)).astype(np.float32)
    v = rng.normal(size=(1, 8, 2, 16)).astype(np.float32)
    cache = cache.update(jnp.asarray(k), jnp.asarray(v), 0)
    kd, vd = cache.read(jnp.float32)
    # per-(pos, head) int8: error within ~1 bf16-scale LSB of the row max
    err = np.abs(np.asarray(kd) - k).max()
    assert err < np.abs(k).max() / 127 * 1.6
