"""repro.autoprec: hardware-aware automatic mixed-precision search.

Covers the subsystem's contracts:

* layer enumeration (``ArchConfig.quant_layer_macs``) names exactly the
  projections ``prepare_params`` quantizes, across model families;
* sensitivity is measured through the REAL quantization path: the batched
  one-pass (mixed-tier row group) profiler is bit-identical to the
  sequential per-tier profiler, and perturbing to 8 bits is exactly 0;
* search: greedy trajectory properties, the differentiable relaxation
  annealing to the separable optimum, Pareto pruning, and the repaired
  ``allocate_bits_by_sensitivity`` (even defaults, budget respected, thin
  wrapper over the same core);
* persistence: JSON round-trip of a searched PrecisionSchedule is exact,
  and an engine built from a LOADED schedule is token-identical to one
  built from the in-memory original with zero weight re-preparations;
* SLOPolicy deadline-aware tier auto-selection, unit + engine level;
* the end-to-end invariant: ``repro.launch.autoprec`` emits a schedule
  file whose loaded schedule validates (even bits only) and
  Pareto-dominates the uniform-8 baseline on modeled cycles at small
  measured divergence.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.autoprec import (CostModel, SearchResult, greedy_search,
                            greedy_trajectory, load_schedule,
                            load_schedule_with_meta, pareto_front,
                            profile_sensitivity, random_calibration,
                            relaxed_search, save_schedule,
                            schedule_from_dict, schedule_from_results,
                            schedule_to_dict, search)
from repro.configs import reduced_config
from repro.core.decompose import RUNTIME_W_BITS
from repro.core.policy import (LayerPrecision, PrecisionSchedule,
                               allocate_bits_by_sensitivity,
                               uniform_schedule)
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.serve import Request, ServeEngine, SLOPolicy
from repro.serve import engine as engine_mod


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def calib(setup):
    cfg, _, _ = setup
    return random_calibration(cfg, batches=1, batch=2, seq=8, seed=3)


# ----------------------------------------------------------- layer workload
@pytest.mark.parametrize("arch", ["granite-3-8b", "mamba2-1.3b",
                                  "llama4-scout-17b-a16e"])
def test_quant_layer_macs_names_match_prepared_weights(arch):
    """The enumeration prices exactly the layers the engine quantizes —
    dense attention+MLP, SSM projections, MoE experts (+ shared)."""
    cfg = reduced_config(arch)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.core.policy import uniform_policy
    _, paths = engine_mod.prepare_params(
        params, uniform_policy(8, 8, backend="decomposed"), model)
    prepared = sorted(engine_mod._path_to_layer_name(p) for p in paths)
    macs = cfg.quant_layer_macs()
    assert sorted(macs) == prepared
    assert all(isinstance(m, int) and m > 0 for m in macs.values())


# ------------------------------------------------------------- sensitivity
def test_batched_one_pass_profiler_matches_sequential(setup, calib):
    """The one-pass mixed-tier row-group profiler is BIT-identical to the
    sequential per-tier profiler (the PR-3 mixed-batch stability contract,
    exercised on the full forward), and the 8-bit probe is exactly 0."""
    cfg, model, params = setup
    layers = ["layers.pos0.attn.q_proj", "layers.pos0.mlp.down_proj",
              "lm_head"]
    kw = dict(calib=calib, choices=(2, 4, 8), layers=layers)
    prof_b = profile_sensitivity(model, params, batched=True, block=4, **kw)
    prof_s = profile_sensitivity(model, params, batched=False, **kw)
    for n in layers:
        for b in (2, 4, 8):
            assert prof_b.kl[n][b] == prof_s.kl[n][b], (n, b)
            assert prof_b.mse[n][b] == prof_s.mse[n][b], (n, b)
        assert prof_b.kl[n][8] == 0.0 and prof_b.mse[n][8] == 0.0
        assert prof_b.kl[n][2] > 0.0       # truncation must actually bite
        assert all(v >= 0.0 for v in prof_b.kl[n].values())
    assert prof_b.table is prof_b.kl       # default metric


# ------------------------------------------------------------------ search
def _toy_sens_cost():
    cfg = reduced_config("granite-3-8b")
    cost = CostModel.for_config(cfg)
    sens = {n: {2: 1.0 / (i + 1), 4: 0.25 / (i + 1), 6: 0.05 / (i + 1)}
            for i, n in enumerate(cost.layers)}
    return sens, cost


def test_greedy_search_trajectory_properties():
    sens, cost = _toy_sens_cost()
    results = greedy_search(sens, cost, choices=(2, 4, 6, 8))
    assert results[0].avg_bits == 2.0 and results[-1].avg_bits == 8.0
    cycles = [r.cycles_per_token for r in results]
    divs = [r.pred_divergence for r in results]
    assert cycles == sorted(cycles)                  # cost only climbs
    assert divs == sorted(divs, reverse=True)        # divergence only falls
    assert all(b in (2, 4, 6, 8)
               for r in results for b in r.assignment.values())
    front = pareto_front(results)
    assert len(front) >= 2
    for a, b in zip(front, front[1:]):
        assert b.cycles_per_token > a.cycles_per_token
        assert b.divergence < a.divergence


def test_relaxed_search_anneals_to_separable_optimum():
    """With the additive surrogate, the annealed softmax must land on the
    per-layer argmin of sens + lambda * cycles."""
    sens, cost = _toy_sens_cost()
    for lam in (1e-4, 1e-2):
        (res,) = relaxed_search(sens, cost, choices=(2, 4, 6, 8),
                                lambdas=[lam])
        for layer in cost.layers:
            want = min((2, 4, 6, 8), key=lambda b: (
                (sens[layer].get(b, 0.0) if b < 8 else 0.0)
                + lam * cost.layer_cycles(layer, b)))
            assert res.assignment[layer] == want, (layer, lam)


def test_search_merges_strategies_into_pareto_front():
    sens, cost = _toy_sens_cost()
    front = search(sens, cost, choices=(2, 4, 6, 8), strategy="both")
    assert front and front[0].cycles_per_token < front[-1].cycles_per_token
    with pytest.raises(ValueError):
        search(sens, cost, strategy="bogus")


def test_cost_model_validation_and_pricing():
    _, cost = _toy_sens_cost()
    uniform = {n: 8 for n in cost.layers}
    assert cost.average_bits(uniform) == 8.0
    assert cost.uniform_cycles(2) < cost.uniform_cycles(4) \
        < cost.uniform_cycles(8)
    with pytest.raises(KeyError):
        cost.cycles_per_token({n: 8 for n in list(cost.layers)[1:]})
    with pytest.raises(KeyError):
        cost.cycles_per_token(dict(uniform, bogus=8))


def test_allocator_defaults_even_and_respects_budget():
    """The repaired classic allocator: even-only default choices (the
    runtime superplane contract), budget respected, sensitivity ordering
    preserved; odd widths remain available explicitly for the QAT path."""
    sens = {"a": 10.0, "b": 1.0, "c": 0.1}
    counts = {"a": 100, "b": 100, "c": 100}
    pol = allocate_bits_by_sensitivity(sens, counts, avg_bits=4.0)
    bits = {n: pol.lookup(n).w_bits for n in sens}
    assert all(b in RUNTIME_W_BITS for b in bits.values())
    assert bits["a"] >= bits["b"] >= bits["c"]
    assert pol.average_bits(sens, [counts[n] for n in sens]) <= 4.0 + 1e-9
    # Even-bit assignments drop straight into a PrecisionSchedule rule set.
    PrecisionSchedule(tiers={"auto": LayerPrecision(backend="decomposed")},
                      rules={"auto": {n: LayerPrecision(
                          w_bits=b, backend="decomposed")
                          for n, b in bits.items()}})
    # Explicit odd choices stay allowed (fake-quant/QAT policies only).
    pol_odd = allocate_bits_by_sensitivity(sens, counts, avg_bits=4.0,
                                           choices=(2, 3, 4, 5, 6, 8))
    assert all(2 <= pol_odd.lookup(n).w_bits <= 8 for n in sens)
    with pytest.raises(ValueError):
        allocate_bits_by_sensitivity(sens, counts, 4.0, choices=(1, 4))
    with pytest.raises(ValueError):
        allocate_bits_by_sensitivity(sens, counts, 4.0, choices=(4, 9))


def test_greedy_trajectory_budget_retires_over_budget_layers():
    sens = {"big": {2: 1.0, 4: 0.1}, "small": {2: 0.5, 4: 0.05}}
    cost = {"big": {2: 200.0, 4: 400.0}, "small": {2: 2.0, 4: 4.0}}
    traj = greedy_trajectory(["big", "small"], sens, cost, (2, 4),
                             budget=210.0)
    # Promoting "big" (rate 0.9/200) busts the budget; "small" (0.45/2)
    # is promoted first anyway and fits.
    assert traj[-1] == {"big": 2, "small": 4}


# -------------------------------------------------------------- persistence
def _searched_schedule():
    base = LayerPrecision(w_bits=8, a_bits=8, backend="decomposed")
    return PrecisionSchedule(
        tiers={"auto": base, "base": base},
        rules={"auto": {
            "layers.pos0.attn.q_proj": dataclasses.replace(base, w_bits=4),
            "layers.pos0.mlp.*": dataclasses.replace(base, w_bits=2),
        }},
        default_tier="auto",
        kv_tiers={"auto": 8, "base": None})


def test_schedule_json_roundtrip_is_exact(tmp_path):
    sched = _searched_schedule()
    assert schedule_from_dict(schedule_to_dict(sched)) == sched
    # The policy-side hooks delegate to the same format.
    assert PrecisionSchedule.from_json_dict(sched.to_json_dict()) == sched
    path = str(tmp_path / "sched.json")
    save_schedule(path, sched, meta={"note": "test"})
    loaded, meta = load_schedule_with_meta(path)
    assert loaded == sched
    assert loaded.default_tier == "auto"
    assert loaded.kv_tiers == {"auto": 8, "base": None}
    assert meta == {"note": "test"}
    assert load_schedule(path) == sched


def test_schedule_file_validation_rejects_bad_contents(tmp_path):
    sched = _searched_schedule()
    d = schedule_to_dict(sched)
    d["rules"]["auto"]["layers.pos0.attn.q_proj"]["w_bits"] = 5
    with pytest.raises(ValueError):          # odd width: not truncatable
        schedule_from_dict(d)
    d2 = schedule_to_dict(sched)
    del d2["tiers"]["auto"]["a_signed"]      # missing field -> ValueError,
    with pytest.raises(ValueError):          # not a bare KeyError
        schedule_from_dict(d2)
    with pytest.raises(ValueError):
        schedule_from_dict({"rules": {}})    # no tiers at all
    path = str(tmp_path / "bogus.json")
    with open(path, "w") as f:
        f.write('{"format": "something.else", "schedule": {}}')
    with pytest.raises(ValueError):
        load_schedule(path)


def test_schedule_from_results_validates_and_names_tiers():
    res = SearchResult(assignment={"lm_head": 4}, a_bits=8, avg_bits=4.0,
                       cycles_per_token=1.0, energy_per_token_j=1.0,
                       pred_divergence=0.0, strategy="greedy")
    sched = schedule_from_results([res], tier_names=["auto"])
    assert sched.default_tier == "auto"
    assert set(sched.tier_names) == {"auto", "base"}
    assert sched.lookup("lm_head", "auto").w_bits == 4
    assert sched.lookup("lm_head", "base").w_bits == 8
    odd = dataclasses.replace(res, assignment={"lm_head": 3})
    with pytest.raises(ValueError):
        schedule_from_results([odd])
    with pytest.raises(ValueError):
        schedule_from_results([res], tier_names=["base"])
    with pytest.raises(ValueError):
        schedule_from_results([])


# ------------------------------------------------------- SLO auto-selection
def test_slo_policy_select_tier_unit():
    pol = SLOPolicy(tier_costs={"8/8": 4.0, "4/4": 2.0, "2/2": 1.0},
                    auto_tier=True)
    assert pol.auto_tier
    req = Request(uid=0, prompt=np.array([1]), max_new_tokens=10)
    # Best-effort: keep the requested tier.
    assert pol.select_tier(req, 0.0, 0.0) is None
    # Loose deadline: the highest-quality tier fits.
    loose = dataclasses.replace(req, deadline=100.0)
    assert pol.select_tier(loose, 0.0, 0.0) == "8/8"
    # Mid deadline: 8/8 (40 ticks) no longer fits, 4/4 (20) does.
    mid = dataclasses.replace(req, deadline=25.0)
    assert pol.select_tier(mid, 0.0, 0.0) == "4/4"
    # Aged in queue: the remaining budget shrinks with `now`.
    assert pol.select_tier(loose, 0.0, 90.0) == "2/2"
    # Infeasible everywhere: fall back to the fastest tier.
    tight = dataclasses.replace(req, deadline=5.0)
    assert pol.select_tier(tight, 0.0, 0.0) == "2/2"
    # No cost table: nothing to select with.
    assert SLOPolicy(auto_tier=True).select_tier(loose, 0.0, 0.0) is None
    # Cost ties keep the request's own tier (e.g. a searched schedule
    # priced WITHOUT mac_counts: tiers differing only in per-layer rules
    # collapse to one cost — switching buys nothing and must not happen).
    flat = SLOPolicy(tier_costs={"auto": 1.0, "base": 1.0}, auto_tier=True)
    tied = dataclasses.replace(req, deadline=100.0, tier="auto")
    assert flat.select_tier(tied, 0.0, 0.0) == "auto"
    assert flat.select_tier(dataclasses.replace(tied, deadline=1.0),
                            0.0, 0.0) == "auto"


def test_rules_aware_tier_pricing_with_mac_counts():
    """relative_tier_costs(mac_counts=...) makes searched-schedule tiers
    (per-layer rules over a common 8-bit default) price differently — the
    hook `repro.launch.serve --schedule-file --slo` uses; without MAC
    counts they collapse to identical costs.  For uniform tiers the
    MAC-weighted pricing reduces exactly to the default pricing."""
    from repro.hwmodel.energy import relative_tier_costs
    cfg = reduced_config("granite-3-8b")
    macs = cfg.quant_layer_macs()
    searched = _searched_schedule()
    flat = relative_tier_costs(searched)
    assert flat["auto"] == flat["base"] == 1.0
    priced = relative_tier_costs(searched, mac_counts=macs)
    assert priced["auto"] < priced["base"] == max(priced.values())
    pol = SLOPolicy(searched, auto_tier=True, mac_counts=macs)
    assert pol.cost("auto") < pol.cost("base")
    uniform = uniform_schedule({"8/8": (8, 8), "2/2": (2, 2)})
    assert relative_tier_costs(uniform, mac_counts=macs) \
        == pytest.approx(relative_tier_costs(uniform))


def test_engine_auto_tier_admits_tight_deadline_faster(setup):
    """Engine-level: with SLOPolicy(auto_tier=True), a tight-deadline
    request admitted at the schedule's default 8/8 tier is retagged to the
    faster 2/2 tier at admission (and decodes there), while a
    loose-deadline request keeps the default."""
    cfg, model, params = setup
    sched = uniform_schedule({"8/8": (8, 8), "2/2": (2, 2)})
    rt = Runtime(policy=sched.policy_for(), mode="serve", moe_dropless=True,
                 schedule=sched)
    pol = SLOPolicy(sched, auto_tier=True)
    cost_slow = pol.cost("8/8")
    assert cost_slow > pol.cost("2/2") == 1.0
    eng = ServeEngine(model, params, rt, max_batch=2, max_len=32,
                      decode_chunk=2, scheduler_policy=pol)
    rng = np.random.default_rng(0)
    max_new = 3
    loose = Request(uid=0, prompt=rng.integers(0, cfg.vocab_size, size=4),
                    max_new_tokens=max_new,
                    deadline=10.0 * max_new * cost_slow)
    # Feasible at 2/2 (cost 1.0) but NOT at 8/8.
    tight = Request(uid=1, prompt=rng.integers(0, cfg.vocab_size, size=4),
                    max_new_tokens=max_new,
                    deadline=(max_new * cost_slow) / 2.0)
    h_loose, h_tight = eng.submit(loose), eng.submit(tight)
    events = eng.step()
    assert h_loose.tier == "8/8"       # default kept: 8/8 fits its slack
    assert h_tight.tier == "2/2"       # retagged at admission
    assert eng.stats.tier_autoselects == 1
    eng.drain()
    assert {e.tier for e in h_tight.events} == {"2/2"}
    assert {e.tier for e in h_loose.events} == {"8/8"}


# ------------------------------------------------------------- end to end
def test_autoprec_cli_end_to_end_and_serving(setup, tmp_path):
    """The PR's acceptance invariant: the CLI writes a schedule file whose
    loaded PrecisionSchedule (a) validates with even bits only, (b)
    Pareto-dominates the uniform-8 baseline on modeled cycles within the
    measured-divergence budget, and (c) serves through ServeEngine
    token-identically to the in-memory original with zero weight
    re-preparations."""
    cfg, model, params = setup
    from repro.launch.autoprec import main as autoprec_main
    path = str(tmp_path / "auto_sched.json")
    out = autoprec_main([
        "--arch", "granite-3-8b", "--reduced", "--choices", "2", "4",
        "--calib-batches", "1", "--calib-batch", "2", "--calib-len", "8",
        "--eval-top", "3", "--max-divergence", "0.05", "--out", path])

    loaded, meta = load_schedule_with_meta(path)
    # (a) validates: even truncatable widths everywhere, serving backend.
    assert loaded == out["schedule"]
    assert all(p.w_bits in RUNTIME_W_BITS for p in loaded._all_precisions())
    # (b) dominates uniform-8 on modeled cycles within the divergence
    # budget — recomputed independently from the persisted assignment.
    cost = CostModel.for_config(cfg)
    selected = out["selected"]
    assignment = {n: int(b)
                  for n, b in meta["selected"]["assignment"].items()}
    assert cost.cycles_per_token(assignment) \
        == pytest.approx(selected.cycles_per_token)
    assert selected.cycles_per_token < cost.uniform_cycles(8)
    assert selected.measured_divergence <= 0.05
    assert meta["pareto_front"], "front must be persisted"

    # (c) serving parity: loaded vs in-memory schedule, one shared
    # superplane store, zero preparations after construction.
    rng = np.random.default_rng(7)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               size=3 + i % 3),
                    max_new_tokens=2 + i % 2,
                    tier=("auto", "base")[i % 2]) for i in range(4)]
    rt_mem = Runtime(policy=out["schedule"].policy_for(), mode="serve",
                     moe_dropless=True, schedule=out["schedule"])
    eng_mem = ServeEngine(model, params, rt_mem, max_batch=2, max_len=32,
                          decode_chunk=2)
    rt_load = Runtime(policy=loaded.policy_for(), mode="serve",
                      moe_dropless=True, schedule=loaded)
    eng_load = ServeEngine(model, eng_mem.params, rt_load, max_batch=2,
                           max_len=32, decode_chunk=2)
    preps = engine_mod.PREPARE_CALLS
    got_mem = eng_mem.run(reqs)
    got_load = eng_load.run([dataclasses.replace(r) for r in reqs])
    assert engine_mod.PREPARE_CALLS == preps, "re-prepared after construction"
    assert got_mem == got_load
    assert all(len(v) == r.max_new_tokens
               for r, v in zip(reqs, got_mem.values()))
