"""Property tests: Eq. (1) bit-serial MAC semantics (paper §III-B)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import bitserial, decompose


@given(w_bits=st.integers(2, 8), a_bits=st.integers(2, 8),
       w_signed=st.booleans(), a_signed=st.booleans(),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_eq1_equals_integer_dot(w_bits, a_bits, w_signed, a_signed, seed):
    rng = np.random.default_rng(seed)
    wlo, whi = decompose.weight_range(w_bits, w_signed)
    alo, ahi = decompose.weight_range(a_bits, a_signed)
    w = rng.integers(wlo, whi + 1, size=(9, 5))
    a = rng.integers(alo, ahi + 1, size=(3, 9))
    got = bitserial.bitserial_mac(a, w, a_bits, w_bits,
                                  a_signed=a_signed, w_signed=w_signed)
    want = a.astype(np.int64) @ w.astype(np.int64)
    assert np.array_equal(np.asarray(got), want)


@pytest.mark.parametrize("w_bits", [2, 5, 8])
@pytest.mark.parametrize("a_bits", [2, 8])
def test_eq1_equals_integer_dot_deterministic(w_bits, a_bits):
    """Non-hypothesis fallback: seeded sweep of the Eq. (1) contract."""
    rng = np.random.default_rng(w_bits * 16 + a_bits)
    for w_signed in (True, False):
        for a_signed in (True, False):
            wlo, whi = decompose.weight_range(w_bits, w_signed)
            alo, ahi = decompose.weight_range(a_bits, a_signed)
            w = rng.integers(wlo, whi + 1, size=(9, 5))
            a = rng.integers(alo, ahi + 1, size=(3, 9))
            got = bitserial.bitserial_mac(a, w, a_bits, w_bits,
                                          a_signed=a_signed,
                                          w_signed=w_signed)
            assert np.array_equal(np.asarray(got),
                                  a.astype(np.int64) @ w.astype(np.int64))


def test_sign_bit_plane_is_negative():
    bits, weights = bitserial.activation_bitplanes(
        np.array([-3], np.int32), 4, signed=True)
    assert list(np.asarray(weights)) == [1, 2, 4, -8]
    # -3 = 0b1101 two's complement in 4 bits
    assert list(np.asarray(bits)[:, 0]) == [1, 0, 1, 1]


def test_unsigned_plane_weights_all_positive():
    _, weights = bitserial.activation_bitplanes(
        np.array([7], np.int32), 4, signed=False)
    assert list(np.asarray(weights)) == [1, 2, 4, 8]


def test_cycle_counts():
    assert bitserial.cycles_per_mac(8) == 8
    assert bitserial.shift_add_clock_divider(8) == 8  # clk_SA = clk/8
