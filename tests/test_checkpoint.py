"""Fault-tolerant checkpointing: atomicity, resume, async, elasticity."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32),
                       "c": jnp.ones((2,), jnp.bfloat16)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t, extra={"data_step": 7})
    restored, extra = ckpt.restore(str(tmp_path), 7, _tree(1))
    assert extra == {"data_step": 7}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_step_and_gc(tmp_path):
    t = _tree()
    for s in (1, 5, 9, 12):
        ckpt.save(str(tmp_path), s, t)
    assert ckpt.latest_step(str(tmp_path)) == 12
    ckpt.gc_old(str(tmp_path), keep=2)
    assert ckpt.list_steps(str(tmp_path)) == [9, 12]


def test_interrupted_save_is_ignored(tmp_path):
    """A .tmp dir from a crash mid-save must not be seen as a checkpoint."""
    t = _tree()
    ckpt.save(str(tmp_path), 3, t)
    # Simulate preemption: a partial tmp dir for step 8.
    os.makedirs(tmp_path / "step_00000008.tmp")
    with open(tmp_path / "step_00000008.tmp" / "leaf_00000.npy", "w") as f:
        f.write("garbage")
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_corrupt_manifest_dir_skipped(tmp_path):
    ckpt.save(str(tmp_path), 2, _tree())
    os.makedirs(tmp_path / "step_00000005")          # no manifest.json
    assert ckpt.latest_step(str(tmp_path)) == 2


def test_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.zeros((2, 2))})
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore(str(tmp_path), 1, {"a": jnp.zeros((3, 3))})


def test_missing_leaf_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.zeros((2,))})
    with pytest.raises(KeyError):
        ckpt.restore(str(tmp_path), 1, {"a": jnp.zeros((2,)),
                                        "b": jnp.zeros((2,))})


def test_async_checkpointer(tmp_path):
    acp = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        acp.save(s, _tree(s))
    acp.wait()
    assert ckpt.list_steps(str(tmp_path)) == [2, 3]
    restored, _ = ckpt.restore(str(tmp_path), 3, _tree())
    np.testing.assert_array_equal(
        np.asarray(restored["a"]), np.asarray(_tree(3)["a"]))


def test_elastic_restore_with_sharding_fn(tmp_path):
    """Restore re-places leaves via a caller-provided sharding function
    (mesh may differ between save and load)."""
    t = _tree()
    ckpt.save(str(tmp_path), 4, t)
    calls = []

    def sharding_fn(path, arr):
        calls.append(path)
        return jax.devices()[0]          # place onto the (new) topology

    restored, _ = ckpt.restore(str(tmp_path), 4, _tree(1),
                               sharding_fn=sharding_fn)
    assert len(calls) == len(jax.tree.leaves(t))
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(t["a"]))


def test_train_resume_determinism(tmp_path):
    """Train 4 steps == train 2, checkpoint, restore, train 2 more."""
    from repro.configs import reduced_config
    from repro.core.policy import uniform_policy
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models.layers import Runtime
    from repro.models.transformer import LM
    from repro.train import optimizer as optim
    from repro.train.step import make_train_step

    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    rt = Runtime(policy=uniform_policy(8, 8, backend="dense"))
    ocfg = optim.OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    step = jax.jit(make_train_step(model, rt, ocfg))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                  global_batch=4))

    def run(state, start, n):
        for i in range(start, start + n):
            b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            state, _ = step(state, b)
        return state

    params = model.init(jax.random.PRNGKey(0))
    s_full = run({"params": params, "opt": optim.init_state(params, ocfg)},
                 0, 4)
    s_half = run({"params": params, "opt": optim.init_state(params, ocfg)},
                 0, 2)
    ckpt.save(str(tmp_path), 2, s_half, extra={"data_step": 2})
    target = {"params": params, "opt": optim.init_state(params, ocfg)}
    s_rest, extra = ckpt.restore(str(tmp_path), 2, target)
    s_resumed = run(s_rest, extra["data_step"], 2)
    for a, b in zip(jax.tree.leaves(s_full["params"]),
                    jax.tree.leaves(s_resumed["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)
