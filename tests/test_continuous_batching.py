"""Slot-based continuous batching: scheduler, slot arena, engine semantics.

Covers the refactor's contracts: per-slot admission without re-prefilling
occupied slots, the jitted multi-step decode loop with active masking,
decode-step accounting (the seed's finished-slots-keep-decoding waste bug),
and token-level parity with the batch-at-a-time reference engine.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.policy import uniform_policy
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.serve import slots as slots_lib
from repro.serve.engine import BatchServeEngine, Request, ServeEngine
from repro.serve.scheduler import Scheduler

RT_DENSE = Runtime(policy=uniform_policy(8, 8, backend="dense"),
                   mode="serve", moe_dropless=True)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, n, *, seed=0, plen=lambda i: 3 + i % 5,
              budget=lambda i: 2 + 3 * (i % 3)):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=plen(i)),
                    max_new_tokens=budget(i)) for i in range(n)]


# ---------------------------------------------------------------- scheduler
def test_scheduler_fifo_admission_and_release():
    sched = Scheduler(2)
    reqs = [Request(uid=i, prompt=np.array([1]), max_new_tokens=3)
            for i in range(4)]
    for r in reqs:
        sched.submit(r)
    assert sched.free_slots() == [0, 1]
    assert sched.admit(0).uid == 0
    assert sched.admit(1).uid == 1
    assert sched.free_slots() == []
    with pytest.raises(ValueError):
        sched.admit(0)                      # occupied
    sched.slots[0].tokens = [7, 8, 9]
    sched.slots[0].remaining = 0
    assert sched.release_done() == [0]
    assert sched.finished[0] == [7, 8, 9]
    assert sched.admit(0).uid == 2          # FIFO into the freed slot
    assert sched.has_work


def test_scheduler_admit_empty_queue():
    sched = Scheduler(1)
    assert sched.admit(0) is None
    assert not sched.has_work


# --------------------------------------------------------------- slot arena
def test_slot_arena_view_write_isolation(setup):
    cfg, model, _ = setup
    arena = slots_lib.SlotArena(model, max_slots=3, max_len=16)
    # Fill slot 1's sub-cache with ones, write it back.
    sub = slots_lib.slot_view(arena.caches, 1)
    sub1 = jax.tree.map(jnp.ones_like, sub)
    caches = slots_lib.slot_write(arena.caches, sub1, 1)
    for a, b in zip(jax.tree.leaves(caches), jax.tree.leaves(arena.caches)):
        a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
        np.testing.assert_array_equal(a[:, 1], np.ones_like(a[:, 1]))
        np.testing.assert_array_equal(a[:, 0], b[:, 0])    # others untouched
        np.testing.assert_array_equal(a[:, 2], b[:, 2])
    # Reset restores zeros in that slot only.
    caches = slots_lib.slot_reset(caches, 1)
    for a in jax.tree.leaves(caches):
        np.testing.assert_array_equal(np.asarray(a[:, 1], np.float32), 0)


# ------------------------------------------------------------------- engine
def test_engine_matches_reference_heterogeneous(setup):
    """Continuous batching == batch-at-a-time reference, token-identical,
    with heterogeneous prompt lengths AND decode budgets."""
    cfg, model, params = setup
    reqs = _requests(cfg, 7, seed=3)
    cont = ServeEngine(model, params, RT_DENSE, max_batch=3, max_len=64,
                       decode_chunk=4)
    got = cont.run(reqs)
    ref = BatchServeEngine(model, params, RT_DENSE, max_batch=3, max_len=64)
    want = ref.run(reqs)
    for r in reqs:
        assert len(got[r.uid]) == r.max_new_tokens
        assert got[r.uid] == want[r.uid], r.uid


def test_engine_decode_step_accounting_regression(setup):
    """The seed bug: finished slots kept decoding until the batch-wide
    max_new_tokens.  The active mask must free a slot's decode work the
    step its budget is exhausted: active slot-steps == sum of per-request
    decode budgets exactly, and total executed steps beat the baseline."""
    cfg, model, params = setup
    budgets = [2, 14, 2, 2]
    reqs = _requests(cfg, 4, seed=4, plen=lambda i: 4,
                     budget=lambda i: budgets[i])
    cont = ServeEngine(model, params, RT_DENSE, max_batch=2, max_len=64,
                       decode_chunk=4)
    cont.run(reqs)
    # Token 1 comes from prefill, so each request owes max_new - 1 decode
    # steps; the active mask must execute EXACTLY that much slot work.
    assert cont.stats.decode_slot_steps == sum(b - 1 for b in budgets)

    ref = BatchServeEngine(model, params, RT_DENSE, max_batch=2, max_len=64)
    ref.run(reqs)
    # Batch-at-a-time: both batches decode to their batch max (14 and 2),
    # every slot, regardless of its own budget.
    assert ref.stats.decode_slot_steps == 2 * 14 + 2 * 2
    assert cont.stats.decode_steps < ref.stats.decode_steps


def test_engine_admits_into_freed_slot_without_reprefill(setup):
    """3 requests, 2 slots: when a short request frees its slot, the queued
    request is prefilled into it while the long request's slot keeps its
    cache (no re-prefill of occupied slots => exactly 3 prefills, and the
    long request's output is unaffected by the slot swap)."""
    cfg, model, params = setup
    budgets = [2, 12, 2]
    reqs = _requests(cfg, 3, seed=5, plen=lambda i: 5,
                     budget=lambda i: budgets[i])
    cont = ServeEngine(model, params, RT_DENSE, max_batch=2, max_len=64,
                       decode_chunk=2)
    got = cont.run(reqs)
    assert cont.stats.prefills == 3          # one per request, ever
    solo = ServeEngine(model, params, RT_DENSE, max_batch=1, max_len=64,
                       decode_chunk=2)
    want = solo.run([reqs[1]])
    assert got[1] == want[1]


def test_engine_streaming_submit(setup):
    """submit() mid-flight: requests arriving between decode chunks are
    admitted into freed slots."""
    cfg, model, params = setup
    reqs = _requests(cfg, 4, seed=6)
    eng = ServeEngine(model, params, RT_DENSE, max_batch=2, max_len=64,
                      decode_chunk=2)
    eng.submit(reqs[0])
    eng.submit(reqs[1])
    eng.step()
    eng.submit(reqs[2])                      # arrives while 0/1 decode
    eng.submit(reqs[3])
    while eng.scheduler.has_work:
        eng.step()
    results = eng.results
    solo = ServeEngine(model, params, RT_DENSE, max_batch=1, max_len=64)
    want = solo.run(reqs)
    for r in reqs:
        assert results[r.uid] == want[r.uid]


def test_engine_rejects_oversized_request(setup):
    cfg, model, params = setup
    eng = ServeEngine(model, params, RT_DENSE, max_batch=2, max_len=16)
    with pytest.raises(ValueError):
        eng.submit(Request(uid=0, prompt=np.zeros(12, np.int32),
                           max_new_tokens=8))
    with pytest.raises(ValueError):
        eng.submit(Request(uid=1, prompt=np.zeros(0, np.int32)))
    with pytest.raises(ValueError):
        eng.submit(Request(uid=2, prompt=np.zeros(3, np.int32),
                           max_new_tokens=0))
    # Duplicate uids would silently collide in the results dict.
    eng2 = ServeEngine(model, params, RT_DENSE, max_batch=2, max_len=16)
    eng2.submit(Request(uid=5, prompt=np.zeros(3, np.int32),
                        max_new_tokens=2))
    with pytest.raises(ValueError):
        eng2.submit(Request(uid=5, prompt=np.zeros(3, np.int32),
                            max_new_tokens=2))
    # The baseline engine enforces the same admission contract.
    base = BatchServeEngine(model, params, RT_DENSE, max_batch=2, max_len=16)
    with pytest.raises(ValueError):
        base.run([Request(uid=0, prompt=np.zeros(12, np.int32),
                          max_new_tokens=8)])
    with pytest.raises(ValueError):
        base.run([Request(uid=0, prompt=np.zeros(3, np.int32),
                          max_new_tokens=0)])


def test_engine_prepares_weights_at_construction(setup):
    """The engine's weight preload: raw float params in, QuantizedWeight
    plane pytree resident from construction on."""
    from repro.kernels.ops import QuantizedWeight
    cfg, model, params = setup
    policy = uniform_policy(4, 8, backend="decomposed")
    rt = Runtime(policy=policy, mode="serve", moe_dropless=True)
    eng = ServeEngine(model, params, rt, max_batch=2, max_len=32)
    assert eng.quantized_paths
    qws = [l for l in jax.tree.leaves(
        eng.params, is_leaf=lambda x: isinstance(x, QuantizedWeight))
        if isinstance(l, QuantizedWeight)]
    assert qws and all(q.w_bits == 4 for q in qws)
    reqs = _requests(cfg, 3, seed=8)
    out = eng.run(reqs)
    assert all(len(out[r.uid]) == r.max_new_tokens for r in reqs)


@pytest.mark.parametrize("arch", ["jamba-1.5-large-398b", "mamba2-1.3b"])
def test_engine_ssm_archs_match_reference(arch):
    """Hybrid and pure-SSM stacks: masked SSD state/conv updates keep
    per-request outputs identical to the solo reference."""
    cfg = reduced_config(arch)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = _requests(cfg, 4, seed=9, plen=lambda i: 2 + 3 * (i % 3),
                     budget=lambda i: 1 + 2 * (i % 3))
    cont = ServeEngine(model, params, RT_DENSE, max_batch=2, max_len=64,
                       decode_chunk=3)
    got = cont.run(reqs)
    ref = BatchServeEngine(model, params, RT_DENSE, max_batch=1, max_len=64)
    want = ref.run(reqs)
    for r in reqs:
        assert got[r.uid] == want[r.uid], r.uid


def test_engine_kv_quantized_cache_runs(setup):
    cfg, model, params = setup
    reqs = _requests(cfg, 3, seed=10)
    eng = ServeEngine(model, params, RT_DENSE, max_batch=2, max_len=64,
                      kv_bits=8, decode_chunk=4)
    out = eng.run(reqs)
    assert all(len(out[r.uid]) == r.max_new_tokens for r in reqs)
    assert all(0 <= t < cfg.padded_vocab for v in out.values() for t in v)
