"""MobileNetV2-style quantized conv net (the paper's own workload)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import uniform_policy
from repro.models.convnet import ConvNet, ConvNetConfig
from repro.models.layers import Runtime


def test_forward_shapes_and_finite():
    cfg = ConvNetConfig()
    net = ConvNet(cfg)
    params = net.init(jax.random.PRNGKey(0))
    rt = Runtime(policy=uniform_policy(4, 8, backend="fake_quant",
                                       a_signed=False))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    logits = net.apply(params, x, rt)
    assert logits.shape == (2, 10)
    assert np.isfinite(np.asarray(logits)).all()


def test_learns_synthetic_classes():
    """Mixed-precision QAT learns a linearly-separable image task."""
    cfg = ConvNetConfig(num_classes=4, blocks=((1, 16, 1), (4, 24, 2)))
    net = ConvNet(cfg)
    params = net.init(jax.random.PRNGKey(0))
    rt = Runtime(policy=uniform_policy(4, 8, backend="fake_quant",
                                       a_signed=False))
    rng = np.random.default_rng(0)

    # Class = channel brightness pattern (survives global mean pooling).
    patterns = np.array([[1, 0, 0], [0, 1, 0], [0, 0, 1],
                         [0.6, 0.6, 0.6]], np.float32)

    def batch(i):
        ys = rng.integers(0, 4, size=16)
        xs = rng.normal(size=(16, 32, 32, 3)).astype(np.float32) * 0.1
        xs += patterns[ys][:, None, None, :]
        return jnp.asarray(xs), jnp.asarray(ys)

    def loss_fn(p, xs, ys):
        logits = net.apply(p, xs, rt)
        lse = jax.nn.logsumexp(logits, -1)
        ll = jnp.take_along_axis(logits, ys[:, None], 1)[:, 0]
        return jnp.mean(lse - ll)

    @jax.jit
    def step(p, xs, ys):
        l, g = jax.value_and_grad(loss_fn)(p, xs, ys)
        # signSGD: scale-robust for the tiny-logit toy net
        return l, jax.tree.map(lambda a, b: a - 0.01 * jnp.sign(b), p, g)

    losses = []
    for i in range(40):
        xs, ys = batch(i)
        l, params = step(params, xs, ys)
        losses.append(float(l))
    assert losses[-1] < losses[0] * 0.8, losses[::6]
