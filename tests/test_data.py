"""Deterministic synthetic data pipeline."""
import numpy as np

from repro.data.pipeline import DataConfig, SyntheticLM


def test_deterministic_across_instances():
    a = SyntheticLM(DataConfig(vocab_size=100, seq_len=8, global_batch=4))
    b = SyntheticLM(DataConfig(vocab_size=100, seq_len=8, global_batch=4))
    for step in (0, 1, 17):
        np.testing.assert_array_equal(a.batch(step)["tokens"],
                                      b.batch(step)["tokens"])


def test_steps_differ():
    d = SyntheticLM(DataConfig(vocab_size=100, seq_len=8, global_batch=4))
    assert not np.array_equal(d.batch(0)["tokens"], d.batch(1)["tokens"])


def test_shards_differ_and_cover_batch():
    d = SyntheticLM(DataConfig(vocab_size=1000, seq_len=8, global_batch=8))
    s0 = d.batch(0, shard=0, num_shards=2)
    s1 = d.batch(0, shard=1, num_shards=2)
    assert s0["tokens"].shape == (4, 8)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_labels_are_next_tokens():
    d = SyntheticLM(DataConfig(vocab_size=50, seq_len=8, global_batch=2,
                               task="uniform"))
    b = d.batch(0)
    assert b["tokens"].shape == b["labels"].shape


def test_arith_task_is_learnable_structure():
    """>=80 % of transitions follow the (x + stride) % V rule."""
    d = SyntheticLM(DataConfig(vocab_size=97, seq_len=64, global_batch=8))
    b = d.batch(0)
    toks, labels = b["tokens"], b["labels"]
    hits = 0
    total = 0
    for r in range(toks.shape[0]):
        # infer stride from the most common delta
        deltas = (labels[r] - toks[r]) % 97
        stride = np.bincount(deltas).argmax()
        hits += (deltas == stride).sum()
        total += len(deltas)
    assert hits / total > 0.75


def test_embed_stub_output():
    d = SyntheticLM(DataConfig(vocab_size=100, seq_len=8, global_batch=2,
                               embed_dim=16))
    b = d.batch(0)
    assert b["embeds"].shape == (2, 8, 16)
    assert b["embeds"].dtype == np.float32
