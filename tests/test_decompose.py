"""Property tests: Table-I weight decomposition (paper §III-A)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import decompose


BITS = st.integers(min_value=2, max_value=8)


@given(bits=BITS, signed=st.booleans(), data=st.data())
@settings(max_examples=60, deadline=None)
def test_roundtrip(bits, signed, data):
    lo, hi = decompose.weight_range(bits, signed)
    w = data.draw(st.lists(st.integers(lo, hi), min_size=1, max_size=64))
    w = np.asarray(w, np.int32)
    planes = decompose.decompose_weights(w, bits, signed=signed)
    back = decompose.recompose_weights(planes, bits, signed=signed)
    assert np.array_equal(np.asarray(back), w)


@given(bits=BITS, signed=st.booleans(), data=st.data())
@settings(max_examples=40, deadline=None)
def test_plane_value_ranges(bits, signed, data):
    """Every plane stays within its Table-I mode range: non-MSB planes are
    unsigned 2-bit; the MSB plane is signed 2- or 3-bit (or unsigned)."""
    lo, hi = decompose.weight_range(bits, signed)
    w = np.asarray(data.draw(st.lists(st.integers(lo, hi), min_size=4,
                                      max_size=64)), np.int32)
    planes = np.asarray(decompose.decompose_weights(w, bits, signed=signed))
    for c in range(planes.shape[0]):
        plo, phi = decompose.plane_value_range(bits, c, signed)
        assert planes[c].min() >= plo and planes[c].max() <= phi


def test_schedule_matches_table1():
    assert decompose.DECOMP_SCHEDULE == {
        2: (2,), 3: (3,), 4: (2, 2), 5: (3, 2), 6: (2, 2, 2),
        7: (3, 2, 2), 8: (2, 2, 2, 2)}


def test_plane_shifts_are_2c():
    for bits in decompose.SUPPORTED_BITS:
        p = decompose.num_planes(bits)
        assert decompose.plane_shifts(bits) == tuple(2 * c for c in range(p))


def test_only_msb_plane_is_3bit():
    for bits, widths in decompose.DECOMP_SCHEDULE.items():
        assert all(w == 2 for w in widths[1:])
        assert widths[0] in (2, 3)


@pytest.mark.parametrize("bits", range(2, 9))
@pytest.mark.parametrize("signed", [True, False])
def test_roundtrip_deterministic(bits, signed):
    """Non-hypothesis fallback: exhaustive roundtrip over the full range."""
    lo, hi = decompose.weight_range(bits, signed)
    w = np.arange(lo, hi + 1, dtype=np.int32)
    planes = decompose.decompose_weights(w, bits, signed=signed)
    back = decompose.recompose_weights(planes, bits, signed=signed)
    assert np.array_equal(np.asarray(back), w)
    for c in range(planes.shape[0]):
        plo, phi = decompose.plane_value_range(bits, c, signed)
        pc = np.asarray(planes[c])
        assert pc.min() >= plo and pc.max() <= phi


@given(bits=BITS)
@settings(max_examples=10, deadline=None)
def test_decomposed_matmul_exact(bits):
    rng = np.random.default_rng(bits)
    lo, hi = decompose.weight_range(bits, True)
    w = rng.integers(lo, hi + 1, size=(23, 11))
    x = rng.integers(-128, 128, size=(5, 23))
    planes = decompose.decompose_weights(w, bits)
    got = decompose.decomposed_matmul(x, planes, bits)
    assert np.array_equal(np.asarray(got),
                          x.astype(np.int64) @ w.astype(np.int64))
