"""Distributed substrate: sharding rules, gradient compression, pipeline
parallelism, reduced dry-run.  Multi-device tests run in subprocesses with
XLA_FLAGS-faked CPU devices so the main test session keeps 1 device.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(body: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_param_sharding_rules_cover_all_archs():
    """Every full-config param leaf gets a valid spec on a tiny fake mesh."""
    from repro.configs import ARCHS
    from repro.distributed import sharding_rules as rules
    from repro.models.transformer import LM
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch, cfg in ARCHS.items():
        shapes = jax.eval_shape(LM(cfg).init, jax.random.key(0))
        sh = rules.tree_shardings(mesh, shapes)
        assert len(jax.tree.leaves(sh)) == len(jax.tree.leaves(shapes))


def test_projection_specs_are_2d_sharded():
    from repro.configs import get_config
    from repro.distributed import sharding_rules as rules
    from repro.models.transformer import LM
    import numpy as np
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("qwen3-8b")
    shapes = jax.eval_shape(LM(cfg).init, jax.random.key(0))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    specs = {jax.tree_util.keystr(kp): rules.param_spec(
        mesh, jax.tree_util.keystr(kp), leaf) for kp, leaf in flat}
    qproj = next(v for k, v in specs.items() if "q_proj" in k)
    assert qproj == jax.sharding.PartitionSpec(None, "data", "model")
    emb = next(v for k, v in specs.items() if "emb" in k)
    assert emb == jax.sharding.PartitionSpec("model", "data")


def test_grok_expert_fallback_to_tp():
    """8 experts cannot divide a 16-way model axis -> 2D TP fallback."""
    from repro.distributed import sharding_rules as rules
    import numpy as np
    devs = np.array(jax.devices() * 16)[:16].reshape(1, 16)
    mesh = jax.sharding.Mesh(devs, ("data", "model"))
    leaf = jax.ShapeDtypeStruct((8, 6144, 32768), jnp.bfloat16)
    spec = rules.param_spec(mesh, "['periods']['pos0']['moe']['gate_proj']['w']",
                            leaf)
    assert spec[0] is None          # experts NOT sharded (8 % 16 != 0)


def test_compressed_psum_matches_mean():
    out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import compressed_psum
        from repro.distributed.sharding import shard_map
        mesh = jax.make_mesh((8,), ("dp",))
        def f(g, e):
            return compressed_psum(g, e, axis_name="dp", bits=8)
        fm = jax.jit(shard_map(f, mesh=mesh, in_specs=(P("dp"), P("dp")),
                               out_specs=(P("dp"), P("dp"))))
        rng = np.random.default_rng(0)
        g = rng.normal(size=(8, 16, 32)).astype(np.float32)
        e = np.zeros_like(g)
        mean, err = fm(g, e)
        mean = np.asarray(mean)
        want = g.mean(0, keepdims=True)
        rel = np.abs(mean - want).max() / np.abs(want).max()
        assert rel < 0.05, rel
        # error feedback: err holds the residual
        assert np.abs(np.asarray(err)).max() > 0
        print("COMPRESSION_OK", rel)
    """)
    assert "COMPRESSION_OK" in out


def test_error_feedback_reduces_bias():
    """Averaged over steps, error feedback drives the compression bias ~0."""
    out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import compressed_psum
        from repro.distributed.sharding import shard_map
        mesh = jax.make_mesh((4,), ("dp",))
        def f(g, e):
            return compressed_psum(g, e, axis_name="dp", bits=8)
        fm = jax.jit(shard_map(f, mesh=mesh, in_specs=(P("dp"), P("dp")),
                               out_specs=(P("dp"), P("dp"))))
        rng = np.random.default_rng(1)
        g = rng.normal(size=(4, 8, 8)).astype(np.float32)  # constant grads
        e = np.zeros_like(g)
        acc = 0.0
        n = 20
        for _ in range(n):
            mean, e = fm(g, e)
            acc = acc + np.asarray(mean)
        want = g.mean(0, keepdims=True) * n
        rel = np.abs(acc - want).max() / np.abs(want).max()
        assert rel < 0.01, rel
        print("EF_OK", rel)
    """)
    assert "EF_OK" in out


def test_pipeline_matches_sequential():
    out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import run_pipeline
        mesh = jax.make_mesh((4,), ("stage",))
        def stage_fn(w, x):
            return jnp.tanh(x @ w)
        rng = np.random.default_rng(0)
        ws = rng.normal(size=(4, 16, 16)).astype(np.float32) * 0.5
        xs = rng.normal(size=(6, 3, 16)).astype(np.float32)  # 6 microbatches
        got = np.asarray(run_pipeline(mesh, stage_fn, jnp.asarray(ws),
                                      jnp.asarray(xs)))
        want = xs
        for s in range(4):
            want = np.tanh(want @ ws[s])
        assert np.allclose(got, want, atol=1e-5), np.abs(got-want).max()
        print("PIPELINE_OK")
    """)
    assert "PIPELINE_OK" in out


def test_reduced_dryrun_end_to_end(tmp_path):
    """The dry-run driver itself (2x2 mesh, tiny config) — lowering,
    compile, memory/cost/collective extraction."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["REPRO_DRYRUN_DEVICES"] = "4"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen3-8b",
         "--shape", "train_4k", "--reduced", "--out", str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    res = json.load(open(tmp_path / "qwen3-8b__train_4k__2x2.json"))
    assert not res["skipped"]
    assert res["flops"] > 0
    assert res["collectives"]["total_bytes"] > 0
    assert res["memory"]["temp_size_in_bytes"] > 0


def test_long500k_skip_rule():
    from repro.configs import get_config
    from repro.launch.specs import SHAPES, cell_applicable
    ok, _ = cell_applicable(get_config("qwen3-8b"), SHAPES["long_500k"])
    assert not ok
    ok, _ = cell_applicable(get_config("mamba2-1.3b"), SHAPES["long_500k"])
    assert ok
    ok, _ = cell_applicable(get_config("jamba-1.5-large-398b"),
                            SHAPES["long_500k"])
    assert ok


def test_make_production_mesh_shapes():
    """Mesh factory contract (validated on fake devices in a subprocess)."""
    out = run_subprocess("""
        from repro.launch.mesh import make_production_mesh
        m = make_production_mesh()
        assert m.devices.shape == (16, 16) and m.axis_names == ("data", "model")
        m2 = make_production_mesh(multi_pod=True)
        assert m2.devices.shape == (2, 16, 16)
        assert m2.axis_names == ("pod", "data", "model")
        print("MESH_OK")
    """, devices=512)
    assert "MESH_OK" in out


def test_elastic_resume_across_device_counts(tmp_path):
    """Checkpoint written under a 4-device mesh restores onto 8 devices —
    the elastic re-shard contract."""
    body_save = """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import checkpoint as ckpt
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        xs = jax.device_put(x, NamedSharding(mesh, P("data")))
        ckpt.save(r"CKPT_DIR", 1, {"w": xs}, extra={"mesh": len(jax.devices())})
        print("SAVED", len(jax.devices()))
    """.replace("CKPT_DIR", str(tmp_path))
    body_load = """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import checkpoint as ckpt
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        target = {"w": jnp.zeros((8, 8), jnp.float32)}
        def shard_fn(path, arr):
            return NamedSharding(mesh, P("data"))
        restored, extra = ckpt.restore(r"CKPT_DIR", 1, target,
                                       sharding_fn=shard_fn)
        w = restored["w"]
        assert len(w.sharding.device_set) == len(jax.devices())
        np.testing.assert_array_equal(
            np.asarray(w), np.arange(64, dtype=np.float32).reshape(8, 8))
        print("RESTORED", len(jax.devices()), "from", extra["mesh"])
    """.replace("CKPT_DIR", str(tmp_path))
    out = run_subprocess(body_save, devices=4)
    assert "SAVED 4" in out
    out = run_subprocess(body_load, devices=8)
    assert "RESTORED 8 from 4" in out


def test_reduced_dryrun_decode_cell(tmp_path):
    """Decode-kind cell through the dry-run driver (prepared quantized
    weights + KV caches + serve_step lowering)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["REPRO_DRYRUN_DEVICES"] = "4"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mamba2-1.3b",
         "--shape", "long_500k", "--reduced", "--kv-bits", "8",
         "--out", str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    res = json.load(open(tmp_path / "mamba2-1.3b__long_500k__2x2.json"))
    assert not res["skipped"]
    assert res["kind"] == "decode"
    assert res["flops"] > 0
