"""Docs CI: every relative link and code path referenced in README.md and
docs/*.md must exist in the repo (pure file checks — no JAX import, so the
docs CI job can run this standalone)."""
import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC_FILES = [os.path.join(REPO, "README.md")] + sorted(
    os.path.join(REPO, "docs", f)
    for f in os.listdir(os.path.join(REPO, "docs")) if f.endswith(".md"))

# [text](target) markdown links, skipping images is irrelevant here.
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#][^)]*)\)")
# `inline code` spans that look like file paths: contain a "/" or end in a
# known source suffix.  Python dotted module paths and attribute references
# are skipped.
_CODE_RE = re.compile(r"`([^`\s]+)`")
_PATH_SUFFIXES = (".py", ".md", ".txt", ".yml", ".yaml", ".json")
# Paths inside backticks may be repo-relative or package-relative.
_SEARCH_ROOTS = ("", "src/repro", "src")


def _doc_ids():
    return [os.path.relpath(p, REPO) for p in DOC_FILES]


def _exists_anywhere(path: str) -> bool:
    for root in _SEARCH_ROOTS:
        if os.path.exists(os.path.join(REPO, root, path)):
            return True
    return False


def test_docs_exist_and_are_linked_from_readme():
    assert os.path.isfile(os.path.join(REPO, "docs", "architecture.md"))
    assert os.path.isfile(os.path.join(REPO, "docs", "serving.md"))
    assert os.path.isfile(os.path.join(REPO, "docs", "autoprec.md"))
    assert os.path.isfile(os.path.join(REPO, "docs", "distributed.md"))
    assert os.path.isfile(os.path.join(REPO, "docs", "speculative.md"))
    assert os.path.isfile(os.path.join(REPO, "docs", "observability.md"))
    readme = open(os.path.join(REPO, "README.md")).read()
    assert "docs/architecture.md" in readme, "README must link the docs"
    assert "docs/serving.md" in readme, "README must link the docs"
    assert "docs/autoprec.md" in readme, "README must link the docs"
    assert "docs/distributed.md" in readme, "README must link the docs"
    assert "docs/speculative.md" in readme, "README must link the docs"
    assert "docs/observability.md" in readme, "README must link the docs"
    arch = open(os.path.join(REPO, "docs", "architecture.md")).read()
    assert "speculative.md" in arch, \
        "architecture.md must link the speculative-decoding doc"
    serving = open(os.path.join(REPO, "docs", "serving.md")).read()
    assert "observability.md" in serving, \
        "serving.md must link the observability doc"


@pytest.mark.parametrize("doc", _doc_ids())
def test_relative_links_resolve(doc):
    path = os.path.join(REPO, doc)
    base = os.path.dirname(path)
    text = open(path).read()
    missing = []
    for target in _LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        target = target.split("#")[0]
        if not target:
            continue
        if not os.path.exists(os.path.join(base, target)):
            missing.append(target)
    assert not missing, f"{doc}: broken relative links: {missing}"


@pytest.mark.parametrize("doc", _doc_ids())
def test_code_paths_exist(doc):
    text = open(os.path.join(REPO, doc)).read()
    missing = []
    for span in _CODE_RE.findall(text):
        span = span.rstrip(",.;:")
        looks_like_path = "/" in span and span.endswith(_PATH_SUFFIXES)
        if not looks_like_path:
            continue
        if not _exists_anywhere(span):
            missing.append(span)
    assert not missing, f"{doc}: referenced code paths not found: {missing}"
