"""One-kernel mixed-tier decode: group-switching GEMM + fused hot path.

Bit-identity contract (interpret mode, CPU):

  * ``grouped_matmul`` (one Pallas dispatch, per-row-block plane-prefix
    depth via the multiplier table) == per-group ``bitserial_matmul`` /
    ``packed_bitserial_matmul`` calls == ``decompose.decomposed_matmul_
    grouped`` — across all even tiers, signedness, packed/unpacked stores
    and non-trivial permutations;
  * ``ops.matmul(fused=True)`` == the per-group legacy loop
    (``fused=False``), eager AND jitted;
  * ``quantize_activations_grouped`` (one per-row-range pass) == per-config
    ``quantize_activations`` row-for-row;
  * engine level: a fused ``ServeEngine`` emits bit-identical tokens to
    ``fused_decode=False``, and the fused decode step's Pallas dispatch
    count is CONSTANT in the number of tier groups (regression test for
    the O(groups) -> O(1) dispatch claim).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core import decompose
from repro.core.policy import LayerPrecision, uniform_schedule
from repro.kernels import ops, ref
from repro.kernels import grouped_matmul as gmm
from repro.kernels.act_quant import act_quant_rows
from repro.kernels.bitserial_matmul import (bitserial_matmul,
                                            packed_bitserial_matmul)
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.serve.engine import ServeEngine
from repro.serve.request import Request

TIERS = {"8/8": (8, 8), "6/6": (6, 8), "4/4": (4, 4), "2/2": (2, 2)}


# ------------------------------------------------------------ kernel level
@pytest.mark.parametrize("packed", [False, True])
@pytest.mark.parametrize("signed", [True, False])
def test_grouped_matmul_vs_per_group(packed, signed):
    """ONE group-switching dispatch == per-group kernel calls == oracle,
    over a 4-group 8/6/4/2 layout."""
    rng = np.random.default_rng(7)
    m, k, n = 128, 128, 128
    row_groups = ((32, 8), (32, 6), (32, 4), (32, 2))
    lo, hi = decompose.weight_range(8, signed)
    q8 = rng.integers(lo, hi + 1, size=(k, n)).astype(np.int16)
    planes = decompose.decompose_superplanes(jnp.asarray(q8),
                                             signed=signed)  # MSB-first
    x = rng.integers(-128, 128, size=(m, k)).astype(np.int8)

    plane_groups = tuple((r, decompose.num_prefix_planes(b))
                         for r, b in row_groups)
    mult = jnp.asarray(decompose.prefix_multipliers(plane_groups))
    pmax = max(p for _, p in plane_groups)

    if packed:
        wmat = ops.pack_planes(planes[::-1], 8)   # pack wants LSB-first
        got = gmm.grouped_matmul(jnp.asarray(x), wmat, mult, nplanes=pmax,
                                 packed=True, signed=signed, interpret=True)
    else:
        got = gmm.grouped_matmul(jnp.asarray(x), planes[:pmax], mult,
                                 nplanes=pmax, signed=signed, interpret=True)

    want = decompose.decomposed_matmul_grouped(jnp.asarray(x), planes,
                                               row_groups)
    assert np.array_equal(np.asarray(got), np.asarray(want))

    # ... and == the per-group single-tier kernels the fused path replaced.
    off = 0
    for rows, eff in row_groups:
        xg = jnp.asarray(x[off:off + rows])
        xg = jnp.concatenate([xg] * (128 // rows), axis=0)  # pad to bm tile
        if packed:
            per = packed_bitserial_matmul(xg, wmat, w_bits=8, eff_bits=eff,
                                          signed=signed, interpret=True)
        else:
            per = bitserial_matmul(
                xg, planes[:decompose.num_prefix_planes(eff)], w_bits=eff,
                msb_first=True, interpret=True)
        assert np.array_equal(np.asarray(per)[:rows],
                              np.asarray(got)[off:off + rows]), (rows, eff)
        off += rows


def test_prefix_multipliers_exact():
    """mult[r, c] = 4^(P'_r - 1 - c) inside the row's prefix, 0 beyond —
    the compile-time table that gives each row block its shift chain."""
    pg = ((2, 4), (1, 3), (2, 1))
    mult = decompose.prefix_multipliers(pg)
    assert mult.shape == (5, 4) and mult.dtype == np.int32
    assert mult[0].tolist() == [64, 16, 4, 1]
    assert mult[2].tolist() == [16, 4, 1, 0]
    assert mult[3].tolist() == [1, 0, 0, 0]


@pytest.mark.parametrize("backend", ["decomposed", "pallas"])
@pytest.mark.parametrize("packed", [False, True])
def test_ops_matmul_fused_vs_legacy(backend, packed):
    """float-in/float-out: fused one-kernel path == per-group legacy loop,
    bitwise, eager and jitted, with a non-trivial permutation."""
    rng = np.random.default_rng(1)
    k, n = 96, 80
    w = rng.normal(size=(k, n)).astype(np.float32)
    x = jnp.asarray(rng.normal(size=(6, k)).astype(np.float32))
    qw = ops.prepare_superplane(jnp.asarray(w), packed=packed)
    rg = tuple((r, LayerPrecision(b, a, backend=backend))
               for r, b, a in ((2, 8, 8), (1, 6, 8), (2, 4, 4), (1, 2, 2)))
    perm = jnp.asarray(np.array([3, 1, 4, 0, 5, 2], np.int32))

    def run(fused):
        return ops.matmul(x, None, rg[0][1], qw=qw, row_groups=rg,
                          perm=perm, fused=fused)

    y_legacy = np.asarray(run(False), np.float32)
    assert np.array_equal(np.asarray(run(True), np.float32), y_legacy)
    # auto-eligibility (fused=None) picks the fused path: same bits.
    assert np.array_equal(np.asarray(run(None), np.float32), y_legacy)
    # jitted == eager == each other (the engine always runs jitted).
    jf = np.asarray(jax.jit(lambda: run(True))(), np.float32)
    ju = np.asarray(jax.jit(lambda: run(False))(), np.float32)
    assert np.array_equal(jf, ju) and np.array_equal(jf, y_legacy)


def test_ops_matmul_fused_3d_decode_shape():
    """[B, 1, K] decode shape through the fused path == legacy, bitwise."""
    rng = np.random.default_rng(2)
    x3 = jnp.asarray(rng.normal(size=(7, 1, 64)).astype(np.float32))
    qw = ops.prepare_superplane(
        jnp.asarray(rng.normal(size=(64, 48)).astype(np.float32)))
    rg = tuple((r, LayerPrecision(b, a, backend="decomposed"))
               for r, b, a in ((3, 8, 8), (2, 4, 4), (2, 2, 2)))
    perm = jnp.asarray(np.array([6, 0, 2, 4, 1, 3, 5], np.int32))
    yf = ops.matmul(x3, None, rg[0][1], qw=qw, row_groups=rg, perm=perm,
                    fused=True)
    yr = ops.matmul(x3, None, rg[0][1], qw=qw, row_groups=rg, perm=perm,
                    fused=False)
    assert yf.shape == (7, 1, 48)
    assert np.array_equal(np.asarray(yf, np.float32),
                          np.asarray(yr, np.float32))


def test_fused_requires_one_backend_and_signed():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(2, 32)).astype(np.float32))
    qw = ops.prepare_superplane(
        jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32)))
    perm = jnp.asarray(np.arange(2, dtype=np.int32))
    mixed_be = ((1, LayerPrecision(8, 8, backend="decomposed")),
                (1, LayerPrecision(4, 4, backend="pallas")))
    with pytest.raises(ValueError, match="one integer backend"):
        ops.matmul(x, None, mixed_be[0][1], qw=qw, row_groups=mixed_be,
                   perm=perm, fused=True)
    unsigned = ((1, LayerPrecision(8, 8, backend="decomposed")),
                (1, LayerPrecision(4, 4, a_signed=False,
                                   backend="decomposed")))
    with pytest.raises(ValueError, match="signed activations"):
        ops.matmul(x, None, unsigned[0][1], qw=qw, row_groups=unsigned,
                   perm=perm, fused=True)
    # ...and auto-eligibility (fused=None) silently falls back to legacy.
    y = ops.matmul(x, None, unsigned[0][1], qw=qw, row_groups=unsigned,
                   perm=perm)
    assert y.shape == (2, 16)


# --------------------------------------------------------- activation quant
def test_quantize_activations_grouped_vs_per_config():
    """One per-row-range pass == per-config quantization, row for row."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(6, 64)).astype(np.float32))
    rg = tuple((r, LayerPrecision(b, a, backend="decomposed"))
               for r, b, a in ((2, 8, 8), (1, 6, 8), (2, 4, 4), (1, 2, 2)))
    perm = np.array([3, 1, 4, 0, 5, 2], np.int32)
    qg, sg = ops.quantize_activations_grouped(x, rg, jnp.asarray(perm))
    bits = [8, 8, 8, 4, 4, 2]     # per sorted row
    for i in range(6):
        qe, se = ops.quantize_activations(x, bits[i], signed=True)
        assert np.array_equal(np.asarray(qg)[i], np.asarray(qe)[perm[i]]), i
        assert np.array_equal(np.asarray(sg)[i], np.asarray(se)[perm[i]]), i


def test_act_quant_rows_kernel_vs_ref():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32))
    qmax = jnp.asarray(
        rng.choice([1.0, 7.0, 31.0, 127.0], size=(128, 1)).astype(np.float32))
    qk, sk = act_quant_rows(x, qmax, interpret=True)
    qr, sr = ref.act_quant_rows_ref(x, qmax)
    assert np.array_equal(np.asarray(qk), np.asarray(qr))
    assert np.array_equal(np.asarray(sk), np.asarray(sr))


def test_act_quant_scale_jit_stable():
    """The quant scale must not depend on compilation context (the fused /
    per-group bit-identity contract rests on it): jit == eager, bitwise."""
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))
    for bits in (2, 4, 6, 8):
        qe, se = ops.quantize_activations(x, bits, signed=True)
        qj, sj = jax.jit(
            lambda v, b=bits: ops.quantize_activations(v, b, signed=True))(x)
        assert np.array_equal(np.asarray(qe), np.asarray(qj)), bits
        assert np.array_equal(np.asarray(se), np.asarray(sj)), bits


# ------------------------------------------------------------- engine level
KV_TIERS = {"8/8": None, "4/4": 8, "2/2": 4}
ENGINE_TIERS = {"8/8": (8, 8), "4/4": (4, 4), "2/2": (2, 2)}


def _mk_engine(model, params, rt, **kw):
    return ServeEngine(model, params, rt, max_batch=3, max_len=64,
                       decode_chunk=3, **kw)


def _requests(cfg, rng):
    tiers = ["8/8", "4/4", "2/2", "2/2", "8/8", "4/4"]
    return [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               size=3 + i % 4),
                    max_new_tokens=b, tier=t)
            for i, (t, b) in enumerate(zip(tiers, (7, 8, 6, 4, 2, 3)))]


def test_engine_fused_token_identity_and_layout_cache():
    """Fused decode == per-group decode, token for token; repeated slot-tier
    vectors hit the layout cache; dispatch counts get recorded."""
    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = uniform_schedule(ENGINE_TIERS, kv_tiers=KV_TIERS)
    rt = Runtime(policy=sched.policy_for(), mode="serve", moe_dropless=True,
                 schedule=sched)
    rng = np.random.default_rng(11)
    reqs = _requests(cfg, rng)

    eng_f = _mk_engine(model, params, rt, count_dispatches=True)
    got_f = eng_f.run(reqs)
    eng_u = _mk_engine(model, eng_f.params, rt, fused_decode=False)
    got_u = eng_u.run([Request(uid=r.uid, prompt=r.prompt,
                               max_new_tokens=r.max_new_tokens, tier=r.tier)
                       for r in reqs])
    assert got_f == got_u

    # Budgets span >1 chunk at the same occupancy: the second chunk's
    # layout derivation must be a cache hit, not a re-sort.
    assert eng_f.stats.layout_cache_hits > 0
    assert eng_f.stats.layout_cache_misses >= 1
    # count_dispatches=True records one jaxpr count per distinct layout
    # (decomposed backend on CPU -> zero pallas_call equations).
    assert len(eng_f.stats.decode_dispatches) >= 1
    assert all(v == 0 for v in eng_f.stats.decode_dispatches.values())
    assert eng_u.stats.decode_dispatches == {}


def test_decode_dispatch_count_constant_in_groups():
    """Regression: with the pallas backend, the fused decode step costs a
    CONSTANT number of Pallas dispatches regardless of how many tier
    groups share the batch; the per-group path scales linearly.  Counted
    by tracing (jax.make_jaxpr) — nothing executes, so this runs on CPU."""
    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = uniform_schedule(ENGINE_TIERS, backend="pallas",
                             kv_tiers=KV_TIERS)
    rt = Runtime(policy=sched.policy_for(), mode="serve", moe_dropless=True,
                 schedule=sched)
    eng_f = ServeEngine(model, params, rt, max_batch=4, max_len=64,
                        decode_chunk=2)
    eng_u = ServeEngine(model, eng_f.params, rt, max_batch=4, max_len=64,
                        decode_chunk=2, fused_decode=False)
    g2 = (("8/8", 2), ("4/4", 2))
    g3 = (("8/8", 1), ("4/4", 2), ("2/2", 1))
    n2f = eng_f.decode_dispatch_count(groups=g2)
    n3f = eng_f.decode_dispatch_count(groups=g3)
    n2u = eng_u.decode_dispatch_count(groups=g2)
    n3u = eng_u.decode_dispatch_count(groups=g3)
    assert n2f == n3f, (n2f, n3f)          # group-count independent
    assert n2f < n2u and n3f < n3u         # and strictly fewer dispatches
    assert n3u > n2u                       # per-group pays per group
