"""Loop-aware HLO cost parser: the roofline's data source."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost


def _analyze(f, *shapes):
    c = jax.jit(f).lower(*shapes).compile()
    return hlo_cost.analyze(c.as_text())


def test_scan_flops_scaled_by_trip_count():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def f_scan(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    def f_unroll(x, ws):
        for i in range(ws.shape[0]):
            x, _ = body(x, ws[i])
        return x

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    exp = 2 * 64 * 128 * 128 * 8
    a_scan = _analyze(f_scan, x, ws)
    a_unroll = _analyze(f_unroll, x, ws)
    assert a_scan["flops"] == pytest.approx(exp, rel=0.01)
    assert a_unroll["flops"] == pytest.approx(exp, rel=0.01)
    # XLA's own cost_analysis undercounts the scan (sanity of the premise).
    # Older jax returns a one-element list of dicts, newer returns the dict.
    ca = jax.jit(f_scan).lower(x, ws).compile().cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert ca["flops"] < exp / 4


def test_nested_scan():
    def f(x, ws):
        def outer(x, w):
            def inner(y, _):
                return jnp.tanh(y @ w), None
            return jax.lax.scan(inner, x, None, length=3)[0], None
        return jax.lax.scan(outer, x, ws)[0]

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    exp = 2 * 32 * 64 * 64 * 4 * 3
    assert _analyze(f, x, ws)["flops"] == pytest.approx(exp, rel=0.01)


def test_plain_matmul_flops():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((128, 256), jnp.bfloat16)
    b = jax.ShapeDtypeStruct((256, 512), jnp.bfloat16)
    got = _analyze(f, a, b)
    assert got["flops"] == pytest.approx(2 * 128 * 256 * 512, rel=0.01)
    # bytes >= operands + result; XLA:CPU promotes bf16 dots to f32 (explicit
    # converts + f32 dot), inflating traffic up to ~6x vs native-bf16 TPU —
    # documented in EXPERIMENTS.md §Roofline caveats.
    exp_bytes = (128 * 256 + 256 * 512 + 128 * 512) * 2
    assert exp_bytes <= got["bytes"] <= 6 * exp_bytes


def test_collective_parsing_synthetic():
    hlo = """
HloModule test

ENTRY %main (p: f32[64,128]) -> f32[64,128] {
  %p = f32[64,128]{1,0} parameter(0)
  %ag = f32[64,128]{1,0} all-gather(%x), replica_groups=[16,4]<=[64], dimensions={0}
  %ar = f32[64,128]{1,0} all-reduce(%ag), replica_groups=[8,8]<=[64], to_apply=%add
  %rs = f32[64,128]{1,0} reduce-scatter(%ar), replica_groups=[16,4]<=[64], dimensions={0}
  ROOT %cp = f32[64,128]{1,0} collective-permute(%rs), source_target_pairs={{0,1}}
}
"""
    res = hlo_cost.analyze(hlo)
    n = 64 * 128 * 4
    c = res["collectives"]["bytes_per_op"]
    assert c["all-gather"] == n / 4           # operand = result / group
    assert c["all-reduce"] == n
    assert c["reduce-scatter"] == n * 4       # operand = result * group
    assert c["collective-permute"] == n
    assert res["collectives"]["counts"]["all-gather"] == 1
