"""Hardware model vs every number the paper reports (§IV)."""
import pytest

from repro.hwmodel import adder_tree_cost, breakdown, energy, mobilenet


def test_table2_reproduced():
    m = adder_tree_cost.table2_model()
    p = adder_tree_cost.PAPER_TABLE2
    assert m["area"] == pytest.approx(p["area"], abs=0.01)
    assert m["power_unsigned"] == pytest.approx(p["power_unsigned"], abs=0.01)
    assert m["power_signed"] == pytest.approx(p["power_signed"], abs=0.01)


def test_table2_structure_derived():
    """Structural facts that hold WITHOUT calibration: the CSA tree uses
    fewer adders than the BAT, and the activity factors are physical."""
    m = adder_tree_cost.table2_model()
    assert m["csa_fa"] + m["csa_ha"] < m["bat_fa"]
    assert 0 < m["activity_msb"] < m["activity_low"] < 1.5
    # unsigned cheaper than signed (MSB path quiet) is structural:
    assert m["power_unsigned"] < m["power_signed"] < 1.0


def test_pe_efficiency_calibration_points():
    for (w, a), eff in energy.PAPER_PE_EFF.items():
        assert energy.pe_efficiency(w, a) == pytest.approx(eff, rel=1e-3)


def test_array_power_nearly_constant():
    """Implied array power ~9.1-10 mW across modes: the efficiency scaling
    is (almost) purely the ops/cycle scaling of weight combination."""
    powers = [energy.pe_power_w(w, a) for (w, a) in energy.PAPER_PE_EFF]
    assert max(powers) / min(powers) < 1.15


def test_peak_throughput():
    assert energy.peak_throughput_tops() == pytest.approx(
        energy.PAPER_PEAK_TOPS, rel=0.01)


def test_accelerator_efficiencies():
    t3 = energy.table3_ours()
    assert t3["eff_8bit"] == pytest.approx(4.69, rel=0.01)
    assert t3["eff_4bit"] == pytest.approx(17.45, rel=0.01)
    assert t3["eff_2bit"] == pytest.approx(68.94, rel=0.01)


def test_improvement_vs_bitsystolic_matches_claims():
    """Paper: +18.7 % / +10.5 % / +11.2 % at 8/4/2-bit."""
    imp = energy.improvement_vs_bitsystolic()
    assert imp["8bit"] == pytest.approx(0.187, abs=0.005)
    assert imp["4bit"] == pytest.approx(0.105, abs=0.005)
    assert imp["2bit"] == pytest.approx(0.112, abs=0.005)


def test_fig8_efficiency_decreases_with_toggle():
    curve = energy.fig8_curve(4, 4)
    vals = [curve[t] for t in sorted(curve)]
    assert vals == sorted(vals, reverse=True)
    assert curve[0.5] == pytest.approx(52.1, rel=1e-3)


def test_fig7_independent_path_area():
    assert breakdown.indep_path_fraction() == pytest.approx(
        breakdown.PAPER_INDEP_FRACTION, abs=0.002)


def test_fig7_fractions_sum_to_one():
    af = breakdown.area_fractions()
    assert sum(af.values()) == pytest.approx(1.0)
    pf = breakdown.power_breakdown()
    assert sum(pf.values()) == pytest.approx(1.0)
    assert pf["indep_shift_add"] == 0.0      # gated outside 6/7-bit modes


def test_mobilenet_macs_standard():
    assert mobilenet.total_macs() == pytest.approx(300e6, rel=0.05)


def test_mobilenet_mixed_precision_reduction():
    """A budget in [3, 4] avg bits reproduces the paper's 35.2 % power
    reduction (the paper's exact per-layer map is unpublished)."""
    reductions = {b: mobilenet.power_reduction_vs_8bit(b)
                  for b in (3.0, 3.25, 3.5, 3.75, 4.0)}
    best = min(reductions.items(),
               key=lambda kv: abs(kv[1] - mobilenet.PAPER_REDUCTION))
    assert abs(best[1] - mobilenet.PAPER_REDUCTION) < 0.05, reductions


def test_reduction_monotone_in_budget():
    lo = mobilenet.power_reduction_vs_8bit(3.0)
    hi = mobilenet.power_reduction_vs_8bit(7.0)
    assert lo > hi > 0


def test_mobilenet_throughput_speedup():
    """Mixed precision speeds up inference as well as saving energy
    (cycle model: macs/cycle scales with plane count and a_bits)."""
    sp = mobilenet.throughput_speedup_vs_8bit(3.75)
    assert 1.5 < sp < 6.0
    layers = mobilenet.mobilenet_v2_layers()
    fixed8 = {l.name: 8 for l in layers}
    fps = mobilenet.inference_fps(fixed8)
    # 301M MACs at 128 macs/cycle @500MHz -> ~200 fps ballpark
    assert 50 < fps < 1000, fps
