"""Packed vs unpacked vs plain-HLO kernel parity (bit-exact), plus the
activation-quant backend routing.

Three implementations of the paper's plane-decomposed GEMM must agree
exactly with the integer matmul ground truth on every supported precision:

  * ``decompose.decomposed_matmul``   — plain-HLO oracle
  * ``bitserial_matmul``              — Pallas, unpacked int8 planes
  * ``packed_bitserial_matmul``       — Pallas, byte-packed planes (even bits)
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import decompose
from repro.kernels import ops
from repro.kernels.bitserial_matmul import (bitserial_matmul,
                                            packed_bitserial_matmul)


def _case(w_bits, signed, seed, m=128, k=128, n=128):
    # m/k/n at the kernel tile size: the raw kernels take pre-tiled operands
    # (ops.bitserial_matmul_pallas owns the padding for ragged shapes).
    rng = np.random.default_rng(seed)
    lo, hi = decompose.weight_range(w_bits, signed)
    w = rng.integers(lo, hi + 1, size=(k, n))
    x = rng.integers(-128, 128, size=(m, k)).astype(np.int8)
    want = x.astype(np.int64) @ w.astype(np.int64)
    return x, w, want


@pytest.mark.parametrize("w_bits", [2, 4, 6, 8])
@pytest.mark.parametrize("signed", [True, False])
def test_packed_unpacked_decomposed_parity(w_bits, signed):
    """All even w_bits x signed/unsigned: the three backends agree exactly."""
    x, w, want = _case(w_bits, signed, seed=w_bits + 100 * signed)
    planes = decompose.decompose_weights(w, w_bits, signed=signed)
    packed = ops.pack_planes(planes, w_bits)

    got_ref = decompose.decomposed_matmul(jnp.asarray(x), planes, w_bits)
    got_unpacked = bitserial_matmul(jnp.asarray(x), planes, w_bits=w_bits,
                                    interpret=True)
    got_packed = packed_bitserial_matmul(jnp.asarray(x), packed,
                                         w_bits=w_bits, signed=signed,
                                         interpret=True)
    np.testing.assert_array_equal(np.asarray(got_ref), want)
    np.testing.assert_array_equal(np.asarray(got_unpacked), want)
    np.testing.assert_array_equal(np.asarray(got_packed), want)


@pytest.mark.parametrize("w_bits", [3, 5, 7])
def test_odd_bits_unpacked_parity(w_bits):
    """Odd widths have no packed layout; unpacked and oracle still agree."""
    x, w, want = _case(w_bits, True, seed=w_bits)
    planes = decompose.decompose_weights(w, w_bits)
    got_ref = decompose.decomposed_matmul(jnp.asarray(x), planes, w_bits)
    got_unpacked = bitserial_matmul(jnp.asarray(x), planes, w_bits=w_bits,
                                    interpret=True)
    np.testing.assert_array_equal(np.asarray(got_ref), want)
    np.testing.assert_array_equal(np.asarray(got_unpacked), want)


@pytest.mark.parametrize("w_bits", [4, 8])
def test_prepared_weight_packed_vs_unpacked_matmul(w_bits):
    """ops.matmul end to end: packed and unpacked QuantizedWeight planes
    produce identical dequantized outputs."""
    from repro.core.policy import LayerPrecision
    rng = np.random.default_rng(w_bits)
    w = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
    prec = LayerPrecision(w_bits, 8, backend="decomposed")
    qw_u = ops.prepare_weight(w, prec, packed=False)
    qw_p = ops.prepare_weight(w, prec, packed=True)
    y_u = ops.matmul(x, None, prec, qw=qw_u)
    y_p = ops.matmul(x, None, prec, qw=qw_p)
    np.testing.assert_array_equal(np.asarray(y_u, np.float32),
                                  np.asarray(y_p, np.float32))


@pytest.mark.parametrize("a_bits", [4, 8])
@pytest.mark.parametrize("signed", [True, False])
def test_quantize_activations_pallas_routes_and_matches(a_bits, signed):
    """use_pallas=True must actually run the Pallas kernel (the seed had a
    dead branch that always fell through to the oracle) and agree with it
    bit-exactly."""
    rng = np.random.default_rng(a_bits)
    x = jnp.asarray(rng.normal(size=(2, 5, 96)), jnp.float32)
    q_ref, s_ref = ops.quantize_activations(x, a_bits, signed=signed,
                                            use_pallas=False)
    q_pl, s_pl = ops.quantize_activations(x, a_bits, signed=signed,
                                          use_pallas=True)
    assert q_pl.shape == q_ref.shape and s_pl.shape == s_ref.shape
    np.testing.assert_array_equal(np.asarray(q_pl), np.asarray(q_ref))
    # Scales agree to float32 ULP (interpret-mode division rounding).
    np.testing.assert_allclose(np.asarray(s_pl, np.float32),
                               np.asarray(s_ref, np.float32), rtol=1e-6)
