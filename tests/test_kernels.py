"""Pallas kernels vs pure-jnp oracles (interpret mode): shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import decompose
from repro.core.policy import LayerPrecision
from repro.kernels import ops, ref
from repro.kernels.act_quant import act_quant
from repro.kernels.bitserial_matmul import (bitserial_matmul,
                                            packed_bitserial_matmul)


@pytest.mark.parametrize("w_bits", range(2, 9))
@pytest.mark.parametrize("shape", [(128, 256, 128), (256, 128, 256)])
def test_bitserial_matmul_all_bits(w_bits, shape):
    m, k, n = shape
    rng = np.random.default_rng(w_bits)
    lo, hi = decompose.weight_range(w_bits, True)
    w = rng.integers(lo, hi + 1, size=(k, n)).astype(np.int8)
    planes = decompose.decompose_weights(w, w_bits)
    x = rng.integers(-128, 128, size=(m, k)).astype(np.int8)
    got = bitserial_matmul(jnp.asarray(x), planes, w_bits=w_bits,
                           interpret=True)
    want = ref.bitserial_matmul_ref(jnp.asarray(x), planes, w_bits)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("w_bits", [2, 4, 6, 8])
@pytest.mark.parametrize("signed", [True, False])
def test_packed_kernel(w_bits, signed):
    rng = np.random.default_rng(w_bits)
    lo, hi = decompose.weight_range(w_bits, signed)
    w = rng.integers(lo, hi + 1, size=(256, 128))
    planes = decompose.decompose_weights(w, w_bits, signed=signed)
    packed = ops.pack_planes(planes, w_bits)
    x = rng.integers(-128, 128, size=(128, 256)).astype(np.int8)
    got = packed_bitserial_matmul(jnp.asarray(x), packed, w_bits=w_bits,
                                  signed=signed, interpret=True)
    assert np.array_equal(np.asarray(got),
                          x.astype(np.int64) @ w.astype(np.int64))
    # pack/unpack roundtrip
    assert np.array_equal(
        np.asarray(ops.unpack_planes(packed, w_bits, signed)),
        np.asarray(planes))


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("m,k", [(128, 64), (256, 512)])
def test_act_quant_kernel(bits, m, k):
    rng = np.random.default_rng(m)
    x = (rng.normal(size=(m, k)) * 3).astype(np.float32)
    q, s = act_quant(jnp.asarray(x), bits=bits, interpret=True)
    qr, sr = ref.act_quant_ref(jnp.asarray(x), bits=bits)
    assert np.array_equal(np.asarray(q), np.asarray(qr))
    assert np.allclose(np.asarray(s), np.asarray(sr))


def test_ops_matmul_pads_unaligned_shapes():
    """Wrapper handles shapes that do not tile by 128."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(5, 96)).astype(np.float32)
    w = rng.normal(size=(96, 80)).astype(np.float32)
    y_dec = ops.matmul(jnp.asarray(x), jnp.asarray(w),
                       LayerPrecision(4, 8, backend="decomposed"))
    y_pal = ops.matmul(jnp.asarray(x), jnp.asarray(w),
                       LayerPrecision(4, 8, backend="pallas"))
    assert y_dec.shape == (5, 80)
    assert np.array_equal(np.asarray(y_dec), np.asarray(y_pal))


def test_backend_consistency_quality():
    """All quantized backends approximate the dense matmul."""
    rng = np.random.default_rng(1)
    x = rng.normal(size=(8, 128)).astype(np.float32)
    w = rng.normal(size=(128, 64)).astype(np.float32)
    dense = x @ w
    for be in ("fake_quant", "decomposed", "pallas"):
        y = np.asarray(ops.matmul(jnp.asarray(x), jnp.asarray(w),
                                  LayerPrecision(8, 8, backend=be)))
        rel = np.abs(y - dense).max() / np.abs(dense).max()
        assert rel < 0.03, (be, rel)


def test_quantized_weight_prepare_roundtrip():
    rng = np.random.default_rng(2)
    w = rng.normal(size=(64, 32)).astype(np.float32)
    prec = LayerPrecision(w_bits=5, a_bits=8)
    qw = ops.prepare_weight(jnp.asarray(w), prec)
    assert qw.planes.shape == (2, 64, 32)          # 5-bit = 3-2 decomposition
    q = decompose.recompose_weights(qw.planes, 5)
    back = np.asarray(q).astype(np.float32) * np.asarray(qw.scale)
    # Odd widths keep round-to-nearest (half-LSB bound); even widths use
    # nested truncation, whose 1-LSB floor bound is covered by
    # tests/test_precision_tiers.py.
    assert np.abs(back - w).max() <= np.asarray(qw.scale).max() * 0.51 + 1e-6


def test_lower_precision_monotone_error():
    """More weight bits -> better approximation (sanity of the whole path)."""
    rng = np.random.default_rng(3)
    x = rng.normal(size=(16, 128)).astype(np.float32)
    w = rng.normal(size=(128, 64)).astype(np.float32)
    dense = x @ w
    errs = []
    for bits in (2, 4, 8):
        y = np.asarray(ops.matmul(jnp.asarray(x), jnp.asarray(w),
                                  LayerPrecision(bits, 8, backend="decomposed")))
        errs.append(np.abs(y - dense).mean())
    assert errs[0] > errs[1] > errs[2]


@pytest.mark.parametrize("eff", [2, 4, 6, 8])
def test_grouped_dequant_matmul_single_group(eff):
    """Fused dequant epilogue == (prefix-plane GEMM) * scales, bf16 out.

    Single-group degenerate case of the group-switching kernel (the mixed
    layouts are swept in test_grouped_kernel.py): the epilogue must apply
    x_scale [M,1] and per-row w_scale [M,N] exactly as the unfused
    ``acc.astype(f32) * xs * ws`` association does."""
    from repro.kernels import grouped_matmul as gmm
    rng = np.random.default_rng(eff)
    m, k, n = 128, 256, 128
    w = rng.normal(size=(k, n)).astype(np.float32)
    qw = ops.prepare_superplane(jnp.asarray(w))
    planes = qw.get_planes_msb()
    nplanes = decompose.num_prefix_planes(eff)
    plane_groups = ((m, nplanes),)
    mult = jnp.asarray(decompose.prefix_multipliers(plane_groups))
    x = rng.integers(-128, 128, size=(m, k)).astype(np.int8)
    xs = (rng.random((m, 1)) * 0.1 + 0.01).astype(np.float32)
    ws = (rng.random((1, n)) * 0.1 + 0.01).astype(np.float32)
    ws_rows = jnp.broadcast_to(jnp.asarray(ws), (m, n))
    got = gmm.grouped_dequant_matmul(
        jnp.asarray(x), planes[:nplanes], mult, jnp.asarray(xs), ws_rows,
        nplanes=nplanes, interpret=True)
    acc = decompose.decomposed_matmul_grouped(jnp.asarray(x), planes,
                                              ((m, eff),))
    want = (np.asarray(acc).astype(np.float32) * xs * ws).astype(jnp.bfloat16)
    assert got.dtype == jnp.bfloat16
    assert np.array_equal(np.asarray(got, np.float32),
                          np.asarray(want, np.float32))
