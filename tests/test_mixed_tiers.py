"""Mixed-tier decode batches + per-request KV-cache precision tiers.

The PR's contracts:

  * per-row-group matmul — one plane-prefix GEMM per contiguous tier group
    (ops.matmul / bitserial_matmul_pallas / the decomposed oracle), exact
    per row vs homogeneous execution;
  * mixed KV arena — one byte-lane arena serving bf16 / int8 / int4-packed
    slots side by side, bit-identical per slot to the homogeneous cache at
    that kv precision, with int4 round-trip error bounded by half an LSB;
  * engine — a single decode batch holding tiers {8/8, 4/4, 2/2} produces
    per-request tokens identical to fixed-tier BatchServeEngine references
    AND natively-prepared fixed-precision engines, with zero prepare_params
    calls after construction; slots are reused across different kv tiers.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core import decompose
from repro.core.policy import (LayerPrecision, PrecisionSchedule,
                               uniform_policy, uniform_schedule)
from repro.kernels import ops
from repro.models.layers import KVCache, Runtime
from repro.models.transformer import LM
from repro.serve import engine as engine_mod
from repro.serve.engine import BatchServeEngine, Request, ServeEngine

TIERS = {"8/8": (8, 8), "4/4": (4, 4), "2/2": (2, 2)}
KV_TIERS = {"8/8": None, "4/4": 8, "2/2": 4}


# ------------------------------------------------------ grouped matmul path
def test_ops_matmul_row_groups_match_homogeneous():
    """Every row of a mixed-tier grouped matmul equals the homogeneous
    matmul at that row's precision — both integer backends."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(64, 48)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(7, 1, 64)), jnp.float32)
    for packed in (False, True):
        qw = ops.prepare_superplane(w, signed=True, packed=packed)
        for backend in ("decomposed", "pallas"):
            groups = tuple(
                (n, LayerPrecision(b, b, backend=backend))
                for n, b in ((3, 8), (2, 4), (2, 2)))
            got = ops.matmul(x, None, groups[0][1], qw=qw, row_groups=groups)
            off = 0
            for n, prec in groups:
                want = ops.matmul(x[off:off + n], None, prec, qw=qw)
                np.testing.assert_array_equal(
                    np.asarray(got[off:off + n], np.float32),
                    np.asarray(want, np.float32), err_msg=backend)
                off += n


def test_ops_matmul_row_groups_with_permutation():
    """``perm`` gathers rows into group order; codes/scales come from the
    un-permuted full-batch quantization (the bitwise-stability contract)."""
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
    qw = ops.prepare_superplane(w, signed=True)
    groups = ((2, LayerPrecision(8, 8, backend="decomposed")),
              (2, LayerPrecision(2, 2, backend="decomposed")))
    perm = jnp.asarray([2, 0, 3, 1])     # rows 2,0 are 8-bit; rows 3,1 2-bit
    got = ops.matmul(x, None, groups[0][1], qw=qw, row_groups=groups,
                     perm=perm)
    for i, (row, prec) in enumerate(zip([2, 0, 3, 1],
                                        [groups[0][1]] * 2 + [groups[1][1]] * 2)):
        want = ops.matmul(x[row:row + 1], None, prec, qw=qw)
        np.testing.assert_array_equal(np.asarray(got[i:i + 1], np.float32),
                                      np.asarray(want, np.float32))


def test_kernel_level_row_groups():
    """The Pallas wrapper and the decomposed oracle both take per-row-group
    effective widths and agree exactly."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.integers(-127, 128, size=(6, 64)), jnp.int8)
    w = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    sp = ops.prepare_superplane(w, signed=True)
    rg = ((2, 8), (1, 6), (2, 4), (1, 2))
    got_pallas = ops.bitserial_matmul_pallas(x, sp, row_groups=rg)
    got_oracle = decompose.decomposed_matmul_grouped(
        x.astype(jnp.int32), sp.planes, rg)
    np.testing.assert_array_equal(np.asarray(got_pallas),
                                  np.asarray(got_oracle))
    off = 0
    for n, eff in rg:
        want = ops.bitserial_matmul_pallas(x[off:off + n], sp, eff_bits=eff)
        np.testing.assert_array_equal(np.asarray(got_pallas[off:off + n]),
                                      np.asarray(want))
        off += n
    with pytest.raises(ValueError, match="cover"):
        ops.bitserial_matmul_pallas(x, sp, row_groups=((2, 8),))
    with pytest.raises(ValueError, match="cover"):
        decompose.decomposed_matmul_grouped(x.astype(jnp.int32), sp.planes,
                                            ((2, 8),))


# ---------------------------------------------------------- mixed KV arena
def test_mixed_kv_arena_matches_homogeneous_modes():
    """Each slot of the mixed byte-lane arena stores/reads EXACTLY what the
    homogeneous cache at that slot's kv tier does (prefill + decode)."""
    rng = np.random.default_rng(3)
    B, S, KVH, DH = 4, 8, 2, 16
    k = jnp.asarray(rng.normal(size=(B, S, KVH, DH)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KVH, DH)), jnp.float32)
    k1 = jnp.asarray(rng.normal(size=(B, 1, KVH, DH)), jnp.float32)
    v1 = jnp.asarray(rng.normal(size=(B, 1, KVH, DH)), jnp.float32)
    slot_modes = [None, 8, 4, 8]

    mixed = KVCache.create(B, S, KVH, DH, kv_bits=(16, 8, 4))
    mixed = dataclasses.replace(mixed, kv_bits=jnp.asarray(
        [16 if m is None else m for m in slot_modes], jnp.int32))
    mixed = mixed.update(k, v, 0, new_length=jnp.asarray([5, 5, 5, 5]))
    mixed = mixed.append(k1, v1, active=jnp.asarray([True, True, True, False]))
    km, vm = mixed.read()

    for i, mode in enumerate(slot_modes):
        ref = KVCache.create(B, S, KVH, DH, kv_bits=mode)
        ref = ref.update(k, v, 0, new_length=jnp.asarray([5, 5, 5, 5]))
        ref = ref.append(k1, v1,
                         active=jnp.asarray([True, True, True, False]))
        kr, vr = ref.read()
        np.testing.assert_array_equal(np.asarray(km[i], np.float32),
                                      np.asarray(kr[i], np.float32),
                                      err_msg=f"slot {i} mode {mode}")
        np.testing.assert_array_equal(np.asarray(vm[i], np.float32),
                                      np.asarray(vr[i], np.float32))
        np.testing.assert_array_equal(np.asarray(mixed.length),
                                      np.asarray(ref.length))


def test_kv_int4_roundtrip_error_bound():
    """int4-packed KV: |dequant - x| <= scale/2 per (position, head) row
    (round-to-nearest with scale = amax/7), and codes use the full range."""
    rng = np.random.default_rng(4)
    B, S, KVH, DH = 2, 4, 2, 32
    x = jnp.asarray(rng.normal(size=(B, S, KVH, DH)), jnp.float32)
    c = KVCache.create(B, S, KVH, DH, kv_bits=4).update(x, x, 0)
    kq, _ = c.read(jnp.float32)
    amax = np.abs(np.asarray(x)).max(axis=-1, keepdims=True)
    scale = np.maximum(amax, 1e-8) / 7.0
    err = np.abs(np.asarray(kq, np.float32) - np.asarray(x))
    # bf16 scale storage adds <= 2^-9 relative on top of the half-LSB bound
    assert (err <= scale * (0.5 + 2.0 ** -8)).all(), err.max()
    codes = np.asarray(c.k)
    assert codes.max() > 0          # packed nibbles actually populated


def test_kv_cache_create_validation():
    with pytest.raises(ValueError, match="kv_bits"):
        KVCache.create(1, 4, 2, 16, kv_bits=3)
    with pytest.raises(ValueError, match="even head_dim"):
        KVCache.create(1, 4, 2, 15, kv_bits=4)
    with pytest.raises(ValueError, match="tiers must be from"):
        KVCache.create(1, 4, 2, 16, kv_bits=(16, 5))
    c = KVCache.create(2, 4, 2, 16, kv_bits=(16, 8, 4))
    assert c.mixed and c.modes == (16, 8, 4)
    assert c.k.shape[-1] == 32      # lanes sized for the widest (bf16) tier
    assert c.head_dim == 16


def test_schedule_kv_tiers_validation_and_lookup():
    sched = uniform_schedule(TIERS, kv_tiers=KV_TIERS)
    assert sched.kv_bits_for("8/8") is None
    assert sched.kv_bits_for("4/4") == 8
    assert sched.kv_code_for("8/8") == 16
    assert sched.kv_code_for("2/2") == 4
    assert sched.kv_modes == (16, 8, 4)
    # Tiers left out of kv_tiers default to bf16.
    part = uniform_schedule(TIERS, kv_tiers={"2/2": 4})
    assert part.kv_bits_for("8/8") is None and part.kv_modes == (16, 4)
    assert uniform_schedule(TIERS).kv_modes is None
    with pytest.raises(ValueError, match="unknown tier"):
        uniform_schedule(TIERS, kv_tiers={"9/9": 8})
    with pytest.raises(ValueError, match="kv tier must be"):
        uniform_schedule(TIERS, kv_tiers={"8/8": 2})


# ------------------------------------------------------------------- engine
@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = uniform_schedule(TIERS, kv_tiers=KV_TIERS)
    rt = Runtime(policy=sched.policy_for(), mode="serve", moe_dropless=True,
                 schedule=sched)
    return cfg, model, params, sched, rt


def _reqs(cfg, tiers, budgets, seed=11):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               size=3 + i % 4),
                    max_new_tokens=b, tier=t)
            for i, (t, b) in enumerate(zip(tiers, budgets))]


def test_mixed_batch_token_identity_all_references(setup):
    """THE acceptance criterion: one decode batch holding {8/8, 4/4, 2/2}
    (weight AND kv tiers) is per-request token-identical to (a) fixed-tier
    BatchServeEngine references sharing the superplane store and (b)
    natively-prepared fixed-precision engines, with zero prepare_params
    calls after construction."""
    cfg, model, params, sched, rt = setup
    tiers = ["8/8", "4/4", "2/2", "2/2", "8/8", "4/4", "2/2"]
    reqs = _reqs(cfg, tiers, [3, 4, 2, 4, 2, 3, 3])
    eng = ServeEngine(model, params, rt, max_batch=3, max_len=64,
                      decode_chunk=3)
    preps = engine_mod.PREPARE_CALLS
    got = eng.run(reqs)
    assert engine_mod.PREPARE_CALLS == preps, "re-prepared weights mid-run"
    assert eng.stats.mixed_tier_chunks >= 1, "no mixed-tier batch was run"

    for tier, (w, a) in TIERS.items():
        sub = [r for r in reqs if r.tier == tier]
        # (a) fixed-tier baseline over the SAME superplane store; its KV
        # cache automatically follows the schedule's kv tier.
        base = BatchServeEngine(model, eng.params, rt, max_batch=1,
                                max_len=64, tier=tier)
        assert base.kv_bits == KV_TIERS[tier]
        want = base.run([Request(uid=r.uid, prompt=r.prompt,
                                 max_new_tokens=r.max_new_tokens, tier=tier)
                         for r in sub])
        for r in sub:
            assert got[r.uid] == want[r.uid], ("batch-ref", tier, r.uid)
        # (b) natively prepared at the tier precision, homogeneous kv mode.
        native = ServeEngine(
            model, params,
            Runtime(policy=uniform_policy(w, a, backend="decomposed"),
                    mode="serve", moe_dropless=True),
            max_batch=3, max_len=64, decode_chunk=3,
            kv_bits=KV_TIERS[tier])
        want_n = native.run([Request(uid=r.uid, prompt=r.prompt,
                                     max_new_tokens=r.max_new_tokens)
                             for r in sub])
        for r in sub:
            assert got[r.uid] == want_n[r.uid], ("native", tier, r.uid)


def test_slot_reuse_across_kv_tiers(setup):
    """One slot serves bf16 -> int4 -> int8 requests back to back: the
    per-slot kv tier lane is rewritten at each admission and outputs stay
    identical to per-tier references."""
    cfg, model, params, sched, rt = setup
    tiers = ["8/8", "2/2", "4/4", "2/2"]
    reqs = _reqs(cfg, tiers, [2, 3, 2, 2], seed=13)
    eng = ServeEngine(model, params, rt, max_batch=1, max_len=64,
                      decode_chunk=2)
    got = eng.run(reqs)
    assert eng.arena.tiers == [None]          # all released at drain
    for tier in set(tiers):
        sub = [r for r in reqs if r.tier == tier]
        base = BatchServeEngine(model, eng.params, rt, max_batch=1,
                                max_len=64, tier=tier)
        want = base.run([Request(uid=r.uid, prompt=r.prompt,
                                 max_new_tokens=r.max_new_tokens, tier=tier)
                         for r in sub])
        for r in sub:
            assert got[r.uid] == want[r.uid], (tier, r.uid)


def test_serialized_mode_matches_mixed(setup):
    """mixed_tiers=False (the PR-2 tier-serialized baseline) produces the
    same per-request tokens, with serialized-mode stats."""
    cfg, model, params, sched, rt = setup
    tiers = ["4/4", "2/2", "4/4", "2/2"]
    reqs = _reqs(cfg, tiers, [3, 2, 2, 3], seed=17)
    mixed = ServeEngine(model, params, rt, max_batch=2, max_len=64,
                        decode_chunk=3)
    got_m = mixed.run(reqs)
    serial = ServeEngine(model, mixed.params, rt, max_batch=2, max_len=64,
                         decode_chunk=3, mixed_tiers=False)
    got_s = serial.run([Request(uid=r.uid, prompt=r.prompt,
                                max_new_tokens=r.max_new_tokens, tier=r.tier)
                        for r in reqs])
    for r in reqs:
        assert got_m[r.uid] == got_s[r.uid], r.uid
    assert serial.stats.mixed_tier_chunks == 0
    assert mixed.stats.tier_switches == 0


def test_group_layout_derivation(setup):
    """The per-step layout: tiers in schedule order, free slots riding in
    the default tier's group, perm realizing the sorted order."""
    cfg, model, params, sched, rt = setup
    eng = ServeEngine(model, params, rt, max_batch=4, max_len=32)
    eng.arena.tiers = ["2/2", None, "4/4", "2/2"]
    groups, perm = eng._group_layout()
    assert groups == (("8/8", 1), ("4/4", 1), ("2/2", 2))
    assert list(perm) == [1, 2, 0, 3]


def test_engine_kv_conflict_validation(setup):
    cfg, model, params, sched, rt = setup
    with pytest.raises(ValueError, match="kv_bits conflicts"):
        ServeEngine(model, params, rt, max_batch=2, max_len=32, kv_bits=8)
