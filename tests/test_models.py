"""Per-arch smoke tests (reduced configs) + serving parity + KV quant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced_config
from repro.core.policy import uniform_policy
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.train import optimizer as optim
from repro.train.step import make_train_step

RT_QAT = Runtime(policy=uniform_policy(4, 8, backend="fake_quant"),
                 moe_dropless=True)
RT_EXACT = Runtime(policy=uniform_policy(8, 8, backend="dense"),
                   moe_dropless=True)


def _batch(cfg, b=2, s=16, seed=0):
    key = jax.random.PRNGKey(seed)
    if cfg.frontend == "none":
        toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
        return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    emb = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    labels = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return {"embeds": emb, "labels": labels}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_and_train_step(arch):
    """One forward + one train step on CPU: shapes + no NaNs (assignment)."""
    cfg = reduced_config(arch)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = _batch(cfg)
    logits, aux = model.forward(params, RT_QAT, tokens=batch.get("tokens"),
                                embeds=batch.get("embeds"))
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    ocfg = optim.OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    step = make_train_step(model, RT_QAT, ocfg)
    state = {"params": params, "opt": optim.init_state(params, ocfg)}
    state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ["qwen3-8b", "grok-1-314b", "mamba2-1.3b",
                                  "jamba-1.5-large-398b", "pixtral-12b"])
def test_decode_matches_full_forward(arch):
    cfg = reduced_config(arch)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(1))
    b, s = 2, 12
    batch = _batch(cfg, b, s, seed=7)
    kw = ({"tokens": batch["tokens"]} if "tokens" in batch
          else {"embeds": batch["embeds"]})
    full, _ = model.forward(params, RT_EXACT, **kw)
    cache = model.init_cache(b, max_len=32)
    if "tokens" in kw:
        pre = {"tokens": kw["tokens"][:, :-1]}
        dec = {"tokens": kw["tokens"][:, -1:]}
    else:
        pre = {"embeds": kw["embeds"][:, :-1]}
        dec = {"embeds": kw["embeds"][:, -1:]}
    logits_p, cache = model.prefill(params, RT_EXACT, cache, **pre)
    logits_d, cache = model.decode_step(params, RT_EXACT, cache, **dec)
    np.testing.assert_allclose(np.asarray(logits_p[:, 0], np.float32),
                               np.asarray(full[:, -2], np.float32),
                               atol=1e-3)
    # Decode attention / SSM state updates run on bf16 operands with f32
    # accumulation (the serving-efficient form); vs the f32-heavy full
    # forward that is ~1e-2..3e-2 on logits.
    np.testing.assert_allclose(np.asarray(logits_d[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               atol=3e-2)


def test_quantized_kv_cache_close():
    """int8 KV cache decode stays close to bf16-cache decode."""
    cfg = reduced_config("qwen3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                              cfg.vocab_size)
    outs = {}
    for kv_bits in (None, 8):
        cache = model.init_cache(2, max_len=32, kv_bits=kv_bits)
        _, cache = model.prefill(params, RT_EXACT, cache,
                                 tokens=toks[:, :-1])
        logits, _ = model.decode_step(params, RT_EXACT, cache,
                                      tokens=toks[:, -1:])
        outs[kv_bits] = np.asarray(logits, np.float32)
    denom = np.abs(outs[None]).max()
    assert np.abs(outs[8] - outs[None]).max() / denom < 0.05


def test_full_configs_match_assignment():
    """Exact full-size config values from the assignment table."""
    q = get_config("qwen3-8b")
    assert (q.num_layers, q.d_model, q.num_heads, q.num_kv_heads,
            q.d_ff, q.vocab_size) == (36, 4096, 32, 8, 12288, 151936)
    assert q.qk_norm
    j = get_config("jamba-1.5-large-398b")
    assert (j.num_layers, j.d_model, j.num_experts, j.experts_per_token) \
        == (72, 8192, 16, 2)
    assert j.attn_every == 8 and j.ssm
    g = get_config("grok-1-314b")
    assert (g.num_experts, g.experts_per_token, g.d_ff) == (8, 2, 32768)
    m = get_config("mamba2-1.3b")
    assert m.ssm and m.num_heads == 0 and m.ssm_state == 128
    mg = get_config("musicgen-large")
    assert mg.num_kv_heads == mg.num_heads == 32 and mg.vocab_size == 2048


def test_param_counts_plausible():
    """Param counts should land near the models' nameplate sizes."""
    approx = {
        "qwen3-8b": (8e9, 0.35),
        "grok-1-314b": (314e9, 0.15),
        "jamba-1.5-large-398b": (398e9, 0.15),
        "mamba2-1.3b": (1.3e9, 0.35),
        "pixtral-12b": (12e9, 0.35),
    }
    for arch, (target, tol) in approx.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < tol, (arch, n)


def test_moe_aux_loss_nonzero():
    cfg = reduced_config("grok-1-314b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = _batch(cfg)
    _, aux = model.forward(params, RT_QAT, tokens=batch["tokens"])
    assert float(aux) > 0
