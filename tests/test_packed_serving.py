"""Packed-plane serving path end to end (beyond-paper layout)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.policy import uniform_policy
from repro.kernels.ops import QuantizedWeight
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.serve.engine import prepare_params


@pytest.mark.parametrize("w_bits", [4, 8])
def test_packed_equals_unpacked_serving(w_bits):
    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    policy = uniform_policy(w_bits, 8, backend="decomposed")
    rt = Runtime(policy=policy, mode="serve", moe_dropless=True)

    unpacked, _ = prepare_params(params, policy, model, packed=False)
    packed, _ = prepare_params(params, policy, model, packed=True)
    y_u, _ = model.forward(unpacked, rt, tokens=toks)
    y_p, _ = model.forward(packed, rt, tokens=toks)
    np.testing.assert_array_equal(np.asarray(y_u, np.float32),
                                  np.asarray(y_p, np.float32))


def test_packed_storage_bytes():
    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    policy = uniform_policy(4, 8, backend="decomposed")
    unpacked, _ = prepare_params(params, policy, model, packed=False)
    packed, _ = prepare_params(params, policy, model, packed=True)

    def proj_bytes(tree):
        leaves = jax.tree.leaves(
            tree, is_leaf=lambda x: isinstance(x, QuantizedWeight))
        total = 0
        for l in leaves:
            if isinstance(l, QuantizedWeight):
                arr = l.planes if l.planes is not None else l.packed
                total += arr.size * arr.dtype.itemsize
        return total

    # 4-bit: 2 int8 planes (2 B/weight) vs 1 packed byte -> exactly half.
    assert proj_bytes(packed) * 2 == proj_bytes(unpacked)


def test_odd_bits_fall_back_to_planes():
    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    policy = uniform_policy(5, 8, backend="decomposed")
    prepared, _ = prepare_params(params, policy, model, packed=True)
    qws = [l for l in jax.tree.leaves(
        prepared, is_leaf=lambda x: isinstance(x, QuantizedWeight))
        if isinstance(l, QuantizedWeight)]
    assert all(q.packed is None and q.planes is not None for q in qws)
