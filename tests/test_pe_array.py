"""64x64 PE-array functional simulator (paper §III, Figs 2-5)."""
import numpy as np
import pytest

from repro.core import decompose
from repro.core.pe_array import (PEArrayConfig, array_utilization,
                                 logical_columns_per_pass, pe_array_matmul,
                                 peak_tops)

CFG = PEArrayConfig()


@pytest.mark.parametrize("w_bits,a_bits", [(2, 2), (3, 5), (4, 4), (5, 3),
                                           (6, 8), (7, 2), (8, 8)])
@pytest.mark.parametrize("signed", [True, False])
def test_bit_exact_matmul(w_bits, a_bits, signed):
    rng = np.random.default_rng(w_bits * 10 + a_bits)
    wlo, whi = decompose.weight_range(w_bits, signed)
    w = rng.integers(wlo, whi + 1, size=(100, 20))   # row tiling: 100 > 64
    a = rng.integers(-(1 << (a_bits - 1)), 1 << (a_bits - 1), size=(3, 100))
    out, stats = pe_array_matmul(a, w, w_bits=w_bits, a_bits=a_bits,
                                 w_signed=signed)
    assert np.array_equal(np.asarray(out),
                          a.astype(np.int64) @ w.astype(np.int64))
    assert stats.row_tiles == 2
    assert stats.cycles > 0


def test_utilization_table():
    """Fig 4: independent shift-add paths lift 3-plane utilization to 63/64;
    without them a quarter of the array idles."""
    for bits in (2, 3, 4, 5, 8):
        assert array_utilization(CFG, bits) == 1.0
    assert array_utilization(CFG, 6) == 63 / 64
    assert array_utilization(CFG, 7) == 63 / 64
    no_fig4 = PEArrayConfig(independent_shift_add=False)
    assert array_utilization(no_fig4, 6) == 0.75
    n, idle = logical_columns_per_pass(no_fig4, 7)
    assert n == 16 and idle == 16


def test_peak_throughput_matches_paper():
    """4.09 TOPS peak at 2/2-bit, 1 GHz (paper Table III)."""
    assert peak_tops(CFG, 2, 2) == pytest.approx(4.096, rel=1e-3)
    assert peak_tops(CFG, 8, 8) == pytest.approx(0.256, rel=1e-3)


def test_throughput_scales_with_precision():
    vals = [peak_tops(CFG, b, b) for b in (2, 3, 4, 8)]
    assert vals == sorted(vals, reverse=True)
