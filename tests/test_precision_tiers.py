"""Runtime-reconfigurable precision serving: one preloaded superplane store,
any even (w_bits, a_bits) at decode time.

Covers the refactor's contracts end to end:

  * nested quantization — the b-bit code is the LSB-truncation of the 8-bit
    code, for every even b;
  * plane-prefix parity — truncated-superplane matmul is BIT-EXACT with a
    weight freshly prepared at the effective width, for all three integer
    backends (decomposed HLO, unpacked Pallas, packed Pallas) and both
    signedness modes;
  * schedule semantics — tier lookup, per-tier layer rules, validation;
  * tier-grouped admission in the scheduler;
  * engine semantics — two tiers decoding in the same slot arena are
    token-identical to single-tier engines, with ZERO weight preparation
    after construction.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core import decompose, quant
from repro.core.policy import (LayerPrecision, PrecisionSchedule,
                               uniform_policy, uniform_schedule)
from repro.kernels import ops
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.serve import engine as engine_mod
from repro.serve.engine import BatchServeEngine, Request, ServeEngine
from repro.serve.scheduler import Scheduler

EVEN_BITS = (2, 4, 6, 8)


# ------------------------------------------------------- nested quantization
@pytest.mark.parametrize("signed", [True, False])
@pytest.mark.parametrize("bits", EVEN_BITS)
def test_nested_quantize_is_truncation_of_8bit(bits, signed):
    rng = np.random.default_rng(bits)
    x = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    cfg = quant.QuantConfig(bits=bits, signed=signed, per_channel=True,
                            channel_axis=-1)
    q, s = quant.nested_quantize(x, cfg)
    cfg8 = quant.QuantConfig(bits=8, signed=signed, per_channel=True,
                             channel_axis=-1)
    q8, s8 = quant.quantize(x, cfg8)
    np.testing.assert_array_equal(
        np.asarray(q, np.int32), np.asarray(q8, np.int32) >> (8 - bits))
    np.testing.assert_array_equal(
        np.asarray(s), np.asarray(s8) * float(1 << (8 - bits)))


def test_superplane_prefix_recomposes_to_truncated_code():
    rng = np.random.default_rng(0)
    for signed in (True, False):
        lo, hi = decompose.weight_range(8, signed)
        q8 = jnp.asarray(rng.integers(lo, hi + 1, size=(33, 17)), jnp.int32)
        planes = decompose.decompose_superplanes(q8, signed=signed)
        assert planes.shape == (4, 33, 17)
        for eff in EVEN_BITS:
            got = decompose.recompose_superplane_prefix(planes, eff,
                                                        signed=signed)
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(q8) >> (8 - eff))


# ----------------------------------------------------- plane-prefix parity
@pytest.mark.parametrize("signed", [True, False])
@pytest.mark.parametrize("eff_bits", EVEN_BITS)
def test_truncated_superplane_bit_exact_with_fresh_prepare(eff_bits, signed):
    """The satellite contract: for every even w_bits' <= 8 and both
    signedness modes, the truncated-superplane matmul equals a freshly
    prepared w_bits' weight on the unpacked, packed, and decomposed
    backends — bit-exact, including scales."""
    rng = np.random.default_rng(eff_bits + 10 * signed)
    w = jnp.asarray(rng.normal(size=(96, 80)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(7, 96)), jnp.float32)

    sp_u = ops.prepare_superplane(w, signed=signed, packed=False)
    sp_p = ops.prepare_superplane(w, signed=signed, packed=True)
    assert sp_u.msb_first and sp_u.w_bits == 8

    prec_dec = LayerPrecision(w_bits=eff_bits, a_bits=8, w_signed=signed,
                              backend="decomposed")
    prec_pal = prec_dec.with_backend("pallas")
    fresh_u = ops.prepare_weight(w, prec_dec, packed=False)
    fresh_p = ops.prepare_weight(w, prec_dec, packed=True)

    # Artifact-level: truncation reproduces the fresh preparation exactly.
    tr_u = ops.truncate_weight(sp_u, eff_bits)
    np.testing.assert_array_equal(np.asarray(tr_u.planes),
                                  np.asarray(fresh_u.planes))
    np.testing.assert_array_equal(np.asarray(tr_u.scale),
                                  np.asarray(fresh_u.scale))
    tr_p = ops.truncate_weight(sp_p, eff_bits)
    np.testing.assert_array_equal(np.asarray(tr_p.packed),
                                  np.asarray(fresh_p.packed))

    # Matmul-level: runtime truncation == fresh weights, every backend.
    want = np.asarray(ops.matmul(x, None, prec_dec, qw=fresh_u), np.float32)
    for prec, qw, label in [
        (prec_dec, sp_u, "decomposed/unpacked"),
        (prec_dec, sp_p, "decomposed/packed"),
        (prec_pal, sp_u, "pallas/unpacked"),
        (prec_pal, sp_p, "pallas/packed"),
        (prec_pal, fresh_p, "pallas/fresh-packed"),
    ]:
        got = np.asarray(ops.matmul(x, None, prec, qw=qw), np.float32)
        np.testing.assert_array_equal(got, want, err_msg=label)


def test_runtime_truncation_requires_superplane():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
    qw = ops.prepare_weight(w, LayerPrecision(w_bits=8, a_bits=8,
                                              backend="decomposed"))
    with pytest.raises(ValueError, match="superplane"):
        ops.matmul(x, None, LayerPrecision(w_bits=4, a_bits=8,
                                           backend="decomposed"), qw=qw)
    with pytest.raises(ValueError, match="superplane"):
        ops.truncate_weight(qw, 4)


def test_packed_kernel_eff_bits_mxu_pass_law():
    """The packed kernel reads only eff_bits/2 fields: effective width sets
    the arithmetic, independent of the stored byte."""
    from repro.kernels.bitserial_matmul import packed_bitserial_matmul
    rng = np.random.default_rng(3)
    q8 = rng.integers(-128, 128, size=(128, 128))
    planes = decompose.decompose_weights(jnp.asarray(q8), 8)
    packed = ops.pack_planes(planes, 8)
    x = jnp.asarray(rng.integers(-128, 128, size=(128, 128)), jnp.int8)
    for eff in EVEN_BITS:
        got = packed_bitserial_matmul(x, packed, w_bits=8, eff_bits=eff,
                                      interpret=True)
        want = np.asarray(x, np.int64) @ (q8 >> (8 - eff))
        np.testing.assert_array_equal(np.asarray(got), want)


# ----------------------------------------------------------------- schedule
def test_schedule_lookup_rules_and_validation():
    sched = PrecisionSchedule(
        tiers={"hi": LayerPrecision(8, 8, backend="decomposed"),
               "lo": LayerPrecision(2, 4, backend="decomposed")},
        rules={"lo": {"*.o_proj": LayerPrecision(4, 4,
                                                 backend="decomposed")}})
    assert sched.default_tier == "hi"
    assert sched.lookup("layers.pos0.attn.q_proj", "lo").w_bits == 2
    assert sched.lookup("layers.pos0.attn.o_proj", "lo").w_bits == 4
    assert sched.lookup("layers.pos0.attn.o_proj").w_bits == 8  # default tier
    pol = sched.policy_for("lo")
    assert pol.lookup("x.o_proj").w_bits == 4 and pol.default.w_bits == 2
    assert sched.prepare_policy().default.w_bits == 8

    with pytest.raises(ValueError, match="truncatable"):
        uniform_schedule({"odd": (5, 8)})
    with pytest.raises(ValueError, match="backend"):
        uniform_schedule({"t": (4, 8)}, backend="fake_quant")
    with pytest.raises(ValueError, match="w_signed"):
        PrecisionSchedule(tiers={
            "a": LayerPrecision(4, 8, backend="decomposed"),
            "b": LayerPrecision(4, 8, w_signed=False, backend="decomposed")})
    with pytest.raises(ValueError, match="at least one"):
        PrecisionSchedule(tiers={})
    with pytest.raises(KeyError):
        sched.lookup("x", "nope")


# ---------------------------------------------------------------- scheduler
def test_scheduler_tier_grouped_admission():
    sched = Scheduler(2)
    for i, t in enumerate(["a", "b", "a", "b"]):
        sched.submit(Request(uid=i, prompt=np.array([1]), max_new_tokens=2,
                             tier=t))
    # peek() = what an idle serialized engine switches its next tier to.
    assert sched.peek().tier == "a"
    # Tier-constrained admission skips queued other-tier requests (they keep
    # their FIFO position for their own tier's phase).
    assert sched.admit(0, tier="a").uid == 0
    assert sched.admit(1, tier="a").uid == 2
    assert sched.peek().tier == "b"
    sched.slots[0] = None
    assert sched.admit(0, tier=None) is None     # no untiered request waits
    assert sched.admit(0, tier="b").uid == 1     # FIFO within tier b
    sched.slots[1] = None
    assert sched.admit(1).uid == 3               # unconstrained: FIFO head


# ------------------------------------------------------------------- engine
@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = uniform_schedule({"8/8": (8, 8), "4/4": (4, 4), "2/2": (2, 2)})
    rt = Runtime(policy=sched.policy_for(), mode="serve", moe_dropless=True,
                 schedule=sched)
    return cfg, model, params, sched, rt


def _reqs(cfg, tiers, seed=7, budget=lambda i: 2 + i % 3):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               size=3 + i % 4),
                    max_new_tokens=budget(i), tier=t)
            for i, t in enumerate(tiers)]


def test_engine_two_tiers_one_arena_match_single_tier_engines(setup):
    """The acceptance criterion: one engine constructed once serves mixed
    tiers from one preloaded store — zero preparation after construction —
    and each tier's outputs are token-identical to (a) a fixed-tier engine
    sharing the store and (b) an engine prepared NATIVELY at that
    precision."""
    cfg, model, params, sched, rt = setup
    tiers = ["4/4", "2/2", "2/2", "4/4", "4/4", "2/2"]
    reqs = _reqs(cfg, tiers)
    eng = ServeEngine(model, params, rt, max_batch=2, max_len=64,
                      decode_chunk=3)
    preps = engine_mod.PREPARE_CALLS
    got = eng.run(reqs)
    assert engine_mod.PREPARE_CALLS == preps, "re-prepared weights mid-run"
    assert set(eng.stats.decode_steps_by_tier) == {"4/4", "2/2"}
    # Mixed-tier admission: both tiers decode in the SAME batch (no
    # tier-serialized switching — that is the mixed_tiers=False baseline).
    assert eng.stats.mixed_tier_chunks >= 1
    assert eng.stats.tier_switches == 0

    for tier, (w, a) in (("4/4", (4, 4)), ("2/2", (2, 2))):
        sub = [r for r in reqs if r.tier == tier]
        # (a) fixed-tier baseline over the SAME superplane store
        base = BatchServeEngine(model, eng.params, rt, max_batch=1,
                                max_len=64, tier=tier)
        want = base.run([Request(uid=r.uid, prompt=r.prompt,
                                 max_new_tokens=r.max_new_tokens, tier=tier)
                         for r in sub])
        for r in sub:
            assert got[r.uid] == want[r.uid], (tier, r.uid)
        # (b) natively prepared at the tier precision (no schedule at all)
        native = ServeEngine(
            model, params,
            Runtime(policy=uniform_policy(w, a, backend="decomposed"),
                    mode="serve", moe_dropless=True),
            max_batch=2, max_len=64, decode_chunk=3)
        want_n = native.run([Request(uid=r.uid, prompt=r.prompt,
                                     max_new_tokens=r.max_new_tokens)
                             for r in sub])
        for r in sub:
            assert got[r.uid] == want_n[r.uid], ("native", tier, r.uid)


def test_engine_superplane_store_is_single_8bit_artifact(setup):
    cfg, model, params, sched, rt = setup
    eng = ServeEngine(model, params, rt, max_batch=2, max_len=32)
    qws = [l for l in jax.tree.leaves(
        eng.params, is_leaf=lambda x: isinstance(x, ops.QuantizedWeight))
        if isinstance(l, ops.QuantizedWeight)]
    assert qws and all(q.w_bits == 8 and q.msb_first for q in qws)


def test_engine_default_tier_and_validation(setup):
    cfg, model, params, sched, rt = setup
    eng = ServeEngine(model, params, rt, max_batch=2, max_len=32)
    mine = Request(uid=0, prompt=np.array([1, 2]), max_new_tokens=2)
    eng.submit(mine)
    assert eng.scheduler.waiting[0].tier == "8/8"   # normalized to default
    assert mine.tier is None                        # caller's object untouched
    with pytest.raises(ValueError, match="unknown tier"):
        eng.submit(Request(uid=1, prompt=np.array([1]), max_new_tokens=1,
                           tier="3/3"))
    # Untiered engine rejects tiered requests.
    plain = ServeEngine(
        model, params,
        Runtime(policy=uniform_policy(8, 8, backend="dense"), mode="serve",
                moe_dropless=True),
        max_batch=2, max_len=32)
    with pytest.raises(ValueError, match="without a PrecisionSchedule"):
        plain.submit(Request(uid=0, prompt=np.array([1]), max_new_tokens=1,
                             tier="8/8"))
    with pytest.raises(ValueError, match="unknown tier"):
        BatchServeEngine(model, params, rt, max_batch=2, max_len=32,
                         tier="9/9")
