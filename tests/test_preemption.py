"""Overload survival: slot preemption, spill/restore, shedding, cancel.

The contract under test, end to end:

* **Token identity** — a preempted-and-resumed request's final token
  stream is bit-identical to its uninterrupted run, across mixed weight
  tiers x KV-cache tiers (bf16 / int8 / int4-packed), through
  resume-into-a-DIFFERENT-slot, after a mid-stream ``set_tier``
  migration, and on a 2-device tensor-parallel mesh (subprocess with fake
  devices).
* **Spill/restore** — ``spill_dir`` routes snapshots through the
  checkpoint subsystem (atomic async step dirs) and back, byte-clean:
  same tokens, step dirs deleted as requests resume, stale ``.tmp`` dirs
  ignored.
* **State hygiene** — cancelling a QUEUED request leaks no scheduler
  state (the submitted-clock regression), SUSPENDED/SHED guard rails on
  ``set_tier`` / ``preempt`` / ``cancel`` hold, and the policy-level
  displacement/shedding rules are deterministic host arithmetic.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.policy import uniform_schedule
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.serve import (Request, RequestStatus, Scheduler, ServeEngine,
                         SLOPolicy, SuspendedState)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

TIERS = {"8/8": (8, 8), "4/4": (4, 4), "2/2": (2, 2)}
KV_TIERS = {"8/8": None, "4/4": 8, "2/2": 4}   # bf16 / int8 / int4-packed


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = uniform_schedule(TIERS, kv_tiers=KV_TIERS)
    rt = Runtime(policy=sched.policy_for(), mode="serve", moe_dropless=True,
                 schedule=sched)
    return cfg, model, params, sched, rt


def _requests(cfg, n, *, seed=0, max_new=8, tiers=("8/8", "4/4", "2/2")):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=3 + i % 4),
                    max_new_tokens=max_new, tier=tiers[i % len(tiers)])
            for i in range(n)]


def _clone(reqs):
    return [Request(uid=r.uid, prompt=r.prompt,
                    max_new_tokens=r.max_new_tokens, tier=r.tier,
                    deadline=r.deadline, tenant=r.tenant) for r in reqs]


@pytest.fixture(scope="module")
def reference(setup):
    """Uninterrupted tokens for the shared request set (bit-stability:
    batch composition and admission order never change a request's
    stream, so ONE reference run covers every preemption schedule)."""
    cfg, model, params, sched, rt = setup
    reqs = _requests(cfg, 3)
    eng = ServeEngine(model, params, rt, max_batch=2, max_len=64,
                      decode_chunk=2)
    return reqs, eng.run(_clone(reqs))


# --------------------------------------------------------- token identity
def test_preempt_resume_token_identity_mixed_tiers(setup, reference):
    """Preempt every request once, mid-stream, across all three weight x
    KV tiers; the drained streams must equal the uninterrupted run's."""
    cfg, model, params, sched, rt = setup
    reqs, want = reference
    eng = ServeEngine(model, params, rt, max_batch=2, max_len=64,
                      decode_chunk=2)
    handles = {r.uid: eng.submit(r) for r in _clone(reqs)}
    preempted = set()
    for _ in range(64):
        if not eng.has_work:
            break
        eng.step()
        for uid, h in handles.items():
            if (uid not in preempted and h.status is RequestStatus.RUNNING
                    and len(h.tokens) >= 2):
                sus = eng.preempt(uid)
                assert isinstance(sus, SuspendedState)
                assert h.status is RequestStatus.SUSPENDED
                assert sus.tokens == h.tokens and sus.cache is not None
                preempted.add(uid)
                break
    finished = eng.drain()
    assert preempted == set(want)         # every request was suspended once
    assert finished == want
    assert eng.stats.preemptions == 3 and eng.stats.resumes == 3
    assert eng.stats.spill_bytes == 0     # no spill_dir: host-resident
    assert eng.suspended == {}


def test_resume_into_different_slot(setup, reference):
    """Preempt BOTH running requests and re-admit in swapped order: each
    resumes in the OTHER slot, token streams unchanged."""
    cfg, model, params, sched, rt = setup
    reqs, want = reference
    eng = ServeEngine(model, params, rt, max_batch=2, max_len=64,
                      decode_chunk=2)
    a, b = _clone(reqs[:2])
    ha, hb = eng.submit(a), eng.submit(b)
    eng.step()
    slot_a, slot_b = ha.slot, hb.slot
    assert {slot_a, slot_b} == {0, 1}
    eng.preempt(b.uid)      # FIFO re-queues b BEFORE a: admission swaps
    eng.preempt(a.uid)
    eng.step()
    assert ha.slot == slot_b and hb.slot == slot_a
    finished = eng.drain()
    assert finished == {r.uid: want[r.uid] for r in (a, b)}


def test_preempt_after_kv_migration(setup):
    """set_tier (KV lane requantized in place) THEN preempt: the snapshot
    carries the migrated KV precision, and the resumed stream matches an
    uninterrupted run migrated at the same point."""
    cfg, model, params, sched, rt = setup
    req = _requests(cfg, 1, seed=7, tiers=("8/8",))[0]   # bf16 KV start

    def serve(preempt_after_migration):
        eng = ServeEngine(model, params, rt, max_batch=2, max_len=64,
                          decode_chunk=2)
        h = eng.submit(_clone([req])[0])
        while len(h.tokens) < 2:
            eng.step()
        h.set_tier("2/2")            # bf16 -> int4-packed KV, live
        if preempt_after_migration:
            eng.preempt(req.uid)
        eng.drain()
        assert eng.stats.kv_migrations == 1
        return h.tokens

    assert serve(True) == serve(False)


def test_mesh_preempt_resume_token_identity():
    """2-device TP mesh: sharded snapshot/restore round-trips through
    preemption token-identically (subprocess: fake devices need XLA_FLAGS
    before jax import)."""
    body = """
        import jax, numpy as np
        from repro.configs import reduced_config
        from repro.core.policy import uniform_schedule
        from repro.launch.mesh import make_serve_mesh
        from repro.models.layers import Runtime
        from repro.models.transformer import LM
        from repro.serve import Request, ServeEngine

        cfg = reduced_config("granite-3-8b")
        model = LM(cfg)
        params = model.init(jax.random.PRNGKey(0))
        sched = uniform_schedule({"8/8": (8, 8), "2/2": (2, 2)},
                                 kv_tiers={"8/8": None, "2/2": 4})
        rt = Runtime(policy=sched.policy_for(), mode="serve",
                     moe_dropless=True, schedule=sched)
        rng = np.random.default_rng(3)
        prompts = [rng.integers(0, cfg.vocab_size, size=4) for _ in range(2)]

        def reqs():
            return [Request(uid=i, prompt=prompts[i], max_new_tokens=6,
                            tier=t)
                    for i, t in enumerate(("8/8", "2/2"))]

        def serve(mesh, preempt):
            eng = ServeEngine(model, params, rt, max_batch=2, max_len=32,
                              decode_chunk=2, mesh=mesh)
            handles = [eng.submit(r) for r in reqs()]
            eng.step()
            if preempt:
                eng.preempt(0)
                eng.preempt(1)
            out = eng.drain()
            assert not preempt or eng.stats.resumes == 2
            return out

        mesh = make_serve_mesh(2)
        want = serve(None, False)
        assert serve(mesh, False) == want     # sharded == unsharded
        assert serve(mesh, True) == want      # + preempt/resume on mesh
        print("MESH_PREEMPT_OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "MESH_PREEMPT_OK" in r.stdout


# ----------------------------------------------------------- spill/restore
def test_spill_restore_roundtrip(setup, reference, tmp_path):
    """spill_dir: snapshots go to atomic step dirs and come back
    byte-clean; resumed spills are deleted; stale .tmp dirs are inert."""
    cfg, model, params, sched, rt = setup
    reqs, want = reference
    spill = tmp_path / "spill"
    os.makedirs(spill / "step_00000099.tmp")     # crash debris: ignored
    eng = ServeEngine(model, params, rt, max_batch=2, max_len=64,
                      decode_chunk=2, spill_dir=str(spill))
    handles = {r.uid: eng.submit(r) for r in _clone(reqs[:2])}
    eng.step()
    sus = eng.preempt(0)
    assert sus.cache is None and sus.spill_step is not None
    assert sus.nbytes > 0 and eng.stats.spill_bytes == sus.nbytes
    eng._spiller.wait()
    assert (spill / "step_00000000" / "manifest.json").exists()
    finished = eng.drain()
    assert finished == {r.uid: want[r.uid] for r in reqs[:2]}
    assert not (spill / "step_00000000").exists()   # unspilled + removed
    assert (spill / "step_00000099.tmp").exists()   # untouched debris


def test_cancel_suspended_removes_spill(setup, tmp_path):
    cfg, model, params, sched, rt = setup
    eng = ServeEngine(model, params, rt, max_batch=2, max_len=64,
                      decode_chunk=2, spill_dir=str(tmp_path))
    h = eng.submit(_requests(cfg, 1, seed=5)[0])
    eng.step()
    eng.preempt(0)
    eng._spiller.wait()
    assert (tmp_path / "step_00000000").exists()
    eng.cancel(0)
    assert h.status is RequestStatus.SHED
    assert not (tmp_path / "step_00000000").exists()
    assert not eng.has_work and eng.suspended == {}
    assert eng.retire(0) == h.tokens


# ------------------------------------------------- state hygiene / guards
def test_cancel_queued_drops_submitted_clock():
    """The QUEUED-cancellation leak, regression-tested at the scheduler
    level: cancel must drop the waiting entry AND its submitted-clock
    entry (policies age requests off that map)."""
    s = Scheduler(1)
    s.submit(Request(uid=7, prompt=np.zeros(2, np.int32)), now=3.0)
    assert 7 in s.submitted_at
    assert s.cancel(7) is True
    assert s.waiting == type(s.waiting)() and s.submitted_at == {}
    assert s.cancel(7) is False            # idempotent on unknown uids


def test_engine_cancel_queued_no_leak(setup, reference):
    cfg, model, params, sched, rt = setup
    reqs, want = reference
    eng = ServeEngine(model, params, rt, max_batch=2, max_len=64,
                      decode_chunk=2)
    handles = [eng.submit(r) for r in _clone(reqs)]
    eng.step()                              # 0,1 running; 2 queued
    assert handles[2].status is RequestStatus.QUEUED
    eng.cancel(2)
    assert handles[2].status is RequestStatus.SHED
    assert 2 not in eng.scheduler.submitted_at
    assert eng.stats.sheds == 1
    finished = eng.drain()
    assert finished == {r.uid: want[r.uid] for r in reqs[:2]}
    assert eng.retire(2) == []              # partial stream: nothing yet
    eng.submit(_clone(reqs[2:])[0])         # retired uid is reusable
    assert eng.drain()[2] == want[2]


def test_preempt_and_cancel_guard_rails(setup):
    cfg, model, params, sched, rt = setup
    eng = ServeEngine(model, params, rt, max_batch=1, max_len=64,
                      decode_chunk=2)
    r0, r1 = _requests(cfg, 2, seed=9)
    h0, h1 = eng.submit(r0), eng.submit(r1)
    # preempt() from inside a round (on_token callback) must raise —
    # registered BEFORE any event so no out-of-round replay fires it.
    errs = []

    def cb(ev):
        try:
            eng.preempt(ev.uid)
        except RuntimeError as e:
            errs.append(e)

    h0.on_token(cb)
    with pytest.raises(KeyError):
        eng.preempt(99)
    with pytest.raises(RuntimeError, match="only RUNNING"):
        eng.preempt(r0.uid)                  # still QUEUED
    eng.step()
    assert errs and "scheduling round" in str(errs[0])
    with pytest.raises(RuntimeError, match="preempt it first"):
        eng.cancel(r0.uid)                   # RUNNING
    eng.preempt(r0.uid)                      # between rounds: fine
    with pytest.raises(RuntimeError, match="suspended"):
        h0.set_tier("2/2")                   # snapshot pinned at its tier
    with pytest.raises(RuntimeError, match="only RUNNING"):
        eng.preempt(r0.uid)                  # already SUSPENDED
    eng.drain()
    with pytest.raises(RuntimeError):
        eng.cancel(r0.uid)                   # already FINISHED
    assert h0.done and h1.done


# ----------------------------------------------- policy rules (host only)
def _entry(slot, uid, *, deadline=None, tier=None, tenant=None, rem=8,
           tick=0.0, max_new=8):
    r = Request(uid=uid, prompt=np.zeros(2, np.int32),
                max_new_tokens=max_new, tier=tier, deadline=deadline,
                tenant=tenant)
    return (slot, r, rem, tick)


def test_preempt_victim_rule():
    pol = SLOPolicy(tier_costs={"hi": 4.0, "lo": 1.0}, preempt=True)
    urgent = Request(uid=1, prompt=np.zeros(2, np.int32), max_new_tokens=4,
                     tier="lo", deadline=2.0)
    sub = {1: 0.0}
    running = [_entry(0, 10, rem=20),                    # best-effort
               _entry(1, 11, deadline=100.0, rem=4, tier="lo")]
    # Urgent slack = 0+2 - 0 - 4 = -2 <= 0: displace the best-effort slot.
    assert pol.preempt_victim([urgent], running, sub, now=0.0) == 10
    # A slot freeing in time (rem <= slack floor) suppresses preemption.
    soon = [_entry(0, 10, rem=0)]
    assert pol.preempt_victim([urgent], soon, sub, now=0.0) is None
    # No strictly-slacker victim: equal urgency never thrashes.
    tight = [_entry(0, 10, deadline=2.0, rem=4, tier="lo", tick=0.0)]
    assert pol.preempt_victim([urgent], tight, sub, now=0.0) is None
    # Patient waiting request: nobody displaced.
    patient = Request(uid=2, prompt=np.zeros(2, np.int32),
                      max_new_tokens=1, tier="lo", deadline=500.0)
    assert pol.preempt_victim([patient], running, {2: 0.0}, now=0.0) is None
    # Disabled policy never names a victim.
    off = SLOPolicy(tier_costs={"lo": 1.0})
    assert off.preempt_victim([urgent], running, sub, now=0.0) is None


def test_admission_decision_shed_and_downtier():
    pol = SLOPolicy(tier_costs={"hi": 4.0, "lo": 1.0}, shed=True)
    mk = lambda **kw: Request(uid=0, prompt=np.zeros(2, np.int32), **kw)
    # Best-effort: always admitted.
    assert pol.admission_decision(mk(max_new_tokens=99), [], [], 2, {},
                                  0.0) == "admit"
    # Feasible at own tier on an idle engine.
    assert pol.admission_decision(
        mk(max_new_tokens=4, tier="lo", deadline=10.0),
        [], [], 2, {}, 0.0) == "admit"
    # Infeasible at any tier: shed.
    assert pol.admission_decision(
        mk(max_new_tokens=4, tier="lo", deadline=2.0),
        [], [], 2, {}, 0.0) == "shed"
    # auto_tier: downtier to the highest-cost tier that still fits.
    auto = SLOPolicy(tier_costs={"hi": 4.0, "lo": 1.0}, shed=True,
                     auto_tier=True)
    assert auto.admission_decision(
        mk(max_new_tokens=4, tier="hi", deadline=8.0),
        [], [], 2, {}, 0.0) == "lo"
    # Outranking queued work pushes the projection past the deadline.
    rival = mk(max_new_tokens=40, tier="lo", deadline=1.0)
    rival = Request(uid=5, prompt=rival.prompt, max_new_tokens=40,
                    tier="lo", deadline=1.0)
    assert pol.admission_decision(
        mk(max_new_tokens=4, tier="lo", deadline=10.0),
        [rival], [], 1, {5: 0.0}, 0.0) == "shed"
    # Non-displaceable running work counts too (preempt off).
    busy = [_entry(0, 9, rem=40, tier="lo")]
    assert pol.admission_decision(
        mk(max_new_tokens=4, tier="lo", deadline=10.0),
        [], busy, 1, {}, 0.0) == "shed"
    # With preempt on, best-effort running work is displaceable: admit.
    both = SLOPolicy(tier_costs={"hi": 4.0, "lo": 1.0}, shed=True,
                     preempt=True)
    assert both.admission_decision(
        mk(max_new_tokens=4, tier="lo", deadline=10.0),
        [], busy, 1, {}, 0.0) == "admit"


def test_tenant_weighted_slack_and_validation():
    pol = SLOPolicy(tier_costs={"lo": 1.0},
                    tenant_weights={"gold": 3.0})
    mk = lambda uid, tenant: Request(
        uid=uid, prompt=np.zeros(2, np.int32), max_new_tokens=4,
        tier="lo", deadline=100.0, tenant=tenant)
    sub = {1: 0.0, 2: 0.0}
    a, b = mk(1, None), mk(2, "gold")
    # Equal raw slack, but gold's age counts 3x: it wins at now=10.
    assert pol.weighted_slack(a, sub, 10.0) > pol.weighted_slack(b, sub, 10.0)
    assert pol.select([a, b], sub, 10.0) == 1
    # Weight 1.0 tenants collapse to the unweighted ordering.
    flat = SLOPolicy(tier_costs={"lo": 1.0})
    assert flat.weighted_slack(b, sub, 10.0) == flat.slack(b, sub, 10.0)
    assert flat.select([a, b], sub, 10.0) == 0   # pure FIFO tie-break
    with pytest.raises(ValueError, match="weight"):
        SLOPolicy(tier_costs={"lo": 1.0}, tenant_weights={"x": 0.5})
