"""Quantization + QAT fake-quant (STE)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import quant


@given(bits=st.integers(2, 8), signed=st.booleans(),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_roundtrip_error_bound(bits, signed, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(16, 8)).astype(np.float32)
    if not signed:
        x = np.abs(x)
    cfg = quant.QuantConfig(bits=bits, signed=signed, per_channel=False)
    q, s = quant.quantize(jnp.asarray(x), cfg)
    back = np.asarray(quant.dequantize(q, s))
    # Max error bounded by half an LSB of the symmetric quantizer.
    assert np.abs(back - x).max() <= float(s) * 0.5 + 1e-6


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("signed", [True, False])
def test_roundtrip_error_bound_deterministic(bits, signed):
    """Non-hypothesis fallback: seeded instance of the half-LSB bound."""
    rng = np.random.default_rng(bits + 10 * signed)
    x = rng.normal(size=(16, 8)).astype(np.float32)
    if not signed:
        x = np.abs(x)
    cfg = quant.QuantConfig(bits=bits, signed=signed, per_channel=False)
    q, s = quant.quantize(jnp.asarray(x), cfg)
    back = np.asarray(quant.dequantize(q, s))
    assert np.abs(back - x).max() <= float(s) * 0.5 + 1e-6


def test_per_channel_scales():
    x = np.stack([np.ones(8), 100 * np.ones(8)], axis=1).astype(np.float32)
    cfg = quant.QuantConfig(bits=8, per_channel=True, channel_axis=-1)
    q, s = quant.quantize(jnp.asarray(x), cfg)
    assert s.shape == (1, 2)
    assert float(s[0, 1]) == pytest.approx(100 * float(s[0, 0]), rel=1e-5)


def test_ste_gradient_passes_in_range():
    cfg = quant.QuantConfig(bits=4, per_channel=False)
    # strictly inside [qmin*scale, qmax*scale] = [-0.8, 0.7]
    x = jnp.linspace(-0.6, 0.6, 16)

    def f(x):
        return jnp.sum(quant.fake_quant(x, cfg, scale=jnp.float32(0.1)))

    g = jax.grad(f)(x)
    assert np.allclose(np.asarray(g), 1.0)  # straight-through inside range


def test_ste_gradient_clips_out_of_range():
    cfg = quant.QuantConfig(bits=4, per_channel=False)

    def f(x):
        return jnp.sum(quant.fake_quant(x, cfg, scale=jnp.float32(0.1)))

    g = jax.grad(f)(jnp.asarray([100.0, -100.0]))
    assert np.allclose(np.asarray(g), 0.0)  # clipped region: zero grad


def test_int_matmul_dequant_close_to_float():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(4, 32)).astype(np.float32)
    w = rng.normal(size=(32, 8)).astype(np.float32)
    xq, xs = quant.quantize(jnp.asarray(x), quant.QuantConfig(per_channel=False))
    wq, ws = quant.quantize(jnp.asarray(w), quant.QuantConfig())
    y = np.asarray(quant.int_matmul_dequant(xq, wq, xs, ws))
    rel = np.abs(y - x @ w).max() / np.abs(x @ w).max()
    assert rel < 0.02
