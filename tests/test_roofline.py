"""Roofline term derivation + report formatting."""
import pytest

from repro.launch import roofline


def _cell(flops=1e12, byts=1e12, coll=1e10, f32_coll=0.0, chips=256,
          model_flops=1e15):
    return {
        "skipped": False, "arch": "x", "shape": "train_4k", "mesh": "16x16",
        "backend": "fake_quant", "n_devices": chips,
        "flops": flops, "bytes_accessed": byts,
        "collectives": {"total_bytes": coll, "f32_bytes": f32_coll},
        "model_flops": model_flops,
    }


def test_terms_and_dominance():
    t = roofline.roofline_terms(_cell(flops=197e12, byts=819e9, coll=50e9))
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(1.0)
    t2 = roofline.roofline_terms(_cell(byts=8190e9))
    assert t2["dominant"] == "memory"


def test_tpu_adjusted_collective():
    t = roofline.roofline_terms(_cell(coll=100e9, f32_coll=100e9))
    # all-f32 collectives: TPU-native (bf16) moves half
    assert t["collective_tpu_adj_s"] == pytest.approx(
        t["collective_s"] / 2)


def test_useful_ratio_and_fraction():
    c = _cell(flops=2e12, chips=100, model_flops=1e14)
    t = roofline.roofline_terms(c)
    assert t["useful_ratio"] == pytest.approx(1e14 / 2e14)
    assert 0 < t["roofline_fraction"] <= 1.0


def test_int8_peak_halves_compute_term():
    c = _cell()
    a = roofline.roofline_terms(c, int8_peak=False)
    b = roofline.roofline_terms(c, int8_peak=True)
    assert b["compute_s"] == pytest.approx(a["compute_s"] / 2)


def test_skipped_cells_render():
    cells = [{"skipped": True, "arch": "a", "shape": "long_500k",
              "mesh": "16x16", "reason": "pure full-attention"},
             _cell()]
    table = roofline.format_table(cells)
    assert "SKIP" in table and "**memory**" in table or "**" in table
