"""Sampling subsystem invariants (repro.spec.sampling).

The contract under test: seeded temperature/top-k selection is
bit-identical between eager and jit, independent of batch composition
and slot assignment, deterministic across mesh widths, and EXACTLY the
old argmax for temperature == 0 rows.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.spec import sampling
from test_sharded_serving import run_subprocess


def _logits(rng, b, v=97):
    return jnp.asarray(rng.standard_normal((b, v)), jnp.float32)


def _state(seeds, draws, temps, topks):
    keys = jnp.asarray(np.stack([sampling.request_key(s) for s in seeds]))
    return (keys, jnp.asarray(draws, jnp.int32),
            jnp.asarray(temps, jnp.float32), jnp.asarray(topks, jnp.int32))


def test_greedy_rows_equal_argmax_exactly():
    rng = np.random.default_rng(0)
    logits = _logits(rng, 4)
    keys, draws, temp, topk = _state([1, 2, 3, 4], [0, 5, 0, 9],
                                     [0.0, 0.0, 0.0, 0.0], [0, 0, 7, 0])
    toks, new_draws = sampling.sample_tokens(logits, keys, draws, temp, topk)
    assert np.array_equal(np.asarray(toks),
                          np.asarray(jnp.argmax(logits, axis=-1)))
    # greedy rows never burn randomness
    assert np.array_equal(np.asarray(new_draws), np.asarray(draws))


def test_eager_jit_bit_identical():
    rng = np.random.default_rng(1)
    logits = _logits(rng, 5)
    state = _state([10, 11, 12, 13, 14], [0, 1, 2, 3, 4],
                   [0.0, 0.7, 1.0, 1.3, 2.0], [0, 0, 8, 3, 1])
    eager = sampling.sample_tokens(logits, *state)
    jitted = jax.jit(sampling.sample_tokens)(logits, *state)
    for a, b in zip(eager, jitted):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    pe = sampling.sampling_probs(logits, state[2], state[3])
    pj = jax.jit(sampling.sampling_probs)(logits, state[2], state[3])
    assert np.array_equal(np.asarray(pe), np.asarray(pj))


def test_stream_independent_of_batch_composition():
    """A request's draws depend only on (seed, counter): the same row
    sampled alone, in a different slot, or beside different neighbours
    yields the identical token."""
    rng = np.random.default_rng(2)
    row = _logits(rng, 1)
    solo = sampling.sample_tokens(
        row, *_state([42], [3], [0.9], [11]))[0][0]
    big = jnp.concatenate([_logits(rng, 2), row, _logits(rng, 1)])
    batched = sampling.sample_tokens(
        big, *_state([7, 8, 42, 9], [0, 1, 3, 2],
                     [1.0, 0.5, 0.9, 1.5], [4, 0, 11, 2]))[0][2]
    assert int(solo) == int(batched)


def test_top_k_containment():
    rng = np.random.default_rng(3)
    logits = _logits(rng, 64)
    k = 5
    keys, draws, temp, topk = _state(range(64), [0] * 64, [1.0] * 64,
                                     [k] * 64)
    toks, _ = sampling.sample_tokens(logits, keys, draws, temp, topk)
    top = np.argsort(-np.asarray(logits), axis=-1)[:, :k]
    for b in range(64):
        assert int(toks[b]) in top[b]


def test_sampling_probs_greedy_point_mass():
    rng = np.random.default_rng(4)
    logits = _logits(rng, 3)
    p = sampling.sampling_probs(logits, jnp.zeros((3,), jnp.float32),
                                jnp.zeros((3,), jnp.int32))
    am = np.asarray(jnp.argmax(logits, axis=-1))
    expect = np.zeros(p.shape, np.float32)
    expect[np.arange(3), am] = 1.0
    assert np.array_equal(np.asarray(p), expect)


def test_inactive_rows_hold_token_and_counter():
    rng = np.random.default_rng(5)
    logits = _logits(rng, 2)
    state = _state([1, 2], [4, 4], [1.0, 1.0], [0, 0])
    active = jnp.array([True, False])
    toks, draws = sampling.sample_tokens(logits, *state, active=active)
    assert int(draws[0]) == 5 and int(draws[1]) == 4
    ref, _ = sampling.sample_tokens(logits[:1], state[0][:1], state[1][:1],
                                    state[2][:1], state[3][:1])
    assert int(toks[0]) == int(ref[0])


def test_sampled_serving_identical_across_mesh_widths():
    """The full engine stream (temperature sampling inside the jitted
    decode chunk) is bit-identical on 1 and 2 mesh shards."""
    body = """
import jax
import numpy as np
from repro.configs import reduced_config
from repro.core.policy import uniform_schedule
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.serve import Request, SamplingParams, ServeEngine

cfg = reduced_config("granite-3-8b")
model = LM(cfg)
params = model.init(jax.random.PRNGKey(0))
sched = uniform_schedule({"8/8": (8, 8), "4/4": (4, 4)},
                         kv_tiers={"8/8": 8, "4/4": 8})
rt = Runtime(policy=sched.policy_for(), mode="serve", schedule=sched)
rng = np.random.default_rng(0)
prompts = [list(rng.integers(0, cfg.vocab_size, size=5)) for _ in range(2)]

def serve(mesh):
    eng = ServeEngine(model, params, rt, max_batch=2, max_len=32,
                      decode_chunk=2, mesh=mesh)
    return eng.run([
        Request(uid=i, prompt=p, max_new_tokens=5,
                tier=["8/8", "4/4"][i],
                sampling=SamplingParams(temperature=0.8, top_k=12,
                                        seed=100 + i))
        for i, p in enumerate(prompts)])

unsharded = serve(None)
mesh = jax.make_mesh((jax.device_count(),), ("model",))
sharded = serve(mesh)
assert unsharded == sharded, (unsharded, sharded)
print("MESH_SAMPLING_OK")
"""
    out = run_subprocess(body, devices=2)
    assert "MESH_SAMPLING_OK" in out
