"""Serving engine: prepared quantized weights + batched greedy decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.policy import uniform_policy
from repro.kernels.ops import QuantizedWeight
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.serve.engine import Request, ServeEngine, prepare_params


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_prepare_params_quantizes_projections(setup):
    cfg, model, params = setup
    policy = uniform_policy(4, 8, backend="decomposed")
    prepared, paths = prepare_params(params, policy, model)
    assert any("q_proj" in p for p in paths)
    assert not any("embed" in p for p in paths)
    leaves = jax.tree.leaves(
        prepared, is_leaf=lambda x: isinstance(x, QuantizedWeight))
    qws = [l for l in leaves if isinstance(l, QuantizedWeight)]
    assert qws and all(q.planes.dtype == jnp.int8 for q in qws)
    assert all(q.w_bits == 4 for q in qws)


def test_quantized_serving_close_to_dense(setup):
    cfg, model, params = setup
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 10), 0,
                              cfg.vocab_size)
    rt_dense = Runtime(policy=uniform_policy(8, 8, backend="dense"),
                       mode="serve", moe_dropless=True)
    dense, _ = model.forward(params, rt_dense, tokens=toks)

    policy = uniform_policy(8, 8, backend="decomposed")
    prepared, _ = prepare_params(params, policy, model)
    rt_q = Runtime(policy=policy, mode="serve", moe_dropless=True)
    quant, _ = model.forward(prepared, rt_q, tokens=toks)
    d = np.asarray(dense, np.float32)
    q = np.asarray(quant, np.float32)
    assert np.abs(d - q).max() / np.abs(d).max() < 0.1
    # top-1 agreement on most positions (untrained weights -> near-uniform
    # logits, so even tiny perturbations flip some argmaxes)
    agree = (d.argmax(-1) == q.argmax(-1)).mean()
    assert agree > 0.7


def test_engine_greedy_decode(setup):
    cfg, model, params = setup
    policy = uniform_policy(6, 8, backend="decomposed")
    prepared, _ = prepare_params(params, policy, model)
    rt = Runtime(policy=policy, mode="serve", moe_dropless=True)
    eng = ServeEngine(model, prepared, rt, max_batch=3, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, size=5),
                    max_new_tokens=4 + i) for i in range(5)]
    results = eng.run(reqs)
    assert set(results) == {0, 1, 2, 3, 4}
    for i, r in enumerate(reqs):
        assert len(results[r.uid]) == r.max_new_tokens
        assert all(0 <= t < cfg.padded_vocab for t in results[r.uid])


def test_engine_batches_match_single(setup):
    """Batched engine output == one-request-at-a-time output."""
    cfg, model, params = setup
    rt = Runtime(policy=uniform_policy(8, 8, backend="dense"), mode="serve",
                 moe_dropless=True)
    eng_b = ServeEngine(model, params, rt, max_batch=4, max_len=64)
    eng_s = ServeEngine(model, params, rt, max_batch=1, max_len=64)
    rng = np.random.default_rng(1)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, size=6),
                    max_new_tokens=5) for i in range(3)]
    # same-length prompts => identical left-padding in both engines
    got_b = eng_b.run(reqs)
    got_s = eng_s.run(reqs)
    assert got_b == got_s
