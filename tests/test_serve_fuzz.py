"""Property-based serving-invariant fuzz harness.

Random interleavings of the full engine op surface — ``submit`` (tiered /
deadlined / tenant-tagged / speculative), ``step``, QUEUED ``set_tier``,
``preempt``, ``cancel``, ``retire`` — run against ONE shared warm engine
(compiles are the whole cost; every interleaving reuses the traced
steps), with an SLOPolicy that has every overload feature enabled
(preemption, shedding, tenant weights, time-slice fairness).  Greedy
speculative requests reuse the plain references directly — the
draft/verify/rollback round is token-identical to verify-tier decoding
by construction, so speculation composes with every other op under test
at zero extra reference cost.  After EVERY op the structural invariants below are
checked, and at the end of each interleaving the engine is drained,
streams are compared against precomputed unpressured references, and the
engine must return to a completely empty state (the leak check).

Invariants (``check_invariants``):

* slot <-> handle consistency: every occupied slot's uid maps to a
  RUNNING handle pointing back at that slot; free slots carry no tier
  tag; no uid appears in two of {running, waiting, suspended}.
* accounting: ``decode_slot_steps + decode_idle_slot_steps ==
  decode_steps * max_batch`` — masked-lane bookkeeping never drifts.
* stream integrity: ``handle.tokens`` is exactly the event token
  sequence, event indices are contiguous from 0, and only the last event
  of a FINISHED handle is ``final``.
* suspension bookkeeping: ``engine.suspended`` uids are exactly the
  SUSPENDED handles, each also waiting in the queue, and the policy's
  ``remaining_tokens`` never names a non-suspended uid.

Token identity uses the PR-3 bit-stability contract: a request's greedy
stream depends only on (prompt, tier), never on batch composition or
admission order — so ONE reference run per (profile, tier) pair covers
every interleaving.  RUNNING ``set_tier`` migrations are exercised by
``tests/test_streaming_api.py`` and deliberately excluded here (a
migrated stream is a hybrid of two tiers and has no precomputable
reference).

``SERVE_FUZZ_EXAMPLES`` (default 200 — the CI floor) sets the seeded
interleaving count; hypothesis, when installed, drives extra randomized
seeds through the same harness.
"""
import dataclasses
import os

import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import reduced_config
from repro.core.policy import uniform_schedule
from repro.models.layers import Runtime
from repro.models.transformer import LM
from repro.serve import (Request, RequestStatus, ServeEngine, SLOPolicy,
                         SpecConfig)
from repro.telemetry import Telemetry

N_EXAMPLES = int(os.environ.get("SERVE_FUZZ_EXAMPLES", "200"))

TIERS = {"8/8": (8, 8), "4/4": (4, 4), "2/2": (2, 2)}
KV_TIERS = {"8/8": None, "4/4": 8, "2/2": 4}
MAX_BATCH = 3

# (prompt length, max_new_tokens, deadline, tenant) request profiles; the
# fuzzer draws (profile, tier) pairs.  Deadlines are generous enough that
# sheds happen only under real queue pressure, which keeps them rare but
# nonzero across the run.
PROFILES = [
    (3, 4, None, None),
    (5, 6, None, "gold"),
    (4, 8, None, None),
    (6, 3, 200.0, None),
    (4, 5, 120.0, "gold"),
    (7, 7, None, None),
]


@pytest.fixture(scope="module")
def fuzz_engine():
    """ONE warm engine + unpressured reference streams for every
    (profile, tier) pair (computed in a single run — bit-stability makes
    batching them together legal)."""
    cfg = reduced_config("granite-3-8b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = uniform_schedule(TIERS, kv_tiers=KV_TIERS)
    rt = Runtime(policy=sched.policy_for(), mode="serve", moe_dropless=True,
                 schedule=sched)
    pol = SLOPolicy(sched, preempt=True, preempt_slack=4.0, shed=True,
                    tenant_weights={"gold": 2.0}, time_slice=6)
    # The fuzz engine carries live telemetry so every interleaving also
    # fuzzes the hooks, and check_invariants can assert the registry twins
    # never drift from EngineStats.
    eng = ServeEngine(model, params, rt, max_batch=MAX_BATCH, max_len=64,
                      decode_chunk=2, scheduler_policy=pol,
                      telemetry=Telemetry())
    rng = np.random.default_rng(1234)
    prompts = [rng.integers(0, cfg.vocab_size, size=plen)
               for plen, _, _, _ in PROFILES]
    refs = {}
    uid = 0
    batch = []
    for p, (_, max_new, _, _) in enumerate(PROFILES):
        for tier in TIERS:
            batch.append((uid, p, tier,
                          Request(uid=uid, prompt=prompts[p],
                                  max_new_tokens=max_new, tier=tier)))
            uid += 1
    out = eng.run([r for _, _, _, r in batch])
    for u, p, tier, _ in batch:
        refs[(p, tier)] = out[u]
        eng.retire(u)
    assert_empty(eng)
    return eng, prompts, refs, [uid]      # [uid]: shared mutable counter


def assert_empty(eng):
    """The leak check: after drain + retire-all the engine must hold ZERO
    per-request state, host or scheduler side."""
    assert not eng.has_work
    assert eng.handles == {}
    assert eng.suspended == {}
    assert eng._seen_uids == set()
    assert list(eng.scheduler.waiting) == []
    assert eng.scheduler.submitted_at == {}
    assert eng.scheduler.finished == {}
    assert all(s is None for s in eng.scheduler.slots)
    assert all(t is None for t in eng.arena.tiers)
    pol = eng.scheduler.policy
    assert pol.remaining_tokens == {}


def check_invariants(eng):
    st_ = eng.stats
    assert st_.decode_slot_steps + st_.decode_idle_slot_steps \
        == st_.decode_steps * MAX_BATCH
    # Telemetry twin sync: after EVERY engine op the registry counters
    # equal their EngineStats source of truth, per-tier labels included.
    reg = eng.telemetry.registry
    for f in dataclasses.fields(st_):
        v = getattr(st_, f.name)
        if isinstance(v, int):
            assert reg.value("serve_" + f.name) == float(v), f.name
    for tier, n in st_.decode_steps_by_tier.items():
        assert reg.value("serve_decode_steps_by_tier", tier=tier) == float(n)
    for tier, n in st_.tokens_by_tier.items():
        assert reg.value("serve_tokens_by_tier", tier=tier) == float(n)
    running_uids = set()
    for slot, state in eng.scheduler.occupied():
        h = eng.handles[state.uid]
        assert h.status is RequestStatus.RUNNING and h.slot == slot
        assert eng.arena.tiers[slot] == state.request.tier is not None
        running_uids.add(state.uid)
    for slot in eng.scheduler.free_slots():
        assert eng.arena.tiers[slot] is None
    waiting_uids = [r.uid for r in eng.scheduler.waiting]
    assert len(waiting_uids) == len(set(waiting_uids))
    assert running_uids.isdisjoint(waiting_uids)
    suspended_uids = set(eng.suspended)
    assert suspended_uids.isdisjoint(running_uids)
    assert suspended_uids <= set(waiting_uids)   # suspended wait to resume
    assert set(eng.scheduler.policy.remaining_tokens) <= suspended_uids
    for uid, h in eng.handles.items():
        assert h.tokens == [e.token for e in h.events]
        assert [e.index for e in h.events] == list(range(len(h.events)))
        assert all(not e.final for e in h.events[:-1])
        if h.status is RequestStatus.SUSPENDED:
            assert uid in suspended_uids
            assert eng.suspended[uid].tokens == h.tokens
        elif h.status is RequestStatus.FINISHED:
            assert h.events and h.events[-1].final
            assert len(h.tokens) == h.request.max_new_tokens
            assert eng.scheduler.finished.get(uid) == h.tokens
        elif h.status is RequestStatus.RUNNING:
            assert uid in running_uids
        elif h.status is RequestStatus.QUEUED:
            assert uid in waiting_uids and uid not in suspended_uids


def run_interleaving(fuzz, seed, n_ops=24):
    eng, prompts, refs, counter = fuzz
    rng = np.random.default_rng(seed)
    tiers = list(TIERS)
    live = {}                 # uid -> (profile, handle)
    shed, cancelled = set(), set()

    def submit_one():
        uid = counter[0]
        counter[0] += 1
        p = int(rng.integers(len(PROFILES)))
        plen, max_new, deadline, tenant = PROFILES[p]
        # Greedy speculative requests share the plain references: the
        # verify-tier stream is token-identical by construction, so the
        # (profile, tier) reference covers them too.  One fixed
        # (draft_tier, k) keeps the extra jit traces bounded.
        spec = SpecConfig(draft_tier="4/4", k=2) \
            if rng.random() < 0.2 else None
        h = eng.submit(Request(uid=uid, prompt=prompts[p],
                               max_new_tokens=max_new,
                               tier=tiers[int(rng.integers(len(tiers)))],
                               deadline=deadline, tenant=tenant,
                               spec=spec))
        live[uid] = (p, h)
        if h.status is RequestStatus.SHED:
            shed.add(uid)

    def by_status(status):
        return [u for u, (_, h) in live.items() if h.status is status]

    for _ in range(n_ops):
        op = rng.choice(["submit", "step", "step", "preempt", "set_tier",
                         "cancel", "retire"])
        if op == "submit" and len(live) < 12:
            submit_one()
        elif op == "step":
            eng.step()
        elif op == "preempt":
            uids = by_status(RequestStatus.RUNNING)
            if uids:
                eng.preempt(uids[int(rng.integers(len(uids)))])
        elif op == "set_tier":
            uids = by_status(RequestStatus.QUEUED)
            if uids:
                u = uids[int(rng.integers(len(uids)))]
                live[u][1].set_tier(tiers[int(rng.integers(len(tiers)))])
        elif op == "cancel":
            uids = by_status(RequestStatus.QUEUED) \
                + by_status(RequestStatus.SUSPENDED)
            if uids:
                u = uids[int(rng.integers(len(uids)))]
                eng.cancel(u)
                cancelled.add(u)
        elif op == "retire":
            done = [u for u, (_, h) in live.items() if h.done]
            if done:
                u = done[int(rng.integers(len(done)))]
                p, h = live.pop(u)
                assert eng.retire(u) == h.tokens
        check_invariants(eng)

    # Drain whatever is in flight, still checking every round.
    while eng.has_work:
        eng.step()
        check_invariants(eng)

    # Terminal accounting + token identity vs the unpressured references.
    for uid, (p, h) in live.items():
        assert h.done, (uid, h.status)
        if uid in shed or uid in cancelled:
            assert h.status is RequestStatus.SHED
        else:
            assert h.status is RequestStatus.FINISHED
            assert h.tokens == refs[(p, h.tier)], \
                f"uid {uid} (profile {p}, tier {h.tier}) diverged"
        assert eng.retire(uid) == h.tokens
    assert_empty(eng)


# ----------------------------------------------------------- seeded sweep
def test_fuzz_seeded_interleavings(fuzz_engine):
    """The CI floor: >= 200 (SERVE_FUZZ_EXAMPLES) deterministic seeded
    interleavings, every op followed by the full invariant check."""
    eng = fuzz_engine[0]
    spec0 = eng.stats.spec_rounds
    slice0 = eng.stats.time_slice_preemptions
    for seed in range(N_EXAMPLES):
        run_interleaving(fuzz_engine, seed)
    # The op mix must actually have exercised the new machinery: greedy
    # speculative rounds (verified against the plain references inside
    # run_interleaving) and time-slice preemptions both fire.
    assert eng.stats.spec_rounds > spec0
    assert eng.stats.time_slice_preemptions > slice0


def test_fuzz_overload_heavy(fuzz_engine):
    """Pressure profile: bursts of submits far beyond slot capacity, so
    policy-driven preemption and shedding fire constantly."""
    eng, prompts, refs, counter = fuzz_engine
    preempts0, sheds0 = eng.stats.preemptions, eng.stats.sheds
    for seed in range(40):
        rng = np.random.default_rng(10_000 + seed)
        live = {}
        for _ in range(int(rng.integers(6, 10))):   # 2-3x slot capacity
            uid = counter[0]
            counter[0] += 1
            p = int(rng.integers(len(PROFILES)))
            plen, max_new, deadline, tenant = PROFILES[p]
            if rng.random() < 0.3:
                deadline = 30.0   # tight: forces urgency under the burst
            h = eng.submit(Request(
                uid=uid, prompt=prompts[p], max_new_tokens=max_new,
                tier=list(TIERS)[int(rng.integers(3))],
                deadline=deadline, tenant=tenant))
            live[uid] = (p, h)
        while eng.has_work:
            eng.step()
            check_invariants(eng)
        for uid, (p, h) in live.items():
            if h.status is RequestStatus.FINISHED:
                assert h.tokens == refs[(p, h.tier)]
            eng.retire(uid)
        assert_empty(eng)
    # Under sustained 2-3x overload the displacement rule must have fired.
    assert eng.stats.preemptions > preempts0 or eng.stats.sheds > sheds0


# ------------------------------------------------------- hypothesis sweep
@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=25, deadline=None, database=None)
def test_fuzz_hypothesis_interleavings(fuzz_engine, seed):
    """Randomized seeds through the same harness (skips cleanly when
    hypothesis is not installed; the seeded sweep above still runs)."""
    run_interleaving(fuzz_engine, seed)
